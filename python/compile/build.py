"""`make artifacts` entry point: train → export → AOT-lower → calibrate.

Incremental: every weight bundle is skipped if its .bin already exists
(delete artifacts/ to force a full rebuild), HLO is re-lowered only when
missing, and the manifest + acceptance calibration are refreshed at the
end of every run. Python runs ONLY here — never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from . import aot, corpus, export, model, train
from .configs import (
    BLOCK,
    K_MAX,
    LLAMA2T,
    LLAMA3T,
    MIXTRALT,
    PREFILL_CHUNK,
    ModelConfig,
    all_archs,
    flex_draft_config,
    generic_draft_config,
)

# Datasets of the paper's evaluation; nq_rag shares the nq grammar (same
# knowledge, different prompt shape) so it reuses the nq LoRA target.
EVAL_DOMAINS = ["gsm8k", "nq", "mtbench", "wmt14", "cnndm", "humaneval"]

# Per-family training budgets (steps). llama2t is the headline model of
# Tables II–V; the scalability families (Table VI) train a bit shorter.
STEPS = {
    "llama2t": dict(base=380, lora=220, full=300, flex=550, generic=250, synced=260),
    "llama3t": dict(base=300, lora=200, flex=450, generic=0, synced=0),
    "mixtralt": dict(base=260, lora=180, flex=400, generic=0, synced=0),
}


class Builder:
    def __init__(self, out_dir: str, log=print):
        self.out = out_dir
        self.log = log
        self.weights: dict[str, dict] = {}  # manifest "weights" section
        os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    def _path(self, name: str) -> str:
        return os.path.join(self.out, "weights", f"{name}.bin")

    def have(self, name: str) -> bool:
        return os.path.exists(self._path(name))

    def save(self, name: str, tensors, meta: dict) -> None:
        export.write_bundle(self._path(name), {k: np.asarray(v) for k, v in tensors.items()})
        self.register(name, meta)

    def register(self, name: str, meta: dict) -> None:
        self.weights[name] = {**meta, "file": f"weights/{name}.bin"}

    def load(self, name: str):
        import jax.numpy as jnp

        return {k: jnp.asarray(v) for k, v in export.read_bundle(self._path(name)).items()}


def build_family(b: Builder, cfg: ModelConfig, budgets: dict) -> None:
    """Train every bundle for one target family (with caching)."""
    fam = cfg.name
    base_name = f"target_{fam}_base"
    t0 = time.time()

    if not b.have(base_name):
        params = train.train_base(cfg, seed=1, steps=budgets["base"], log=b.log)
        b.save(base_name, params, {"arch": fam, "kind": "base"})
    else:
        b.register(base_name, {"arch": fam, "kind": "base"})
    base = b.load(base_name)

    # Evolving cloud versions: one PEFT update per eval domain.
    domains = EVAL_DOMAINS if fam == "llama2t" else ["mtbench"]
    for dom in domains:
        name = f"lora_{fam}_{dom}"
        if not b.have(name):
            lora = train.train_lora(cfg, base, dom, seed=2, steps=budgets["lora"], log=b.log)
            b.save(name, lora, {"arch": fam, "kind": "lora", "base": base_name, "domain": dom})
        else:
            b.register(name, {"arch": fam, "kind": "lora", "base": base_name, "domain": dom})

    # Table II's "Code (Full)": full-parameter FT breaks the anchor.
    if fam == "llama2t":
        name = f"target_{fam}_code_full"
        if not b.have(name):
            params = train.train_full(cfg, base, "humaneval", seed=3, steps=budgets["full"], log=b.log)
            b.save(name, params, {"arch": fam, "kind": "full", "domain": "humaneval"})
        else:
            b.register(name, {"arch": fam, "kind": "full", "domain": "humaneval"})

    # FlexSpec's static draft: ONE distillation against the base teacher.
    dcfg = flex_draft_config(cfg)
    name = f"draft_flex_{fam}"
    if not b.have(name):
        params, _wp = train.distill_draft(dcfg, cfg, base, seed=4, steps=budgets["flex"], log=b.log)
        b.save(name, params, {"arch": dcfg.name, "kind": "draft_flex", "target": base_name})
    else:
        b.register(name, {"arch": dcfg.name, "kind": "draft_flex", "target": base_name})

    # Std-SD generic draft + per-version synced drafts (llama2t only).
    if budgets.get("generic"):
        gcfg = generic_draft_config(cfg)
        name = f"draft_generic_{fam}"
        if not b.have(name):
            params = train.train_generic(gcfg, seed=5, steps=budgets["generic"], log=b.log)
            b.save(name, params, {"arch": gcfg.name, "kind": "draft_generic"})
        else:
            b.register(name, {"arch": gcfg.name, "kind": "draft_generic"})

    if budgets.get("synced"):
        for dom in domains:
            name = f"draft_synced_{fam}_{dom}"
            meta = {"arch": dcfg.name, "kind": "draft_synced", "target": f"lora_{fam}_{dom}", "domain": dom}
            if not b.have(name):
                lora = b.load(f"lora_{fam}_{dom}")
                params, _wp = train.distill_draft(
                    dcfg, cfg, base, teacher_lora=lora, seed=6, steps=budgets["synced"],
                    domain=dom, style='evolved', log=b.log,
                )
                b.save(name, params, meta)
            else:
                b.register(name, meta)

    b.log(f"[build] family {fam} done in {time.time() - t0:.0f}s")


def calibrate(b: Builder, log=print) -> dict:
    """Measure draft/target acceptance for the headline pairs (Table II
    shape + policy priors). Stored in the manifest; cross-checked by the
    rust experiment harness."""
    cfg = LLAMA2T
    zero = model.init_lora(cfg, jax.random.PRNGKey(0), zero=True)
    base = b.load("target_llama2t_base")
    flex = b.load("draft_flex_llama2t")
    gen = b.load("draft_generic_llama2t")
    dcfg, gcfg = flex_draft_config(cfg), generic_draft_config(cfg)
    out: dict[str, float] = {}

    def acc(tag, tparams, tlora, dcfg_, dparams, domain):
        v = train.acceptance_rate(cfg, tparams, tlora, dcfg_, dparams, domain, n_prompts=6, gen_len=40)
        out[tag] = round(v, 4)
        log(f"[calibrate] {tag} = {v:.3f}")

    acc("flex_vs_base@general", base, zero, dcfg, flex, "general")
    acc("generic_vs_base@general", base, zero, gcfg, gen, "general")
    for dom in ("gsm8k", "humaneval"):
        lora = b.load(f"lora_llama2t_{dom}")
        acc(f"flex_vs_base@{dom}", base, zero, dcfg, flex, dom)
        acc(f"generic_vs_base@{dom}", base, zero, gcfg, gen, dom)
        acc(f"flex_vs_lora@{dom}", base, lora, dcfg, flex, dom)
        acc(f"generic_vs_lora@{dom}", base, lora, gcfg, gen, dom)
        sync = b.load(f"draft_synced_llama2t_{dom}")
        acc(f"synced_vs_lora@{dom}", base, lora, dcfg, sync, dom)
    full = b.load("target_llama2t_code_full")
    acc("flex_vs_full@humaneval", full, zero, dcfg, flex, "humaneval")
    acc("generic_vs_full@humaneval", full, zero, gcfg, gen, "humaneval")
    return out


def build_manifest(b: Builder, hlo_paths: dict, calib: dict) -> dict:
    archs = {}
    for name, cfg in all_archs().items():
        archs[name] = {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "n_experts": cfg.n_experts,
            "lora_rank": cfg.lora_rank,
            "draft_head": cfg.draft_head,
            "kv_shape": list(cfg.kv_shape()),
            "params": [[n, list(s)] for n, s in cfg.param_spec()],
            "lora": [[n, list(s)] for n, s in cfg.lora_spec()],
            "hlo_block": hlo_paths[f"{name}.block"],
            "hlo_prefill": hlo_paths[f"{name}.prefill"],
        }
    verify = {str(v): p for v, p in ((k.split("_v")[1], p) for k, p in hlo_paths.items() if k.startswith("verify_v"))}
    domains = {
        d.name: {
            "offset": d.offset, "size": d.size, "mult": d.mult, "inc": d.inc,
            "p_det": d.p_det, "p_eos": d.p_eos,
            "prompt_len": list(d.prompt_len), "gen_len": list(d.gen_len),
            "evolved_mult": d.evolved_mult, "evolved_inc": d.evolved_inc,
            "evolve_mod": d.evolve_mod,
        }
        for d in corpus.DOMAINS.values()
    }
    return {
        "version": 1,
        "block": BLOCK,
        "k_max": K_MAX,
        "prefill_chunk": PREFILL_CHUNK,
        "bos": corpus.BOS, "eos": corpus.EOS, "pad": corpus.PAD,
        "archs": archs,
        "weights": b.weights,
        "verify_hlo": verify,
        "domains": domains,
        "acceptance_calibration": calib,
    }


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--skip-calibration", action="store_true")
    p.add_argument("--family", default=None, help="build a single target family")
    args = p.parse_args()
    t0 = time.time()

    b = Builder(args.out)
    fams = [LLAMA2T, LLAMA3T, MIXTRALT]
    if args.family:
        fams = [f for f in fams if f.name == args.family]
    for cfg in fams:
        build_family(b, cfg, STEPS[cfg.name])

    hlo_paths = {}
    for key, rel in aot_cached(args.out).items():
        hlo_paths[key] = rel

    calib_path = os.path.join(args.out, "calibration.json")
    if args.skip_calibration and os.path.exists(calib_path):
        calib = json.load(open(calib_path))
    else:
        calib = calibrate(b)
        json.dump(calib, open(calib_path, "w"), indent=1, sort_keys=True)

    manifest = build_manifest(b, hlo_paths, calib)
    export.write_manifest(os.path.join(args.out, "manifest.json"), manifest)
    print(f"[build] artifacts complete in {time.time() - t0:.0f}s -> {args.out}")


def aot_cached(out_dir: str, log=print) -> dict:
    """Lower only the HLO files that are missing."""
    archs = all_archs()
    paths: dict[str, str] = {}
    missing: dict[str, ModelConfig] = {}
    for name in archs:
        for kind in ("block", "prefill"):
            rel = f"hlo/{name}.{kind}.hlo.txt"
            paths[f"{name}.{kind}"] = rel
            if not os.path.exists(os.path.join(out_dir, rel)):
                missing[name] = archs[name]
    for v in sorted({c.vocab for c in archs.values()}):
        rel = f"hlo/verify_v{v}.hlo.txt"
        paths[f"verify_v{v}"] = rel
        if not os.path.exists(os.path.join(out_dir, rel)):
            os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(aot.lower_verify(v))
            log(f"[aot] verify_v{v} lowered")
    for name, cfg in missing.items():
        for kind, n in (("block", BLOCK), ("prefill", PREFILL_CHUNK)):
            rel = f"hlo/{name}.{kind}.hlo.txt"
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(aot.lower_arch(cfg, n))
            log(f"[aot] {name}.{kind} lowered")
    return paths


if __name__ == "__main__":
    main()
