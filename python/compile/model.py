"""L2: the JAX transformer family (targets + drafts) that FlexSpec serves.

Two forward paths share the same parameters:

  * `forward_block` — the serving path lowered to HLO by aot.py and driven
    from rust through PJRT. Single sequence, fixed token block with a
    valid-length mask, persistent KV cache passed in/out as one array so
    the rust coordinator can do position-pointer rollback (paper §IV-C).
    Calls the L1 Pallas kernels (attention, fused SwiGLU).
  * `forward_train` — the training path: full-sequence causal forward over
    a batch in pure jnp (fast on CPU), used by train.py for base training,
    LoRA fine-tuning and Algorithm 1 draft distillation.

Both paths are asserted equal (up to kernel tolerance) by
python/tests/test_model.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import attention as attn_k
from .kernels import fused_mlp as mlp_k
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jnp.ndarray]:
    """Scaled-normal init for every parameter in cfg.param_spec()."""
    params: dict[str, jnp.ndarray] = {}
    for name, shape in cfg.param_spec():
        key, sub = jax.random.split(key)
        if name.endswith((".ln1", ".ln2")) or name == "ln_f":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b1", ".b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 1.0 / max(fan_in, 1) ** 0.5
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


def init_lora(cfg: ModelConfig, key: jax.Array, zero: bool = False) -> dict[str, jnp.ndarray]:
    """LoRA adapters; A ~ normal, B = 0 at init (standard LoRA init)."""
    lora: dict[str, jnp.ndarray] = {}
    for name, shape in cfg.lora_spec():
        key, sub = jax.random.split(key)
        if zero or name.split(".")[-1].startswith("B"):
            lora[name] = jnp.zeros(shape, jnp.float32)
        else:
            lora[name] = jax.random.normal(sub, shape, jnp.float32) / shape[0] ** 0.5
    return lora


def empty_kv(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.zeros(cfg.kv_shape(), jnp.float32)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x: [..., seq, d_head]; positions: [seq] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [seq, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


LORA_SCALE = 2.0  # alpha / r with alpha = 2r


def _proj(h, params, lora, layer: int, which: str, cfg: ModelConfig):
    """Linear projection with optional LoRA delta (layers < lora_layers)."""
    w = params[f"L{layer}.w{which}"]
    y = h @ w
    if lora is not None and which in ("q", "v", "o") and layer < cfg.lora_layers:
        a = lora[f"L{layer}.A{which}"]
        b = lora[f"L{layer}.B{which}"]
        y = y + ((h @ a) @ b) * LORA_SCALE
    return y


def _mlp_block(h2, params, layer: int, cfg: ModelConfig, use_kernels: bool):
    """Dense SwiGLU or top-k MoE MLP over [tokens, d_model]."""
    p = f"L{layer}"
    swiglu = mlp_k.swiglu if use_kernels else kref.swiglu_ref
    if not cfg.n_experts:
        return swiglu(h2, params[f"{p}.wg"], params[f"{p}.wu"], params[f"{p}.wd"])
    # MoE: dense-compute every expert, weight by renormalised top-k gate.
    # NOTE: jax.lax.top_k lowers to an HLO `topk(...)` op whose text syntax
    # the xla_extension 0.5.1 parser rejects; a k-step max reduction
    # produces the same threshold with parser-compatible ops.
    gate_logits = h2 @ params[f"{p}.gate"]  # [tokens, E]
    remaining = gate_logits
    thresh = None
    for _ in range(cfg.top_k):
        cur = jnp.max(remaining, axis=-1, keepdims=True)
        thresh = cur
        remaining = jnp.where(remaining >= cur, kref.NEG_INF, remaining)
    masked = jnp.where(gate_logits >= thresh, gate_logits, kref.NEG_INF)
    gates = jax.nn.softmax(masked, axis=-1)  # [tokens, E]
    out = jnp.zeros_like(h2)
    for e in range(cfg.n_experts):
        y = swiglu(h2, params[f"{p}.E{e}.wg"], params[f"{p}.E{e}.wu"], params[f"{p}.E{e}.wd"])
        out = out + gates[:, e : e + 1] * y
    return out


def _head_mlp(x, params):
    """H_small (paper eq. 4): trainable 2-layer MLP on top of the frozen
    anchor block; returns the draft hidden state h_d."""
    h = jax.nn.gelu(x @ params["head.w1"] + params["head.b1"])
    return x + (h @ params["head.w2"] + params["head.b2"])


# ---------------------------------------------------------------------------
# Serving path (lowered to HLO)
# ---------------------------------------------------------------------------


def forward_block(
    cfg: ModelConfig,
    params: dict[str, jnp.ndarray],
    lora: dict[str, jnp.ndarray] | None,
    tokens: jnp.ndarray,  # [B] int32
    pos: jnp.ndarray,  # [1] int32 — absolute position of tokens[0]
    valid: jnp.ndarray,  # [1] int32 — number of real tokens in the block
    kv: jnp.ndarray,  # cfg.kv_shape() f32
    use_kernels: bool = True,
):
    """One verification/draft block forward with KV-cache update.

    Rows >= valid are padding: they write KV slots that the absolute-
    position mask (kv_valid = pos + valid) prevents anyone from attending,
    and that the next round provably overwrites (DESIGN.md). Returns
    (logits [B, vocab], kv_out).
    """
    b = tokens.shape[0]
    pos_s = pos.reshape(())
    valid_s = valid.reshape(())
    positions = pos_s + jnp.arange(b, dtype=jnp.int32)
    x = params["embed"][tokens]  # [B, d]
    attend = attn_k.attention if use_kernels else kref.attention_ref

    for i in range(cfg.n_layers):
        h = rmsnorm(x, params[f"L{i}.ln1"])
        q = _proj(h, params, lora, i, "q", cfg)
        k = _proj(h, params, lora, i, "k", cfg)
        v = _proj(h, params, lora, i, "v", cfg)
        # [B, d] -> [H, B, dh]
        q = q.reshape(b, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
        k = k.reshape(b, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
        v = v.reshape(b, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
        q = rope(q, positions)
        k = rope(k, positions)
        kv = jax.lax.dynamic_update_slice(kv, k[None, None], (i, 0, 0, pos_s, 0))
        kv = jax.lax.dynamic_update_slice(kv, v[None, None], (i, 1, 0, pos_s, 0))
        o = attend(q, kv[i, 0], kv[i, 1], pos_s, pos_s + valid_s)  # [H, B, dh]
        o = o.transpose(1, 0, 2).reshape(b, cfg.d_model)
        x = x + _proj(o, params, lora, i, "o", cfg)
        h2 = rmsnorm(x, params[f"L{i}.ln2"])
        x = x + _mlp_block(h2, params, i, cfg, use_kernels)

    if cfg.draft_head:
        x = _head_mlp(x, params)
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["lm_head"]
    return logits, kv


# ---------------------------------------------------------------------------
# Training path (full-sequence, batched, pure jnp)
# ---------------------------------------------------------------------------


def forward_train(
    cfg: ModelConfig,
    params: dict[str, jnp.ndarray],
    lora: dict[str, jnp.ndarray] | None,
    tokens: jnp.ndarray,  # [batch, T] int32
):
    """Batched causal forward (no cache). Returns (logits [B,T,V],
    hidden [B,T,d] — the pre-ln_f hidden used as h_t / h_d in Algorithm 1)."""
    bsz, t = tokens.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    x = params["embed"][tokens]  # [B, T, d]
    causal = jnp.tril(jnp.ones((t, t), bool))

    for i in range(cfg.n_layers):
        h = rmsnorm(x, params[f"L{i}.ln1"])
        q = _proj(h, params, lora, i, "q", cfg)
        k = _proj(h, params, lora, i, "k", cfg)
        v = _proj(h, params, lora, i, "v", cfg)
        q = q.reshape(bsz, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        k = k.reshape(bsz, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        v = v.reshape(bsz, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        q = rope(q, positions)
        k = rope(k, positions)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / cfg.d_head**0.5
        s = jnp.where(causal[None, None], s, kref.NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, v)
        o = o.transpose(0, 2, 1, 3).reshape(bsz, t, cfg.d_model)
        x = x + _proj(o, params, lora, i, "o", cfg)
        h2 = rmsnorm(x, params[f"L{i}.ln2"])
        flat = h2.reshape(bsz * t, cfg.d_model)
        x = x + _mlp_block(flat, params, i, cfg, use_kernels=False).reshape(bsz, t, cfg.d_model)

    if cfg.draft_head:
        x = _head_mlp(x, params)
    hidden = x
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["lm_head"]
    return logits, hidden


# ---------------------------------------------------------------------------
# Anchor transplant (paper Algorithm 1, step 1)
# ---------------------------------------------------------------------------

# Frozen pieces of the edge draft (paper eq. 4): the input embedding and
# the transplanted anchor block. H_small — the 2-layer MLP *and* its
# vocabulary projection (lm_head) + final norm — is trainable.
FROZEN_DRAFT_PARAMS = ("embed", "L0.")


def transplant_anchor(
    target_params: dict[str, jnp.ndarray],
    target_cfg: ModelConfig,
    draft_params: dict[str, jnp.ndarray],
) -> dict[str, jnp.ndarray]:
    """Copy the frozen pieces of the base target into a draft param dict:
    embedding, the anchor block (target layer L-1 -> draft layer 0), ln_f
    and the LM head. Everything else (H_small) stays trainable."""
    out = dict(draft_params)
    last = target_cfg.n_layers - 1
    for name, val in target_params.items():
        if name in ("embed", "ln_f", "lm_head"):
            out[name] = val
        elif name.startswith(f"L{last}."):
            out["L0." + name.split(".", 1)[1]] = val
    return out


def is_frozen_draft_param(name: str) -> bool:
    return name.startswith(FROZEN_DRAFT_PARAMS)
