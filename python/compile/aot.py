"""AOT lowering: jax → HLO *text* artifacts the rust runtime loads.

HLO text (NOT `lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()`)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids that xla_extension 0.5.1 (the version the published `xla` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Three entry points per architecture:

  * <arch>.block.hlo.txt   — forward_block over BLOCK(=9) tokens: the
    verify/decode/draft-step executable (valid-length masked);
  * <arch>.prefill.hlo.txt — forward_block over PREFILL_CHUNK(=64) tokens:
    chunked prompt ingestion;
  * verify_v<vocab>.hlo.txt — the fused Pallas verification kernel.

Argument order contract with rust (runtime/model.rs): jax flattens the
argument pytree depth-first with dict keys sorted, i.e.

    [params (sorted names)..., lora (sorted names, targets only)...,
     tokens, pos, valid, kv]

and returns a tuple (logits, kv_out).
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import BLOCK, K_MAX, PREFILL_CHUNK, ModelConfig, all_archs
from .kernels import verify as verify_k


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _block_fn(cfg: ModelConfig, n_tokens: int):
    """Build the jittable serving entry point for one architecture."""
    if cfg.lora_rank:

        def fn(params, lora, tokens, pos, valid, kv):
            return model.forward_block(cfg, params, lora, tokens, pos, valid, kv, use_kernels=True)

    else:

        def fn(params, tokens, pos, valid, kv):
            return model.forward_block(cfg, params, None, tokens, pos, valid, kv, use_kernels=True)

    return fn


def _abstract(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_arch(cfg: ModelConfig, n_tokens: int) -> str:
    """Lower forward_block for `n_tokens` to HLO text."""
    params = {name: _abstract(shape) for name, shape in cfg.param_spec()}
    tokens = _abstract((n_tokens,), jnp.int32)
    pos = _abstract((1,), jnp.int32)
    valid = _abstract((1,), jnp.int32)
    kv = _abstract(cfg.kv_shape())
    fn = _block_fn(cfg, n_tokens)
    if cfg.lora_rank:
        lora = {name: _abstract(shape) for name, shape in cfg.lora_spec()}
        lowered = jax.jit(fn).lower(params, lora, tokens, pos, valid, kv)
    else:
        lowered = jax.jit(fn).lower(params, tokens, pos, valid, kv)
    return to_hlo_text(lowered)


def lower_verify(vocab: int) -> str:
    """Lower the fused verification kernel for one vocabulary size."""
    logits = _abstract((BLOCK, vocab))
    draft = _abstract((K_MAX,), jnp.int32)
    n = _abstract((1,), jnp.int32)
    lowered = jax.jit(verify_k.verify).lower(logits, draft, n)
    return to_hlo_text(lowered)


def build_hlo(out_dir: str, archs: dict[str, ModelConfig] | None = None, log=print) -> dict:
    """Lower every entry point; returns {key: relative path} for the manifest."""
    archs = archs or all_archs()
    hlo_dir = os.path.join(out_dir, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    paths: dict[str, str] = {}

    vocabs = sorted({c.vocab for c in archs.values()})
    for v in vocabs:
        rel = f"hlo/verify_v{v}.hlo.txt"
        text = lower_verify(v)
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        paths[f"verify_v{v}"] = rel
        log(f"[aot] verify_v{v}: {len(text) / 1e3:.0f} kB")

    for name, cfg in archs.items():
        for kind, n in (("block", BLOCK), ("prefill", PREFILL_CHUNK)):
            rel = f"hlo/{name}.{kind}.hlo.txt"
            text = lower_arch(cfg, n)
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(text)
            paths[f"{name}.{kind}"] = rel
            log(f"[aot] {name}.{kind}: {len(text) / 1e3:.0f} kB")
    return paths


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifacts directory")
    p.add_argument("--arch", default=None, help="lower a single architecture")
    args = p.parse_args()
    archs = all_archs()
    if args.arch:
        archs = {args.arch: archs[args.arch]}
    build_hlo(args.out, archs)


if __name__ == "__main__":
    main()
