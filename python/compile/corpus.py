"""Synthetic domain corpora, deterministically matched with the rust side.

The paper trains/evaluates on GSM8K, NQ, NQ-RAG, MT-Bench, WMT14 and
CNN/DM and fine-tunes cloud targets per domain. We cannot ship those
datasets, so each domain is a synthetic grammar over a shared vocabulary:
a mostly-deterministic affine next-token rule inside a domain-specific
token range, with in-domain noise and a shared "common word" range. LoRA
fine-tuning a target on one grammar shifts its distribution exactly the
way task fine-tuning does in the paper (DESIGN.md substitution log).

CROSS-LANGUAGE CONTRACT: rust/src/workload/corpus.rs implements the same
splitmix64 PRNG and the same tables; python/tests/test_corpus.py and the
rust unit tests both pin golden sequences so the serving-time workload
distribution provably equals the training distribution.
"""

from __future__ import annotations

import dataclasses

MASK64 = (1 << 64) - 1

PAD, BOS, EOS = 0, 1, 2
COMMON_OFFSET, COMMON_SIZE = 448, 64


class SplitMix64:
    """splitmix64; bit-identical to rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_range(self, n: int) -> int:
        return self.next_u64() % n


@dataclasses.dataclass(frozen=True)
class Domain:
    """One synthetic task grammar (wire-format: mirrored in rust).

    Each domain has a *base* affine next-token rule and an *evolved*
    variant used to fine-tune the cloud target: transitions from tokens
    with (cur % evolve_mod == evolve_mod-1) follow (evolved_mult,
    evolved_inc) instead. evolve_mod therefore controls how much of the
    domain's behaviour the cloud update rewrites — the knob behind the
    paper's distribution-shift gradient (Table II)."""

    name: str
    offset: int  # first token id of the domain range
    size: int  # number of domain tokens
    mult: int  # affine rule multiplier
    inc: int  # affine rule increment
    p_det: float  # probability of following the deterministic rule
    p_eos: float  # per-token EOS probability once past min length
    prompt_len: tuple[int, int]  # [lo, hi) prompt lengths (tokens, excl BOS)
    gen_len: tuple[int, int]  # [lo, hi) target output lengths
    evolved_mult: int = 0  # evolved-rule multiplier (0 => mult+2)
    evolved_inc: int = 0
    evolve_mod: int = 4  # evolve transitions from cur % mod == mod-1


# Domain table. prompt/gen lengths echo the paper's task shapes: RAG and
# summarisation are prompt-heavy, chat/translation mid, math/qa shorter.
DOMAINS: dict[str, Domain] = {
    d.name: d
    for d in (
        Domain("general", 16, 48, 5, 11, 0.75, 0.020, (8, 24), (24, 64)),
        Domain("gsm8k", 64, 64, 7, 3, 0.85, 0.015, (12, 32), (32, 96)),
        Domain("humaneval", 128, 64, 11, 5, 0.85, 0.012, (10, 28), (40, 112), evolve_mod=3),
        Domain("mtbench", 192, 64, 3, 17, 0.78, 0.018, (8, 40), (32, 96)),
        Domain("nq", 256, 64, 13, 7, 0.80, 0.030, (6, 20), (16, 48)),
        Domain("nq_rag", 256, 64, 13, 7, 0.80, 0.025, (48, 120), (24, 64)),
        Domain("wmt14", 320, 64, 9, 13, 0.80, 0.020, (12, 36), (24, 72)),
        Domain("cnndm", 384, 64, 5, 19, 0.80, 0.022, (64, 160), (24, 80)),
    )
}

# nq and nq_rag share a grammar range (same knowledge domain, different
# prompt shape) — exactly the paper's NQ vs NQ-RAG split.


# Grammar styles:
#   base    — the pretraining rule;
#   evolved — the cloud update: transitions from cur % evolve_mod ==
#             evolve_mod-1 rewritten (the Table II shift knob);
#   foreign — a *different provider's* data distribution used to train
#             the Std-SD generic draft: general text is shared (mod-4
#             sliver differs); every task domain follows that provider's
#             own rules entirely.
BASE, EVOLVED, FOREIGN, FULL_SHIFT = "base", "evolved", "foreign", "full_shift"


def subset_hash(cur: int, salt: int) -> int:
    """Multiplicative hash picking pseudorandom token subsets. Residue
    classes of `cur` are invariant tracks of the affine dynamics (a mod-m
    trigger would leave some trajectories untouched and absorb others —
    bimodal acceptance); hashing decorrelates the rewritten subset from
    the trajectory structure so every request sees the same rewrite rate."""
    return ((cur * 2654435761 + salt * 40503) & 0xFFFFFFFF) >> 13


def rule_next(cur: int, dom: Domain, style: str = BASE) -> int:
    """The deterministic part of the grammar under a given style."""
    if style == EVOLVED and subset_hash(cur, dom.offset) % dom.evolve_mod == dom.evolve_mod - 1:
        m = dom.evolved_mult or dom.mult + 2
        c = dom.evolved_inc or dom.inc + 5
        return dom.offset + ((cur * m + c) % dom.size)
    if style == FULL_SHIFT and cur % 2 == 0:
        # full-parameter FT rewrite: absorbing on the even subset (the
        # harsh Table II "Code (Full)" drift — trajectories converge into
        # fully rewritten behaviour, collapsing base-aligned drafts).
        return dom.offset + ((cur * (dom.mult + 2) + dom.inc + 5) % dom.size)
    if style == FOREIGN:
        # Another provider's corpus: general web text is universal (only a
        # mod-4 sliver differs), but task-domain conventions differ on the
        # odd half of the transitions — an off-the-shelf draft gets the
        # domains only partially right (paper Table II's 0.45-on-math
        # regime), and the odd class is exactly where EVOLVED trajectories
        # concentrate, so its acceptance collapses further under updates.
        if dom.name == "general":
            if subset_hash(cur, 77) % 4 == 0:
                return dom.offset + ((cur * (dom.mult + 4) + dom.inc + 7) % dom.size)
        elif subset_hash(cur, 77) % 2 == 1:
            return dom.offset + ((cur * (dom.mult + 4) + dom.inc + 7) % dom.size)
    return dom.offset + ((cur * dom.mult + dom.inc) % dom.size)


def next_token(cur: int, dom: Domain, rng: SplitMix64, style: str = BASE) -> int:
    """One grammar step. Deterministic affine rule with prob p_det, else
    in-domain noise (50%) or a common-range word (50%)."""
    if rng.next_f64() < dom.p_det:
        return rule_next(cur, dom, style)
    if rng.next_f64() < 0.5:
        return dom.offset + rng.next_range(dom.size)
    return COMMON_OFFSET + rng.next_range(COMMON_SIZE)


def gen_tokens(dom: Domain, rng: SplitMix64, length: int, start: int | None = None, style: str = BASE) -> list[int]:
    """Generate `length` grammar tokens (no BOS/EOS framing)."""
    cur = dom.offset + rng.next_range(dom.size) if start is None else start
    out = []
    for _ in range(length):
        out.append(cur)
        cur = next_token(cur, dom, rng, style)
    return out


def gen_document(dom: Domain, rng: SplitMix64, min_len: int = 16, max_len: int = 96, style: str = BASE) -> list[int]:
    """BOS + grammar tokens + stochastic EOS — a training document."""
    toks = [BOS]
    cur = dom.offset + rng.next_range(dom.size)
    for i in range(max_len - 2):
        toks.append(cur)
        if i >= min_len and rng.next_f64() < dom.p_eos:
            break
        cur = next_token(cur, dom, rng, style)
    toks.append(EOS)
    return toks


def gen_prompt(dom: Domain, rng: SplitMix64) -> list[int]:
    """BOS + a prompt-length grammar prefix — a serving request prompt."""
    lo, hi = dom.prompt_len
    n = lo + rng.next_range(hi - lo)
    return [BOS] + gen_tokens(dom, rng, n)


# Base-model pretraining mixture: mostly general, a light taste of every
# task domain (the paper's generic pretraining corpus).
BASE_MIX: list[tuple[str, float]] = [
    ("general", 0.58),
    ("gsm8k", 0.07),
    ("humaneval", 0.07),
    ("mtbench", 0.07),
    ("nq", 0.07),
    ("wmt14", 0.07),
    ("cnndm", 0.07),
]


def pick_domain(rng: SplitMix64, mix: list[tuple[str, float]]) -> Domain:
    r = rng.next_f64()
    acc = 0.0
    for name, w in mix:
        acc += w
        if r < acc:
            return DOMAINS[name]
    return DOMAINS[mix[-1][0]]


# Distillation mixture: the "broad generic corpus" (RedPajama stand-in) —
# uniform-ish domain coverage so the one-time draft alignment sees every
# task family the way a web-scale corpus would.
DISTILL_MIX: list[tuple[str, float]] = [
    ("general", 0.30),
    ("gsm8k", 0.1167),
    ("humaneval", 0.1167),
    ("mtbench", 0.1167),
    ("nq", 0.1167),
    ("wmt14", 0.1167),
    ("cnndm", 0.1165),
]


def training_batch(rng: SplitMix64, batch: int, seqlen: int, mix=None, domain: str | None = None, style: str = BASE):
    """[batch, seqlen] int32 array of packed documents (PAD-filled tails).

    `style=EVOLVED` generates the fine-tuning corpus of an *updated* cloud
    target; `style=FOREIGN` the off-provider corpus of the generic draft."""
    import numpy as np

    out = np.zeros((batch, seqlen), dtype=np.int32)
    for b in range(batch):
        dom = DOMAINS[domain] if domain else pick_domain(rng, mix or BASE_MIX)
        row: list[int] = []
        while len(row) < seqlen:
            row.extend(gen_document(dom, rng, min_len=12, max_len=seqlen, style=style))
        out[b] = row[:seqlen]
    return out
