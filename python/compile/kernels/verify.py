"""L1 Pallas kernel: fused greedy speculative verification.

Given the target model's logits over a draft block and the proposed draft
token ids, compute in a single VMEM-resident pass:

  * the greedy (argmax) token per row,
  * the accepted prefix length tau (Algorithm 2, step 2 of the paper),
  * the correction/bonus token argmax(logits[tau]).

On real hardware this fuses what would otherwise be a [block, vocab]
argmax launch + host-side prefix scan + a second gather launch; the whole
tile (block <= 9, vocab <= 2048) fits comfortably in VMEM (9*2048*4 =
72 KiB), so a single grid cell handles it. Lowered with interpret=True for
CPU PJRT (see attention.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _verify_kernel(logits_ref, draft_ref, n_ref, tau_ref, corr_ref, greedy_ref):
    logits = logits_ref[...]  # [block, vocab]
    block = logits.shape[0]
    draft = draft_ref[...]  # [block - 1]
    n_draft = n_ref[0]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [block]
    idx = jax.lax.iota(jnp.int32, block - 1)
    ok = (greedy[:-1] == draft) & (idx < n_draft)
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    tau = jnp.minimum(jnp.sum(prefix).astype(jnp.int32), n_draft)
    tau_ref[0] = tau
    corr_ref[0] = greedy[tau]
    greedy_ref[...] = greedy


def verify(logits, draft, n_draft):
    """Fused verification; same contract as ref.verify_ref but additionally
    returns the per-row greedy tokens (used by the cloud engine to seed the
    next round and by the stochastic path as the T=0 special case).

    logits: [block, vocab] f32; draft: [block-1] i32; n_draft: [] or [1] i32.
    Returns (tau [1] i32, correction [1] i32, greedy [block] i32).
    """
    block, vocab = logits.shape
    n = jnp.reshape(n_draft.astype(jnp.int32), (1,))
    return pl.pallas_call(
        _verify_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((block,), jnp.int32),
        ),
        interpret=True,
    )(logits, draft, n)
