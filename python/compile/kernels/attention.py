"""L1 Pallas kernel: blocked causal flash-attention with absolute-position
masking, used by every transformer layer in the L2 model.

Hardware adaptation (paper targets CUDA threadblocks / tensor cores):
  * the threadblock tiling of FlashAttention becomes a BlockSpec HBM→VMEM
    schedule — one (head, q-tile) grid cell streams KV tiles of shape
    [block_k, d_head] through VMEM;
  * the warp-synchronous online softmax becomes a fori_loop-carried
    (m, l, acc) triple living in VMEM registers;
  * WMMA matmuls become MXU-shaped jnp.dot with f32 accumulation.

The kernel MUST be lowered with interpret=True: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Correctness is
checked against ref.attention_ref by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = ref.NEG_INF


def _attn_kernel(pos_ref, valid_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One grid cell = one attention head over the whole query tile.

    q_ref: [q_len, d_head] — the query tile for this head.
    k_ref/v_ref: [kv_len, d_head] — the full KV buffer for this head.
    pos_ref/valid_ref: [1] int32 — absolute position of query row 0 and the
      number of valid KV rows (kv_valid_len).
    """
    q = q_ref[...].astype(jnp.float32)
    q_len, d_head = q.shape
    kv_len = k_ref.shape[0]
    pos = pos_ref[0]
    kv_valid = valid_ref[0]
    n_tiles = kv_len // block_k

    q_abs = pos + jax.lax.iota(jnp.int32, q_len)  # [q_len]

    def body(t, carry):
        m_prev, l_prev, acc_prev = carry
        start = t * block_k
        k_tile = jax.lax.dynamic_slice(k_ref[...], (start, 0), (block_k, d_head)).astype(jnp.float32)
        v_tile = jax.lax.dynamic_slice(v_ref[...], (start, 0), (block_k, d_head)).astype(jnp.float32)
        # [q_len, block_k] scores on the MXU, f32 accumulation
        s = jax.lax.dot_general(
            q, k_tile, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        k_abs = start + jax.lax.iota(jnp.int32, block_k)  # [block_k]
        mask = (k_abs[None, :] <= q_abs[:, None]) & (k_abs[None, :] < kv_valid)
        s = jnp.where(mask, s, NEG_INF)
        # online softmax update
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
            p, v_tile, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((q_len,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_len,), jnp.float32)
    acc0 = jnp.zeros((q_len, d_head), jnp.float32)
    # PERF (EXPERIMENTS.md §Perf L1-1): unroll the KV-tile loop for small
    # tile counts — the xla_extension 0.5.1 CPU runtime executes HLO while
    # loops with heavy per-iteration dispatch and cannot fuse across them;
    # straight-line tiles fuse into one kernel. fori_loop remains for long
    # contexts where unrolling would bloat the module.
    if n_tiles <= 8:
        carry = (m0, l0, acc0)
        for t in range(n_tiles):
            carry = body(t, carry)
        m, l, acc = carry
    else:
        m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def attention(q, k, v, q_pos, kv_valid_len, *, block_k: int = 64, scale: float | None = None):
    """Pallas flash-attention. Same contract as ref.attention_ref.

    q: [n_heads, q_len, d_head]; k, v: [n_heads, kv_len, d_head];
    q_pos, kv_valid_len: scalar int32 (absolute positions, see ref).
    """
    n_heads, q_len, d_head = q.shape
    kv_len = k.shape[1]
    if scale is None:
        scale = 1.0 / (d_head**0.5)
    block_k = min(block_k, kv_len)
    assert kv_len % block_k == 0, (kv_len, block_k)
    pos = jnp.reshape(q_pos.astype(jnp.int32), (1,))
    valid = jnp.reshape(kv_valid_len.astype(jnp.int32), (1,))

    kernel = functools.partial(_attn_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(n_heads,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((None, q_len, d_head), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, kv_len, d_head), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, kv_len, d_head), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_len, d_head), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_heads, q_len, d_head), q.dtype),
        interpret=True,
    )(pos, valid, q, k, v)
