"""L1 Pallas kernel: fused SwiGLU MLP tile.

Computes (silu(x@Wg) * (x@Wu)) @ Wd without materialising the [tokens,
d_ff] intermediates in HBM: one grid cell per token tile keeps the gate/up
activations in VMEM scratch. On TPU this is the classic MLP fusion the MXU
wants — two [tile, d_model]x[d_model, d_ff] matmuls feeding an elementwise
VPU epilogue and one [tile, d_ff]x[d_ff, d_model] matmul, all f32
accumulation. interpret=True for CPU PJRT execution (see attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [tile, d_model]
    wg = wg_ref[...].astype(jnp.float32)
    wu = wu_ref[...].astype(jnp.float32)
    wd = wd_ref[...].astype(jnp.float32)
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    act = g * (1.0 / (1.0 + jnp.exp(-g))) * u
    o = jax.lax.dot_general(act, wd, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def swiglu(x, w_gate, w_up, w_down, *, tile: int = 64):
    """Fused SwiGLU MLP; same contract as ref.swiglu_ref.

    x: [tokens, d_model]; w_gate/w_up: [d_model, d_ff]; w_down: [d_ff, d_model].
    """
    tokens, d_model = x.shape
    d_ff = w_gate.shape[1]
    tile = min(tile, tokens)
    assert tokens % tile == 0, (tokens, tile)
    grid = (tokens // tile,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d_model), lambda i: (i, 0)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d_model), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tokens, d_model), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down)
