"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here written in
straight-line jax.numpy. The pytest suite sweeps shapes/dtypes with
hypothesis and asserts allclose between kernel and oracle; the L2 model
calls the kernels, the tests call both.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, q_pos, kv_valid_len, scale=None):
    """Causal attention with absolute-position masking.

    Args:
      q: [n_heads, q_len, d_head] queries for a block of new tokens.
      k: [n_heads, kv_len, d_head] keys (full cache buffer, stale tail ok).
      v: [n_heads, kv_len, d_head] values.
      q_pos: scalar int32 — absolute position of the first query row.
      kv_valid_len: scalar int32 — query row r (absolute position
        q_pos + r) may attend keys at buffer index j iff j <= q_pos + r and
        j < kv_valid_len (the cache stores key for position j at index j).
    Returns:
      [n_heads, q_len, d_head] attention outputs.
    """
    h, ql, d = q.shape
    kv_len = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    kpos = jnp.arange(kv_len)[None, :]  # [1, kv]
    qabs = q_pos + jnp.arange(ql)[:, None]  # [q, 1]
    mask = (kpos <= qabs) & (kpos < kv_valid_len)  # [q, kv]
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def verify_ref(logits, draft, n_draft):
    """Greedy speculative verification (paper Algorithm 2, step 2).

    Row j of `logits` is the target's next-token distribution after
    consuming draft token j-1 (row 0: after the last committed token).
    Draft token j (0-based) is accepted iff it equals argmax(logits[j]) and
    all earlier draft tokens were accepted. The correction token is
    argmax(logits[tau]).

    Args:
      logits: [block, vocab] float logits (block >= n_draft + 1).
      draft:  [block - 1] int32 draft token ids (only first n_draft valid).
      n_draft: scalar int32 — number of proposed draft tokens (may be 0).
    Returns:
      (tau, correction): accepted prefix length and the bonus token.
    """
    block = logits.shape[0]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [block]
    idx = jnp.arange(block - 1)
    ok = (greedy[:-1] == draft) & (idx < n_draft)
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    tau = jnp.minimum(prefix.sum().astype(jnp.int32), n_draft.astype(jnp.int32))
    correction = greedy[tau]
    return tau, correction


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP: (silu(x@Wg) * (x@Wu)) @ Wd.

    x: [tokens, d_model]; w_gate/w_up: [d_model, d_ff]; w_down: [d_ff, d_model].
    """
    g = x @ w_gate
    u = x @ w_up
    act = g * (1.0 / (1.0 + jnp.exp(-g))) * u  # silu(g) * u
    return act @ w_down


def softmax_temp_ref(logits, temperature):
    """Temperature softmax over the last axis (used by the sampling path)."""
    z = logits / temperature
    z = z - z.max(axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


def sample_verify_ref(logits, draft_probs, draft, n_draft, uniforms, temperature=1.0):
    """Stochastic speculative verification (Leviathan-style acceptance).

    Token j accepted with prob min(1, p_t(x_j)/p_d(x_j)); on the first
    rejection the correction token is the argmax of the residual
    max(p_t - p_d, 0) (deterministic residual pick keeps the rust side
    bit-reproducible). If every proposal is accepted, the bonus token is
    the argmax of p_t at the next position — sampling noise enters through
    the accept tests only.

    Args:
      logits: [block, vocab] target logits.
      draft_probs: [block-1, vocab] draft distribution for each proposal.
      draft: [block-1] int32 proposed ids.
      n_draft: scalar int32.
      uniforms: [block-1] pre-drawn U(0,1) accept tests.
    Returns (tau, correction).
    """
    block, vocab = logits.shape
    pt = softmax_temp_ref(logits, jnp.asarray(temperature, logits.dtype))
    idx = jnp.arange(block - 1)
    p_t_j = pt[idx, draft]  # [block-1]
    p_d_j = draft_probs[idx, draft]
    ratio = p_t_j / jnp.maximum(p_d_j, 1e-20)
    ok = (uniforms < jnp.minimum(1.0, ratio)) & (idx < n_draft)
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    tau = jnp.minimum(prefix.sum().astype(jnp.int32), n_draft.astype(jnp.int32))
    resid = jnp.maximum(
        pt[tau]
        - jnp.where(tau < n_draft, draft_probs[jnp.minimum(tau, block - 2)], 0.0),
        0.0,
    )
    dist = jnp.where(tau < n_draft, resid, pt[tau])
    correction = jnp.argmax(dist).astype(jnp.int32)
    return tau, correction
