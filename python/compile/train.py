"""Offline training pipeline (build-time only; never on the request path).

Produces every weight bundle the rust coordinator serves:

  * base targets        — pretrained on the BASE_MIX corpus (CE loss);
  * LoRA domain targets — the paper's evolving cloud versions: adapters on
    layers 1..L-1 only (backbone-freezing constraint, §IV-A), trained on a
    single domain grammar;
  * full-FT target      — the paper's "Code (Full)" version from Table II,
    which violates the anchor invariant on purpose;
  * FlexSpec draft      — Algorithm 1: frozen anchor transplant + H_small
    distilled against the *base* target with L = l1*L_feat + l2*L_KD;
  * synced drafts       — the EAGLE-2/Medusa "(Ideal Synced)" stand-ins:
    the same draft architecture re-distilled against each evolved target;
  * generic draft       — Std-SD baseline: an independent small LM trained
    with plain CE on the general grammar only.

Everything is deterministic given the seed. A tiny hand-rolled Adam is
used (optax is not available in this environment).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .configs import ModelConfig

# ---------------------------------------------------------------------------
# Adam (hand-rolled, pytree-valued)
# ---------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, state, params, lr, b1=0.9, b2=0.99, eps=1e-8, wd=0.0):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1, bc2 = 1 - b1**tf, 1 - b2**tf
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + wd * p),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, steps, peak, warmup=20):
    w = jnp.minimum(step / warmup, 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(steps - warmup, 1), 0.0, 1.0)
    return peak * w * 0.5 * (1 + jnp.cos(jnp.pi * prog))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def ce_loss(cfg: ModelConfig, params, lora, tokens):
    """Next-token cross entropy, PAD-masked."""
    logits, _ = model.forward_train(cfg, params, lora, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != corpus.PAD).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def distill_loss(
    draft_cfg: ModelConfig,
    trainable,
    frozen,
    wp,
    teacher_cfg: ModelConfig,
    teacher_params,
    teacher_lora,
    tokens,
    l_feat: float = 0.1,
    l_kd: float = 1.0,
    temp: float = 1.0,
):
    """Algorithm 1 multi-objective loss: L = l1*L_feat + l2*L_KD.

    L_feat aligns W_p @ h_d with the teacher hidden h_t (paper eq. 5);
    L_KD is temperature-softened KL(teacher || student) (paper eq. 6).
    """
    params = {**frozen, **trainable}
    z_d, h_d = model.forward_train(draft_cfg, params, None, tokens)
    z_t, h_t = model.forward_train(teacher_cfg, teacher_params, teacher_lora, tokens)
    z_t = jax.lax.stop_gradient(z_t)
    h_t = jax.lax.stop_gradient(h_t)
    mask = (tokens != corpus.PAD).astype(jnp.float32)[..., None]

    proj = h_d @ wp
    feat = jnp.sum(((proj - h_t) ** 2) * mask) / jnp.maximum(mask.sum() * h_t.shape[-1], 1.0)

    pt = jax.nn.softmax(z_t / temp, axis=-1)
    logq = jax.nn.log_softmax(z_d / temp, axis=-1)
    logp = jax.nn.log_softmax(z_t / temp, axis=-1)
    kl = (pt * (logp - logq)).sum(-1, keepdims=True)
    kd = (temp**2) * jnp.sum(kl * mask) / jnp.maximum(mask.sum(), 1.0)
    return l_feat * feat + l_kd * kd


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------

BATCH = 24
SEQLEN = 64


def _batches(seed: int, steps: int, batch=BATCH, seqlen=SEQLEN, mix=None, domain=None, style=corpus.BASE):
    rng = corpus.SplitMix64(seed)
    for _ in range(steps):
        yield jnp.asarray(corpus.training_batch(rng, batch, seqlen, mix=mix, domain=domain, style=style))


def train_base(cfg: ModelConfig, seed: int = 1, steps: int = 350, lr: float = 3e-3, log=print):
    """Pretrain a base target on the general mixture."""
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    lora_zero = model.init_lora(cfg, jax.random.PRNGKey(seed), zero=True)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, tokens, lr_now):
        loss, grads = jax.value_and_grad(lambda p: ce_loss(cfg, p, lora_zero, tokens))(params)
        params, opt = adam_update(grads, opt, params, lr_now)
        return params, opt, loss

    t0 = time.time()
    for i, tokens in enumerate(_batches(seed * 7919 + 13, steps)):
        params, opt, loss = step(params, opt, tokens, cosine_lr(i, steps, lr))
        if i % 50 == 0 or i == steps - 1:
            log(f"[base {cfg.name}] step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    return params


def train_lora(cfg: ModelConfig, base_params, domain: str, seed: int = 2, steps: int = 200, lr: float = 5e-3, log=print):
    """PEFT evolution of the cloud target: adapters only, one domain."""
    lora = model.init_lora(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(lora)

    @jax.jit
    def step(lora, opt, tokens, lr_now):
        loss, grads = jax.value_and_grad(lambda l: ce_loss(cfg, base_params, l, tokens))(lora)
        lora, opt = adam_update(grads, opt, lora, lr_now)
        return lora, opt, loss

    for i, tokens in enumerate(_batches(seed * 104729 + 29, steps, domain=domain, style=corpus.EVOLVED)):
        lora, opt, loss = step(lora, opt, tokens, cosine_lr(i, steps, lr))
        if i % 50 == 0 or i == steps - 1:
            log(f"[lora {cfg.name}/{domain}] step {i:4d} loss {float(loss):.4f}")
    return lora


def train_full(cfg: ModelConfig, base_params, domain: str, seed: int = 3, steps: int = 300, lr: float = 2e-3, log=print):
    """Full-parameter fine-tuning (Table II 'Code (Full)'): every weight
    moves, breaking the anchor invariant on purpose."""
    params = base_params
    lora_zero = model.init_lora(cfg, jax.random.PRNGKey(seed), zero=True)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, tokens, lr_now):
        loss, grads = jax.value_and_grad(lambda p: ce_loss(cfg, p, lora_zero, tokens))(params)
        params, opt = adam_update(grads, opt, params, lr_now)
        return params, opt, loss

    for i, tokens in enumerate(_batches(seed * 15485863 + 31, steps, domain=domain, style=corpus.FULL_SHIFT)):
        params, opt, loss = step(params, opt, tokens, cosine_lr(i, steps, lr))
        if i % 50 == 0 or i == steps - 1:
            log(f"[full {cfg.name}/{domain}] step {i:4d} loss {float(loss):.4f}")
    return params


def distill_draft(
    draft_cfg: ModelConfig,
    teacher_cfg: ModelConfig,
    teacher_params,
    teacher_lora=None,
    seed: int = 4,
    steps: int = 500,
    lr: float = 2e-3,
    mix=None,
    domain: str | None = None,
    style: str = corpus.BASE,
    log=print,
):
    """Algorithm 1: one-time offline distillation of the draft head.

    FlexSpec's own draft is distilled ONCE against the *base* teacher on
    the general mixture (domain=None). The EAGLE-2/Medusa "(Ideal Synced)"
    stand-ins re-run this per evolved target with domain/evolved set —
    that re-distillation is exactly the sync cost FlexSpec avoids.

    Returns (params, wp): full draft params (frozen transplant + trained
    H_small) and the feature-regression projection W_p (training-only)."""
    params = model.init_params(draft_cfg, jax.random.PRNGKey(seed))
    params = model.transplant_anchor(teacher_params, teacher_cfg, params)
    trainable = {k: v for k, v in params.items() if not model.is_frozen_draft_param(k)}
    frozen = {k: v for k, v in params.items() if model.is_frozen_draft_param(k)}
    wp = jnp.eye(draft_cfg.d_model, dtype=jnp.float32)
    state = {"p": trainable, "wp": wp}
    opt = adam_init(state)

    @jax.jit
    def step(state, opt, tokens, lr_now):
        def loss_fn(s):
            return distill_loss(
                draft_cfg, s["p"], frozen, s["wp"],
                teacher_cfg, teacher_params, teacher_lora, tokens,
            )

        loss, grads = jax.value_and_grad(loss_fn)(state)
        state, opt = adam_update(grads, opt, state, lr_now)
        return state, opt, loss

    for i, tokens in enumerate(
        _batches(seed * 179424673 + 37, steps, mix=mix or corpus.DISTILL_MIX, domain=domain, style=style)
    ):
        state, opt, loss = step(state, opt, tokens, cosine_lr(i, steps, lr))
        if i % 50 == 0 or i == steps - 1:
            log(f"[distill {draft_cfg.name}<-{teacher_cfg.name}] step {i:4d} loss {float(loss):.4f}")
    return {**frozen, **state["p"]}, state["wp"]


def train_generic(cfg: ModelConfig, seed: int = 5, steps: int = 150, lr: float = 3e-3, log=print):
    """Std-SD baseline draft: brief plain-CE pretraining with no alignment
    to any target — the paper's off-the-shelf generic 7B draft."""
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, tokens, lr_now):
        loss, grads = jax.value_and_grad(lambda p: ce_loss(cfg, p, None, tokens))(params)
        params, opt = adam_update(grads, opt, params, lr_now)
        return params, opt, loss

    # an off-the-shelf small LM: decent on general text, shallow on the
    # task domains (it was never trained on the provider's corpora) —
    # foreign data distribution + thin domain exposure.
    gen_mix = [("general", 0.76)] + [(d, 0.04) for d, _ in corpus.BASE_MIX[1:]]
    for i, tokens in enumerate(_batches(seed * 32452843 + 41, steps, mix=gen_mix, style=corpus.FOREIGN)):
        params, opt, loss = step(params, opt, tokens, cosine_lr(i, steps, lr))
        if i % 50 == 0 or i == steps - 1:
            log(f"[generic {cfg.name}] step {i:4d} loss {float(loss):.4f}")
    return params


# ---------------------------------------------------------------------------
# Acceptance-rate evaluation (used for calibration + tests, mirrors the
# rust round loop at the distribution level)
# ---------------------------------------------------------------------------


def acceptance_rate(
    target_cfg: ModelConfig,
    target_params,
    target_lora,
    draft_cfg: ModelConfig,
    draft_params,
    domain: str,
    n_prompts: int = 8,
    gen_len: int = 48,
    seed: int = 9,
) -> float:
    """Positionwise greedy agreement of draft vs target along the target's
    own greedy trajectory — the steady-state token acceptance rate of
    greedy speculative decoding."""
    rng = corpus.SplitMix64(seed)
    dom = corpus.DOMAINS[domain]
    buf_len = min(target_cfg.max_seq, 192)  # fixed shape => one jit compile

    @jax.jit
    def both(tokens, last):
        tl, _ = model.forward_train(target_cfg, target_params, target_lora, tokens)
        dl, _ = model.forward_train(draft_cfg, draft_params, None, tokens)
        return jnp.argmax(tl[0, last], -1), jnp.argmax(dl[0, last], -1)

    agree = total = 0
    for _ in range(n_prompts):
        prompt = corpus.gen_prompt(dom, rng)[: SEQLEN // 2]
        buf = np.zeros((1, buf_len), np.int32)
        n = len(prompt)
        buf[0, :n] = prompt
        for _ in range(gen_len):
            nxt, dnx = both(jnp.asarray(buf), n - 1)
            nxt, dnx = int(nxt), int(dnx)
            agree += int(nxt == dnx)
            total += 1
            if nxt == corpus.EOS or n >= buf_len:
                break
            buf[0, n] = nxt
            n += 1
    return agree / max(total, 1)
