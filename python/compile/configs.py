"""Model architecture configs shared by training, AOT lowering and export.

The reproduction scales the paper's model zoo down to tiny transformers
(DESIGN.md substitution log): acceptance-rate dynamics — the quantity that
drives every table — come from real draft/target interaction; absolute
FLOPs are supplied by hardware profiles on the rust side.

Parameter naming contract (also the export order, see export.py):
params is a flat {name: array} dict; jax flattens dicts in sorted-key
order, and the rust runtime feeds literals in the same sorted order read
from the weight-bundle manifest. Changing a name here is a wire-format
change.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one model in the zoo (dense, MoE, or draft)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    n_experts: int = 0  # 0 => dense SwiGLU MLP; >0 => MoE with top_k routing
    top_k: int = 2
    lora_rank: int = 0  # 0 => no LoRA runtime args lowered into the HLO
    draft_head: bool = False  # FlexSpec draft: anchor block + H_small MLP head

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def lora_layers(self) -> int:
        """LoRA adapters are injected into layers 1..L-1 only: the paper's
        backbone-freezing constraint keeps the anchor block (layer L) and
        LM head invariant so the frozen edge draft stays feature-aligned."""
        return max(self.n_layers - 1, 0) if self.lora_rank else 0

    def param_spec(self) -> list[tuple[str, tuple[int, ...]]]:
        """Ordered (sorted-name) list of parameter names and shapes."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        spec: dict[str, tuple[int, ...]] = {"embed": (v, d), "ln_f": (d,), "lm_head": (d, v)}
        for i in range(self.n_layers):
            p = f"L{i}"
            spec[f"{p}.ln1"] = (d,)
            spec[f"{p}.ln2"] = (d,)
            for w in ("wq", "wk", "wv", "wo"):
                spec[f"{p}.{w}"] = (d, d)
            if self.n_experts:
                spec[f"{p}.gate"] = (d, self.n_experts)
                for e in range(self.n_experts):
                    spec[f"{p}.E{e}.wg"] = (d, ff)
                    spec[f"{p}.E{e}.wu"] = (d, ff)
                    spec[f"{p}.E{e}.wd"] = (ff, d)
            else:
                spec[f"{p}.wg"] = (d, ff)
                spec[f"{p}.wu"] = (d, ff)
                spec[f"{p}.wd"] = (ff, d)
        if self.draft_head:
            spec["head.w1"] = (d, 2 * d)
            spec["head.b1"] = (2 * d,)
            spec["head.w2"] = (2 * d, d)
            spec["head.b2"] = (d,)
        return sorted(spec.items())

    def lora_spec(self) -> list[tuple[str, tuple[int, ...]]]:
        """Ordered LoRA adapter names/shapes (empty when lora_rank == 0)."""
        r, d = self.lora_rank, self.d_model
        spec: dict[str, tuple[int, ...]] = {}
        for i in range(self.lora_layers):
            for w in ("q", "v", "o"):
                spec[f"L{i}.A{w}"] = (d, r)
                spec[f"L{i}.B{w}"] = (r, d)
        return sorted(spec.items())

    def kv_shape(self) -> tuple[int, ...]:
        return (self.n_layers, 2, self.n_heads, self.max_seq, self.d_head)

    def n_params(self) -> int:
        return sum(int_prod(s) for _, s in self.param_spec())


def int_prod(shape: tuple[int, ...]) -> int:
    out = 1
    for s in shape:
        out *= s
    return out


# Speculation block: K_max draft tokens + 1 committed token per round.
K_MAX = 8
BLOCK = K_MAX + 1
PREFILL_CHUNK = 64

# ---------------------------------------------------------------------------
# The model zoo. Names are wire-format (manifest keys, rust-side lookups).
# ---------------------------------------------------------------------------

LLAMA2T = ModelConfig(
    name="llama2t", vocab=512, d_model=128, n_layers=4, n_heads=4,
    d_ff=256, max_seq=256, lora_rank=8,
)
# "Llama-3-like": larger vocabulary, wider MLP — distinct training data.
LLAMA3T = ModelConfig(
    name="llama3t", vocab=1024, d_model=128, n_layers=4, n_heads=4,
    d_ff=384, max_seq=256, lora_rank=8,
)
# "Mixtral-like": sparse MoE MLPs, 4 experts, top-2 routing.
MIXTRALT = ModelConfig(
    name="mixtralt", vocab=512, d_model=128, n_layers=4, n_heads=4,
    d_ff=192, max_seq=256, n_experts=4, top_k=2, lora_rank=8,
)


def flex_draft_config(target: ModelConfig) -> ModelConfig:
    """FlexSpec edge draft for a target family: one transformer block (the
    frozen anchor, copied from the target's last layer) + trainable H_small
    (2-layer MLP) + the target's frozen embedding/LM head (paper eq. 4)."""
    return ModelConfig(
        name=f"draft_flex_{target.name}", vocab=target.vocab,
        d_model=target.d_model, n_layers=1, n_heads=target.n_heads,
        d_ff=target.d_ff, max_seq=target.max_seq,
        n_experts=target.n_experts, top_k=target.top_k, draft_head=True,
    )


def generic_draft_config(target: ModelConfig) -> ModelConfig:
    """Std-SD baseline draft: an independently trained small LM (the paper's
    generic Llama-2-7B stand-in) with no anchor sharing."""
    return ModelConfig(
        name=f"draft_generic_{target.name}", vocab=target.vocab,
        d_model=96, n_layers=1, n_heads=target.n_heads,
        d_ff=192, max_seq=target.max_seq,
    )


TARGETS = {c.name: c for c in (LLAMA2T, LLAMA3T, MIXTRALT)}


def all_archs() -> dict[str, ModelConfig]:
    """Every architecture that needs its own HLO entry points."""
    archs: dict[str, ModelConfig] = dict(TARGETS)
    for t in TARGETS.values():
        for c in (flex_draft_config(t), generic_draft_config(t)):
            archs[c.name] = c
    return archs
