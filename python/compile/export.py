"""Weight-bundle export: the FSWB1 flat binary format + manifest.json.

No serde/npz on the rust side, so the bundle format is deliberately
trivial (little-endian throughout):

    magic   8 bytes  b"FSWB1\\0\\0\\0"
    u32     n_tensors
    repeat n_tensors times (tensors sorted by name):
      u32   name_len, then name bytes (utf-8)
      u32   dtype     (0 = f32, 1 = i32)
      u32   ndim, then u32 dims[ndim]
      u64   byte_len, then raw data

rust/src/runtime/weights.rs is the matching reader; both sides pin the
same golden file in tests.
"""

from __future__ import annotations

import json
import os
import struct

import numpy as np

MAGIC = b"FSWB1\x00\x00\x00"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_bundle(path: str, tensors: dict) -> None:
    """Write {name: array} to an FSWB1 file (sorted by name)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(np.asarray(tensors[name]))
            if arr.dtype not in DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", DTYPES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_bundle(path: str) -> dict:
    """Read an FSWB1 file back into {name: np.ndarray} (round-trip test)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode("utf-8")
            (dt,) = struct.unpack("<I", f.read(4))
            (nd,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            (nb,) = struct.unpack("<Q", f.read(8))
            dtype = {0: np.float32, 1: np.int32}[dt]
            out[name] = np.frombuffer(f.read(nb), dtype=dtype).reshape(shape)
    return out


def write_manifest(path: str, manifest: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
