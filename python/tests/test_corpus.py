"""Corpus generator invariants + golden sequences pinned against the rust
implementation (rust/src/workload/corpus.rs pins the same values)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import corpus


def test_splitmix64_golden():
    """Golden values for seed=42 — MUST match rust util::rng tests."""
    r = corpus.SplitMix64(42)
    got = [r.next_u64() for _ in range(4)]
    assert got == [
        13679457532755275413,
        2949826092126892291,
        5139283748462763858,
        6349198060258255764,
    ], got


def test_splitmix64_f64_range():
    r = corpus.SplitMix64(7)
    xs = [r.next_f64() for _ in range(1000)]
    assert all(0.0 <= x < 1.0 for x in xs)
    assert 0.4 < float(np.mean(xs)) < 0.6


def test_gen_tokens_golden():
    """Golden grammar sequence for (seed=42, gsm8k) — pinned in rust too."""
    r = corpus.SplitMix64(42)
    toks = corpus.gen_tokens(corpus.DOMAINS["gsm8k"], r, 12)
    assert toks == [85, 86, 93, 78, 101, 100, 127, 124, 103, 84, 79, 108], toks


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), name=st.sampled_from(sorted(corpus.DOMAINS)))
def test_tokens_stay_in_vocab(seed, name):
    dom = corpus.DOMAINS[name]
    r = corpus.SplitMix64(seed)
    for t in corpus.gen_tokens(dom, r, 64):
        in_domain = dom.offset <= t < dom.offset + dom.size
        in_common = corpus.COMMON_OFFSET <= t < corpus.COMMON_OFFSET + corpus.COMMON_SIZE
        assert in_domain or in_common


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), name=st.sampled_from(sorted(corpus.DOMAINS)))
def test_document_framing(seed, name):
    dom = corpus.DOMAINS[name]
    r = corpus.SplitMix64(seed)
    doc = corpus.gen_document(dom, r, min_len=12, max_len=64)
    assert doc[0] == corpus.BOS and doc[-1] == corpus.EOS
    assert len(doc) <= 64


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), name=st.sampled_from(sorted(corpus.DOMAINS)))
def test_prompt_length_bounds(seed, name):
    dom = corpus.DOMAINS[name]
    r = corpus.SplitMix64(seed)
    p = corpus.gen_prompt(dom, r)
    assert p[0] == corpus.BOS
    assert dom.prompt_len[0] <= len(p) - 1 < dom.prompt_len[1]


def test_evolved_rule_differs_only_on_subset():
    """The evolution rewrites exactly the cur % evolve_mod == mod-1
    transitions (the Table II distribution-shift knob)."""
    dom = corpus.DOMAINS["gsm8k"]
    changed = 0
    for cur in range(dom.offset, dom.offset + dom.size):
        a = corpus.rule_next(cur, dom, corpus.BASE)
        b = corpus.rule_next(cur, dom, corpus.EVOLVED)
        if corpus.subset_hash(cur, dom.offset) % dom.evolve_mod == dom.evolve_mod - 1:
            changed += a != b
        else:
            assert a == b, cur
    assert changed > 0


def test_foreign_rule_semantics():
    # general: only the mod-4 sliver differs (web text is universal)
    dom = corpus.DOMAINS["general"]
    diff = 0
    for cur in range(dom.offset, dom.offset + dom.size):
        a = corpus.rule_next(cur, dom, corpus.BASE)
        f = corpus.rule_next(cur, dom, corpus.FOREIGN)
        if corpus.subset_hash(cur, 77) % 4 != 0:
            assert a == f, cur
        else:
            diff += a != f
    assert diff > 0
    # task domains: the foreign provider differs on the odd transitions
    g = corpus.DOMAINS["gsm8k"]
    for c in range(g.offset, g.offset + g.size):
        a = corpus.rule_next(c, g, corpus.BASE)
        f = corpus.rule_next(c, g, corpus.FOREIGN)
        if corpus.subset_hash(c, 77) % 2 == 0:
            assert a == f, c


def test_training_batch_shape_and_padding():
    r = corpus.SplitMix64(3)
    batch = corpus.training_batch(r, 4, 64, domain="wmt14")
    assert batch.shape == (4, 64)
    assert batch.dtype == np.int32
    assert (batch >= 0).all() and (batch < 512).all()


def test_base_mix_weights_sum_to_one():
    assert abs(sum(w for _, w in corpus.BASE_MIX) - 1.0) < 1e-9


def test_domain_ranges_disjoint_from_common():
    for d in corpus.DOMAINS.values():
        assert d.offset + d.size <= corpus.COMMON_OFFSET
        assert d.offset >= 16
