"""L2 correctness: transformer forward paths, KV-cache semantics, LoRA,
MoE, and the anchor transplant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

jax.config.update("jax_platform_name", "cpu")

CFG = configs.LLAMA2T
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, KEY)


@pytest.fixture(scope="module")
def lora():
    return model.init_lora(CFG, jax.random.PRNGKey(7))


def _block(params, lora, toks, pos, valid, kv, use_kernels=False, cfg=CFG):
    return model.forward_block(
        cfg, params, lora, toks,
        jnp.array([pos], jnp.int32), jnp.array([valid], jnp.int32), kv,
        use_kernels=use_kernels,
    )


def test_param_spec_matches_init(params):
    spec = dict(CFG.param_spec())
    assert set(spec) == set(params)
    for name, shape in spec.items():
        assert params[name].shape == shape, name


def test_kernel_and_ref_paths_agree(params, lora):
    toks = jnp.arange(9, dtype=jnp.int32) + 3
    kv = model.empty_kv(CFG)
    a, kva = _block(params, lora, toks, 0, 9, kv, use_kernels=False)
    b, kvb = _block(params, lora, toks, 0, 9, kv, use_kernels=True)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(kva, kvb, rtol=2e-4, atol=2e-4)


def test_train_and_block_paths_agree(params, lora):
    toks = jnp.arange(9, dtype=jnp.int32) + 3
    lb, _ = _block(params, lora, toks, 0, 9, model.empty_kv(CFG))
    lt, _ = model.forward_train(CFG, params, lora, toks[None])
    np.testing.assert_allclose(lb, lt[0], rtol=2e-4, atol=2e-4)


def test_incremental_equals_full(params, lora):
    """Chunked decoding through the KV cache == one-shot forward."""
    toks = (jnp.arange(12, dtype=jnp.int32) * 13 + 5) % CFG.vocab
    full, _ = _block(
        params, lora, jnp.pad(toks, (0, 0)), 0, 12,
        model.empty_kv(CFG),
    ) if False else model.forward_train(CFG, params, lora, toks[None])
    kv = model.empty_kv(CFG)
    outs = []
    pos = 0
    for chunk in (toks[:5], toks[5:8], toks[8:12]):
        n = chunk.shape[0]
        padded = jnp.pad(chunk, (0, 9 - n))
        logits, kv = _block(params, lora, padded, pos, n, kv)
        outs.append(logits[:n])
        pos += n
    got = jnp.concatenate(outs)
    np.testing.assert_allclose(got, full[0], rtol=2e-4, atol=2e-4)


def test_padding_rows_do_not_corrupt_state(params, lora):
    """Rounds with padded blocks followed by overwrites must equal the
    clean trajectory — the KV rollback safety argument from DESIGN.md."""
    toks = (jnp.arange(10, dtype=jnp.int32) * 7 + 11) % CFG.vocab
    # clean: 10 tokens in two blocks of 5
    kv = model.empty_kv(CFG)
    l1, kv = _block(params, lora, jnp.pad(toks[:5], (0, 4)), 0, 5, kv)
    clean, kv_clean = _block(params, lora, jnp.pad(toks[5:], (0, 4)), 5, 5, kv)
    # dirty: first block claims valid=5 but carries 4 garbage rows, then a
    # "rollback" writes the real tokens 5.. over the garbage.
    kv = model.empty_kv(CFG)
    garbage = jnp.concatenate([toks[:5], jnp.full((4,), 99, jnp.int32)])
    _, kv = _block(params, lora, garbage, 0, 5, kv)
    dirty, _ = _block(params, lora, jnp.pad(toks[5:], (0, 4)), 5, 5, kv)
    np.testing.assert_allclose(clean, dirty, rtol=1e-5, atol=1e-5)


def test_lora_changes_output_and_zero_lora_does_not(params, lora):
    toks = jnp.arange(9, dtype=jnp.int32)
    zero = model.init_lora(CFG, KEY, zero=True)
    base, _ = _block(params, None, toks, 0, 9, model.empty_kv(CFG))
    with_zero, _ = _block(params, zero, toks, 0, 9, model.empty_kv(CFG))
    np.testing.assert_allclose(base, with_zero, atol=1e-6)
    # make a genuinely nonzero adapter (init has B=0 so delta is 0)
    hot = {k: (v if k.split(".")[-1].startswith("A") else jnp.ones_like(v) * 0.1)
           for k, v in lora.items()}
    with_hot, _ = _block(params, hot, toks, 0, 9, model.empty_kv(CFG))
    assert float(jnp.abs(with_hot - base).max()) > 1e-3


def test_lora_never_touches_anchor_layer(params):
    """Backbone-freezing constraint: no adapter exists for layer L-1."""
    last = CFG.n_layers - 1
    for name, _ in CFG.lora_spec():
        assert not name.startswith(f"L{last}."), name


def test_moe_forward_shapes_and_gating():
    cfg = configs.MIXTRALT
    p = model.init_params(cfg, KEY)
    toks = jnp.arange(9, dtype=jnp.int32)
    logits, kv = model.forward_block(
        cfg, p, model.init_lora(cfg, KEY, zero=True), toks,
        jnp.array([0], jnp.int32), jnp.array([9], jnp.int32),
        model.empty_kv(cfg), use_kernels=False,
    )
    assert logits.shape == (9, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


def test_anchor_transplant_copies_frozen_pieces():
    tp = model.init_params(CFG, KEY)
    dc = configs.flex_draft_config(CFG)
    dp = model.init_params(dc, jax.random.PRNGKey(9))
    out = model.transplant_anchor(tp, CFG, dp)
    last = CFG.n_layers - 1
    np.testing.assert_array_equal(out["embed"], tp["embed"])
    np.testing.assert_array_equal(out["L0.wq"], tp[f"L{last}.wq"])
    np.testing.assert_array_equal(out["L0.wg"], tp[f"L{last}.wg"])
    # H_small stays from the draft init (trainable)
    np.testing.assert_array_equal(out["head.w1"], dp["head.w1"])
    # frozen set is exactly embed + anchor block
    frozen = {k for k in out if model.is_frozen_draft_param(k)}
    assert frozen == {k for k in out if k == "embed" or k.startswith("L0.")}


def test_rope_orthogonality():
    """RoPE preserves norms and relative-position inner products."""
    x = jax.random.normal(KEY, (2, 8, 32))
    r0 = model.rope(x, jnp.arange(8, dtype=jnp.int32))
    np.testing.assert_allclose(
        jnp.linalg.norm(r0, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )
    # shift both positions by the same offset: inner products unchanged
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 32))
    def ip(off):
        qr = model.rope(q, jnp.array([3 + off], jnp.int32))
        kr = model.rope(k, jnp.array([7 + off], jnp.int32))
        return float((qr * kr).sum())
    assert abs(ip(0) - ip(11)) < 1e-4


def test_empty_kv_shape():
    assert model.empty_kv(CFG).shape == CFG.kv_shape()
    assert CFG.kv_shape() == (4, 2, 4, 256, 32)


def test_moe_gating_matches_lax_topk():
    """The k-step max-reduction gate threshold (used because HLO `topk`
    text is unparseable by xla_extension 0.5.1) must select exactly the
    same expert set as jax.lax.top_k."""
    cfg = configs.MIXTRALT
    key = jax.random.PRNGKey(3)
    gate = jax.random.normal(key, (32, cfg.n_experts))
    top_vals, _ = jax.lax.top_k(gate, cfg.top_k)
    want = gate >= top_vals[..., -1:]
    # reproduce the model's loop
    remaining = gate
    thresh = None
    for _ in range(cfg.top_k):
        cur = jnp.max(remaining, axis=-1, keepdims=True)
        thresh = cur
        remaining = jnp.where(remaining >= cur, -1e30, remaining)
    got = gate >= thresh
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
