"""Training-pipeline smoke tests (short budgets — the real training runs
in `make artifacts`)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import configs, corpus, model, train

TINY = configs.ModelConfig(
    name="tiny_test", vocab=512, d_model=32, n_layers=2, n_heads=2,
    d_ff=64, max_seq=64, lora_rank=4,
)


def test_adam_reduces_ce_loss():
    params = model.init_params(TINY, jax.random.PRNGKey(0))
    opt = train.adam_init(params)
    lora = model.init_lora(TINY, jax.random.PRNGKey(0), zero=True)
    rng = corpus.SplitMix64(1)
    tokens = jnp.asarray(corpus.training_batch(rng, 8, 32, domain="general"))
    first = last = None
    for i in range(30):
        loss, grads = jax.value_and_grad(lambda p: train.ce_loss(TINY, p, lora, tokens))(params)
        params, opt = train.adam_update(grads, opt, params, 5e-3)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first * 0.8, (first, last)


def test_cosine_lr_schedule_shape():
    assert float(train.cosine_lr(0, 100, 1.0, warmup=10)) < 0.2
    peak = float(train.cosine_lr(10, 100, 1.0, warmup=10))
    assert peak > 0.9
    assert float(train.cosine_lr(99, 100, 1.0, warmup=10)) < 0.05


def test_distill_loss_decreases_and_freezes_anchor():
    teacher = model.init_params(TINY, jax.random.PRNGKey(1))
    dcfg = configs.flex_draft_config(TINY)
    params = model.init_params(dcfg, jax.random.PRNGKey(2))
    params = model.transplant_anchor(teacher, TINY, params)
    frozen = {k: v for k, v in params.items() if model.is_frozen_draft_param(k)}
    trainable = {k: v for k, v in params.items() if not model.is_frozen_draft_param(k)}
    wp = jnp.eye(dcfg.d_model)
    state = {"p": trainable, "wp": wp}
    opt = train.adam_init(state)
    rng = corpus.SplitMix64(3)
    tokens = jnp.asarray(corpus.training_batch(rng, 8, 32, domain="general"))

    def loss_fn(s):
        return train.distill_loss(dcfg, s["p"], frozen, s["wp"], TINY, teacher, None, tokens)

    first = last = None
    for _ in range(25):
        loss, grads = jax.value_and_grad(loss_fn)(state)
        state, opt = train.adam_update(grads, opt, state, 3e-3)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first, (first, last)
    # frozen pieces untouched by construction (they are not in the state)
    np.testing.assert_array_equal(frozen["embed"], teacher["embed"])


def test_lora_training_only_touches_adapters():
    base = model.init_params(TINY, jax.random.PRNGKey(4))
    base_copy = jax.tree.map(lambda x: x.copy(), base)
    lora = train.train_lora(TINY, base, "gsm8k", steps=30, log=lambda *a: None)
    for k in base:
        np.testing.assert_array_equal(base[k], base_copy[k])
    assert set(lora) == {n for n, _ in TINY.lora_spec()}
    # B matrices should have moved off zero after a few steps
    assert any(float(jnp.abs(v).max()) > 1e-9 for k, v in lora.items() if ".B" in k)


def test_acceptance_rate_bounds():
    cfg = TINY
    p = model.init_params(cfg, jax.random.PRNGKey(5))
    zero = model.init_lora(cfg, jax.random.PRNGKey(5), zero=True)
    # model vs itself must agree ~perfectly
    v = train.acceptance_rate(cfg, p, zero, cfg, p, "general", n_prompts=2, gen_len=8)
    assert v > 0.99
