"""AOT lowering smoke tests: HLO text is produced, parseable-looking and
carries the expected parameter arity (argument-order contract with rust)."""

import re

from compile import aot, configs


def entry_arity(text: str) -> int:
    """Number of ENTRY parameters, from the entry_computation_layout."""
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", text, re.S)
    assert m, "no entry layout in HLO text"
    body = m.group(1)
    return len(re.findall(r"[fsu]\d+\[", body))


def tiny():
    return configs.ModelConfig(
        name="tiny_aot", vocab=64, d_model=16, n_layers=2, n_heads=2,
        d_ff=32, max_seq=128, lora_rank=2,
    )


def test_lower_arch_produces_hlo_text():
    cfg = tiny()
    text = aot.lower_arch(cfg, 4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # parameter count: params + lora + tokens/pos/valid/kv
    n_expected = len(cfg.param_spec()) + len(cfg.lora_spec()) + 4
    assert entry_arity(text) == n_expected


def test_lower_draft_arch_has_no_lora_params():
    cfg = configs.flex_draft_config(tiny())
    text = aot.lower_arch(cfg, 4)
    n_expected = len(cfg.param_spec()) + 4
    assert entry_arity(text) == n_expected


def test_lower_verify_kernel():
    text = aot.lower_verify(64)
    assert "HloModule" in text
    assert entry_arity(text) == 3  # logits, draft, n


def test_block_and_prefill_differ_only_in_token_arity():
    cfg = tiny()
    b = aot.lower_arch(cfg, configs.BLOCK)
    p = aot.lower_arch(cfg, configs.PREFILL_CHUNK)
    assert f"s32[{configs.BLOCK}]" in b
    assert f"s32[{configs.PREFILL_CHUNK}]" in p
