"""FSWB1 bundle round-trip + manifest schema sanity."""

import numpy as np

from compile import configs, export


def test_bundle_roundtrip(tmp_path):
    tensors = {
        "b.mat": np.arange(12, dtype=np.float32).reshape(3, 4),
        "a.vec": np.array([1, 2, 3], dtype=np.int32),
        "scalarish": np.array([3.5], dtype=np.float32),
    }
    p = str(tmp_path / "w" / "t.bin")
    export.write_bundle(p, tensors)
    back = export.read_bundle(p)
    assert sorted(back) == sorted(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_bundle_golden_header(tmp_path):
    """Pin the first bytes of the format — rust reader pins the same."""
    p = str(tmp_path / "g.bin")
    export.write_bundle(p, {"x": np.array([1.0, 2.0], dtype=np.float32)})
    raw = open(p, "rb").read()
    assert raw[:8] == b"FSWB1\x00\x00\x00"
    assert raw[8:12] == (1).to_bytes(4, "little")
    assert raw[12:16] == (1).to_bytes(4, "little")  # name len
    assert raw[16:17] == b"x"


def test_param_spec_sorted_and_counts():
    for name, cfg in configs.all_archs().items():
        spec = cfg.param_spec()
        names = [n for n, _ in spec]
        assert names == sorted(names), name
        assert cfg.n_params() == sum(configs.int_prod(s) for _, s in spec)
        lora = cfg.lora_spec()
        lnames = [n for n, _ in lora]
        assert lnames == sorted(lnames)
        if cfg.lora_rank == 0:
            assert lora == []


def test_kv_shape_consistency():
    for cfg in configs.all_archs().values():
        l, two, h, s, d = cfg.kv_shape()
        assert (l, two, h, s, d) == (cfg.n_layers, 2, cfg.n_heads, cfg.max_seq, cfg.d_head)
