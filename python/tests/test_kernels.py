"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/positions; every property asserts
allclose (or exact equality for integer outputs) against ref.py. These
tests are the core correctness signal for the AOT artifacts: the same
kernel code is lowered into every .hlo.txt the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, fused_mlp, ref, verify

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_heads=st.sampled_from([1, 2, 4]),
    q_len=st.sampled_from([1, 5, 9, 64]),
    kv_len=st.sampled_from([64, 128, 256]),
    d_head=st.sampled_from([16, 32]),
    block_k=st.sampled_from([32, 64, 128]),
)
def test_attention_matches_ref(seed, n_heads, q_len, kv_len, d_head, block_k):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n_heads, q_len, d_head)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n_heads, kv_len, d_head)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_heads, kv_len, d_head)), jnp.float32)
    max_pos = kv_len - q_len
    pos = jnp.asarray(int(rng.integers(0, max_pos + 1)), jnp.int32)
    valid = jnp.asarray(int(rng.integers(1, q_len + 1)), jnp.int32)
    got = attention.attention(q, k, v, pos, pos + valid, block_k=min(block_k, kv_len))
    want = ref.attention_ref(q, k, v, pos, pos + valid)
    # padded rows (>= valid) are unspecified: compare valid rows only
    nv = int(valid)
    np.testing.assert_allclose(got[:, :nv], want[:, :nv], rtol=2e-5, atol=2e-5)


def test_attention_first_token():
    """pos=0, one query, one valid key — the degenerate decode start."""
    q = rand(0, (2, 1, 16))
    k = rand(1, (2, 64, 16))
    v = rand(2, (2, 64, 16))
    got = attention.attention(q, k, v, jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32))
    want = ref.attention_ref(q, k, v, jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attention_ignores_stale_tail():
    """Garbage beyond kv_valid_len must not leak into the output."""
    q = rand(3, (1, 4, 16))
    k = rand(4, (1, 64, 16))
    v = rand(5, (1, 64, 16))
    pos, valid = jnp.asarray(8, jnp.int32), jnp.asarray(12, jnp.int32)
    base = attention.attention(q, k, v, pos, valid)
    k2 = k.at[:, 12:].set(1e6)
    v2 = v.at[:, 12:].set(-1e6)
    poisoned = attention.attention(q, k2, v2, pos, valid)
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


def test_attention_softmax_rowsum():
    """Attention output is a convex combination of valid values."""
    q = rand(6, (2, 3, 16))
    k = rand(7, (2, 64, 16))
    v = jnp.ones((2, 64, 16), jnp.float32)
    got = attention.attention(q, k, v, jnp.asarray(5, jnp.int32), jnp.asarray(8, jnp.int32))
    np.testing.assert_allclose(got, jnp.ones_like(got), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# verify
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    vocab=st.sampled_from([64, 512, 1024]),
    n_draft=st.integers(0, 8),
    forced=st.integers(0, 8),
)
def test_verify_matches_ref(seed, vocab, n_draft, forced):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(9, vocab)), jnp.float32)
    draft = jnp.asarray(rng.integers(0, vocab, size=(8,)), jnp.int32)
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    # force an accepted prefix of `forced` tokens
    draft = draft.at[: min(forced, 8)].set(greedy[: min(forced, 8)])
    n = jnp.asarray(n_draft, jnp.int32)
    tau, corr, g = verify.verify(logits, draft, n)
    t_ref, c_ref = ref.verify_ref(logits, draft, n)
    assert int(tau[0]) == int(t_ref)
    assert int(corr[0]) == int(c_ref)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(greedy))


def test_verify_tau_bounds():
    """tau <= n_draft always; tau == n_draft when every proposal matches."""
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(9, 128)), jnp.float32)
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    tau, corr, _ = verify.verify(logits, greedy[:8], jnp.asarray(8, jnp.int32))
    assert int(tau[0]) == 8
    assert int(corr[0]) == int(greedy[8])
    tau0, corr0, _ = verify.verify(logits, greedy[:8], jnp.asarray(0, jnp.int32))
    assert int(tau0[0]) == 0
    assert int(corr0[0]) == int(greedy[0])


def test_verify_reject_at_first_mismatch():
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(9, 128)), jnp.float32)
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    draft = greedy[:8]
    draft = draft.at[3].set((greedy[3] + 1) % 128)
    tau, corr, _ = verify.verify(logits, draft, jnp.asarray(8, jnp.int32))
    assert int(tau[0]) == 3
    assert int(corr[0]) == int(greedy[3])


# ---------------------------------------------------------------------------
# fused SwiGLU MLP
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    tokens=st.sampled_from([1, 8, 9, 64]),
    d_model=st.sampled_from([32, 128]),
    d_ff=st.sampled_from([64, 256]),
    tile=st.sampled_from([8, 64]),
)
def test_swiglu_matches_ref(seed, tokens, d_model, d_ff, tile):
    if tokens % min(tile, tokens) != 0:
        tokens = tile  # keep divisibility; swiglu asserts it
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(tokens, d_model)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(d_model, d_ff)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(d_model, d_ff)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(d_ff, d_model)) * 0.1, jnp.float32)
    got = fused_mlp.swiglu(x, wg, wu, wd, tile=tile)
    want = ref.swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_swiglu_zero_input():
    x = jnp.zeros((8, 32), jnp.float32)
    w = jnp.ones((32, 64), jnp.float32)
    wd = jnp.ones((64, 32), jnp.float32)
    np.testing.assert_allclose(fused_mlp.swiglu(x, w, w, wd), jnp.zeros((8, 32)), atol=1e-7)


# ---------------------------------------------------------------------------
# stochastic verification oracle self-consistency (the rust coordinator
# re-implements this in f32; the oracle's invariants are pinned here)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), n_draft=st.integers(0, 8))
def test_sample_verify_tau_bounds(seed, n_draft):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(9, 64)), jnp.float32)
    dp = jax.nn.softmax(jnp.asarray(rng.normal(size=(8, 64)), jnp.float32), -1)
    draft = jnp.asarray(rng.integers(0, 64, size=(8,)), jnp.int32)
    u = jnp.asarray(rng.uniform(size=(8,)), jnp.float32)
    tau, corr = ref.sample_verify_ref(logits, dp, draft, jnp.asarray(n_draft, jnp.int32), u)
    assert 0 <= int(tau) <= n_draft
    assert 0 <= int(corr) < 64


def test_sample_verify_accepts_identical_distributions():
    """If draft distribution == target distribution and u ~ 0, everything
    is accepted (ratio == 1)."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(9, 32)), jnp.float32)
    pt = jax.nn.softmax(logits[:8], -1)
    draft = jnp.argmax(pt, -1).astype(jnp.int32)
    u = jnp.zeros((8,), jnp.float32)
    tau, _ = ref.sample_verify_ref(logits, pt, draft, jnp.asarray(8, jnp.int32), u)
    assert int(tau) == 8
