//! Tier-1 smoke for the fleet-scale load harness (`flexspec::load`):
//! 10k-session workloads must be deterministic per seed, pass the
//! `ServingMetrics` conservation audit after a full drain, and exercise
//! the churn machinery (Busy deferrals, handoffs, aborts) the scenario
//! presets promise. The heavyweight scale floor (>= 100k live sessions)
//! lives in `benches/load_scale.rs`; this test keeps the per-PR loop
//! fast.

use flexspec::load::{run, Scenario};

const SEEDS: [u64; 3] = [3, 17, 42];

#[test]
fn smoke_10k_deterministic_per_seed() {
    let mut digests = Vec::new();
    for seed in SEEDS {
        let cfg = Scenario::Churn.config(10_000, seed);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(
            a.digest(),
            b.digest(),
            "seed {seed}: same config must give a byte-identical report"
        );
        assert_eq!(a.events, b.events);
        assert_eq!(a.virtual_ms.to_bits(), b.virtual_ms.to_bits());
        let v = a.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "seed {seed}: conservation audit failed: {v:?}");
        a.metrics.check_invariants(0, 0);
        assert_eq!(a.metrics.sessions_opened, 10_000);
        digests.push(a.digest());
    }
    assert_ne!(digests[0], digests[1], "different seeds gave the same run");
    assert_ne!(digests[1], digests[2], "different seeds gave the same run");
}

#[test]
fn churn_smoke_exercises_the_hot_paths() {
    let r = run(&Scenario::Churn.config(10_000, 3));
    // the bounded admission queue must actually turn drafts away ...
    assert!(r.metrics.drafts_busy > 0, "no Busy deferrals at 10k churn");
    // ... and Busy drafts must all resolve (retried into a round or
    // the session aborted): received == verified + deferred
    assert_eq!(
        r.metrics.drafts_received,
        r.metrics.rounds + r.metrics.drafts_busy
    );
    assert!(r.handoffs > 0, "no cross-replica handoffs at 10k churn");
    assert_eq!(r.metrics.sessions_redirected, r.metrics.sessions_imported);
    assert!(
        r.metrics.sessions_completed + r.metrics.sessions_aborted == 10_000,
        "sessions leaked: {} completed + {} aborted != 10000",
        r.metrics.sessions_completed,
        r.metrics.sessions_aborted
    );
    assert!(r.peak_backlog > 0 && r.ttft_ms.count() > 0);
    assert!(r.metrics.latency.queue_ms.count() > 0);
    assert!(r.air_ms_per_token() > 0.0);
}

#[test]
fn flash_floods_the_fleet() {
    let steady = run(&Scenario::Steady.config(10_000, 17));
    let flash = run(&Scenario::Flash.config(10_000, 17));
    // the burst piles sessions up far beyond the steady operating point
    assert!(
        flash.peak_live > 4 * steady.peak_live,
        "flash peak {} vs steady peak {}",
        flash.peak_live,
        steady.peak_live
    );
    // most of the population is simultaneously live at the peak
    assert!(
        flash.peak_live > 5_000,
        "flash only peaked at {} of 10000 sessions",
        flash.peak_live
    );
    assert!(flash.metrics.invariant_violations(0, 0).is_empty());
}
