//! Serving-stack integration tests: loopback + TCP transports against
//! the deterministic synthetic backend (no artifacts needed — these run
//! everywhere, unlike the artifact-gated PJRT tests).
//!
//! The headline property: the loopback serving path (real server code,
//! real frames, real concurrency — just no socket) commits EXACTLY the
//! per-session token counts the virtual-clock scheduler simulation
//! commits for the same seed and a fixed stride.

use anyhow::Result;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve_with, DraftSource, ServeConfig};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::protocol::frame::{Frame, FrameKind, Hello, HelloAck, WIRE_VERSION};
use flexspec::protocol::VerifyMode;
use flexspec::serve::{
    loopback_pair, run_edge_session, serve_cloud, serve_loopback, serve_loopback_mux, BatchMode,
    EdgeReport, EdgeSessionConfig, SyntheticDraft, SyntheticTarget, TcpTransport, Transport,
    VerifierConfig, VerifyBackend,
};

const SEED: u64 = 23;

fn rt() -> tokio::runtime::Runtime {
    tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()
        .unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..5 {
                p.push(100 + ((i * 11 + j * 3) % 100) as i32);
            }
            p
        })
        .collect()
}

/// A target that has evolved away from the frozen draft (drift 0.3), so
/// tau genuinely varies round to round — the determinism check is not
/// trivially satisfied by accept-everything.
fn evolved_target() -> Result<SyntheticTarget> {
    let mut t = SyntheticTarget::new(SEED).with_version("evolved", 0.3);
    t.deploy("evolved")?;
    Ok(t)
}

#[test]
fn loopback_reproduces_scheduler_simulation_counts() {
    const USERS: usize = 4;
    const MAX_NEW: usize = 20;

    // --- virtual-clock simulation ------------------------------------
    let cfg = ServeConfig {
        users: USERS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        ..Default::default()
    };
    let mut backend = evolved_target().unwrap();
    let mut make =
        |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(SEED))) };
    let sim = serve_with(
        &mut backend,
        &mut make,
        &prompts(USERS),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &cfg,
    )
    .unwrap();
    assert_eq!(sim.completed, USERS);
    assert_eq!(sim.per_session.len(), USERS);
    let sim_accepted: usize = sim.per_session.iter().map(|o| o.accepted).sum();
    let sim_drafted: usize = sim.per_session.iter().map(|o| o.drafted).sum();
    assert!(
        sim_accepted > 0 && sim_accepted < sim_drafted,
        "drifted target must partially accept ({sim_accepted}/{sim_drafted})"
    );

    // --- the same protocol over loopback transports ------------------
    let (reports, metrics) = rt()
        .block_on(async {
            let vcfg = VerifierConfig {
                seed: SEED,
                ..Default::default()
            };
            let edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> = prompts(USERS)
                .into_iter()
                .map(|p| {
                    (
                        Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>,
                        p,
                    )
                })
                .collect();
            let ecfg = EdgeSessionConfig {
                max_new: MAX_NEW,
                fixed_k: Some(4),
                seed: SEED,
                ..Default::default()
            };
            serve_loopback(
                vcfg,
                || Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>),
                edges,
                ecfg,
            )
            .await
        })
        .unwrap();

    assert_eq!(metrics.sessions_completed, USERS);
    // reports come back in prompt order; sim.per_session is sorted by
    // session id == prompt order
    for (i, (lr, so)) in reports.iter().zip(&sim.per_session).enumerate() {
        assert_eq!(lr.new_tokens, so.new_tokens, "tokens diverged (prompt {i})");
        assert_eq!(lr.accepted, so.accepted, "accepted diverged (prompt {i})");
        assert_eq!(lr.drafted, so.drafted, "drafted diverged (prompt {i})");
        assert_eq!(lr.rounds, so.rounds, "rounds diverged (prompt {i})");
    }
    assert_eq!(metrics.accepted, sim_accepted);
    assert_eq!(metrics.drafted, sim_drafted);
}

#[test]
fn tcp_serving_completes_sessions_and_survives_hot_swap() {
    const USERS: usize = 4;
    rt().block_on(async {
        let vcfg = VerifierConfig {
            window_ms: 5.0,
            seed: SEED,
            ..Default::default()
        };
        let handle = serve_cloud("127.0.0.1:0", vcfg, || {
            Ok(Box::new(SyntheticTarget::new(SEED).with_version("evolved", 0.5))
                as Box<dyn VerifyBackend>)
        })
        .await
        .unwrap();
        let addr = handle.addr.to_string();

        let mut threads = Vec::new();
        for prompt in prompts(USERS) {
            let addr = addr.clone();
            threads.push(std::thread::spawn(move || -> Result<EdgeReport> {
                let rt = tokio::runtime::Builder::new_current_thread()
                    .enable_all()
                    .build()?;
                rt.block_on(async move {
                    let mut t = TcpTransport::connect(&addr).await?;
                    let mut draft = SyntheticDraft::new(SEED);
                    let ecfg = EdgeSessionConfig {
                        max_new: 24,
                        seed: SEED,
                        ..Default::default()
                    };
                    run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
                })
            }));
        }

        // hot-swap while sessions are (or were just) in flight
        loop {
            tokio::time::sleep(std::time::Duration::from_millis(2)).await;
            if handle.stats().await.unwrap().sessions_opened >= 2 {
                break;
            }
        }
        let seq = handle.deploy("evolved").await.unwrap();
        assert_eq!(seq, 2);

        let reports: Vec<EdgeReport> = tokio::task::spawn_blocking(move || {
            threads
                .into_iter()
                .map(|t| t.join().expect("edge thread panicked"))
                .collect::<Result<Vec<_>>>()
        })
        .await
        .unwrap()
        .unwrap();

        let metrics = handle.shutdown().await.unwrap();
        assert_eq!(metrics.sessions_completed, USERS);
        assert_eq!(metrics.sessions_aborted, 0);
        assert_eq!(metrics.hot_swaps, 1);
        assert_eq!(
            metrics.tokens_committed,
            reports.iter().map(|r| r.new_tokens).sum::<usize>()
        );
        for r in &reports {
            assert!(r.new_tokens >= 24, "session {} under-generated", r.session);
            assert!(r.rtt_ms.count() == r.rounds);
        }
    });
}

#[test]
fn cross_connection_batching_amortizes_windows() {
    const USERS: usize = 4;
    let (_reports, metrics) = rt()
        .block_on(async {
            // generous window + max_batch == USERS: lockstep rounds land
            // in shared batches
            let vcfg = VerifierConfig {
                window_ms: 100.0,
                max_batch: USERS,
                seed: SEED,
                ..Default::default()
            };
            let edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> = prompts(USERS)
                .into_iter()
                .map(|p| {
                    (
                        Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>,
                        p,
                    )
                })
                .collect();
            let ecfg = EdgeSessionConfig {
                max_new: 15,
                fixed_k: Some(4),
                seed: SEED,
                ..Default::default()
            };
            serve_loopback(
                vcfg,
                || Ok(Box::new(SyntheticTarget::new(SEED)) as Box<dyn VerifyBackend>),
                edges,
                ecfg,
            )
            .await
        })
        .unwrap();
    assert!(
        metrics.mean_batch() > 1.5,
        "expected cross-connection batches, got occupancy {}",
        metrics.mean_batch()
    );
    assert!(metrics.batches < metrics.rounds, "batching must merge rounds");
}

/// Satellite: 8 sessions multiplexed over ONE connection must commit
/// exactly what 8 sessions over 8 connections commit, which in turn is
/// exactly what the virtual-clock simulator commits — per-session token
/// counts AND full committed sequences. The mux layer (stream ids,
/// demux, concurrent per-stream verification) must be invisible to the
/// decoding math.
#[test]
fn multiplexed_sessions_match_per_connection_and_simulator() {
    const USERS: usize = 8;
    const MAX_NEW: usize = 18;

    // --- virtual-clock simulation reference --------------------------
    let cfg = ServeConfig {
        users: USERS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        ..Default::default()
    };
    let mut backend = evolved_target().unwrap();
    let mut make =
        |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(SEED))) };
    let sim = serve_with(
        &mut backend,
        &mut make,
        &prompts(USERS),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &cfg,
    )
    .unwrap();
    assert_eq!(sim.completed, USERS);
    assert_eq!(sim.per_session_committed.len(), USERS);

    let edges = || -> Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> {
        prompts(USERS)
            .into_iter()
            .map(|p| {
                (
                    Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>,
                    p,
                )
            })
            .collect()
    };
    let ecfg = EdgeSessionConfig {
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        ..Default::default()
    };
    let vcfg = || VerifierConfig {
        window_ms: 40.0,
        seed: SEED,
        ..Default::default()
    };

    // --- 8 sessions over 8 loopback connections ----------------------
    let (per_conn, _) = rt()
        .block_on(serve_loopback(
            vcfg(),
            || Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>),
            edges(),
            ecfg.clone(),
        ))
        .unwrap();

    // --- 8 sessions multiplexed over ONE loopback connection ---------
    let (muxed, mux_metrics) = rt()
        .block_on(serve_loopback_mux(
            vcfg(),
            || Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>),
            edges(),
            ecfg,
        ))
        .unwrap();

    assert_eq!(mux_metrics.sessions_completed, USERS);
    assert_eq!(mux_metrics.sessions_opened, USERS);
    for i in 0..USERS {
        let (so, pc, mx) = (&sim.per_session[i], &per_conn[i], &muxed[i]);
        assert_eq!(mx.new_tokens, so.new_tokens, "mux vs sim tokens (prompt {i})");
        assert_eq!(mx.accepted, so.accepted, "mux vs sim accepted (prompt {i})");
        assert_eq!(mx.drafted, so.drafted, "mux vs sim drafted (prompt {i})");
        assert_eq!(mx.rounds, so.rounds, "mux vs sim rounds (prompt {i})");
        assert_eq!(
            mx.new_tokens, pc.new_tokens,
            "mux vs per-connection tokens (prompt {i})"
        );
        assert_eq!(
            mx.committed, pc.committed,
            "mux vs per-connection committed sequence (prompt {i})"
        );
        assert_eq!(
            mx.committed, sim.per_session_committed[i],
            "mux vs simulator committed sequence (prompt {i})"
        );
        assert_eq!(mx.reattaches, 0, "fault-free run must not reattach");
    }
    // the single connection still fed the cross-stream batcher
    assert!(
        mux_metrics.mean_batch() > 1.5,
        "expected cross-stream batches on one connection, got occupancy {}",
        mux_metrics.mean_batch()
    );
    assert!(mux_metrics.batches < mux_metrics.rounds);
}

/// Tentpole acceptance: pipelined serving (`--pipeline-depth 2`) — one
/// connection per session AND all sessions muxed on one connection —
/// commits token sequences BYTE-IDENTICAL to the sequential
/// `serve_with` trajectory, while its pipeline counters (rounds
/// pipelined / drafts cancelled / tokens wasted) match the pipelined
/// simulator's exactly: sim == serve, now including the overlap
/// schedule.
#[test]
fn pipelined_loopback_matches_sequential_trajectory_and_sim_counters() {
    const USERS: usize = 4;
    const MAX_NEW: usize = 20;

    let sim_cfg = |depth: usize| ServeConfig {
        users: USERS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        pipeline_depth: depth,
        ..Default::default()
    };
    let run_sim = |depth: usize| {
        let mut backend = evolved_target().unwrap();
        let mut make =
            |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(SEED))) };
        serve_with(
            &mut backend,
            &mut make,
            &prompts(USERS),
            &JETSON_ORIN,
            &A800_70B,
            &NetworkProfile::new(NetworkKind::FourG),
            &sim_cfg(depth),
        )
        .unwrap()
    };

    // sequential reference + pipelined simulator twin
    let seq_sim = run_sim(1);
    let pipe_sim = run_sim(2);
    assert_eq!(
        seq_sim.per_session_committed, pipe_sim.per_session_committed,
        "pipelined sim must not change a single token"
    );
    assert!(pipe_sim.rounds_pipelined > 0, "some speculation must land");
    assert!(pipe_sim.drafts_cancelled > 0, "drifted target must break some");

    let edges = || -> Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> {
        prompts(USERS)
            .into_iter()
            .map(|p| {
                (
                    Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>,
                    p,
                )
            })
            .collect()
    };
    let ecfg = EdgeSessionConfig {
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        pipeline_depth: 2,
        ..Default::default()
    };
    let vcfg = || VerifierConfig {
        window_ms: 40.0,
        seed: SEED,
        ..Default::default()
    };

    let check = |reports: &[EdgeReport], metrics: &flexspec::metrics::ServingMetrics, label: &str| {
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(
                r.committed, seq_sim.per_session_committed[i],
                "{label}: pipelined committed sequence diverged (prompt {i})"
            );
            assert_eq!(r.rounds, seq_sim.per_session[i].rounds, "{label}: rounds (prompt {i})");
            // RTT hiding: strictly fewer exposed waits than a
            // sequential run (which exposes every round's RTT)
            assert!(
                r.exposed_waits < r.rounds,
                "{label}: no RTT hidden (exposed {} of {} rounds, prompt {i})",
                r.exposed_waits,
                r.rounds
            );
            assert!(r.overlapped_waits > 0, "{label}: nothing overlapped (prompt {i})");
            assert_eq!(
                r.exposed_waits + r.overlapped_waits,
                r.rounds,
                "{label}: wait accounting broken (prompt {i})"
            );
        }
        // cloud-side pipeline counters match the pipelined simulator
        assert_eq!(
            metrics.rounds_pipelined, pipe_sim.rounds_pipelined,
            "{label}: rounds_pipelined diverged from sim"
        );
        assert_eq!(
            metrics.drafts_cancelled, pipe_sim.drafts_cancelled,
            "{label}: drafts_cancelled diverged from sim"
        );
        assert_eq!(
            metrics.draft_tokens_wasted, pipe_sim.draft_tokens_wasted,
            "{label}: draft_tokens_wasted diverged from sim"
        );
        // ...and the edge-side tallies agree with the cloud's
        assert_eq!(
            reports.iter().map(|r| r.rounds_pipelined).sum::<usize>(),
            metrics.rounds_pipelined,
            "{label}: edge/cloud pipelined tallies disagree"
        );
        assert_eq!(
            reports.iter().map(|r| r.drafts_cancelled).sum::<usize>(),
            metrics.drafts_cancelled,
            "{label}: edge/cloud cancel tallies disagree"
        );
    };

    // --- one connection per session ----------------------------------
    let (per_conn, metrics) = rt()
        .block_on(serve_loopback(
            vcfg(),
            || Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>),
            edges(),
            ecfg.clone(),
        ))
        .unwrap();
    check(&per_conn, &metrics, "per-conn");

    // --- all sessions muxed on ONE connection ------------------------
    let (muxed, mux_metrics) = rt()
        .block_on(serve_loopback_mux(
            vcfg(),
            || Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>),
            edges(),
            ecfg,
        ))
        .unwrap();
    check(&muxed, &mux_metrics, "mux");
}

/// Tentpole acceptance: under a SATURATED admission queue the cloud
/// answers drafts with `Busy`, the edges retry with backoff, and NOT A
/// SINGLE committed token changes — sequences stay byte-identical to
/// the unsaturated simulator reference, in sequential AND pipelined
/// mode. Edge-side retry tallies must equal the cloud's deferral count
/// (every `Busy` is answered by exactly one re-send on a clean link).
#[test]
fn saturated_admission_queue_defers_but_never_changes_tokens() {
    const USERS: usize = 6;
    const MAX_NEW: usize = 16;

    // unsaturated virtual-clock reference
    let cfg = ServeConfig {
        users: USERS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        ..Default::default()
    };
    let mut backend = evolved_target().unwrap();
    let mut make =
        |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(SEED))) };
    let sim = serve_with(
        &mut backend,
        &mut make,
        &prompts(USERS),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &cfg,
    )
    .unwrap();
    assert_eq!(sim.completed, USERS);
    assert_eq!(sim.drafts_busy_deferred, 0, "reference must be unsaturated");

    let edges = || -> Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> {
        prompts(USERS)
            .into_iter()
            .map(|p| {
                (
                    Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>,
                    p,
                )
            })
            .collect()
    };
    // admission_queue 2 << USERS: concurrent lock-step rounds overflow
    // the bound every window, so deferrals are guaranteed
    let vcfg = || VerifierConfig {
        window_ms: 5.0,
        admission_queue: 2,
        seed: SEED,
        ..Default::default()
    };

    for depth in [1usize, 2] {
        let ecfg = EdgeSessionConfig {
            max_new: MAX_NEW,
            fixed_k: Some(4),
            seed: SEED,
            pipeline_depth: depth,
            ..Default::default()
        };
        let (reports, metrics) = rt()
            .block_on(serve_loopback(
                vcfg(),
                || Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>),
                edges(),
                ecfg,
            ))
            .unwrap();
        assert_eq!(metrics.sessions_completed, USERS, "depth {depth}");
        assert!(
            metrics.drafts_busy > 0,
            "depth {depth}: saturation must defer some drafts"
        );
        let edge_retries: usize = reports.iter().map(|r| r.busy_retries).sum();
        assert_eq!(
            edge_retries, metrics.drafts_busy,
            "depth {depth}: every Busy must be answered by exactly one retry"
        );
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(
                r.committed, sim.per_session_committed[i],
                "depth {depth}: admission control changed a committed token (prompt {i})"
            );
        }
    }
}

/// The simulator's admission-queue mirror: same bound, same retry
/// horizon, same invariant — deferrals move virtual wall time, never a
/// committed token.
#[test]
fn simulator_admission_queue_mirror_keeps_tokens() {
    const USERS: usize = 6;
    let run = |admission_queue: usize| {
        let mut backend = evolved_target().unwrap();
        let mut make =
            |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(SEED))) };
        serve_with(
            &mut backend,
            &mut make,
            &prompts(USERS),
            &JETSON_ORIN,
            &A800_70B,
            &NetworkProfile::new(NetworkKind::FourG),
            &ServeConfig {
                users: USERS,
                max_new: 16,
                fixed_k: Some(4),
                seed: SEED,
                // concurrent arrivals so rounds actually contend
                arrival_mean_ms: 1.0,
                admission_queue,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let open = run(0);
    let tight = run(1);
    assert_eq!(open.drafts_busy_deferred, 0);
    assert!(
        tight.drafts_busy_deferred > 0,
        "bound 1 must defer contended arrivals"
    );
    assert_eq!(
        open.per_session_committed, tight.per_session_committed,
        "sim admission queue changed a committed token"
    );
    assert_eq!(open.per_session, tight.per_session);
    assert!(
        tight.wall_ms >= open.wall_ms,
        "deferrals can only move wall time forward"
    );
}

/// Tentpole acceptance: continuous batching (rolling slot admission +
/// per-slot KV leases, `--batch-mode continuous`) must be invisible to
/// the decoding math. Across sequential, pipelined, and multiplexed
/// serving, and across several seeds, the committed token sequences
/// stay BYTE-IDENTICAL to the windowed runs and to the virtual-clock
/// simulator — only the batching schedule (and therefore queue time)
/// is allowed to change.
#[test]
fn continuous_batching_matrix_matches_windowed_and_simulator() {
    const USERS: usize = 4;
    const MAX_NEW: usize = 16;

    for seed in [3u64, 17, 42] {
        // --- virtual-clock simulator reference -----------------------
        let mk_target = move || -> Result<SyntheticTarget> {
            let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
            t.deploy("evolved")?;
            Ok(t)
        };
        let mut backend = mk_target().unwrap();
        let mut make =
            |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(seed))) };
        let sim = serve_with(
            &mut backend,
            &mut make,
            &prompts(USERS),
            &JETSON_ORIN,
            &A800_70B,
            &NetworkProfile::new(NetworkKind::FourG),
            &ServeConfig {
                users: USERS,
                max_new: MAX_NEW,
                fixed_k: Some(4),
                seed,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(sim.completed, USERS, "seed {seed}");

        let edges = || -> Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> {
            prompts(USERS)
                .into_iter()
                .map(|p| {
                    (
                        Box::new(SyntheticDraft::new(seed)) as Box<dyn DraftSource + Send>,
                        p,
                    )
                })
                .collect()
        };
        let ecfg = |depth: usize| EdgeSessionConfig {
            max_new: MAX_NEW,
            fixed_k: Some(4),
            seed,
            pipeline_depth: depth,
            ..Default::default()
        };
        let vcfg = |mode: BatchMode| VerifierConfig {
            window_ms: 40.0,
            batch_mode: mode,
            seed,
            ..Default::default()
        };

        // --- sequential + pipelined, one connection per session ------
        for depth in [1usize, 2] {
            let (win, _) = rt()
                .block_on(serve_loopback(
                    vcfg(BatchMode::Windowed),
                    move || Ok(Box::new(mk_target()?) as Box<dyn VerifyBackend>),
                    edges(),
                    ecfg(depth),
                ))
                .unwrap();
            let (cont, cm) = rt()
                .block_on(serve_loopback(
                    vcfg(BatchMode::Continuous),
                    move || Ok(Box::new(mk_target()?) as Box<dyn VerifyBackend>),
                    edges(),
                    ecfg(depth),
                ))
                .unwrap();
            assert_eq!(cm.sessions_completed, USERS, "seed {seed} depth {depth}");
            for i in 0..USERS {
                assert_eq!(
                    cont[i].committed, win[i].committed,
                    "seed {seed} depth {depth}: continuous vs windowed committed (prompt {i})"
                );
                assert_eq!(
                    cont[i].committed, sim.per_session_committed[i],
                    "seed {seed} depth {depth}: continuous vs simulator committed (prompt {i})"
                );
                assert_eq!(
                    cont[i].new_tokens, sim.per_session[i].new_tokens,
                    "seed {seed} depth {depth}: tokens (prompt {i})"
                );
            }
            // rolling-batch bookkeeping: one occupancy sample per close,
            // dispatch count within [batches, rounds]
            assert_eq!(
                cm.slot_occupancy.count(),
                cm.batches,
                "seed {seed} depth {depth}: occupancy samples"
            );
            assert!(
                cm.stacked_dispatches >= cm.batches && cm.stacked_dispatches <= cm.rounds,
                "seed {seed} depth {depth}: stacked dispatches {} outside [{}, {}]",
                cm.stacked_dispatches,
                cm.batches,
                cm.rounds
            );
            assert!(
                cm.invariant_violations(0, 0).is_empty(),
                "seed {seed} depth {depth}: {:?}",
                cm.invariant_violations(0, 0)
            );
        }

        // --- all sessions muxed on ONE continuous connection ---------
        let (muxed, mm) = rt()
            .block_on(serve_loopback_mux(
                vcfg(BatchMode::Continuous),
                move || Ok(Box::new(mk_target()?) as Box<dyn VerifyBackend>),
                edges(),
                ecfg(1),
            ))
            .unwrap();
        assert_eq!(mm.sessions_completed, USERS, "seed {seed} mux");
        for i in 0..USERS {
            assert_eq!(
                muxed[i].committed, sim.per_session_committed[i],
                "seed {seed}: mux continuous vs simulator committed (prompt {i})"
            );
        }
        assert!(
            mm.invariant_violations(0, 0).is_empty(),
            "seed {seed} mux: {:?}",
            mm.invariant_violations(0, 0)
        );
    }
}

#[test]
fn wire_version_mismatch_is_rejected() {
    rt().block_on(async {
        let verifier = flexspec::serve::VerifierHandle::spawn(
            VerifierConfig::default(),
            || Ok(Box::new(SyntheticTarget::new(1)) as Box<dyn VerifyBackend>),
        )
        .unwrap();
        let (mut edge, cloud) = loopback_pair();
        let v = verifier.clone();
        let server = tokio::spawn(async move {
            flexspec::serve::handle_conn(cloud, v).await
        });

        let bad_hello = Hello {
            wire_version: WIRE_VERSION + 1,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        edge.send_frame(Frame::control(FrameKind::Hello, bad_hello.encode()))
            .await
            .unwrap();
        let f = edge.recv_frame().await.unwrap().unwrap();
        assert_eq!(f.kind, FrameKind::HelloAck);
        let ack = HelloAck::decode(&f.payload).unwrap();
        assert!(!ack.accepted);
        assert!(ack.reason.contains("mismatch"));
        assert_eq!(ack.wire_version, WIRE_VERSION);
        // server closes the connection after rejecting
        assert!(edge.recv_frame().await.unwrap().is_none());
        server.await.unwrap().unwrap();
        let stats = verifier.stats().await.unwrap();
        assert_eq!(stats.handshakes_rejected, 1);
        assert_eq!(stats.sessions_opened, 0);
        verifier.shutdown().await.unwrap();
    });
}
