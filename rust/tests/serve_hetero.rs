//! Wire-v8 device-matrix suite: a heterogeneous edge population (weak /
//! mid / strong tiers riding `Open` profiles) served over the REAL
//! loopback stack, pinned against the unprofiled v7 path and the
//! virtual-clock simulator.
//!
//! Two headline properties:
//!
//! * carrying a device profile with `branching = 1` is BYTE-IDENTICAL
//!   to the linear v7 protocol — same committed sequences, same
//!   accepted/drafted/round counts — across per-connection, multiplexed,
//!   sequential, and pipelined serving, and across the simulator twin;
//! * raising `branching` to 4 on the same mix drafts bucket-aligned
//!   comb trees whose hedge rows ride the EXISTING stacked dispatches,
//!   so accepted tokens per dispatch strictly increase while not a
//!   single committed token changes.

use anyhow::Result;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve_with, DraftSource, ServeConfig};
use flexspec::device::{ComputeTier, DeviceProfile};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::serve::{
    serve_loopback_each, serve_loopback_mux_each, EdgeSessionConfig, SyntheticDraft,
    SyntheticTarget, VerifierConfig, VerifyBackend,
};

/// The device-matrix seeds — same set the continuous-batching matrix
/// runs, so the two suites pin the same trajectories.
const SEEDS: [u64; 3] = [3, 17, 42];

fn rt() -> tokio::runtime::Runtime {
    tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()
        .unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..5 {
                p.push(100 + ((i * 11 + j * 3) % 100) as i32);
            }
            p
        })
        .collect()
}

/// A target that has evolved away from the frozen draft (drift 0.3), so
/// chains break mid-stride and the comb's alternate leaves have
/// something to catch.
fn mk_target(seed: u64) -> Result<SyntheticTarget> {
    let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
    t.deploy("evolved")?;
    Ok(t)
}

/// The 3-tier population: session i cycles weak → mid → strong, each on
/// its tier's representative hardware with an unmetered budget.
fn tier_cycle(n: usize) -> Vec<DeviceProfile> {
    (0..n)
        .map(|i| DeviceProfile::of(ComputeTier::all()[i % 3].representative()))
        .collect()
}

type Edges = Vec<(Box<dyn DraftSource + Send>, Vec<i32>, EdgeSessionConfig)>;

fn edges(
    seed: u64,
    users: usize,
    max_new: usize,
    profiles: Option<&[DeviceProfile]>,
    branching: usize,
    depth: usize,
) -> Edges {
    prompts(users)
        .into_iter()
        .enumerate()
        .map(|(i, p)| {
            let ecfg = EdgeSessionConfig {
                max_new,
                fixed_k: Some(4),
                seed,
                pipeline_depth: depth,
                profile: profiles.map(|ps| ps[i % ps.len()]),
                branching,
                ..Default::default()
            };
            (
                Box::new(SyntheticDraft::new(seed)) as Box<dyn DraftSource + Send>,
                p,
                ecfg,
            )
        })
        .collect()
}

fn run_sim(
    seed: u64,
    users: usize,
    max_new: usize,
    profiles: Option<Vec<DeviceProfile>>,
    branching: usize,
) -> flexspec::coordinator::ServeReport {
    let mut backend = mk_target(seed).unwrap();
    let mut make =
        |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(seed))) };
    serve_with(
        &mut backend,
        &mut make,
        &prompts(users),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &ServeConfig {
            users,
            max_new,
            fixed_k: Some(4),
            seed,
            profiles,
            branching,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Satellite acceptance, part 1: across the seed matrix, a profiled
/// 3-tier population with `branching = 1` commits EXACTLY what the
/// unprofiled v7 path commits — full sequences and per-session counts —
/// over per-connection sequential, per-connection pipelined, and
/// multiplexed serving, and all of it matches the virtual-clock
/// simulator fed the same profile vector. The device layer must be
/// bookkeeping (tier census, energy meter), never decoding math.
#[test]
fn device_matrix_branching_one_is_byte_identical_to_linear_v7() {
    const USERS: usize = 6;
    const MAX_NEW: usize = 16;

    for seed in SEEDS {
        // --- simulator: unprofiled reference + profiled twin ----------
        let sim = run_sim(seed, USERS, MAX_NEW, None, 1);
        assert_eq!(sim.completed, USERS, "seed {seed}");
        assert_eq!(sim.sessions_by_tier, [0, 0, 0], "seed {seed}");
        let sim_prof = run_sim(seed, USERS, MAX_NEW, Some(tier_cycle(USERS)), 1);
        assert_eq!(
            sim_prof.per_session_committed, sim.per_session_committed,
            "seed {seed}: profiled sim changed a committed token"
        );
        assert_eq!(sim_prof.tree_rounds, 0, "seed {seed}: branching 1 drafted a tree");
        assert_eq!(sim_prof.sessions_by_tier, [2, 2, 2], "seed {seed}: sim tier census");
        for (i, (po, so)) in sim_prof.per_session.iter().zip(&sim.per_session).enumerate() {
            assert_eq!(po.new_tokens, so.new_tokens, "seed {seed} sim tokens (prompt {i})");
            assert_eq!(po.accepted, so.accepted, "seed {seed} sim accepted (prompt {i})");
            assert_eq!(po.drafted, so.drafted, "seed {seed} sim drafted (prompt {i})");
            assert_eq!(po.rounds, so.rounds, "seed {seed} sim rounds (prompt {i})");
        }

        let vcfg = || VerifierConfig {
            window_ms: 40.0,
            seed,
            ..Default::default()
        };
        let cycle = tier_cycle(USERS);

        // --- live loopback: sequential + pipelined, one conn/session --
        for depth in [1usize, 2] {
            let (base, bm) = rt()
                .block_on(serve_loopback_each(
                    vcfg(),
                    move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                    edges(seed, USERS, MAX_NEW, None, 1, depth),
                ))
                .unwrap();
            let (prof, pm) = rt()
                .block_on(serve_loopback_each(
                    vcfg(),
                    move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                    edges(seed, USERS, MAX_NEW, Some(&cycle), 1, depth),
                ))
                .unwrap();
            assert_eq!(pm.sessions_completed, USERS, "seed {seed} depth {depth}");
            assert_eq!(
                bm.sessions_by_device_tier,
                [0, 0, 0],
                "seed {seed} depth {depth}: unprofiled run reported a tier"
            );
            assert_eq!(
                pm.sessions_by_device_tier,
                [2, 2, 2],
                "seed {seed} depth {depth}: live tier census"
            );
            assert_eq!(pm.tree_rounds, 0, "seed {seed} depth {depth}: branching 1 tree");
            for i in 0..USERS {
                assert_eq!(
                    prof[i].committed, base[i].committed,
                    "seed {seed} depth {depth}: profile changed a committed token (prompt {i})"
                );
                assert_eq!(
                    prof[i].committed, sim.per_session_committed[i],
                    "seed {seed} depth {depth}: live vs sim committed (prompt {i})"
                );
                assert_eq!(
                    prof[i].accepted, base[i].accepted,
                    "seed {seed} depth {depth}: accepted diverged (prompt {i})"
                );
                assert_eq!(
                    prof[i].drafted, base[i].drafted,
                    "seed {seed} depth {depth}: drafted diverged (prompt {i})"
                );
                assert_eq!(
                    prof[i].rounds, base[i].rounds,
                    "seed {seed} depth {depth}: rounds diverged (prompt {i})"
                );
            }
            // sequential live counts also reproduce the simulator's
            if depth == 1 {
                for (i, (lr, so)) in prof.iter().zip(&sim.per_session).enumerate() {
                    assert_eq!(lr.accepted, so.accepted, "seed {seed} live vs sim (prompt {i})");
                    assert_eq!(lr.rounds, so.rounds, "seed {seed} live vs sim rounds ({i})");
                }
            }
            assert!(
                pm.invariant_violations(0, 0).is_empty(),
                "seed {seed} depth {depth}: {:?}",
                pm.invariant_violations(0, 0)
            );
        }

        // --- live loopback: all sessions muxed on ONE connection ------
        let (mux_base, _) = rt()
            .block_on(serve_loopback_mux_each(
                vcfg(),
                move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                edges(seed, USERS, MAX_NEW, None, 1, 1),
            ))
            .unwrap();
        let (mux_prof, mm) = rt()
            .block_on(serve_loopback_mux_each(
                vcfg(),
                move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                edges(seed, USERS, MAX_NEW, Some(&cycle), 1, 1),
            ))
            .unwrap();
        assert_eq!(mm.sessions_completed, USERS, "seed {seed} mux");
        assert_eq!(mm.sessions_by_device_tier, [2, 2, 2], "seed {seed}: mux tier census");
        for i in 0..USERS {
            assert_eq!(
                mux_prof[i].committed, mux_base[i].committed,
                "seed {seed}: mux profile changed a committed token (prompt {i})"
            );
            assert_eq!(
                mux_prof[i].committed, sim.per_session_committed[i],
                "seed {seed}: mux vs sim committed (prompt {i})"
            );
        }
    }
}

/// Satellite acceptance, part 2: on the SAME 3-tier mix, raising the
/// branching cap to 4 drafts tier-capped comb trees (weak stays linear,
/// mid hedges 2-wide, strong 4-wide). `max_batch = 1` pins the batching
/// schedule (one round = one batch = one bucket-aligned dispatch), so
/// the dispatch-efficiency gate is deterministic: accepted tokens per
/// stacked dispatch must STRICTLY increase over the forced-linear run,
/// while every committed sequence stays byte-identical.
#[test]
fn tree_speculation_raises_accepted_per_stacked_dispatch() {
    const USERS: usize = 9;
    const MAX_NEW: usize = 48;

    let (mut acc_t, mut disp_t) = (0usize, 0usize);
    let (mut acc_l, mut disp_l) = (0usize, 0usize);
    for seed in SEEDS {
        let vcfg = || VerifierConfig {
            window_ms: 40.0,
            max_batch: 1,
            seed,
            ..Default::default()
        };
        let cycle = tier_cycle(USERS);
        let (lin, lm) = rt()
            .block_on(serve_loopback_each(
                vcfg(),
                move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                edges(seed, USERS, MAX_NEW, Some(&cycle), 1, 1),
            ))
            .unwrap();
        let (tre, tm) = rt()
            .block_on(serve_loopback_each(
                vcfg(),
                move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                edges(seed, USERS, MAX_NEW, Some(&cycle), 4, 1),
            ))
            .unwrap();
        assert_eq!(tm.sessions_completed, USERS, "seed {seed}");

        // the forced-linear profiled run never fans out a row...
        assert_eq!(lm.tree_rounds, 0, "seed {seed}: linear run drafted a tree");
        assert_eq!(lm.verify_rows, lm.rounds, "seed {seed}: linear rows != rounds");
        // ...the tree run does, and the hedge rows ride EXISTING
        // dispatches: max_batch 1 makes every round one batch, and the
        // bucket-aligned comb keeps all of a round's rows in one
        // dispatch class
        assert!(tm.tree_rounds > 0, "seed {seed}: hetero mix never drafted a tree");
        assert!(
            tm.verify_rows > tm.rounds,
            "seed {seed}: tree rounds added no rows ({} rows, {} rounds)",
            tm.verify_rows,
            tm.rounds
        );
        for (label, m) in [("linear", &lm), ("tree", &tm)] {
            assert_eq!(
                m.stacked_dispatches, m.batches,
                "seed {seed} {label}: comb rows split a dispatch"
            );
            assert_eq!(
                m.batches, m.rounds,
                "seed {seed} {label}: max_batch 1 must pin one round per batch"
            );
            assert!(
                m.invariant_violations(0, 0).is_empty(),
                "seed {seed} {label}: {:?}",
                m.invariant_violations(0, 0)
            );
        }

        for i in 0..USERS {
            // alternates only ever catch the token the correction slot
            // would have committed anyway — sequences are invariant
            assert_eq!(
                tre[i].committed, lin[i].committed,
                "seed {seed}: branching changed a committed token (prompt {i})"
            );
            assert!(
                tre[i].rounds <= lin[i].rounds,
                "seed {seed}: tree run took MORE rounds (prompt {i}: {} > {})",
                tre[i].rounds,
                lin[i].rounds
            );
            assert!(
                tre[i].accepted >= lin[i].accepted,
                "seed {seed}: tree run accepted less (prompt {i})"
            );
        }

        // weak sessions (every third) stay linear under the tier cap, so
        // strictly fewer than all rounds are tree rounds
        assert!(
            tm.tree_rounds < tm.rounds,
            "seed {seed}: weak tier must stay linear ({} of {} rounds treed)",
            tm.tree_rounds,
            tm.rounds
        );

        acc_t += tm.accepted;
        disp_t += tm.stacked_dispatches;
        acc_l += lm.accepted;
        disp_l += lm.stacked_dispatches;
    }

    // the gate itself, on the full seed matrix: strictly more accepted
    // tokens per stacked dispatch (cross-multiplied to stay in integers)
    assert!(
        acc_t * disp_l > acc_l * disp_t,
        "tree speculation lost the dispatch-efficiency gate: \
         {acc_t}/{disp_t} accepted/dispatch <= linear {acc_l}/{disp_l}"
    );
    assert!(acc_t > acc_l, "branching 4 never caught an alternate across the matrix");
}

/// Pipelined rounds stay LINEAR by construction — a speculative round
/// must not fan a tree out of an unverified prefix — so a profiled
/// branching-4 session with two rounds in flight still commits the
/// byte-identical sequence the sequential linear run commits.
#[test]
fn pipelined_tree_sessions_stay_byte_identical() {
    const USERS: usize = 6;
    const MAX_NEW: usize = 24;

    for seed in SEEDS {
        let vcfg = || VerifierConfig {
            window_ms: 40.0,
            seed,
            ..Default::default()
        };
        let cycle = tier_cycle(USERS);
        let (lin, _) = rt()
            .block_on(serve_loopback_each(
                vcfg(),
                move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                edges(seed, USERS, MAX_NEW, Some(&cycle), 1, 1),
            ))
            .unwrap();
        let (pipe, pm) = rt()
            .block_on(serve_loopback_each(
                vcfg(),
                move || Ok(Box::new(mk_target(seed)?) as Box<dyn VerifyBackend>),
                edges(seed, USERS, MAX_NEW, Some(&cycle), 4, 2),
            ))
            .unwrap();
        assert_eq!(pm.sessions_completed, USERS, "seed {seed}");
        assert_eq!(pm.sessions_by_device_tier, [2, 2, 2], "seed {seed}: tier census");
        for i in 0..USERS {
            assert_eq!(
                pipe[i].committed, lin[i].committed,
                "seed {seed}: pipelined tree run changed a committed token (prompt {i})"
            );
        }
        assert!(
            pm.invariant_violations(0, 0).is_empty(),
            "seed {seed}: {:?}",
            pm.invariant_violations(0, 0)
        );
    }
}
