//! Fault-injection matrix for the serving stack: seeded drops,
//! duplicates, delays, and forced disconnects (mid-draft, mid-verify-
//! reply, repeated) over the REAL server code (`handle_conn` + verifier
//! thread + resumable edge client), with the committed token sequences
//! asserted IDENTICAL to the fault-free `scheduler::serve_with`
//! trajectory — the paper's decoupling story applied to the link layer:
//! a frozen draft needs only the committed prefix to continue, so no
//! link failure mode may change a single token.
//!
//! Every schedule is deterministic per seed (`FaultPlan` +
//! `SplitMix64`); the seed lists here are the ones CI runs.

use anyhow::Result;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve_with, DraftSource, ServeConfig};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::obs::{SpanKind, Trace};
use flexspec::protocol::frame::{
    Frame, FrameKind, Hello, HelloAck, OpenAck, OpenMsg, ResumeAck, ResumeMsg, WIRE_VERSION,
};
use flexspec::protocol::VerifyMode;
use flexspec::serve::{
    handle_conn, loopback_fault_dial, loopback_pair, run_edge_session, run_session_on, EdgeMux,
    EdgeReport, EdgeSessionConfig, FaultConfig, FaultPlan, FaultSide, ResumableTransport,
    SyntheticDraft, SyntheticTarget, Transport, VerifierConfig, VerifierHandle, VerifyBackend,
};

const SEED: u64 = 23;
/// Fixed seed list for the fault matrix (mirrored in CI).
const FAULT_SEEDS: [u64; 3] = [3, 17, 42];
const USERS: usize = 3;
const MAX_NEW: usize = 24;

fn rt() -> tokio::runtime::Runtime {
    tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()
        .unwrap()
}

/// Receive the next frame for a raw-frame client, skipping the
/// control-stream `ReplicaInfo` telemetry a v5 cloud announces after
/// the handshake.
async fn recv_skipping_info<T: Transport>(t: &mut T) -> Frame {
    loop {
        let f = t.recv_frame().await.unwrap().unwrap();
        if f.kind != FrameKind::ReplicaInfo {
            return f;
        }
    }
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..5 {
                p.push(100 + ((i * 11 + j * 3) % 100) as i32);
            }
            p
        })
        .collect()
}

/// A target that has evolved away from the frozen draft (drift 0.3), so
/// tau varies and corrections are frequent — resume must reconstruct a
/// non-trivial trajectory, not an accept-everything one.
fn evolved_target() -> Result<SyntheticTarget> {
    let mut t = SyntheticTarget::new(SEED).with_version("evolved", 0.3);
    t.deploy("evolved")?;
    Ok(t)
}

/// The fault-free reference trajectories from the virtual-clock
/// simulator (per prompt, full committed sequence).
fn reference_committed(users: usize) -> Vec<Vec<i32>> {
    let cfg = ServeConfig {
        users,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        ..Default::default()
    };
    let mut backend = evolved_target().unwrap();
    let mut make =
        |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(SEED))) };
    let sim = serve_with(
        &mut backend,
        &mut make,
        &prompts(users),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &cfg,
    )
    .unwrap();
    assert_eq!(sim.completed, users);
    sim.per_session_committed
}

fn ecfg() -> EdgeSessionConfig {
    EdgeSessionConfig {
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        // generous: a fault may land on a reattach handshake, costing
        // extra attempts per forced disconnect
        max_reattach: 16,
        ..Default::default()
    }
}

/// Pipelined-mode edge config: two rounds in flight, cancel-on-reject.
fn pipelined_ecfg() -> EdgeSessionConfig {
    EdgeSessionConfig {
        pipeline_depth: 2,
        ..ecfg()
    }
}

fn plan_for(seed: u64, side: FaultSide, disconnects: usize, dup_p: f64, delay_p: f64) -> FaultConfig {
    FaultConfig {
        seed,
        dup_p,
        delay_p,
        max_disconnects: disconnects,
        disconnect_gap: (5, 10),
        disconnect_on: side,
    }
}

/// Run `USERS` sessions, each over its own fault-injected (reconnecting)
/// connection chain against ONE shared verifier; returns the reports and
/// final metrics. `session_cfg` selects sequential vs pipelined mode —
/// the whole matrix runs in both.
fn run_faulty_sessions_with(
    session_cfg: fn() -> EdgeSessionConfig,
    fault_seed: u64,
    side: FaultSide,
    disconnects: usize,
    dup_p: f64,
    delay_p: f64,
) -> (Vec<EdgeReport>, flexspec::metrics::ServingMetrics) {
    rt().block_on(async {
        let vcfg = VerifierConfig {
            seed: SEED,
            ..Default::default()
        };
        let verifier = VerifierHandle::spawn(vcfg, || {
            Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>)
        })
        .unwrap();
        let mut tasks = Vec::new();
        for (i, prompt) in prompts(USERS).into_iter().enumerate() {
            // per-session plan: EVERY session sees its own schedule and
            // its own forced disconnects
            let cfg = plan_for(
                fault_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                side,
                disconnects,
                dup_p,
                delay_p,
            );
            let chan = NetworkProfile::new(NetworkKind::FourG).channel(cfg.seed);
            let plan = FaultPlan::shared(cfg, chan);
            let dial = loopback_fault_dial(verifier.clone(), plan);
            let ecfg = session_cfg();
            tasks.push(tokio::spawn(async move {
                let mut t = ResumableTransport::connect(dial, &ecfg).await?;
                let mut draft = SyntheticDraft::new(SEED);
                run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
            }));
        }
        let mut reports = Vec::new();
        for t in tasks {
            reports.push(t.await.unwrap().unwrap());
        }
        let metrics = verifier.shutdown().await.unwrap();
        (reports, metrics)
    })
}

fn run_faulty_sessions(
    fault_seed: u64,
    side: FaultSide,
    disconnects: usize,
    dup_p: f64,
    delay_p: f64,
) -> (Vec<EdgeReport>, flexspec::metrics::ServingMetrics) {
    run_faulty_sessions_with(ecfg, fault_seed, side, disconnects, dup_p, delay_p)
}

/// Traced variant of the matrix runner: one shared journal covering
/// both the edge and the verifier halves of every session.
fn run_faulty_sessions_traced(
    session_cfg: fn() -> EdgeSessionConfig,
    fault_seed: u64,
    side: FaultSide,
    disconnects: usize,
    dup_p: f64,
    delay_p: f64,
) -> (Vec<EdgeReport>, flexspec::metrics::ServingMetrics, Trace) {
    let trace = Trace::wall();
    let journal = trace.clone();
    let (reports, metrics) = rt().block_on(async move {
        let vcfg = VerifierConfig {
            seed: SEED,
            trace: Some(journal.clone()),
            ..Default::default()
        };
        let verifier = VerifierHandle::spawn(vcfg, || {
            Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>)
        })
        .unwrap();
        let mut tasks = Vec::new();
        for (i, prompt) in prompts(USERS).into_iter().enumerate() {
            let cfg = plan_for(
                fault_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                side,
                disconnects,
                dup_p,
                delay_p,
            );
            let chan = NetworkProfile::new(NetworkKind::FourG).channel(cfg.seed);
            let plan = FaultPlan::shared(cfg, chan);
            let dial = loopback_fault_dial(verifier.clone(), plan);
            let mut ecfg = session_cfg();
            ecfg.trace = Some(journal.clone());
            tasks.push(tokio::spawn(async move {
                let mut t = ResumableTransport::connect(dial, &ecfg).await?;
                let mut draft = SyntheticDraft::new(SEED);
                run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
            }));
        }
        let mut reports = Vec::new();
        for t in tasks {
            reports.push(t.await.unwrap().unwrap());
        }
        let metrics = verifier.shutdown().await.unwrap();
        (reports, metrics)
    });
    (reports, metrics, trace)
}

fn assert_matches_reference(reports: &[EdgeReport], reference: &[Vec<i32>], label: &str) {
    assert_eq!(reports.len(), reference.len());
    for (i, (r, want)) in reports.iter().zip(reference).enumerate() {
        assert_eq!(
            &r.committed, want,
            "{label}: committed sequence diverged from fault-free run (prompt {i})"
        );
        assert_eq!(
            r.new_tokens,
            want.len() - prompts(reference.len())[i].len(),
            "{label}: token count diverged (prompt {i})"
        );
    }
}

#[test]
fn disconnect_mid_draft_resumes_to_identical_trajectory() {
    let reference = reference_committed(USERS);
    for seed in FAULT_SEEDS {
        let (reports, metrics) =
            run_faulty_sessions(seed, FaultSide::Send, 2, 0.0, 0.0);
        assert_matches_reference(&reports, &reference, "drop-mid-draft");
        let resumes: usize = reports.iter().map(|r| r.resumes).sum();
        assert!(
            reports.iter().all(|r| r.reattaches >= 1),
            "seed {seed}: every session must see at least one forced disconnect"
        );
        assert!(resumes >= USERS, "seed {seed}: sessions must resume, not restart");
        assert_eq!(metrics.sessions_completed, USERS);
        assert_eq!(metrics.sessions_evicted, 0);
        assert_eq!(metrics.sessions_aborted, 0);
        assert!(metrics.sessions_resumed >= USERS);
    }
}

#[test]
fn disconnect_mid_verify_reply_resumes_to_identical_trajectory() {
    let reference = reference_committed(USERS);
    for seed in FAULT_SEEDS {
        let (reports, metrics) =
            run_faulty_sessions(seed, FaultSide::Recv, 2, 0.0, 0.0);
        assert_matches_reference(&reports, &reference, "drop-mid-verify-reply");
        assert!(reports.iter().all(|r| r.reattaches >= 1));
        assert_eq!(metrics.sessions_completed, USERS);
        assert_eq!(metrics.sessions_evicted, 0);
    }
}

#[test]
fn duplicated_frames_are_absorbed() {
    let reference = reference_committed(USERS);
    for seed in FAULT_SEEDS {
        let (reports, metrics) = run_faulty_sessions(seed, FaultSide::Any, 0, 0.35, 0.0);
        assert_matches_reference(&reports, &reference, "duplicate-frames");
        assert!(
            reports.iter().all(|r| r.reattaches == 0),
            "duplicates alone must not force reconnects"
        );
        assert_eq!(metrics.sessions_completed, USERS);
        assert_eq!(metrics.sessions_parked, 0);
    }
}

#[test]
fn repeated_disconnects_with_duplicates_and_delays_still_converge() {
    let reference = reference_committed(USERS);
    for seed in FAULT_SEEDS {
        let (reports, metrics) = run_faulty_sessions(seed, FaultSide::Any, 3, 0.15, 0.15);
        assert_matches_reference(&reports, &reference, "kitchen-sink");
        assert_eq!(metrics.sessions_completed, USERS);
        assert_eq!(metrics.sessions_evicted, 0);
    }
}

/// Pipelined rows of the fault matrix (satellite #3): with TWO rounds in
/// flight, forced disconnects land mid-draft, mid-speculative-draft,
/// mid-cancel, and mid-verify-reply — and the committed sequences must
/// STILL be byte-identical to the fault-free SEQUENTIAL simulator
/// trajectory. The cancel-on-reject machinery (basis checks, Cancel
/// frames, speculative queues) must be invisible to the tokens under
/// every link failure mode, because validity is a pure function of the
/// committed sequence on both ends.
#[test]
fn pipelined_disconnects_with_two_rounds_in_flight_resume_identically() {
    let reference = reference_committed(USERS);
    for seed in FAULT_SEEDS {
        for side in [FaultSide::Send, FaultSide::Recv] {
            let (reports, metrics) =
                run_faulty_sessions_with(pipelined_ecfg, seed, side, 2, 0.0, 0.0);
            assert_matches_reference(&reports, &reference, "pipelined-disconnect");
            assert!(
                reports.iter().all(|r| r.reattaches >= 1),
                "seed {seed} {side:?}: every session must see a forced disconnect"
            );
            assert_eq!(metrics.sessions_completed, USERS, "seed {seed} {side:?}");
            assert_eq!(metrics.sessions_evicted, 0, "seed {seed} {side:?}");
            assert!(
                reports.iter().map(|r| r.overlapped_waits).sum::<usize>() > 0,
                "seed {seed} {side:?}: pipelining never engaged"
            );
        }
    }
}

/// Duplicates (including duplicates of CANCELLED speculative drafts —
/// the replay/basis machinery must swallow them), channel delays, and a
/// forced disconnect, all at once, in pipelined mode.
#[test]
fn pipelined_duplicates_delays_and_disconnects_converge() {
    let reference = reference_committed(USERS);
    for seed in FAULT_SEEDS {
        let (reports, metrics) =
            run_faulty_sessions_with(pipelined_ecfg, seed, FaultSide::Any, 1, 0.25, 0.15);
        assert_matches_reference(&reports, &reference, "pipelined-kitchen-sink");
        assert_eq!(metrics.sessions_completed, USERS, "seed {seed}");
        assert_eq!(metrics.sessions_evicted, 0, "seed {seed}");
        assert_eq!(metrics.sessions_aborted, 0, "seed {seed}");
    }
}

/// Observability satellite: the fault matrix re-run with tracing
/// enabled. Three properties, per seed and mode:
///
/// (a) tracing is a pure observer — committed sequences stay identical
///     to the fault-free simulator reference;
/// (b) every committed round leaves a COMPLETE span chain (draft →
///     uplink → queue_wait → bucket_plan → verify_batch → downlink →
///     commit) in the journal, with the cloud-side spans recorded
///     EXACTLY once per verified round — duplicated frames and resume
///     replays are answered from caches and must not re-record;
/// (c) the latency-histogram totals move in lockstep with the round
///     and batch counters even under forced disconnects.
#[test]
fn fault_matrix_with_tracing_keeps_sequences_and_span_chains() {
    let reference = reference_committed(USERS);
    let rows: [(&str, fn() -> EdgeSessionConfig, FaultSide, usize, f64, f64); 3] = [
        ("seq-disconnect", ecfg, FaultSide::Send, 2, 0.0, 0.0),
        ("seq-kitchen-sink", ecfg, FaultSide::Any, 3, 0.15, 0.15),
        ("pipe-disconnect", pipelined_ecfg, FaultSide::Recv, 2, 0.0, 0.0),
    ];
    for (label, session_cfg, side, disconnects, dup_p, delay_p) in rows {
        for seed in FAULT_SEEDS {
            let (reports, metrics, trace) =
                run_faulty_sessions_traced(session_cfg, seed, side, disconnects, dup_p, delay_p);
            // (a) sequences unchanged under tracing
            assert_matches_reference(&reports, &reference, label);
            // (b) complete span chain per committed round
            for r in &reports {
                let evs = trace.events(r.session);
                for round in 0..r.rounds as u32 {
                    for kind in [
                        SpanKind::Draft,
                        SpanKind::Uplink,
                        SpanKind::QueueWait,
                        SpanKind::BucketPlan,
                        SpanKind::VerifyBatch,
                        SpanKind::Downlink,
                        SpanKind::Commit,
                    ] {
                        assert!(
                            evs.iter().any(|e| e.round == round && e.kind == kind),
                            "{label} seed {seed}: session {} round {round} missing {kind:?}",
                            r.session
                        );
                    }
                }
                // cloud-side spans: exactly once per verified round (the
                // edge-side ones may legitimately exceed `rounds` when a
                // disconnect forces a redraft or a verdict replay)
                for kind in [SpanKind::QueueWait, SpanKind::VerifyBatch, SpanKind::Commit] {
                    assert_eq!(
                        trace.count(r.session, kind),
                        r.rounds,
                        "{label} seed {seed}: {kind:?} count for session {}",
                        r.session
                    );
                }
                // every committed token is accounted for by a commit span
                let committed: usize = evs
                    .iter()
                    .filter(|e| e.kind == SpanKind::Commit)
                    .map(|e| e.a as usize)
                    .sum();
                assert_eq!(
                    committed, r.new_tokens,
                    "{label} seed {seed}: commit spans don't cover session {}'s tokens",
                    r.session
                );
            }
            // (c) histogram totals == round/batch counters
            assert_eq!(metrics.latency.verify_ms.count(), metrics.batches as u64, "{label} {seed}");
            assert_eq!(metrics.latency.queue_ms.count(), metrics.rounds as u64, "{label} {seed}");
            assert_eq!(metrics.latency.round_ms.count(), metrics.rounds as u64, "{label} {seed}");
        }
    }
}

/// The flagship mux scenario: several sessions share ONE connection, the
/// connection dies (twice), the mux pump redials, and every session
/// resumes on the new link — committed sequences still bit-identical to
/// the fault-free simulator run.
#[test]
fn mux_connection_drop_resumes_all_sessions() {
    let reference = reference_committed(USERS);
    for seed in FAULT_SEEDS {
        let (reports, metrics) = rt().block_on(async {
            let vcfg = VerifierConfig {
                seed: SEED,
                ..Default::default()
            };
            let verifier = VerifierHandle::spawn(vcfg, || {
                Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>)
            })
            .unwrap();
            // shared-connection plan: gaps scaled up since N sessions
            // multiplex ~N× the frame events per round
            let cfg = FaultConfig {
                seed,
                max_disconnects: 2,
                disconnect_gap: (8, 24),
                disconnect_on: FaultSide::Any,
                ..Default::default()
            };
            let chan = NetworkProfile::new(NetworkKind::FourG).channel(seed);
            let plan = FaultPlan::shared(cfg, chan);
            let mut dial = loopback_fault_dial(verifier.clone(), plan);
            let initial = dial.connect().await.unwrap();
            let ecfg0 = ecfg();
            let mut mux = EdgeMux::connect(initial, Some(dial), &ecfg0).await.unwrap();
            let mut tasks = Vec::new();
            for prompt in prompts(USERS) {
                let mut stream = mux.open_stream();
                let ecfg = ecfg();
                tasks.push(tokio::spawn(async move {
                    let sid = stream.stream_id();
                    let mut draft = SyntheticDraft::new(SEED);
                    run_session_on(&mut stream, sid, &mut draft, &prompt, &ecfg).await
                }));
            }
            let mut reports = Vec::new();
            for t in tasks {
                reports.push(t.await.unwrap().unwrap());
            }
            drop(mux);
            let metrics = verifier.shutdown().await.unwrap();
            (reports, metrics)
        });
        assert_matches_reference(&reports, &reference, "mux-drop");
        assert_eq!(metrics.sessions_completed, USERS, "seed {seed}");
        assert_eq!(metrics.sessions_evicted, 0, "seed {seed}");
        // at least one forced disconnect hit the shared link
        assert!(
            reports.iter().map(|r| r.reattaches).sum::<usize>() >= 1,
            "seed {seed}: the shared connection must have dropped at least once"
        );
    }
}

/// Sessions whose edge never comes back are reaped by the grace-window
/// eviction sweep — KV capacity is reclaimed, nothing leaks.
#[test]
fn unresumed_sessions_are_evicted_after_grace() {
    rt().block_on(async {
        let vcfg = VerifierConfig {
            seed: SEED,
            resume_grace_ms: 50.0,
            ..Default::default()
        };
        let verifier = VerifierHandle::spawn(vcfg, || {
            Ok(Box::new(SyntheticTarget::new(SEED)) as Box<dyn VerifyBackend>)
        })
        .unwrap();
        let (mut edge, cloud) = loopback_pair();
        let v = verifier.clone();
        tokio::spawn(async move {
            let _ = handle_conn(cloud, v).await;
        });
        // raw-frame client: handshake + open, then vanish without Bye
        let hello = Hello {
            wire_version: WIRE_VERSION,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        edge.send_frame(Frame::control(FrameKind::Hello, hello.encode()))
            .await
            .unwrap();
        let ack = HelloAck::decode(&edge.recv_frame().await.unwrap().unwrap().payload).unwrap();
        assert!(ack.accepted);
        let open = OpenMsg {
            prompt: vec![1, 70, 71],
            max_new: 32,
            nonce: 7,
            tier: 1,
            profile: None,
        };
        edge.send_frame(Frame::on(1, FrameKind::Open, open.encode()))
            .await
            .unwrap();
        let oack = OpenAck::decode(&recv_skipping_info(&mut edge).await.payload).unwrap();
        assert!(oack.resume_token != 0);
        drop(edge); // link dies; the session parks

        // wait (bounded) for the eviction sweep to reap it
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let stats = verifier.stats().await.unwrap();
            if stats.sessions_evicted >= 1 {
                assert_eq!(stats.sessions_parked, 1);
                assert_eq!(stats.sessions_completed, 0);
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "eviction sweep never reaped the parked session"
            );
            tokio::time::sleep(std::time::Duration::from_millis(25)).await;
        }
        // the token is gone: a late resume is cleanly rejected
        let (mut edge2, cloud2) = loopback_pair();
        let v = verifier.clone();
        tokio::spawn(async move {
            let _ = handle_conn(cloud2, v).await;
        });
        edge2
            .send_frame(Frame::control(FrameKind::Hello, hello.encode()))
            .await
            .unwrap();
        let _ = recv_skipping_info(&mut edge2).await;
        let resume = ResumeMsg {
            token: oack.resume_token,
            committed_len: 3,
        };
        edge2
            .send_frame(Frame::on(1, FrameKind::Resume, resume.encode()))
            .await
            .unwrap();
        let rack = ResumeAck::decode(&recv_skipping_info(&mut edge2).await.payload).unwrap();
        assert!(!rack.accepted);
        assert!(
            rack.reason.contains("unknown or expired"),
            "unexpected reason: {}",
            rack.reason
        );
        verifier.shutdown().await.unwrap();
    });
}

/// Raw-frame protocol checks: resumes with bogus tokens are rejected
/// with a reason, and a draft on an unbound stream kills the connection
/// (unknown stream ids are rejected, satellite #1's demux contract).
#[test]
fn bogus_resume_and_unknown_stream_are_rejected() {
    rt().block_on(async {
        let verifier = VerifierHandle::spawn(VerifierConfig::default(), || {
            Ok(Box::new(SyntheticTarget::new(SEED)) as Box<dyn VerifyBackend>)
        })
        .unwrap();
        let (mut edge, cloud) = loopback_pair();
        let v = verifier.clone();
        let server = tokio::spawn(async move { handle_conn(cloud, v).await });
        let hello = Hello {
            wire_version: WIRE_VERSION,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        edge.send_frame(Frame::control(FrameKind::Hello, hello.encode()))
            .await
            .unwrap();
        let _ = recv_skipping_info(&mut edge).await;
        // bogus token → rejected ResumeAck, connection stays usable
        let resume = ResumeMsg {
            token: 0xBAAD_F00D,
            committed_len: 10,
        };
        edge.send_frame(Frame::on(3, FrameKind::Resume, resume.encode()))
            .await
            .unwrap();
        let rack = ResumeAck::decode(&recv_skipping_info(&mut edge).await.payload).unwrap();
        assert!(!rack.accepted && !rack.done);
        // draft on a never-bound stream → the server rejects and closes
        edge.send_frame(Frame::on(9, FrameKind::Draft, vec![0; 8]))
            .await
            .unwrap();
        assert!(edge.recv_frame().await.unwrap().is_none(), "server must close");
        let err = server.await.unwrap().unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown stream"),
            "unexpected error: {err:#}"
        );
        verifier.shutdown().await.unwrap();
    });
}
