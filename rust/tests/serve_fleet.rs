//! Fleet-serving integration tests (`serve::fleet`, wire v5): N
//! loopback replicas — each the REAL server code (`handle_conn` + its
//! own verifier thread + backend) — stitched together by the shared
//! handoff ledger and the fleet registry.
//!
//! The headline property (the tentpole's acceptance bar): a session
//! REDIRECTED between replicas mid-decode — sequential, pipelined with
//! rounds in flight, or multiplexed — commits a token sequence
//! byte-identical to the single-replica virtual-clock simulation, for
//! every seed in the pinned list [3, 17, 42]. Handoffs move wall time,
//! never tokens: the frozen draft needs nothing but the committed
//! prefix, on whichever replica it lands.

use anyhow::Result;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve_with, DraftSource, FleetSimConfig, ServeConfig};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::serve::{
    run_edge_session, run_session_on, EdgeMux, EdgeReport, EdgeSessionConfig, FaultConfig,
    FaultPlan, FleetRegistry, Reconnect, ResumableTransport, SyntheticDraft, SyntheticTarget,
    VerifierConfig, VerifyBackend,
};

/// Fixed seed list (mirrored in CI and in `tests/serve_faults.rs`).
const FLEET_SEEDS: [u64; 3] = [3, 17, 42];
const USERS: usize = 3;
const MAX_NEW: usize = 24;

fn rt() -> tokio::runtime::Runtime {
    tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()
        .unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..5 {
                p.push(100 + ((i * 11 + j * 3) % 100) as i32);
            }
            p
        })
        .collect()
}

/// A target that has evolved away from the frozen draft (drift 0.3), so
/// tau genuinely varies — handoffs must reconstruct a non-trivial
/// trajectory. Every replica deploys the same version: version
/// evolution during a handoff is the canary test's subject, not this
/// file's baseline.
fn evolved_target(seed: u64) -> Result<SyntheticTarget> {
    let mut t = SyntheticTarget::new(seed)
        .with_version("evolved", 0.3)
        .with_version("canary", 0.5);
    t.deploy("evolved")?;
    Ok(t)
}

/// Single-replica virtual-clock reference trajectories.
fn reference_committed(seed: u64) -> Vec<Vec<i32>> {
    let cfg = ServeConfig {
        users: USERS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed,
        ..Default::default()
    };
    let mut backend = evolved_target(seed).unwrap();
    let mut make = move |_id: u32| -> Result<Box<dyn DraftSource>> {
        Ok(Box::new(SyntheticDraft::new(seed)))
    };
    let sim = serve_with(
        &mut backend,
        &mut make,
        &prompts(USERS),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &cfg,
    )
    .unwrap();
    assert_eq!(sim.completed, USERS);
    sim.per_session_committed
}

fn ecfg(seed: u64, depth: usize) -> EdgeSessionConfig {
    EdgeSessionConfig {
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed,
        pipeline_depth: depth,
        // a handoff consumes one reattach; leave room for duplicates
        max_reattach: 16,
        ..Default::default()
    }
}

/// Two-replica registry, both on the drifted target.
fn two_replicas(seed: u64) -> FleetRegistry {
    let mut reg = FleetRegistry::new();
    for addr in ["replica-a", "replica-b"] {
        reg.spawn_loopback_replica(addr, VerifierConfig { seed, ..Default::default() }, move || {
            Ok(Box::new(evolved_target(seed)?) as Box<dyn VerifyBackend>)
        })
        .unwrap();
    }
    reg
}

fn assert_matches_reference(reports: &[EdgeReport], reference: &[Vec<i32>], label: &str) {
    assert_eq!(reports.len(), reference.len());
    for (i, (r, want)) in reports.iter().zip(reference).enumerate() {
        assert_eq!(
            &r.committed, want,
            "{label}: committed sequence diverged from the single-replica sim (prompt {i})"
        );
    }
}

/// Wait (bounded) until replica A has opened all `USERS` sessions and
/// verified at least one round — the "mid-decode" trigger point for
/// drains, targeted redirects, and replica death.
async fn await_mid_decode(reg: &FleetRegistry, addr: &str) {
    let v = reg.verifier(addr).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let s = v.stats().await.unwrap();
        if s.sessions_opened >= USERS && s.rounds >= 1 {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "sessions never reached mid-decode on {addr}"
        );
        tokio::time::sleep(std::time::Duration::from_millis(2)).await;
    }
}

/// Tentpole acceptance: drain replica A mid-decode; every session is
/// exported, redirected, imported by B, and finishes there — committed
/// sequences byte-identical to the single-replica sim, in sequential
/// AND pipelined mode (rounds in flight at handoff time), across the
/// pinned seeds.
#[test]
fn drained_replica_hands_sessions_over_with_identical_sequences() {
    for seed in FLEET_SEEDS {
        let reference = reference_committed(seed);
        for depth in [1usize, 2] {
            let (reports, a_stats, b_stats) = rt().block_on(async {
                let mut reg = two_replicas(seed);
                let mut tasks = Vec::new();
                for prompt in prompts(USERS) {
                    let dial = reg.dial("replica-a", None);
                    let ecfg = ecfg(seed, depth);
                    tasks.push(tokio::spawn(async move {
                        let mut t = ResumableTransport::connect(dial, &ecfg).await?;
                        let mut draft = SyntheticDraft::new(seed);
                        run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
                    }));
                }
                await_mid_decode(&reg, "replica-a").await;
                reg.drain("replica-a", "replica-b").unwrap();
                let mut reports = Vec::new();
                for t in tasks {
                    reports.push(t.await.unwrap().unwrap());
                }
                let a = reg.verifier("replica-a").unwrap().shutdown().await.unwrap();
                let b = reg.verifier("replica-b").unwrap().shutdown().await.unwrap();
                (reports, a, b)
            });
            let label = format!("drain seed {seed} depth {depth}");
            assert_matches_reference(&reports, &reference, &label);
            let redirects: usize = reports.iter().map(|r| r.redirects).sum();
            assert!(redirects >= 1, "{label}: no session was handed off");
            assert_eq!(
                a_stats.sessions_redirected, b_stats.sessions_imported,
                "{label}: every export must be imported exactly once"
            );
            assert!(a_stats.sessions_redirected >= 1, "{label}: A exported nothing");
            assert_eq!(
                a_stats.sessions_completed + b_stats.sessions_completed,
                USERS,
                "{label}: completions must split across the fleet"
            );
            assert_eq!(a_stats.sessions_evicted + b_stats.sessions_evicted, 0);
            if depth == 2 {
                assert!(
                    reports.iter().map(|r| r.overlapped_waits).sum::<usize>() > 0,
                    "{label}: pipelining never engaged"
                );
            }
        }
    }
}

/// Satellite (fleet edge cases): duplicated frames — including
/// duplicates of the `Redirect` itself and of `Cancel` frames racing it
/// in pipelined mode — are absorbed: the session converges wherever it
/// lands and the tokens never change.
#[test]
fn duplicate_redirect_delivery_is_absorbed() {
    for seed in FLEET_SEEDS {
        let reference = reference_committed(seed);
        for depth in [1usize, 2] {
            let (reports, a_stats, b_stats) = rt().block_on(async {
                let mut reg = two_replicas(seed);
                let mut tasks = Vec::new();
                for (i, prompt) in prompts(USERS).into_iter().enumerate() {
                    // duplicates only (no disconnects): every frame —
                    // drafts, verdicts, cancels, redirects — may arrive
                    // twice
                    let plan = FaultPlan::shared(
                        FaultConfig {
                            seed: seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                            dup_p: 0.35,
                            max_disconnects: 0,
                            ..Default::default()
                        },
                        NetworkProfile::new(NetworkKind::FourG).channel(seed),
                    );
                    let dial = reg.dial("replica-a", Some(plan));
                    let ecfg = ecfg(seed, depth);
                    tasks.push(tokio::spawn(async move {
                        let mut t = ResumableTransport::connect(dial, &ecfg).await?;
                        let mut draft = SyntheticDraft::new(seed);
                        run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
                    }));
                }
                await_mid_decode(&reg, "replica-a").await;
                reg.drain("replica-a", "replica-b").unwrap();
                let mut reports = Vec::new();
                for t in tasks {
                    reports.push(t.await.unwrap().unwrap());
                }
                let a = reg.verifier("replica-a").unwrap().shutdown().await.unwrap();
                let b = reg.verifier("replica-b").unwrap().shutdown().await.unwrap();
                (reports, a, b)
            });
            let label = format!("dup-redirect seed {seed} depth {depth}");
            assert_matches_reference(&reports, &reference, &label);
            assert_eq!(
                a_stats.sessions_completed + b_stats.sessions_completed,
                USERS,
                "{label}"
            );
            assert_eq!(a_stats.sessions_evicted + b_stats.sessions_evicted, 0, "{label}");
        }
    }
}

/// Satellite (fleet edge cases): on a MUXED connection a redirected
/// stream cannot leave the shared transport — it resumes in place and
/// the exporting replica re-imports it from the ledger, while the
/// SIBLING streams stay pinned and untouched. Tokens never change.
#[test]
fn mux_stream_redirected_in_place_while_siblings_stay_pinned() {
    for seed in FLEET_SEEDS {
        let reference = reference_committed(seed);
        let (reports, a_stats, b_stats) = rt().block_on(async {
            let reg = two_replicas(seed);
            let mut dial = reg.dial("replica-a", None);
            let initial = dial.connect().await.unwrap();
            let ecfg0 = ecfg(seed, 1);
            let mut mux = EdgeMux::connect(initial, Some(dial), &ecfg0).await.unwrap();
            let mut tasks = Vec::new();
            for prompt in prompts(USERS) {
                let mut stream = mux.open_stream();
                let ecfg = ecfg(seed, 1);
                tasks.push(tokio::spawn(async move {
                    let sid = stream.stream_id();
                    let mut draft = SyntheticDraft::new(seed);
                    run_session_on(&mut stream, sid, &mut draft, &prompt, &ecfg).await
                }));
            }
            await_mid_decode(&reg, "replica-a").await;
            // targeted handoff of server session 2: its next head round
            // is redirected; server ids are assigned in open order so
            // exactly one stream is affected
            reg.redirect_session("replica-a", 2, "replica-b").unwrap();
            let mut reports = Vec::new();
            for t in tasks {
                reports.push(t.await.unwrap().unwrap());
            }
            drop(mux);
            let a = reg.verifier("replica-a").unwrap().shutdown().await.unwrap();
            let b = reg.verifier("replica-b").unwrap().shutdown().await.unwrap();
            (reports, a, b)
        });
        let label = format!("mux-redirect seed {seed}");
        assert_matches_reference(&reports, &reference, &label);
        assert_eq!(
            a_stats.sessions_redirected, 1,
            "{label}: exactly one session is targeted"
        );
        assert_eq!(
            a_stats.sessions_imported, 1,
            "{label}: the pinned stream must resume in place (A re-imports)"
        );
        assert_eq!(b_stats.sessions_imported, 0, "{label}: B never sees it");
        assert_eq!(a_stats.sessions_completed, USERS, "{label}: all finish on A");
        // exactly one stream followed the in-place handoff (one resume,
        // one redirect); its siblings never reattached at all
        let moved: Vec<&EdgeReport> = reports.iter().filter(|r| r.redirects > 0).collect();
        assert_eq!(moved.len(), 1, "{label}: exactly one stream is redirected");
        assert!(moved[0].resumes >= 1, "{label}: the move is a resume");
        for r in reports.iter().filter(|r| r.redirects == 0) {
            assert_eq!(r.reattaches, 0, "{label}: siblings must stay pinned");
            assert_eq!(r.resumes, 0, "{label}: siblings must stay pinned");
        }
    }
}

/// Satellite (fleet edge cases): replica DEATH without a handoff — the
/// fleet dial fails over to the survivor, the resume is rejected
/// everywhere (nothing was exported), and the re-root path re-opens
/// from the committed prefix: the trajectory still completes
/// byte-identically. The frozen draft needs nothing but the position.
#[test]
fn replica_death_reroots_sessions_onto_survivor() {
    for seed in FLEET_SEEDS {
        let reference = reference_committed(seed);
        let (reports, b_stats) = rt().block_on(async {
            let mut reg = two_replicas(seed);
            let mut tasks = Vec::new();
            for prompt in prompts(USERS) {
                let dial = reg.dial("replica-a", None);
                let mut cfg = ecfg(seed, 1);
                cfg.reroot_on_unknown_session = true;
                tasks.push(tokio::spawn(async move {
                    let mut t = ResumableTransport::connect(dial, &cfg).await?;
                    let mut draft = SyntheticDraft::new(seed);
                    run_edge_session(&mut t, &mut draft, &prompt, &cfg).await
                }));
            }
            await_mid_decode(&reg, "replica-a").await;
            // kill A: directory entry gone (dials fail over) and the
            // verifier thread stops (its conns die on the next command)
            let a = reg.verifier("replica-a").unwrap();
            reg.mark_dead("replica-a");
            let _ = a.shutdown().await;
            let mut reports = Vec::new();
            for t in tasks {
                reports.push(t.await.unwrap().unwrap());
            }
            let b = reg.verifier("replica-b").unwrap().shutdown().await.unwrap();
            (reports, b)
        });
        let label = format!("replica-death seed {seed}");
        assert_matches_reference(&reports, &reference, &label);
        let reroots: usize = reports.iter().map(|r| r.reroots).sum();
        assert!(reroots >= 1, "{label}: at least one session must re-root");
        assert!(
            b_stats.sessions_completed >= reroots,
            "{label}: re-rooted sessions finish on the survivor"
        );
        assert_eq!(b_stats.sessions_imported, 0, "{label}: nothing was exported");
    }
}

/// Satellite (fleet edge cases): canary rollout + rollback. The canary
/// version is deployed to replica B and rolled back MID-DECODE of A's
/// sessions; A's traffic is byte-identical throughout (its version
/// never moved), and sessions opened on B after the rollback commit the
/// reference bytes again — rollback genuinely restores the verdict
/// function, while B's version sequence records both swaps.
#[test]
fn canary_rollback_mid_decode_restores_reference_bytes() {
    let seed = FLEET_SEEDS[0];
    let reference = reference_committed(seed);
    let (phase1, phase2, b_info) = rt().block_on(async {
        let mut reg = two_replicas(seed);
        // phase 1: USERS sessions on A, mid-decode
        let mut tasks = Vec::new();
        for prompt in prompts(USERS) {
            let dial = reg.dial("replica-a", None);
            let ecfg = ecfg(seed, 1);
            tasks.push(tokio::spawn(async move {
                let mut t = ResumableTransport::connect(dial, &ecfg).await?;
                let mut draft = SyntheticDraft::new(seed);
                run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
            }));
        }
        await_mid_decode(&reg, "replica-a").await;
        // canary B to the drifted-further version, then roll it back
        let seqs = reg.advance_version(&["replica-b"], "canary").await.unwrap();
        assert_eq!(seqs.len(), 1);
        reg.advance_version(&["replica-b"], "evolved").await.unwrap();
        let mut phase1 = Vec::new();
        for t in tasks {
            phase1.push(t.await.unwrap().unwrap());
        }
        // phase 2: fresh sessions on the rolled-back canary
        let mut tasks = Vec::new();
        for prompt in prompts(USERS) {
            let dial = reg.dial("replica-b", None);
            let ecfg = ecfg(seed, 1);
            tasks.push(tokio::spawn(async move {
                let mut t = ResumableTransport::connect(dial, &ecfg).await?;
                let mut draft = SyntheticDraft::new(seed);
                run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
            }));
        }
        let mut phase2 = Vec::new();
        for t in tasks {
            phase2.push(t.await.unwrap().unwrap());
        }
        let b_info = reg.verifier("replica-b").unwrap().info().await.unwrap();
        reg.verifier("replica-a").unwrap().shutdown().await.unwrap();
        reg.verifier("replica-b").unwrap().shutdown().await.unwrap();
        (phase1, phase2, b_info)
    });
    assert_matches_reference(&phase1, &reference, "canary phase 1 (A untouched)");
    assert_matches_reference(&phase2, &reference, "canary phase 2 (B rolled back)");
    assert_eq!(
        b_info.version_name, "evolved",
        "rollback must restore the version"
    );
    assert_eq!(
        b_info.version_seq, 4,
        "deploy(evolved) + canary + rollback = three swaps past the initial seq"
    );
}

/// The virtual-clock fleet twin (`ServeConfig::fleet`): the simulated
/// redirect schedule commits byte-identical tokens to the single-
/// replica run across the pinned seeds, sequential AND pipelined, while
/// the handoffs cost strictly positive virtual time — sim == serve
/// determinism extended to fleet scale.
#[test]
fn fleet_sim_twin_is_byte_identical_across_seeds() {
    for seed in FLEET_SEEDS {
        for depth in [1usize, 2] {
            let run = |fleet: Option<FleetSimConfig>| {
                let mut backend = evolved_target(seed).unwrap();
                let mut make = move |_id: u32| -> Result<Box<dyn DraftSource>> {
                    Ok(Box::new(SyntheticDraft::new(seed)))
                };
                serve_with(
                    &mut backend,
                    &mut make,
                    &prompts(USERS),
                    &JETSON_ORIN,
                    &A800_70B,
                    &NetworkProfile::new(NetworkKind::FourG),
                    &ServeConfig {
                        users: USERS,
                        max_new: MAX_NEW,
                        fixed_k: Some(4),
                        seed,
                        pipeline_depth: depth,
                        fleet,
                        ..Default::default()
                    },
                )
                .unwrap()
            };
            let single = run(None);
            let fleet = run(Some(FleetSimConfig {
                replicas: 2,
                redirect_after_rounds: 2,
                max_redirects: 1,
                ..Default::default()
            }));
            assert_eq!(
                single.per_session_committed, fleet.per_session_committed,
                "seed {seed} depth {depth}: sim handoff changed a token"
            );
            assert!(
                fleet.sessions_redirected >= 1,
                "seed {seed} depth {depth}: schedule never fired"
            );
            assert!(
                fleet.wall_ms > single.wall_ms,
                "seed {seed} depth {depth}: handoffs must cost virtual time"
            );
        }
    }
}
