//! Observability determinism tests (the tentpole acceptance for
//! `flexspec::obs`).
//!
//! The determinism contract already pins committed token sequences
//! sim == serve; this file extends it to the TRACE layer: with a
//! journal installed on both the virtual-clock simulator and the
//! loopback serving stack, every session must emit the SAME canonical
//! event sequence (`Trace::sequence` — `(round, kind)` pairs,
//! timestamps aside) in sequential, pipelined, and multiplexed modes,
//! for seeds 3 / 17 / 42. A trace diff is therefore the first
//! debugging tool for any future determinism violation.

use anyhow::Result;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve_with, DraftSource, ServeConfig, ServeReport};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::obs::{SpanKind, Trace, VirtualClock};
use flexspec::serve::{
    serve_loopback, serve_loopback_mux, EdgeReport, EdgeSessionConfig, SyntheticDraft,
    SyntheticTarget, VerifierConfig, VerifyBackend,
};

const USERS: usize = 4;
const MAX_NEW: usize = 18;

fn rt() -> tokio::runtime::Runtime {
    tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()
        .unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..5 {
                p.push(100 + ((i * 11 + j * 3) % 100) as i32);
            }
            p
        })
        .collect()
}

/// Drifted target (acceptance genuinely varies round to round) so the
/// event sequences are not trivially identical.
fn evolved_target(seed: u64) -> Result<SyntheticTarget> {
    let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
    t.deploy("evolved")?;
    Ok(t)
}

/// Virtual-clock simulation with a journal on a virtual clock.
fn run_sim(seed: u64, depth: usize) -> (ServeReport, Trace) {
    let trace = Trace::new(VirtualClock::shared());
    let cfg = ServeConfig {
        users: USERS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed,
        pipeline_depth: depth,
        trace: Some(trace.clone()),
        ..Default::default()
    };
    let mut backend = evolved_target(seed).unwrap();
    let mut make =
        |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(seed))) };
    let rep = serve_with(
        &mut backend,
        &mut make,
        &prompts(USERS),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &cfg,
    )
    .unwrap();
    (rep, trace)
}

/// Loopback serving run with ONE shared journal covering both halves:
/// the edge records draft/uplink/downlink, the verifier records
/// queue/plan/verify/commit — all keyed by the cloud-assigned session
/// id, so each session's ring carries its full span chain.
fn run_serve(
    seed: u64,
    depth: usize,
    mux: bool,
) -> (Vec<EdgeReport>, flexspec::metrics::ServingMetrics, Trace) {
    let trace = Trace::wall();
    let vcfg = VerifierConfig {
        window_ms: 40.0,
        seed,
        trace: Some(trace.clone()),
        ..Default::default()
    };
    let ecfg = EdgeSessionConfig {
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed,
        pipeline_depth: depth,
        trace: Some(trace.clone()),
        ..Default::default()
    };
    let edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> = prompts(USERS)
        .into_iter()
        .map(|p| {
            (
                Box::new(SyntheticDraft::new(seed)) as Box<dyn DraftSource + Send>,
                p,
            )
        })
        .collect();
    let mk = move || Ok(Box::new(evolved_target(seed)?) as Box<dyn VerifyBackend>);
    let (reports, metrics) = if mux {
        rt().block_on(serve_loopback_mux(vcfg, mk, edges, ecfg)).unwrap()
    } else {
        rt().block_on(serve_loopback(vcfg, mk, edges, ecfg)).unwrap()
    };
    (reports, metrics, trace)
}

/// Tentpole acceptance: identical canonical event sequences, sim vs
/// serve, across sequential / pipelined / multiplexed modes and seeds
/// 3, 17, 42. Loopback reports come back in prompt order; the sim's
/// session ids are 1-based prompt order, the serving stack's are
/// whatever the cloud assigned (`reports[i].session`).
#[test]
fn sim_and_serve_emit_identical_event_sequences() {
    for seed in [3u64, 17, 42] {
        for depth in [1usize, 2] {
            let (sim_rep, sim_tr) = run_sim(seed, depth);
            assert_eq!(sim_rep.completed, USERS);
            for mux in [false, true] {
                let (reports, _, serve_tr) = run_serve(seed, depth, mux);
                assert_eq!(reports.len(), USERS);
                for (i, r) in reports.iter().enumerate() {
                    let sim_seq = sim_tr.sequence(i as u32 + 1);
                    let serve_seq = serve_tr.sequence(r.session);
                    assert!(
                        !serve_seq.is_empty(),
                        "empty trace (seed {seed} depth {depth} mux {mux} prompt {i})"
                    );
                    assert_eq!(
                        serve_seq, sim_seq,
                        "event sequence diverged (seed {seed} depth {depth} mux {mux} prompt {i})"
                    );
                }
            }
        }
    }
}

/// Every committed round must leave a COMPLETE span chain in the
/// journal: draft → uplink → queue_wait → bucket_plan → verify_batch →
/// downlink → commit, each exactly `rounds` times per session (the
/// sequential, fault-free case — pipelined launches may exceed rounds
/// by the cancelled-draft count, covered by the equality test above).
#[test]
fn every_round_leaves_a_complete_span_chain() {
    let (reports, metrics, trace) = run_serve(17, 1, false);
    for r in &reports {
        for kind in [
            SpanKind::Draft,
            SpanKind::Uplink,
            SpanKind::QueueWait,
            SpanKind::BucketPlan,
            SpanKind::VerifyBatch,
            SpanKind::Downlink,
            SpanKind::Commit,
        ] {
            assert_eq!(
                trace.count(r.session, kind),
                r.rounds,
                "span chain broken for session {} at {kind:?}",
                r.session
            );
        }
        assert_eq!(trace.dropped(r.session), 0);
    }
    // histogram totals move in lockstep with the round/batch counters
    assert_eq!(metrics.latency.verify_ms.count(), metrics.batches as u64);
    assert_eq!(metrics.latency.queue_ms.count(), metrics.rounds as u64);
    assert_eq!(metrics.latency.round_ms.count(), metrics.rounds as u64);
    let edge_rtt: u64 = reports.iter().map(|r| r.latency.rtt_ms.count()).sum();
    assert_eq!(edge_rtt, metrics.rounds as u64);

    // the JSONL export round-trips through the JSON parser
    let jsonl = trace.to_jsonl();
    assert_eq!(jsonl.lines().count() as u64, trace.len());
    for line in jsonl.lines().take(20) {
        flexspec::util::json::Json::parse(line).unwrap();
    }
}

/// The simulator mirrors the same latency bookkeeping under virtual
/// time: one verify record per batch, one queue/round/rtt record per
/// verified round — and the trace clock is the sim's virtual clock, so
/// event timestamps are virtual ms bounded by the final wall time.
#[test]
fn simulator_latency_books_and_virtual_timestamps() {
    let (rep, trace) = run_sim(3, 1);
    assert_eq!(rep.latency.verify_ms.count(), rep.batches as u64);
    assert_eq!(rep.latency.queue_ms.count(), rep.rounds as u64);
    assert_eq!(rep.latency.round_ms.count(), rep.rounds as u64);
    assert_eq!(rep.latency.rtt_ms.count(), rep.rounds as u64);
    // rtt includes the downlink the queue wait does not, and both are
    // bounded by the end-to-end round latency books
    assert!(rep.latency.rtt_ms.p50() > 0.0);
    for s in trace.sessions() {
        for e in trace.events(s) {
            assert!(
                e.at_ms.is_finite() && e.at_ms >= 0.0 && e.at_ms <= rep.wall_ms,
                "virtual timestamp {} outside run [0, {}]",
                e.at_ms,
                rep.wall_ms
            );
        }
    }
}
