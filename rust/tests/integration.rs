//! Cross-module integration tests over the REAL artifacts: the full
//! stack composed exactly as the examples/benches use it. Every test
//! no-ops gracefully when `make artifacts` has not run yet.

use flexspec::baselines::Method;
use flexspec::channel::{Channel, ChannelState, ConstChannel, NetworkKind, NetworkProfile};
use flexspec::channel::trace::ChannelTrace;
use flexspec::coordinator::{CloudEngine, Pipeline};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::experiments::{Ctx, REGIME_A, REGIME_B};
use flexspec::runtime::Registry;
use flexspec::workload::{WorkloadGen, EOS};

fn ctx() -> Option<Ctx> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        return None;
    }
    std::env::set_var("FLEXSPEC_ARTIFACTS", root.to_str().unwrap());
    let c = Ctx::open(2, 11).ok()?;
    if !c.reg.manifest.weights.contains_key("draft_flex_llama2t") {
        return None;
    }
    Some(c)
}

fn run_method(
    reg: &Registry,
    method: Method,
    target: &str,
    prompt: &[i32],
    max_new: usize,
    chan: &mut dyn Channel,
    regime: flexspec::experiments::Regime,
    seed: u64,
) -> flexspec::coordinator::RequestResult {
    let mut cloud = CloudEngine::new(reg, target, EOS).unwrap();
    let mut pipe = Pipeline::new(
        method.draft_source(reg, "llama2t", "gsm8k").unwrap(),
        &mut cloud,
        chan,
        method.stride_policy(NetworkKind::FourG),
        &JETSON_ORIN,
        &A800_70B,
        regime.mode,
        regime.temperature,
        regime.top_p,
        method.label(),
    );
    pipe.run_request(prompt, max_new, seed).unwrap()
}

fn const_chan() -> ConstChannel {
    ConstChannel(ChannelState {
        up_bps: 50e6,
        down_bps: 100e6,
        prop_ms: 20.0,
        fading: false,
        loss_rate: 0.002,
    })
}

#[test]
fn every_greedy_method_is_lossless() {
    // THE invariant of speculative decoding: all methods produce the
    // cloud target's exact greedy output.
    let Some(c) = ctx() else { return };
    let mut gen = WorkloadGen::new("gsm8k", 4).unwrap();
    let req = gen.next_request();
    let max_new = req.max_new.min(24);

    let mut chan = const_chan();
    let reference = run_method(
        &c.reg, Method::CloudOnly, "lora_llama2t_gsm8k", &req.prompt, max_new, &mut chan, REGIME_A, 5,
    );
    for m in [
        Method::FlexSpec,
        Method::StdSd,
        Method::Pld,
        Method::Lookahead,
        Method::Eagle2,
        Method::Medusa1,
        Method::Dssd,
    ] {
        let mut chan = const_chan();
        let r = run_method(
            &c.reg, m, "lora_llama2t_gsm8k", &req.prompt, max_new, &mut chan, REGIME_A, 5,
        );
        assert_eq!(r.output, reference.output, "{} lost losslessness", m.label());
    }
}

#[test]
fn pipeline_is_deterministic_given_seeds() {
    let Some(c) = ctx() else { return };
    let mut gen = WorkloadGen::new("mtbench", 9).unwrap();
    let req = gen.next_request();
    let run = || {
        let mut chan = NetworkProfile::new(NetworkKind::WifiWeak).channel(33);
        run_method(
            &c.reg, Method::FlexSpec, "lora_llama2t_mtbench", &req.prompt,
            req.max_new.min(20), &mut chan, REGIME_B, 77,
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.output, b.output);
    assert_eq!(a.decode_ms, b.decode_ms);
    assert_eq!(a.bytes_up, b.bytes_up);
}

#[test]
fn stochastic_regime_respects_acceptance_bounds() {
    let Some(c) = ctx() else { return };
    let mut gen = WorkloadGen::new("nq", 2).unwrap();
    let req = gen.next_request();
    let mut chan = const_chan();
    let r = run_method(
        &c.reg, Method::FlexSpec, "lora_llama2t_nq", &req.prompt,
        req.max_new.min(24), &mut chan, REGIME_B, 3,
    );
    assert!(r.new_tokens > 0);
    assert!(r.accepted <= r.drafted);
    for l in &r.rounds_log {
        assert!(l.tau <= l.k);
        assert_eq!(l.committed, l.tau + 1);
        assert!(l.t_step_ms >= l.t_cloud_ms);
    }
}

#[test]
fn trace_replay_gives_identical_latency_across_runs() {
    let Some(c) = ctx() else { return };
    let mut stoch = NetworkProfile::new(NetworkKind::FourG).channel(5);
    let trace = ChannelTrace::record(&mut stoch, 256, 100.0);
    let mut gen = WorkloadGen::new("wmt14", 6).unwrap();
    let req = gen.next_request();
    let mut t1 = trace.replay();
    let a = run_method(
        &c.reg, Method::FlexSpec, "lora_llama2t_wmt14", &req.prompt,
        req.max_new.min(16), &mut t1, REGIME_A, 8,
    );
    let mut t2 = trace.replay();
    let b = run_method(
        &c.reg, Method::FlexSpec, "lora_llama2t_wmt14", &req.prompt,
        req.max_new.min(16), &mut t2, REGIME_A, 8,
    );
    assert_eq!(a.decode_ms, b.decode_ms);
}

#[test]
fn frozen_draft_survives_hot_swap_across_all_versions() {
    // The headline property: ONE draft bundle, every target version,
    // decode never breaks and greedy output still matches cloud-only.
    let Some(c) = ctx() else { return };
    let versions: Vec<String> = c
        .reg
        .manifest
        .weights
        .values()
        .filter(|w| w.arch == "llama2t" && (w.kind == "lora" || w.kind == "base" || w.kind == "full"))
        .map(|w| w.name.clone())
        .collect();
    assert!(versions.len() >= 5, "zoo too small: {versions:?}");
    let mut gen = WorkloadGen::new("general", 3).unwrap();
    let req = gen.next_request();
    for v in versions {
        let mut chan = const_chan();
        let flex = run_method(&c.reg, Method::FlexSpec, &v, &req.prompt, 12, &mut chan, REGIME_A, 2);
        let mut chan2 = const_chan();
        let co = run_method(&c.reg, Method::CloudOnly, &v, &req.prompt, 12, &mut chan2, REGIME_A, 2);
        assert_eq!(flex.output, co.output, "lossless vs version {v}");
    }
}

#[test]
fn report_pipeline_renders_markdown() {
    let Some(mut c) = ctx() else { return };
    c.requests = 1;
    let entries =
        flexspec::report::run_experiments(&c, &["table1".to_string(), "fig2".to_string()]).unwrap();
    let dir = std::env::temp_dir().join("flexspec_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("report.md");
    flexspec::report::write_markdown(&entries, &path, "# test\n").unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("table1") && text.contains("fig2"));
    assert!(text.contains("| Network Type |"));
}
