//! `flexspec::autoscale` integration tests — the closed-loop control
//! plane, on both sides of the determinism contract.
//!
//! Harness side (virtual clock): autoscaled workloads are byte-
//! deterministic per seed INCLUDING the policy's action log, respect
//! the per-session redirect budget, strand no session on a retired
//! replica, converge without thrashing on a steady workload, and beat
//! the fixed fleet on tail ttft for the same flash crowd.
//!
//! Live side (wall clock, loopback fleet): real replicas driven by an
//! [`AutoscaleController`] rebalancing sessions MID-DECODE commit token
//! sequences byte-identical to the single-replica virtual-clock sim —
//! sequential, pipelined, and multiplexed, across the pinned seeds
//! [3, 17, 42]. The control plane moves wall time, never tokens.

use anyhow::Result;
use flexspec::autoscale::{AutoscaleConfig, AutoscaleController};
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve_with, DraftSource, ServeConfig};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::load::{run, LoadConfig, Scenario};
use flexspec::serve::{
    run_edge_session, run_session_on, EdgeMux, EdgeReport, EdgeSessionConfig, FleetRegistry,
    ResumableTransport, SyntheticDraft, SyntheticTarget, VerifierConfig, VerifyBackend,
};

/// Fixed seed list (mirrored in CI, `tests/serve_fleet.rs`, and
/// `tests/load_scale.rs`).
const SEEDS: [u64; 3] = [3, 17, 42];
const USERS: usize = 3;
const MAX_NEW: usize = 24;

// ---------------------------------------------------------------------
// harness side: the sim twin
// ---------------------------------------------------------------------

/// Flash preset with a bounded admission queue and an aggressive
/// closed loop — the bench's comparison shape at test scale.
fn autoscaled_flash(sessions: usize, seed: u64) -> LoadConfig {
    let mut cfg = Scenario::Flash.config(sessions, seed);
    cfg.admission_queue = 48;
    cfg.autoscale = Some(AutoscaleConfig {
        tick_ms: 500.0,
        min_replicas: cfg.replicas,
        max_replicas: 128,
        scale_up_queue: 4,
        up_ticks: 1,
        cooldown_ticks: 1,
        max_scale_step: 8,
        down_ticks: 20,
        redirect_budget: 2,
        ..AutoscaleConfig::default()
    });
    cfg
}

#[test]
fn autoscaled_runs_are_deterministic_per_seed_including_action_log() {
    let mut digests = Vec::new();
    for seed in SEEDS {
        let cfg = autoscaled_flash(10_000, seed);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(
            a.digest(),
            b.digest(),
            "seed {seed}: same config must give a byte-identical report"
        );
        let (ar, br) = (a.autoscale.as_ref().unwrap(), b.autoscale.as_ref().unwrap());
        assert_eq!(
            ar.log_digest, br.log_digest,
            "seed {seed}: control-plane action log diverged"
        );
        assert_eq!(ar.log_lines, br.log_lines);
        assert!(ar.replicas_added > 0, "seed {seed}: flash never scaled up");
        assert!(
            ar.peak_session_redirects <= 2,
            "seed {seed}: redirect budget exceeded ({})",
            ar.peak_session_redirects
        );
        // no session is stranded on a drained or retired replica
        assert_eq!(
            a.metrics.sessions_completed + a.metrics.sessions_aborted,
            10_000,
            "seed {seed}: sessions leaked"
        );
        let v = a.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "seed {seed}: {v:?}");
        digests.push(a.digest());
    }
    assert_ne!(digests[0], digests[1], "different seeds gave the same run");
    assert_ne!(digests[1], digests[2], "different seeds gave the same run");
}

#[test]
fn steady_fleet_converges_without_thrashing() {
    for seed in SEEDS {
        // steady runs at 0.6x the 4-replica preset capacity: a floor of
        // 3 leaves one trim to equilibrium (~0.8x per replica) —
        // comfortably inside the dead band, so hysteresis must produce
        // EXACTLY one scale-down over the whole run and never a
        // scale-up, however long the workload runs
        let mut cfg = Scenario::Steady.config(6_000, seed);
        assert_eq!(cfg.replicas, 4, "preset geometry moved; re-derive the floor");
        cfg.autoscale = Some(AutoscaleConfig {
            min_replicas: 3,
            down_ticks: 3,
            cooldown_ticks: 2,
            ..AutoscaleConfig::default()
        });
        let r = run(&cfg);
        let a = r.autoscale.as_ref().unwrap();
        let ups = a.log_lines.iter().filter(|l| l.contains("scale_up")).count();
        let downs = a.log_lines.iter().filter(|l| l.contains("scale_down")).count();
        assert_eq!(
            (ups, downs),
            (0, 1),
            "seed {seed}: converged loop must trim once and then hold: {:?}",
            a.log_lines
        );
        assert_eq!(a.replicas_retired, 1, "seed {seed}: the trimmed replica retires");
        assert_eq!(a.final_replicas, 3, "seed {seed}: fleet settles at the floor");
        assert_eq!(
            r.metrics.sessions_completed, 6_000,
            "seed {seed}: steady sessions must all complete"
        );
        assert!(r.metrics.invariant_violations(0, 0).is_empty());
    }
}

#[test]
fn autoscaled_flash_beats_fixed_fleet_on_tail_ttft() {
    let seed = SEEDS[0];
    let mut fixed_cfg = autoscaled_flash(20_000, seed);
    fixed_cfg.autoscale = None;
    let auto_cfg = autoscaled_flash(20_000, seed);
    let fixed = run(&fixed_cfg);
    let auto = run(&auto_cfg);
    let (fq, aq) = (fixed.ttft_ms.quantile(0.99), auto.ttft_ms.quantile(0.99));
    assert!(
        aq < fq,
        "autoscaled ttft p99 {aq:.0} ms must beat the fixed fleet's {fq:.0} ms"
    );
    // the adaptive Busy hint quotes deeper than the fixed fleet's
    // static one-window suggestion
    assert!(fixed.retry_after_max_ms > 0, "fixed fleet never said Busy");
    assert!(
        auto.retry_after_max_ms > fixed.retry_after_max_ms,
        "adaptive hint {} ms never quoted past the static {} ms",
        auto.retry_after_max_ms,
        fixed.retry_after_max_ms
    );
}

// ---------------------------------------------------------------------
// live side: the controller on a loopback fleet
// ---------------------------------------------------------------------

fn rt() -> tokio::runtime::Runtime {
    tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()
        .unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..5 {
                p.push(100 + ((i * 11 + j * 3) % 100) as i32);
            }
            p
        })
        .collect()
}

/// A target drifted from the frozen draft (0.3) so tau genuinely
/// varies — rebalanced sessions must reconstruct a non-trivial
/// trajectory (same baseline as `tests/serve_fleet.rs`).
fn evolved_target(seed: u64) -> Result<SyntheticTarget> {
    let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
    t.deploy("evolved")?;
    Ok(t)
}

/// Single-replica virtual-clock reference trajectories.
fn reference_committed(seed: u64) -> Vec<Vec<i32>> {
    let cfg = ServeConfig {
        users: USERS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed,
        ..Default::default()
    };
    let mut backend = evolved_target(seed).unwrap();
    let mut make = move |_id: u32| -> Result<Box<dyn DraftSource>> {
        Ok(Box::new(SyntheticDraft::new(seed)))
    };
    let sim = serve_with(
        &mut backend,
        &mut make,
        &prompts(USERS),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &cfg,
    )
    .unwrap();
    assert_eq!(sim.completed, USERS);
    sim.per_session_committed
}

fn ecfg(seed: u64, depth: usize) -> EdgeSessionConfig {
    EdgeSessionConfig {
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed,
        pipeline_depth: depth,
        max_reattach: 16,
        ..Default::default()
    }
}

fn two_replicas(seed: u64) -> FleetRegistry {
    let mut reg = FleetRegistry::new();
    for addr in ["replica-a", "replica-b"] {
        reg.spawn_loopback_replica(addr, VerifierConfig { seed, ..Default::default() }, move || {
            Ok(Box::new(evolved_target(seed)?) as Box<dyn VerifyBackend>)
        })
        .unwrap();
    }
    reg
}

/// Rebalance-only control config for a two-replica fleet: the floor
/// pins the size (no scale actions possible at steady queues), but the
/// margin is low enough that A's whole-fleet session load arms a
/// rebalance toward idle B on the first tick that sees it.
fn rebalance_only() -> AutoscaleConfig {
    AutoscaleConfig {
        min_replicas: 2,
        max_replicas: 2,
        rebalance_margin: 1,
        max_redirects_per_tick: 2,
        ..AutoscaleConfig::default()
    }
}

async fn await_mid_decode(reg: &FleetRegistry, addr: &str) {
    let v = reg.verifier(addr).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let s = v.stats().await.unwrap();
        if s.sessions_opened >= USERS && s.rounds >= 1 {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "sessions never reached mid-decode on {addr}"
        );
        tokio::time::sleep(std::time::Duration::from_millis(2)).await;
    }
}

fn assert_matches_reference(reports: &[EdgeReport], reference: &[Vec<i32>], label: &str) {
    assert_eq!(reports.len(), reference.len());
    for (i, (r, want)) in reports.iter().zip(reference).enumerate() {
        assert_eq!(
            &r.committed, want,
            "{label}: committed sequence diverged from the single-replica sim (prompt {i})"
        );
    }
}

/// The live acceptance bar: a controller rebalancing a lopsided fleet
/// MID-DECODE (all sessions opened on A, B idle) never changes a
/// committed token — sequential and pipelined, across the pinned
/// seeds.
#[test]
fn controller_rebalances_mid_decode_with_identical_sequences() {
    for seed in SEEDS {
        let reference = reference_committed(seed);
        for depth in [1usize, 2] {
            let (reports, a_stats, b_stats, actions) = rt().block_on(async {
                let mut reg = two_replicas(seed);
                let mut tasks = Vec::new();
                for prompt in prompts(USERS) {
                    let dial = reg.dial("replica-a", None);
                    let ecfg = ecfg(seed, depth);
                    tasks.push(tokio::spawn(async move {
                        let mut t = ResumableTransport::connect(dial, &ecfg).await?;
                        let mut draft = SyntheticDraft::new(seed);
                        run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
                    }));
                }
                await_mid_decode(&reg, "replica-a").await;
                let mut ctl = AutoscaleController::new(rebalance_only());
                // a few control ticks while the sessions decode: the
                // load gap (A: USERS, B: 0) arms rebalances that move
                // sessions at their next head round
                for t in 0..4u32 {
                    ctl.step(&mut reg, t as f64 * 1000.0, None).await.unwrap();
                    tokio::time::sleep(std::time::Duration::from_millis(5)).await;
                }
                let mut reports = Vec::new();
                for t in tasks {
                    reports.push(t.await.unwrap().unwrap());
                }
                let a = reg.verifier("replica-a").unwrap().shutdown().await.unwrap();
                let b = reg.verifier("replica-b").unwrap().shutdown().await.unwrap();
                (reports, a, b, ctl.policy().log().len())
            });
            let label = format!("controller-rebalance seed {seed} depth {depth}");
            assert_matches_reference(&reports, &reference, &label);
            assert!(actions >= 1, "{label}: the controller never acted");
            assert!(
                a_stats.sessions_redirected >= 1,
                "{label}: no session was rebalanced away from A"
            );
            assert_eq!(
                a_stats.sessions_redirected,
                b_stats.sessions_imported,
                "{label}: every export must be imported exactly once"
            );
            assert_eq!(
                a_stats.sessions_completed + b_stats.sessions_completed,
                USERS,
                "{label}: completions must split across the fleet"
            );
            assert_eq!(a_stats.sessions_evicted + b_stats.sessions_evicted, 0);
        }
    }
}

/// Same bar on a MUXED connection: a rebalanced stream cannot leave the
/// shared transport, so it resumes in place (A re-imports it) while its
/// siblings stay pinned — and no token moves.
#[test]
fn controller_rebalance_on_mux_resumes_in_place_with_identical_sequences() {
    for seed in SEEDS {
        let reference = reference_committed(seed);
        let (reports, a_stats, b_stats) = rt().block_on(async {
            let mut reg = two_replicas(seed);
            let mut dial = reg.dial("replica-a", None);
            let initial = dial.connect().await.unwrap();
            let ecfg0 = ecfg(seed, 1);
            let mut mux = EdgeMux::connect(initial, Some(dial), &ecfg0).await.unwrap();
            let mut tasks = Vec::new();
            for prompt in prompts(USERS) {
                let mut stream = mux.open_stream();
                let ecfg = ecfg(seed, 1);
                tasks.push(tokio::spawn(async move {
                    let sid = stream.stream_id();
                    let mut draft = SyntheticDraft::new(seed);
                    run_session_on(&mut stream, sid, &mut draft, &prompt, &ecfg).await
                }));
            }
            await_mid_decode(&reg, "replica-a").await;
            let mut ctl = AutoscaleController::new(rebalance_only());
            for t in 0..4u32 {
                ctl.step(&mut reg, t as f64 * 1000.0, None).await.unwrap();
                tokio::time::sleep(std::time::Duration::from_millis(5)).await;
            }
            let mut reports = Vec::new();
            for t in tasks {
                reports.push(t.await.unwrap().unwrap());
            }
            drop(mux);
            let a = reg.verifier("replica-a").unwrap().shutdown().await.unwrap();
            let b = reg.verifier("replica-b").unwrap().shutdown().await.unwrap();
            (reports, a, b)
        });
        let label = format!("controller-mux seed {seed}");
        assert_matches_reference(&reports, &reference, &label);
        assert!(
            a_stats.sessions_redirected >= 1,
            "{label}: the controller never rebalanced a stream"
        );
        assert_eq!(
            a_stats.sessions_redirected, a_stats.sessions_imported,
            "{label}: pinned streams resume in place (A re-imports its own exports)"
        );
        assert_eq!(b_stats.sessions_imported, 0, "{label}: B never sees them");
        assert_eq!(a_stats.sessions_completed, USERS, "{label}: all finish on A");
    }
}
