//! API-compatible stub of the `xla` 0.1.6 crate (PJRT C-API bindings).
//!
//! The real crate drives XLA through a prebuilt `xla_extension` shared
//! library. That native payload cannot be fetched in hermetic build
//! environments, so this stub reimplements the *host-side* surface the
//! FlexSpec runtime uses (`Literal` construction/reshape/readback) and
//! turns every *device-side* operation (HLO loading, compilation,
//! execution) into a clear runtime error. The crate therefore always
//! builds; artifact-gated tests and experiments detect the missing
//! backend exactly the way they detect missing artifacts and no-op.
//!
//! To run the real model zoo, point the `xla` path dependency in
//! rust/Cargo.toml at the real crate (same version, same API).

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `anyhow` interop.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the native xla_extension backend, which is not \
         linked in this build (stub crate rust/vendor/xla)"
    ))
}

// ---------------------------------------------------------------------
// Literals (functional: host-side data containers)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::F64(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I64(v) => v.len(),
        }
    }

    fn type_name(&self) -> &'static str {
        match self {
            Data::F32(_) => "f32",
            Data::F64(_) => "f64",
            Data::I32(_) => "i32",
            Data::I64(_) => "i64",
        }
    }
}

/// Element types a `Literal` can hold (the subset FlexSpec uses).
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
    #[doc(hidden)]
    const NAME: &'static str;
}

macro_rules! native {
    ($t:ty, $variant:ident, $name:literal) => {
        impl NativeType for $t {
            fn wrap(v: Vec<Self>) -> Data {
                Data::$variant(v)
            }
            fn unwrap(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
            const NAME: &'static str = $name;
        }
    };
}

native!(f32, F32, "f32");
native!(f64, F64, "f64");
native!(i32, I32, "i32");
native!(i64, I64, "i64");

/// A host tensor: typed element buffer + dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: T::wrap(v.to_vec()),
            dims: vec![v.len() as i64],
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} elements)",
                self.dims,
                dims,
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape_dims(&self) -> &[i64] {
        &self.dims
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error(format!(
                "literal holds {} elements, asked for {}",
                self.data.type_name(),
                T::NAME
            ))
        })
    }

    /// Split a tuple literal into its elements. Stub literals are never
    /// tuples (tuples only come back from execution, which the stub
    /// cannot perform), so this always errors.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("decomposing an executable output tuple"))
    }
}

// ---------------------------------------------------------------------
// HLO modules + computations (stubs: loading always fails)
// ---------------------------------------------------------------------

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error(format!(
            "cannot parse HLO text {}: the native xla_extension backend is \
             not linked in this build (stub crate rust/vendor/xla)",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

// ---------------------------------------------------------------------
// PJRT client / buffers / executables (stubs: execution always fails)
// ---------------------------------------------------------------------

/// A PJRT device handle (opaque in the stub).
pub struct PjRtDevice {
    _private: (),
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The stub "CPU client" constructs fine — callers probe for the
    /// backend by attempting to load/compile HLO, which errors clearly.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling an XLA computation"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("uploading a host literal to a device buffer"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("downloading a device buffer"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing a loaded executable"))
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing a loaded executable"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.shape_dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_constructs_but_execution_is_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        let lit = Literal::vec1(&[1i32]);
        assert!(c.buffer_from_host_literal(None, &lit).is_err());
        let err = HloModuleProto::from_text_file("/tmp/nope.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("nope.hlo.txt"));
    }
}
