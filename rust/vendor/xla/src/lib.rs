//! API-compatible stub of the `xla` 0.1.6 crate (PJRT C-API bindings),
//! forked to support the stacked-batch runtime.
//!
//! The real crate drives XLA through a prebuilt `xla_extension` shared
//! library. That native payload cannot be fetched in hermetic build
//! environments, so this stub reimplements the *host-side* surface the
//! FlexSpec runtime uses (`Literal` construction/reshape/readback) and
//! turns HLO loading/compilation into a clear runtime error. The crate
//! therefore always builds; artifact-gated tests and experiments detect
//! the missing backend exactly the way they detect missing artifacts
//! and no-op.
//!
//! Two deliberate departures from upstream 0.1.6, both needed by the
//! shared device-resident weight cache (`runtime::model::WeightSet`):
//!
//! * **Host-backed buffers.** `buffer_from_host_literal` is functional:
//!   a `PjRtBuffer` owns a host copy of its literal, `to_literal_sync`
//!   reads it back, and donation is modeled by *taking* the literal out
//!   of the buffer (a donated buffer errors on reuse, exactly like a
//!   freed device allocation).
//! * **Per-argument donation.** Upstream `execute_b` donates every
//!   input buffer. [`PjRtLoadedExecutable::execute_b_opts`] takes a
//!   per-argument `donate` mask so long-lived weight buffers survive
//!   the call while per-step activations are still consumed.
//!   `execute_b` keeps the donate-all upstream semantics.
//!
//! Execution itself stays unavailable for *compiled* executables (no
//! native backend), but [`PjRtLoadedExecutable::hosted`] wraps a host
//! closure as an executable so the runtime's dispatch/donation/stacking
//! machinery is testable without artifacts. When the real backend is
//! wanted, point the `xla` path dependency in rust/Cargo.toml at the
//! real crate (same version, same API + the two extensions above).

use std::borrow::Borrow;
use std::cell::RefCell;
use std::fmt;
use std::path::Path;
use std::rc::Rc;

/// Error type mirroring `xla::Error` closely enough for `anyhow` interop.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the native xla_extension backend, which is not \
         linked in this build (stub crate rust/vendor/xla)"
    ))
}

// ---------------------------------------------------------------------
// Literals (functional: host-side data containers)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    /// A tuple of element literals — the shape every jax-lowered entry
    /// point returns (`return_tuple=True`). Hosted executables build
    /// these; `decompose_tuple` splits them.
    Tuple(Vec<Literal>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::F64(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I64(v) => v.len(),
            Data::Tuple(v) => v.iter().map(|l| l.element_count()).sum(),
        }
    }

    fn type_name(&self) -> &'static str {
        match self {
            Data::F32(_) => "f32",
            Data::F64(_) => "f64",
            Data::I32(_) => "i32",
            Data::I64(_) => "i64",
            Data::Tuple(_) => "tuple",
        }
    }
}

/// Element types a `Literal` can hold (the subset FlexSpec uses).
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
    #[doc(hidden)]
    const NAME: &'static str;
}

macro_rules! native {
    ($t:ty, $variant:ident, $name:literal) => {
        impl NativeType for $t {
            fn wrap(v: Vec<Self>) -> Data {
                Data::$variant(v)
            }
            fn unwrap(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
            const NAME: &'static str = $name;
        }
    };
}

native!(f32, F32, "f32");
native!(f64, F64, "f64");
native!(i32, I32, "i32");
native!(i64, I64, "i64");

/// A host tensor: typed element buffer + dimensions. May also be a
/// tuple of tensors (execution outputs).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: T::wrap(v.to_vec()),
            dims: vec![v.len() as i64],
        }
    }

    /// A tuple literal over element literals (the root shape of every
    /// jax-lowered module output).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![elements.len() as i64],
            data: Data::Tuple(elements),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".to_string()));
        }
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} elements)",
                self.dims,
                dims,
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape_dims(&self) -> &[i64] {
        &self.dims
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error(format!(
                "literal holds {} elements, asked for {}",
                self.data.type_name(),
                T::NAME
            ))
        })
    }

    /// Split a tuple literal into its elements. Errors on non-tuple
    /// literals (mirrors upstream, where only execution outputs carry
    /// the tuple root).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.data {
            Data::Tuple(v) => Ok(std::mem::take(v)),
            other => Err(Error(format!(
                "decompose_tuple on a non-tuple {} literal",
                other.type_name()
            ))),
        }
    }
}

// ---------------------------------------------------------------------
// HLO modules + computations (stubs: loading always fails)
// ---------------------------------------------------------------------

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error(format!(
            "cannot parse HLO text {}: the native xla_extension backend is \
             not linked in this build (stub crate rust/vendor/xla)",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

// ---------------------------------------------------------------------
// PJRT client / buffers / executables
// ---------------------------------------------------------------------

/// A PJRT device handle (opaque in the stub).
pub struct PjRtDevice {
    _private: (),
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The stub "CPU client" constructs fine — callers probe for the
    /// backend by attempting to load/compile HLO, which errors clearly.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling an XLA computation"))
    }

    /// Upload a host literal into a (host-backed) device buffer. The
    /// buffer owns its copy; it stays valid across non-donating
    /// executions and is consumed by donation.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer {
            data: RefCell::new(Some(literal.clone())),
        })
    }
}

/// A device buffer, modeled host-side. `None` means the buffer was
/// donated to an execution (the device allocation was consumed); any
/// further use errors, exactly like touching a freed PJRT buffer.
pub struct PjRtBuffer {
    data: RefCell<Option<Literal>>,
}

impl PjRtBuffer {
    fn from_literal(lit: Literal) -> PjRtBuffer {
        PjRtBuffer {
            data: RefCell::new(Some(lit)),
        }
    }

    fn read(&self) -> Result<Literal> {
        self.data
            .borrow()
            .clone()
            .ok_or_else(|| Error("use of donated device buffer".to_string()))
    }

    fn donate(&self) -> Result<Literal> {
        self.data
            .borrow_mut()
            .take()
            .ok_or_else(|| Error("double donation of device buffer".to_string()))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        self.read()
    }
}

type HostFn = dyn Fn(&[Literal]) -> Result<Literal>;

/// A loaded executable. Compiled executables (from `PjRtClient::compile`)
/// never exist in the stub; hosted executables wrap a host closure so
/// the runtime's dispatch, donation, and stacking machinery runs (and is
/// testable) without the native backend.
pub struct PjRtLoadedExecutable {
    host_fn: Option<Rc<HostFn>>,
}

impl PjRtLoadedExecutable {
    /// Wrap a host closure as an executable. The closure receives the
    /// argument literals in order and must return the module's root
    /// tuple (use [`Literal::tuple`]), matching jax's
    /// `return_tuple=True` lowering.
    pub fn hosted<F>(f: F) -> PjRtLoadedExecutable
    where
        F: Fn(&[Literal]) -> Result<Literal> + 'static,
    {
        PjRtLoadedExecutable {
            host_fn: Some(Rc::new(f)),
        }
    }

    fn call(&self, args: Vec<Literal>) -> Result<Vec<Vec<PjRtBuffer>>> {
        let f = self
            .host_fn
            .as_ref()
            .ok_or_else(|| unavailable("executing a loaded executable"))?;
        let out = f(&args)?;
        Ok(vec![vec![PjRtBuffer::from_literal(out)]])
    }

    pub fn execute<L: Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lits: Vec<Literal> = args.iter().map(|l| l.borrow().clone()).collect();
        self.call(lits)
    }

    /// Execute over device buffers, donating EVERY input (upstream
    /// 0.1.6 semantics): each argument buffer is consumed and errors on
    /// reuse. Prefer [`execute_b_opts`](Self::execute_b_opts) when some
    /// arguments (weights) must survive the call.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let donate = vec![true; args.len()];
        self.execute_b_opts(args, &donate)
    }

    /// Execute over device buffers with a per-argument donation mask.
    /// `donate[i] == false` leaves `args[i]` valid after the call (the
    /// device allocation is aliased read-only); `true` consumes it.
    /// The mask must cover every argument.
    pub fn execute_b_opts<B: Borrow<PjRtBuffer>>(
        &self,
        args: &[B],
        donate: &[bool],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        if donate.len() != args.len() {
            return Err(Error(format!(
                "donation mask covers {} of {} arguments",
                donate.len(),
                args.len()
            )));
        }
        let lits: Vec<Literal> = args
            .iter()
            .zip(donate)
            .map(|(b, &d)| {
                let b = b.borrow();
                if d {
                    b.donate()
                } else {
                    b.read()
                }
            })
            .collect::<Result<_>>()?;
        self.call(lits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.shape_dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_literals_decompose_and_reject_reshape() {
        let mut t = Literal::tuple(vec![
            Literal::vec1(&[1i32, 2]),
            Literal::vec1(&[0.5f32]),
        ]);
        assert_eq!(t.element_count(), 3);
        assert!(t.reshape(&[3]).is_err());
        assert!(t.to_vec::<i32>().is_err());
        let elems = t.decompose_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[0].to_vec::<i32>().unwrap(), vec![1, 2]);
        assert!(Literal::vec1(&[1i32]).decompose_tuple().is_err());
    }

    #[test]
    fn client_constructs_but_compilation_is_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        let err = HloModuleProto::from_text_file("/tmp/nope.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("nope.hlo.txt"));
    }

    #[test]
    fn buffers_roundtrip_and_donation_consumes() {
        let c = PjRtClient::cpu().unwrap();
        let lit = Literal::vec1(&[7i32, 8, 9]);
        let buf = c.buffer_from_host_literal(None, &lit).unwrap();
        assert_eq!(buf.to_literal_sync().unwrap(), lit);
        // identity hosted executable returning a 1-tuple
        let exe =
            PjRtLoadedExecutable::hosted(|args| Ok(Literal::tuple(vec![args[0].clone()])));
        // non-donating call: the buffer survives
        let out = exe.execute_b_opts(&[&buf], &[false]).unwrap();
        let mut root = out[0][0].to_literal_sync().unwrap();
        assert_eq!(root.decompose_tuple().unwrap()[0], lit);
        assert_eq!(buf.to_literal_sync().unwrap(), lit);
        // donating call (execute_b = donate-all): consumed afterwards
        exe.execute_b(&[&buf]).unwrap();
        assert!(buf.to_literal_sync().is_err(), "donated buffer must die");
        assert!(exe.execute_b(&[&buf]).is_err(), "double donation");
        // mask must cover every argument
        let b2 = c.buffer_from_host_literal(None, &lit).unwrap();
        assert!(exe.execute_b_opts(&[&b2], &[]).is_err());
    }

    #[test]
    fn compiled_execution_stays_unavailable() {
        let exe = PjRtLoadedExecutable { host_fn: None };
        let lit = Literal::vec1(&[1i32]);
        assert!(exe.execute(&[&lit]).is_err());
    }
}
