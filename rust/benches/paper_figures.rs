//! `cargo bench --bench paper_figures [-- fig5]` — regenerates every
//! FIGURE of the paper's evaluation (Figs. 2, 4, 5, 6) on a reduced
//! request count, printing the series each figure plots.

use flexspec::experiments::{all_experiments, Ctx};

fn main() -> anyhow::Result<()> {
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let ctx = Ctx::open(2, 7)?;  // reduced request count; full grid via `flexspec exp`
    let wanted = |id: &str| {
        id.starts_with("fig") && (filter.is_empty() || filter.iter().any(|f| id.contains(f.as_str())))
    };
    let t0 = std::time::Instant::now();
    for e in all_experiments() {
        if !wanted(e.id) {
            continue;
        }
        println!("\n############ {} — {}", e.id, e.title);
        let s = std::time::Instant::now();
        for t in (e.run)(&ctx)? {
            println!("{}", t.render());
        }
        println!("[{} took {:.1}s]", e.id, s.elapsed().as_secs_f64());
    }
    println!("\npaper_figures total: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
