//! `cargo bench --bench serve_latency` — the CI perf-trajectory run.
//!
//! Two halves:
//!
//! 1. **Observability primitive costs** (`util::bench` groups): the
//!    per-event cost of `Trace::record`, `LogHistogram::record`, a
//!    quantile read, the `LatencySummary` wire codec, and — the number
//!    the < 2% disabled-overhead budget rests on — the cost of the
//!    `Option<Trace>` check an instrumented hot path pays when no
//!    journal is installed.
//! 2. **An 8-session loopback serving run**, untraced and traced, on
//!    the deterministic synthetic backend: wall time plus the
//!    p50/p90/p99/p999 round/queue/verify/rtt quantiles from the
//!    `ServingMetrics` histograms — then the same run under continuous
//!    batching (`--batch-mode continuous`), asserting the rolling
//!    admission loop commits identical tokens while beating the
//!    windowed queue-wait p99 (docs/BATCHING.md).
//!
//! With `FLEXSPEC_BENCH_SERVE_JSON=path` the run writes a
//! machine-readable `BENCH_serve.json` (schema documented in
//! `docs/OBSERVABILITY.md`); CI uploads it as an artifact and gates on
//! the round-latency p99 against the checked-in baseline at the
//! repository root.

use std::time::Instant;

use anyhow::Result;
use flexspec::coordinator::DraftSource;
use flexspec::metrics::ServingMetrics;
use flexspec::obs::{LatencySummary, LogHistogram, SpanKind, Trace};
use flexspec::serve::{
    serve_loopback, BatchMode, EdgeReport, EdgeSessionConfig, SyntheticDraft, SyntheticTarget,
    VerifierConfig, VerifyBackend,
};
use flexspec::util::bench::{black_box, Group};
use flexspec::util::json::Json;

const SEED: u64 = 23;
const USERS: usize = 8;
const MAX_NEW: usize = 24;

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..5 {
                p.push(100 + ((i * 11 + j * 3) % 100) as i32);
            }
            p
        })
        .collect()
}

fn evolved_target() -> Result<SyntheticTarget> {
    let mut t = SyntheticTarget::new(SEED).with_version("evolved", 0.3);
    t.deploy("evolved")?;
    Ok(t)
}

/// One 8-session loopback run; `traced` installs a shared journal on
/// both the verifier and every edge session, `mode` picks the batcher
/// (windowed close-the-window vs continuous rolling slots).
fn run_loopback(
    traced: bool,
    mode: BatchMode,
) -> Result<(f64, ServingMetrics, Vec<EdgeReport>, Option<Trace>)> {
    let trace = traced.then(Trace::wall);
    let vcfg = VerifierConfig {
        window_ms: 12.0,
        batch_mode: mode,
        seed: SEED,
        trace: trace.clone(),
        ..Default::default()
    };
    let ecfg = EdgeSessionConfig {
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        trace: trace.clone(),
        ..Default::default()
    };
    let edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> = prompts(USERS)
        .into_iter()
        .map(|p| {
            (
                Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>,
                p,
            )
        })
        .collect();
    let rt = tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()?;
    let t0 = Instant::now();
    let (reports, metrics) = rt.block_on(serve_loopback(
        vcfg,
        || Ok(Box::new(evolved_target()?) as Box<dyn VerifyBackend>),
        edges,
        ecfg,
    ))?;
    Ok((t0.elapsed().as_secs_f64() * 1e3, metrics, reports, trace))
}

fn quantiles_json(l: &LatencySummary) -> Json {
    let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    Json::obj(vec![
        ("round_p50", num(l.round_ms.p50())),
        ("round_p90", num(l.round_ms.p90())),
        ("round_p99", num(l.round_ms.p99())),
        ("round_p999", num(l.round_ms.p999())),
        ("queue_p99", num(l.queue_ms.p99())),
        ("verify_p99", num(l.verify_ms.p99())),
        ("rtt_p99", num(l.rtt_ms.p99())),
    ])
}

fn main() -> Result<()> {
    // ---- obs primitive costs ------------------------------------------
    let mut g = Group::new("obs: primitive costs").with_budget(60.0);
    let tr = Trace::wall();
    let mut round = 0u32;
    g.add("Trace::record (steady-state, ring at cap)", || {
        round = round.wrapping_add(1);
        tr.record(1, round, SpanKind::Draft, 0.5, 4, 0);
    });
    // the disabled path every instrumented call site pays with no
    // journal installed — the < 2% regression budget rests on this
    let disabled: Option<Trace> = None;
    let mut n = 0u64;
    g.add("disabled: Option<Trace> check on the hot path", || {
        if let Some(t) = black_box(&disabled) {
            t.event(0, 0, SpanKind::Draft);
        }
        n = n.wrapping_add(1);
    });
    let mut h = LogHistogram::new();
    let mut x = 1.0f64;
    g.add("LogHistogram::record", || {
        x = (x * 1.37) % 900.0 + 0.01;
        h.record(black_box(x));
    });
    g.add("LogHistogram::p99 (256 buckets)", || {
        black_box(h.p99());
    });
    let mut summary = LatencySummary::new();
    for i in 0..512 {
        summary.round_ms.record(5.0 + (i % 37) as f64);
        summary.queue_ms.record(0.2 + (i % 11) as f64 * 0.1);
        summary.verify_ms.record(1.0 + (i % 7) as f64);
        summary.rtt_ms.record(8.0 + (i % 29) as f64);
    }
    g.add("LatencySummary wire encode+decode (sparse)", || {
        let mut buf = Vec::with_capacity(256);
        summary.encode_into(&mut buf);
        let (back, _) = LatencySummary::decode_from(&buf).unwrap();
        black_box(back.round_ms.count());
    });

    // ---- 8-session loopback latency run -------------------------------
    // warm-up run (thread spawn, allocator), then the measured pair
    let _ = run_loopback(false, BatchMode::Windowed)?;
    let (wall_off, m_off, _, _) = run_loopback(false, BatchMode::Windowed)?;
    let (wall_on, m_on, reports, trace) = run_loopback(true, BatchMode::Windowed)?;
    assert_eq!(m_on.sessions_completed, USERS);
    assert_eq!(m_off.rounds, m_on.rounds, "tracing changed the trajectory");
    println!(
        "\nserve: {USERS}-session loopback run — wall {wall_off:.0} ms untraced, \
         {wall_on:.0} ms traced ({} rounds, {} batches)",
        m_on.rounds, m_on.batches
    );
    print!("{}", m_on.latency.render_lines("  "));
    let events = trace.as_ref().map_or(0, |t| t.len());
    println!("  trace events recorded: {events}");

    // ---- windowed vs continuous batching cell -------------------------
    let (wall_cont, m_cont, _, _) = run_loopback(false, BatchMode::Continuous)?;
    assert_eq!(m_cont.sessions_completed, USERS);
    assert_eq!(
        m_cont.tokens_committed, m_off.tokens_committed,
        "batch mode changed a committed token"
    );
    let (win_q99, cont_q99) = (m_off.latency.queue_ms.p99(), m_cont.latency.queue_ms.p99());
    assert!(
        cont_q99 < win_q99,
        "continuous queue p99 {cont_q99:.2} ms must beat windowed {win_q99:.2} ms"
    );
    println!(
        "serve: continuous batching — wall {wall_cont:.0} ms, {} batches, \
         {} stacked dispatches, occupancy mean {:.2}",
        m_cont.batches,
        m_cont.stacked_dispatches,
        if m_cont.slot_occupancy.count() == 0 { 0.0 } else { m_cont.slot_occupancy.mean() }
    );
    println!(
        "  queue p99: windowed {win_q99:.2} ms -> continuous {cont_q99:.2} ms"
    );

    // merged edge-side rtt across the 8 sessions
    let mut edge_lat = LatencySummary::new();
    for r in &reports {
        edge_lat.merge(&r.latency);
    }

    // ---- machine-readable report (BENCH_serve.json) -------------------
    if let Some(path) = std::env::var_os("FLEXSPEC_BENCH_SERVE_JSON") {
        let mut lat = m_on.latency.clone();
        lat.rtt_ms.merge(&edge_lat.rtt_ms);
        let j = Json::obj(vec![
            ("schema", Json::str("flexspec-serve-bench-v1")),
            ("users", Json::Num(USERS as f64)),
            ("seed", Json::Num(SEED as f64)),
            ("max_new", Json::Num(MAX_NEW as f64)),
            ("rounds", Json::Num(m_on.rounds as f64)),
            ("batches", Json::Num(m_on.batches as f64)),
            ("tokens_committed", Json::Num(m_on.tokens_committed as f64)),
            ("wall_ms_untraced", Json::Num(wall_off)),
            ("wall_ms_traced", Json::Num(wall_on)),
            ("trace_events", Json::Num(events as f64)),
            ("quantiles_ms", quantiles_json(&lat)),
            ("latency", lat.to_json()),
            (
                "batch_modes",
                Json::obj(vec![
                    (
                        "window",
                        Json::obj(vec![
                            ("wall_ms", Json::Num(wall_off)),
                            ("batches", Json::Num(m_off.batches as f64)),
                            (
                                "stacked_dispatches",
                                Json::Num(m_off.stacked_dispatches as f64),
                            ),
                            ("queue_p99_ms", Json::Num(win_q99)),
                            ("round_p99_ms", Json::Num(m_off.latency.round_ms.p99())),
                        ]),
                    ),
                    (
                        "continuous",
                        Json::obj(vec![
                            ("wall_ms", Json::Num(wall_cont)),
                            ("batches", Json::Num(m_cont.batches as f64)),
                            (
                                "stacked_dispatches",
                                Json::Num(m_cont.stacked_dispatches as f64),
                            ),
                            ("queue_p99_ms", Json::Num(cont_q99)),
                            ("round_p99_ms", Json::Num(m_cont.latency.round_ms.p99())),
                            (
                                "slot_occupancy_mean",
                                Json::Num(if m_cont.slot_occupancy.count() == 0 {
                                    0.0
                                } else {
                                    m_cont.slot_occupancy.mean()
                                }),
                            ),
                        ]),
                    ),
                ]),
            ),
            ("obs_primitives", g.to_json()),
        ]);
        let path = std::path::PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, j.to_string_pretty())?;
        println!("wrote serve bench report to {}", path.display());
    }
    Ok(())
}
