//! `cargo bench --bench load_scale` — the fleet-scale trajectory run.
//!
//! Runs the named workload scenarios at bench scale and emits
//! `BENCH_load.json` (schema `flexspec-load-bench-v3`, documented in
//! `docs/LOADGEN.md`) when `FLEXSPEC_BENCH_LOAD_JSON=path` is set. CI
//! uploads the report as an artifact next to `BENCH_serve.json`, so
//! every PR extends the scalability trajectory.
//!
//! Hard assertions (machine-independent, so CI can gate on them
//! without a perf baseline):
//!
//! * determinism — every scenario runs twice; the digests must match
//!   byte for byte (with autoscale on, the digest folds in the control
//!   plane's action-log digest, so the loop's decisions are pinned
//!   too);
//! * conservation — every report passes the `ServingMetrics` audit;
//! * scale — the flash scenario must sustain >= 100k concurrently
//!   live sessions (the ISSUE's acceptance floor);
//! * control — on the SAME bounded-admission flash crowd, the
//!   autoscaled fleet must beat the fixed fleet on ttft p99 (the
//!   closed loop has to pay for itself, not just act);
//! * hetero — on the heterogeneous device mix (wire v8), tier-capped
//!   tree speculation must strictly beat forced-linear chains on
//!   accepted tokens per stacked dispatch (the bucket-aligned comb
//!   adds rows, never dispatch classes — docs/HETERO.md).
//!
//! Wall-clock numbers (events/s, real seconds) are reported for the
//! trajectory but never gated — they are machine-dependent.
//!
//! `FLEXSPEC_LOAD_MEGA=1` adds the million-session flash run (~10x the
//! default bench cost); it is off in CI's per-PR loop by design.

use std::time::Instant;

use anyhow::{ensure, Result};
use flexspec::autoscale::AutoscaleConfig;
use flexspec::load::{run, LoadConfig, LoadReport, Scenario};
use flexspec::util::json::Json;

const SEED: u64 = 3;
/// The acceptance floor: the flash scenario must hold at least this
/// many concurrently-live virtual sessions.
const FLASH_LIVE_FLOOR: usize = 100_000;
/// Sessions in the fixed-vs-autoscaled flash comparison cells.
const AUTOSCALE_SESSIONS: usize = 120_000;

struct Cell {
    label: &'static str,
    sessions: usize,
    report: LoadReport,
    real_s: f64,
    second_real_s: f64,
}

fn run_cfg_cell(label: &'static str, cfg: &LoadConfig) -> Result<Cell> {
    let t0 = Instant::now();
    let report = run(cfg);
    let real_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let again = run(cfg);
    let second_real_s = t1.elapsed().as_secs_f64();
    ensure!(
        report.digest() == again.digest(),
        "{label}: determinism violated — {:016x} != {:016x}",
        report.digest(),
        again.digest()
    );
    let violations = report.metrics.invariant_violations(0, 0);
    ensure!(
        violations.is_empty(),
        "{label}: conservation audit failed: {violations:?}"
    );
    println!(
        "{:15} {:>9} sessions: {:>9} events in {:.2} s real ({:>9.0} ev/s), \
         peak {:>7} live, ttft p99 {:>9.0} ms, digest {:016x}",
        label,
        cfg.sessions,
        report.events,
        real_s,
        report.events as f64 / real_s.max(1e-9),
        report.peak_live,
        report.ttft_ms.quantile(0.99),
        report.digest()
    );
    Ok(Cell {
        label,
        sessions: cfg.sessions,
        report,
        real_s,
        second_real_s,
    })
}

fn run_cell(scenario: Scenario, sessions: usize) -> Result<Cell> {
    run_cfg_cell(scenario.label(), &scenario.config(sessions, SEED))
}

/// The comparison workload: the flash preset with a bounded admission
/// queue (so Busy hints exist to adapt), fixed fleet vs an aggressive
/// closed loop. Everything except `autoscale` is identical.
fn flash_bounded(autoscaled: bool) -> LoadConfig {
    let mut cfg = Scenario::Flash.config(AUTOSCALE_SESSIONS, SEED);
    cfg.admission_queue = 48;
    if autoscaled {
        cfg.autoscale = Some(AutoscaleConfig {
            tick_ms: 500.0,
            min_replicas: cfg.replicas,
            max_replicas: 256,
            scale_up_queue: 4,
            up_ticks: 1,
            cooldown_ticks: 1,
            max_scale_step: 16,
            down_ticks: 20,
            ..AutoscaleConfig::default()
        });
    }
    cfg
}

fn cell_json(c: &Cell) -> Json {
    Json::obj(vec![
        ("scenario", Json::str(c.label)),
        ("sessions", Json::Num(c.sessions as f64)),
        ("real_s", Json::Num(c.real_s)),
        ("real_s_second_run", Json::Num(c.second_real_s)),
        (
            "events_per_s",
            Json::Num(c.report.events as f64 / c.real_s.max(1e-9)),
        ),
        ("report", c.report.to_json()),
    ])
}

fn main() -> Result<()> {
    let mega = std::env::var("FLEXSPEC_LOAD_MEGA").map_or(false, |v| v == "1");
    println!("load_scale: virtual-clock fleet workloads (seed {SEED})\n");

    let mut cells = vec![
        run_cell(Scenario::Steady, 10_000)?,
        run_cell(Scenario::Diurnal, 10_000)?,
        run_cell(Scenario::Churn, 10_000)?,
        run_cell(Scenario::Flash, 120_000)?,
    ];
    let flash = cells
        .iter()
        .find(|c| c.label == "flash")
        .expect("flash cell");
    ensure!(
        flash.report.peak_live >= FLASH_LIVE_FLOOR,
        "flash scenario peaked at {} live sessions (< {FLASH_LIVE_FLOOR})",
        flash.report.peak_live
    );
    println!(
        "\nflash scale floor: {} live sessions >= {FLASH_LIVE_FLOOR} ok",
        flash.report.peak_live
    );

    // the control-plane gate: same bounded-admission flash crowd, the
    // only difference being the closed loop — it must WIN on tail ttft
    let fixed = run_cfg_cell("flash-fixed", &flash_bounded(false))?;
    let auto = run_cfg_cell("flash-autoscale", &flash_bounded(true))?;
    let fq = fixed.report.ttft_ms.quantile(0.99);
    let aq = auto.report.ttft_ms.quantile(0.99);
    {
        let ar = auto.report.autoscale.as_ref().expect("autoscale report");
        println!(
            "autoscale gate: ttft p99 {aq:.0} ms vs fixed {fq:.0} ms \
             (+{} -{} replicas, {} rebalance redirects, {} actions, \
             retry_after {}–{} ms, log digest {:016x})",
            ar.replicas_added,
            ar.replicas_retired,
            ar.redirects,
            ar.actions,
            auto.report.retry_after_min_ms,
            auto.report.retry_after_max_ms,
            ar.log_digest
        );
        ensure!(
            ar.replicas_added > 0,
            "the flash crowd never triggered a scale-up"
        );
        // the static fleet quotes one window per Busy; the adaptive
        // hint must quote deeper once the backlog is multiple batches
        ensure!(
            auto.report.retry_after_max_ms > fixed.report.retry_after_max_ms,
            "adaptive Busy hints ({} ms) never quoted past the static window ({} ms)",
            auto.report.retry_after_max_ms,
            fixed.report.retry_after_max_ms
        );
    }
    ensure!(
        aq < fq,
        "autoscaled flash ttft p99 {aq:.0} ms must beat the fixed fleet's {fq:.0} ms"
    );
    cells.push(fixed);
    cells.push(auto);

    // the hetero tree gate (wire v8): the SAME heterogeneous device
    // population, tier-capped comb trees vs forced-linear chains — the
    // hedge rows ride existing stacked dispatches, so tree speculation
    // must strictly raise accepted tokens per dispatch
    let tree = run_cell(Scenario::Hetero, 10_000)?;
    let mut linear_cfg = Scenario::Hetero.config(10_000, SEED);
    linear_cfg.branching = 1;
    let linear = run_cfg_cell("hetero-linear", &linear_cfg)?;
    {
        let (ta, la) = (
            tree.report.accepted_per_dispatch(),
            linear.report.accepted_per_dispatch(),
        );
        println!(
            "hetero gate: {ta:.3} accepted/dispatch (tree) vs {la:.3} (linear), \
             {} tree rounds over {} rows",
            tree.report.metrics.tree_rounds, tree.report.metrics.verify_rows
        );
        ensure!(
            tree.report.metrics.tree_rounds > 0,
            "the hetero mix never drafted a tree"
        );
        ensure!(
            linear.report.metrics.tree_rounds == 0
                && linear.report.metrics.verify_rows == linear.report.metrics.rounds,
            "forced-linear hetero run still fanned out rows"
        );
        ensure!(
            ta > la,
            "tree speculation lost the dispatch-efficiency gate: \
             {ta:.3} accepted/dispatch <= linear {la:.3}"
        );
    }
    cells.push(tree);
    cells.push(linear);

    if mega {
        let c = run_cell(Scenario::Flash, 1_000_000)?;
        println!(
            "mega: 1M-session flash peaked at {} live sessions",
            c.report.peak_live
        );
        cells.push(c);
    } else {
        println!("(set FLEXSPEC_LOAD_MEGA=1 for the 1M-session run)");
    }

    if let Some(path) = std::env::var_os("FLEXSPEC_BENCH_LOAD_JSON") {
        let j = Json::obj(vec![
            ("schema", Json::str("flexspec-load-bench-v3")),
            ("seed", Json::Num(SEED as f64)),
            ("flash_live_floor", Json::Num(FLASH_LIVE_FLOOR as f64)),
            ("mega", Json::Num(mega as u8 as f64)),
            ("cells", Json::Arr(cells.iter().map(cell_json).collect())),
        ]);
        let path = std::path::PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, j.to_string_pretty())?;
        println!("wrote load bench report to {}", path.display());
    }
    Ok(())
}
