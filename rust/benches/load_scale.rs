//! `cargo bench --bench load_scale` — the fleet-scale trajectory run.
//!
//! Runs the named workload scenarios at bench scale and emits
//! `BENCH_load.json` (schema `flexspec-load-bench-v1`, documented in
//! `docs/LOADGEN.md`) when `FLEXSPEC_BENCH_LOAD_JSON=path` is set. CI
//! uploads the report as an artifact next to `BENCH_serve.json`, so
//! every PR extends the scalability trajectory.
//!
//! Hard assertions (machine-independent, so CI can gate on them
//! without a perf baseline):
//!
//! * determinism — every scenario runs twice; the digests must match
//!   byte for byte;
//! * conservation — every report passes the `ServingMetrics` audit;
//! * scale — the flash scenario must sustain >= 100k concurrently
//!   live sessions (the ISSUE's acceptance floor).
//!
//! Wall-clock numbers (events/s, real seconds) are reported for the
//! trajectory but never gated — they are machine-dependent.
//!
//! `FLEXSPEC_LOAD_MEGA=1` adds the million-session flash run (~10x the
//! default bench cost); it is off in CI's per-PR loop by design.

use std::time::Instant;

use anyhow::{ensure, Result};
use flexspec::load::{run, LoadReport, Scenario};
use flexspec::util::json::Json;

const SEED: u64 = 3;
/// The acceptance floor: the flash scenario must hold at least this
/// many concurrently-live virtual sessions.
const FLASH_LIVE_FLOOR: usize = 100_000;

struct Cell {
    scenario: Scenario,
    sessions: usize,
    report: LoadReport,
    real_s: f64,
    second_real_s: f64,
}

fn run_cell(scenario: Scenario, sessions: usize) -> Result<Cell> {
    let cfg = scenario.config(sessions, SEED);
    let t0 = Instant::now();
    let report = run(&cfg);
    let real_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let again = run(&cfg);
    let second_real_s = t1.elapsed().as_secs_f64();
    ensure!(
        report.digest() == again.digest(),
        "{}: determinism violated — {:016x} != {:016x}",
        scenario.label(),
        report.digest(),
        again.digest()
    );
    let violations = report.metrics.invariant_violations(0, 0);
    ensure!(
        violations.is_empty(),
        "{}: conservation audit failed: {violations:?}",
        scenario.label()
    );
    println!(
        "{:8} {:>9} sessions: {:>9} events in {:.2} s real ({:>9.0} ev/s), \
         peak {:>7} live, ttft p99 {:>9.0} ms, digest {:016x}",
        scenario.label(),
        sessions,
        report.events,
        real_s,
        report.events as f64 / real_s.max(1e-9),
        report.peak_live,
        report.ttft_ms.quantile(0.99),
        report.digest()
    );
    Ok(Cell {
        scenario,
        sessions,
        report,
        real_s,
        second_real_s,
    })
}

fn cell_json(c: &Cell) -> Json {
    Json::obj(vec![
        ("scenario", Json::str(c.scenario.label())),
        ("sessions", Json::Num(c.sessions as f64)),
        ("real_s", Json::Num(c.real_s)),
        ("real_s_second_run", Json::Num(c.second_real_s)),
        (
            "events_per_s",
            Json::Num(c.report.events as f64 / c.real_s.max(1e-9)),
        ),
        ("report", c.report.to_json()),
    ])
}

fn main() -> Result<()> {
    let mega = std::env::var("FLEXSPEC_LOAD_MEGA").map_or(false, |v| v == "1");
    println!("load_scale: virtual-clock fleet workloads (seed {SEED})\n");

    let mut cells = vec![
        run_cell(Scenario::Steady, 10_000)?,
        run_cell(Scenario::Diurnal, 10_000)?,
        run_cell(Scenario::Churn, 10_000)?,
        run_cell(Scenario::Flash, 120_000)?,
    ];
    let flash = cells
        .iter()
        .find(|c| c.scenario == Scenario::Flash)
        .expect("flash cell");
    ensure!(
        flash.report.peak_live >= FLASH_LIVE_FLOOR,
        "flash scenario peaked at {} live sessions (< {FLASH_LIVE_FLOOR})",
        flash.report.peak_live
    );
    println!(
        "\nflash scale floor: {} live sessions >= {FLASH_LIVE_FLOOR} ok",
        flash.report.peak_live
    );

    if mega {
        let c = run_cell(Scenario::Flash, 1_000_000)?;
        println!(
            "mega: 1M-session flash peaked at {} live sessions",
            c.report.peak_live
        );
        cells.push(c);
    } else {
        println!("(set FLEXSPEC_LOAD_MEGA=1 for the 1M-session run)");
    }

    if let Some(path) = std::env::var_os("FLEXSPEC_BENCH_LOAD_JSON") {
        let j = Json::obj(vec![
            ("schema", Json::str("flexspec-load-bench-v1")),
            ("seed", Json::Num(SEED as f64)),
            ("flash_live_floor", Json::Num(FLASH_LIVE_FLOOR as f64)),
            ("mega", Json::Num(mega as u8 as f64)),
            ("cells", Json::Arr(cells.iter().map(cell_json).collect())),
        ]);
        let path = std::path::PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, j.to_string_pretty())?;
        println!("wrote load bench report to {}", path.display());
    }
    Ok(())
}
