//! `cargo bench --bench microbench` — hot-path microbenchmarks for the
//! §Perf pass: the L3 coordinator pieces (policy search, codec, channel
//! step, sampling) and the PJRT execution path (draft step, verify
//! block, fused verify kernel, full round).

use flexspec::channel::{Channel, NetworkKind, NetworkProfile};
use flexspec::coordinator::edge::{DraftSource, ModelDraft};
use flexspec::coordinator::policy::{AdaptivePolicy, LatencyModel};
use flexspec::coordinator::CloudEngine;
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::protocol::frame::{CancelMsg, Frame, FrameDecoder, FrameKind, RedirectMsg};
use flexspec::protocol::{DraftMsg, VerifyMode, VerifyMsg, WireFormat};
use flexspec::runtime::Registry;
use flexspec::serve::{
    BatchVerifyReq, PipelinedDrafter, SessionCore, SessionLedger, SubmitOutcome, SyntheticDraft,
    SyntheticTarget, VerifierConfig, VerifierCore, VerifyBackend,
};
use flexspec::util::bench::{black_box, maybe_write_json_report, Group};
use flexspec::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    // ---- pure-L3 paths (no artifacts needed) --------------------------
    let mut g = Group::new("L3 coordinator hot paths").with_budget(150.0);

    let mut chan = NetworkProfile::new(NetworkKind::WifiWeak).channel(3);
    let state = chan.sample(0.0);
    let policy = AdaptivePolicy::new(8, 0.15);
    let lat = LatencyModel::build(&state, &JETSON_ORIN, &A800_70B, WireFormat::Sketch);
    g.add("policy: LatencyModel::build + select_k", || {
        let l = LatencyModel::build(
            black_box(&state),
            &JETSON_ORIN,
            &A800_70B,
            WireFormat::Sketch,
        );
        black_box(policy.select_k(&l));
    });
    g.add("channel: stochastic sample", || {
        black_box(chan.sample(black_box(1.0)));
    });
    let msg = DraftMsg {
        session: 1,
        round: 2,
        tokens: vec![100, 101, 102, 103, 104, 105],
        chosen_probs: vec![0.5; 6],
        mode: VerifyMode::Stochastic,
        wire: WireFormat::Sketch,
        basis_len: 0,
        spec: vec![],
        tree: vec![],
    };
    g.add("protocol: DraftMsg encode+decode+air_bytes", || {
        let buf = msg.encode();
        let back = DraftMsg::decode(&buf).unwrap();
        black_box((back.air_bytes(), lat.t_marginal_ms));
    });
    let mut rng = SplitMix64::new(1);
    let logits: Vec<f32> = (0..9 * 512).map(|_| rng.next_normal() as f32).collect();
    g.add("sampling: softmax(512) + top-p", || {
        let p = flexspec::runtime::sampling::sample_top_p(
            black_box(&logits[..512]),
            1.0,
            0.9,
            &mut rng,
        );
        black_box(p);
    });
    g.add("verify: greedy_verify_ref 8 tokens", || {
        let out = flexspec::runtime::sampling::greedy_verify_ref(
            black_box(&logits),
            512,
            &[1, 2, 3, 4, 5, 6, 7, 8],
            8,
        );
        black_box(out);
    });

    // ---- serve: frame codec throughput at K draft tokens --------------
    // (regressions here tax every round of the TCP/loopback serving path)
    let mut gf = Group::new("serve: frame codec (encode -> frame -> decode)").with_budget(80.0);
    let ks = [2usize, 4, 8];
    let frame_msgs: Vec<DraftMsg> = ks
        .iter()
        .map(|&k| DraftMsg {
            session: 3,
            round: 17,
            tokens: (0..k as i32).map(|i| 100 + i).collect(),
            chosen_probs: vec![0.5; k],
            mode: VerifyMode::Stochastic,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        })
        .collect();
    for (i, &k) in ks.iter().enumerate() {
        let fmsg = &frame_msgs[i];
        let nbytes = Frame::on(1, FrameKind::Draft, fmsg.encode()).encode().len();
        gf.add(&format!("draft frame roundtrip K={k} ({nbytes} B/frame)"), || {
            let f = Frame::on(1, FrameKind::Draft, black_box(fmsg).encode());
            let b = f.encode();
            let mut dec = FrameDecoder::new();
            dec.push(&b);
            let out = dec.next_frame().unwrap().unwrap();
            black_box(DraftMsg::decode(&out.payload).unwrap());
        });
    }
    for (i, r) in gf.results.iter().enumerate() {
        let nbytes = Frame::on(1, FrameKind::Draft, frame_msgs[i].encode())
            .encode()
            .len();
        println!(
            "    -> K={}: {:.1} MB/s framed-codec throughput",
            ks[i],
            nbytes as f64 / (r.mean_ns / 1e9) / 1e6
        );
    }

    // ---- serve: pipelined drafting (cancel-on-reject) -----------------
    // (regressions here tax every round of pipelined serving: the spec-
    // tagged draft + cancel codec and the planner's launch/resolve step)
    let mut gp = Group::new("serve: pipelined drafting").with_budget(80.0);
    let spec_msg = DraftMsg {
        session: 3,
        round: 18,
        tokens: (0..4).map(|i| 100 + i).collect(),
        chosen_probs: vec![],
        mode: VerifyMode::Greedy,
        wire: WireFormat::Compact,
        basis_len: 64,
        spec: (0..5).map(|i| 200 + i).collect(),
        tree: vec![],
    };
    gp.add("spec-tagged draft frame roundtrip K=4 + Cancel encode", || {
        let f = Frame::on(1, FrameKind::Draft, black_box(&spec_msg).encode());
        let b = f.encode();
        let mut dec = FrameDecoder::new();
        dec.push(&b);
        let out = dec.next_frame().unwrap().unwrap();
        let m = DraftMsg::decode(&out.payload).unwrap();
        let c = Frame::on(1, FrameKind::Cancel, CancelMsg { round: m.round + 1 }.encode());
        black_box((m.spec.len(), c.encode().len()));
    });
    gp.add("PipelinedDrafter: launch x2 + resolve (depth 2)", || {
        let mut core = SessionCore::new(1, &[1, 70, 71], 64);
        let mut p = PipelinedDrafter::new(2);
        let plan = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan, vec![5, 6, 7, 8], Some(9), 0);
        let plan2 = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan2, vec![10, 11, 12, 13], Some(14), 0);
        let v = VerifyMsg {
            session: 1,
            round: 0,
            tau: 4,
            correction: 9,
            eos: false,
            leaf: None,
        };
        black_box(p.resolve(&mut core, &v).held);
    });

    // RTT-hiding case (acceptance microbench): a pure pipelined decode
    // against the drifted synthetic target exposes strictly fewer
    // round-trip waits than the sequential lock-step loop
    {
        let seed = 23u64;
        let prompt = vec![1i32, 100, 103, 106, 109, 112];
        let mut target = {
            let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
            t.deploy("evolved").unwrap();
            t
        };
        let mut draft = SyntheticDraft::new(seed);
        let mut rng = SplitMix64::new(0);
        target.start_session(1, &prompt).unwrap();
        let mut core = SessionCore::new(1, &prompt, 48);
        let mut cloud = SessionCore::new(1, &prompt, 48);
        let mut p = PipelinedDrafter::new(2);
        while !core.done {
            while let Some(plan) = p.next_launch(&core) {
                let prop = draft.propose(&plan.context, 4, 0.0, 1.0, &mut rng).unwrap();
                let bonus = {
                    let mut c2 = plan.context.clone();
                    c2.extend_from_slice(&prop.tokens);
                    draft
                        .propose(&c2, 1, 0.0, 1.0, &mut rng)
                        .unwrap()
                        .tokens
                        .first()
                        .copied()
                };
                p.launched(&mut core, &plan, prop.tokens, bonus, 0);
            }
            p.note_wait();
            // only basis-valid drafts reach verification, so the head
            // equals the sequential draft from the committed prefix
            let head_tokens = draft
                .propose(&cloud.committed, 4, 0.0, 1.0, &mut rng)
                .unwrap()
                .tokens;
            let v = target
                .verify_block(
                    1,
                    &cloud.committed,
                    &head_tokens,
                    &[],
                    VerifyMode::Greedy,
                    0.0,
                    1.0,
                    &mut rng,
                )
                .unwrap();
            let vm = VerifyMsg {
                session: 1,
                round: p.head_round().unwrap(),
                tau: v.tau as u8,
                correction: v.correction,
                eos: v.eos,
                leaf: None,
            };
            cloud.apply_verdict(&head_tokens, v.tau, v.correction, v.eos, false);
            let _ = p.resolve(&mut core, &vm);
        }
        assert!(
            p.exposed_waits < core.rounds,
            "pipelining must hide RTTs ({} !< {})",
            p.exposed_waits,
            core.rounds
        );
        println!(
            "    -> depth 2 vs sequential: {} of {} RTT waits exposed \
             ({} hidden, {} rounds pipelined, {} drafts cancelled)",
            p.exposed_waits, core.rounds, p.overlapped_waits, p.rounds_pipelined, p.drafts_cancelled
        );
    }

    // ---- serve: batched verification executor -------------------------
    // (the tentpole's amortization claim, measurable: one stacked
    // verify_batch over the window vs the per-session loop, at window
    // sizes 1/4/8 with ragged strides — plus the cost of turning a
    // draft away at a full admission queue, which must stay state-free
    // and cheap since a saturated cloud pays it on every deferral)
    let mut gb = Group::new("serve: batched verification executor").with_budget(80.0);
    for &w in &[1usize, 4, 8] {
        let mut target = SyntheticTarget::new(9);
        let mut draft = SyntheticDraft::new(9);
        let mut brng = SplitMix64::new(0);
        let committed: Vec<Vec<i32>> = (0..w)
            .map(|i| {
                let mut c = vec![1, 100 + i as i32, 120 + 2 * i as i32];
                let p = draft.propose(&c, 6, 0.0, 1.0, &mut brng).unwrap();
                c.extend(p.tokens);
                c
            })
            .collect();
        for (i, c) in committed.iter().enumerate() {
            target.start_session(i as u32 + 1, c).unwrap();
        }
        // ragged strides K ∈ 1..=8 across the window
        let drafts: Vec<Vec<i32>> = committed
            .iter()
            .enumerate()
            .map(|(i, c)| {
                draft
                    .propose(c, 1 + i % 8, 0.0, 1.0, &mut brng)
                    .unwrap()
                    .tokens
            })
            .collect();
        gb.add(&format!("verify window={w}: per-session loop"), || {
            for i in 0..w {
                let v = target
                    .verify_block(
                        i as u32 + 1,
                        &committed[i],
                        &drafts[i],
                        &[],
                        VerifyMode::Greedy,
                        0.0,
                        1.0,
                        &mut brng,
                    )
                    .unwrap();
                black_box(v);
            }
        });
        gb.add(&format!("verify window={w}: stacked verify_batch"), || {
            let reqs: Vec<BatchVerifyReq> = committed
                .iter()
                .zip(&drafts)
                .enumerate()
                .map(|(i, (c, d))| BatchVerifyReq {
                    id: i as u32 + 1,
                    committed: c,
                    draft: d,
                    mode: VerifyMode::Greedy,
                })
                .collect();
            black_box(target.verify_batch(&reqs, 0.0, 1.0, &mut brng).unwrap());
        });
    }
    {
        let cfg = VerifierConfig {
            admission_queue: 1,
            ..Default::default()
        };
        let mut core = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(9)));
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = core.open_session(&pa, 64, 0).unwrap();
        let ob = core.open_session(&pb, 64, 0).unwrap();
        let mk = |session: u32, committed: &[i32]| {
            let mut d = SyntheticDraft::new(9);
            let mut r = SplitMix64::new(0);
            let p = d.propose(committed, 4, 0.0, 1.0, &mut r).unwrap();
            DraftMsg {
                session,
                round: 0,
                tokens: p.tokens,
                chosen_probs: vec![],
                mode: VerifyMode::Greedy,
                wire: WireFormat::Compact,
                basis_len: 0,
                spec: vec![],
                tree: vec![],
            }
        };
        // a's round fills the bound; every further submit is deferred
        core.submit(0.0, oa.attachment, mk(oa.session, &pa), true)
            .unwrap();
        let busy_draft = mk(ob.session, &pb);
        gb.add("admission: queue-full submit -> Busy (state-free)", || {
            match core
                .submit(0.1, ob.attachment, busy_draft.clone(), true)
                .unwrap()
            {
                SubmitOutcome::Busy { retry_after_ms } => {
                    black_box(retry_after_ms);
                }
                other => panic!("expected Busy, got {other:?}"),
            }
        });
    }

    // ---- serve: fleet redirect handoff (wire v5) ----------------------
    // (the cost of moving one live session between replicas: export to
    // the shared ledger + the Redirect frame + ledger import on resume.
    // This is pure control-plane work — it must stay microseconds, since
    // a draining replica pays it once per session and the edge already
    // pays the real price in reconnect round trips)
    let mut gfl = Group::new("serve: fleet redirect handoff").with_budget(80.0);
    {
        let ledger = SessionLedger::new();
        // zero grace + a per-iteration sweep: the handoff tombstones
        // (redirected ids/tokens, verdict cache) must be reclaimed as
        // the loop runs, or the bench would time HashMap growth instead
        // of the constant-time handoff it claims to pin
        let mut a = VerifierCore::new(
            VerifierConfig {
                resume_grace_ms: 0.0,
                ..Default::default()
            },
            Box::new(SyntheticTarget::new(9)),
        )
        .with_ledger(ledger.clone());
        let mut b = VerifierCore::new(
            VerifierConfig::default(),
            Box::new(SyntheticTarget::new(9)),
        )
        .with_ledger(ledger.clone());
        a.set_redirect(Some("replica-b".into()));
        let prompt = vec![1, 70, 71];
        let mut d = SyntheticDraft::new(9);
        let mut r0 = SplitMix64::new(0);
        let p = d.propose(&prompt, 4, 0.0, 1.0, &mut r0).unwrap();
        let mut now = 0.0f64;
        gfl.add("handoff: export -> Redirect -> import (1 session)", || {
            now += 1.0;
            let o = a.open_session(&prompt, 64, 0).unwrap();
            let msg = DraftMsg {
                session: o.session,
                round: 0,
                tokens: p.tokens.clone(),
                chosen_probs: vec![],
                mode: VerifyMode::Greedy,
                wire: WireFormat::Compact,
                basis_len: 0,
                spec: vec![],
                tree: vec![],
            };
            let token = match a.submit_from(now, o.attachment, msg, 5).unwrap() {
                SubmitOutcome::Redirect { resume_token, .. } => resume_token,
                other => panic!("expected Redirect, got {other:?}"),
            };
            let info = b.resume(token, prompt.len()).unwrap();
            b.abort_session(info.session);
            a.evict_expired(now + 1.0);
            black_box(info.committed_len);
        });
        let rmsg = RedirectMsg {
            addr: "replica-b:7412".into(),
            resume_token: 0x1234_5678_9ABC_DEF0,
        };
        gfl.add("Redirect frame roundtrip", || {
            let f = Frame::on(1, FrameKind::Redirect, black_box(&rmsg).encode());
            let bytes = f.encode();
            let mut dec = FrameDecoder::new();
            dec.push(&bytes);
            let out = dec.next_frame().unwrap().unwrap();
            black_box(RedirectMsg::decode(&out.payload).unwrap());
        });
    }

    // ---- PJRT execution paths (need artifacts) ------------------------
    let Ok(reg) = Registry::open_default() else {
        println!("\n(artifacts missing — run `make artifacts` for the PJRT benches)");
        maybe_write_json_report(&[&g, &gf, &gp, &gb, &gfl])?;
        return Ok(());
    };
    if !reg.manifest.weights.contains_key("draft_flex_llama2t") {
        maybe_write_json_report(&[&g, &gf, &gp, &gb, &gfl])?;
        return Ok(());
    }
    let mut g2 = Group::new("PJRT execution paths").with_budget(2000.0);

    let draft_rt = reg.model("draft_flex_llama2t")?;
    let mut draft = ModelDraft::new(draft_rt.clone())?;
    let committed: Vec<i32> = (0..24).map(|i| 64 + (i * 7 % 64)).collect();
    let mut rng2 = SplitMix64::new(2);
    g2.add("edge: ModelDraft.propose k=6 (incl. ingest)", || {
        draft.reset().unwrap();
        let p = draft
            .propose(black_box(&committed), 6, 0.0, 1.0, &mut rng2)
            .unwrap();
        black_box(p.tokens.len());
    });

    let target = reg.model("target_llama2t_base")?;
    let lora = reg.zero_lora("llama2t")?;
    let mut kv = target.new_kv()?;
    target.prefill(Some(&lora), &committed, &mut kv)?;
    let block: Vec<i32> = (0..9).map(|i| 70 + i).collect();
    g2.add("cloud: target forward_block(9) no-commit", || {
        let pos = kv.pos;
        let out = target
            .forward_block(Some(&lora), black_box(&block), &mut kv, 0)
            .unwrap();
        kv.pos = pos;
        black_box(out.rows);
    });
    let mut kvp = target.new_kv()?;
    g2.add("cloud: target prefill(64)", || {
        kvp.pos = 0;
        let row = target
            .prefill(Some(&lora), black_box(&committed), &mut kvp)
            .unwrap();
        black_box(row[0]);
    });

    let verify = reg.verify(512)?;
    let vlogits = vec![0.5f32; 9 * 512];
    let dtoks = [1i32, 2, 3, 4, 5, 6, 7, 8];
    g2.add("L1: fused Pallas verify kernel (9x512)", || {
        let (tau, corr, _) = verify.verify(black_box(&vlogits), &dtoks, 8).unwrap();
        black_box((tau, corr));
    });

    let mut cloud = CloudEngine::new(&reg, "lora_llama2t_gsm8k", 2)?;
    let prompt: Vec<i32> = vec![1, 70, 77, 85, 90, 71];
    cloud.start_session(1, &prompt)?;
    let mut committed2 = prompt.clone();
    let mut rng3 = SplitMix64::new(3);
    let mut draft = ModelDraft::new(reg.model("draft_flex_llama2t")?)?; // fresh context
    draft.reset()?;
    g2.add("e2e: full verify round (draft 5 + verify + commit)", || {
        if cloud.remaining_capacity(1) < 12 {
            cloud.end_session(1);
            cloud.start_session(1, &prompt).unwrap();
            committed2 = prompt.clone();
            draft.reset().unwrap();
        }
        let p = draft.propose(&committed2, 5, 0.0, 1.0, &mut rng3).unwrap();
        let v = cloud
            .verify(
                1,
                &committed2,
                &p.tokens,
                &p.prob_rows,
                VerifyMode::Greedy,
                0.0,
                1.0,
                &mut rng3,
            )
            .unwrap();
        for &t in &p.tokens[..v.outcome.tau] {
            committed2.push(t);
        }
        committed2.push(v.outcome.correction);
        black_box(v.outcome.tau);
    });

    println!(
        "\nstats: target block_calls={} prefills={} tokens={} exec_time={:.1}ms",
        target.stats.block_calls.get(),
        target.stats.prefill_calls.get(),
        target.stats.tokens_processed.get(),
        target.stats.exec_nanos.get() as f64 / 1e6,
    );
    maybe_write_json_report(&[&g, &gf, &gp, &gb, &gfl, &g2])?;
    Ok(())
}
