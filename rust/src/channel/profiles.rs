//! Network profiles for the paper's three evaluation regimes.
//!
//! Calibration (EXPERIMENTS.md §Calibration): profile parameters are set
//! so that the *Cloud-Only* per-token latencies land near the paper's
//! anchors (5G ≈ 432 ms, 4G ≈ 595 ms, weak WiFi ≈ 1220 ms with a 70B-class
//! cloud step of ~380 ms) and so that the paper's §III-D claim —
//! "transmitting five tokens may incur ≈200 ms of uplink delay" in
//! weak-signal conditions — holds for the draft-block payload of the
//! protocol layer.

use super::fading::StochasticChannel;
use super::NetworkKind::*;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NetworkKind {
    FiveG,
    FourG,
    WifiWeak,
}

impl NetworkKind {
    pub fn parse(s: &str) -> Option<NetworkKind> {
        match s.to_ascii_lowercase().as_str() {
            "5g" | "fiveg" | "strong" => Some(FiveG),
            "4g" | "fourg" | "lte" | "avg" => Some(FourG),
            "wifi" | "wifi_weak" | "weak" => Some(WifiWeak),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FiveG => "5G (Strong)",
            FourG => "4G (Avg)",
            WifiWeak => "WiFi (Weak)",
        }
    }

    pub fn all() -> [NetworkKind; 3] {
        [FiveG, FourG, WifiWeak]
    }
}

/// Parameters of one wireless regime.
#[derive(Debug, Clone)]
pub struct NetworkProfile {
    pub kind: NetworkKind,
    /// Median uplink rate (bits/s) — log-normal shadowing multiplies this.
    pub up_bps: f64,
    pub down_bps: f64,
    /// Log-normal shadowing sigma (of ln rate).
    pub sigma: f64,
    /// One-way propagation delay, ms (median).
    pub prop_ms: f64,
    /// Jitter sigma on prop (lognormal).
    pub prop_sigma: f64,
    /// Gilbert-Elliott burst process: P(good -> bad) per sample.
    pub p_enter_fade: f64,
    /// P(bad -> good) per sample.
    pub p_exit_fade: f64,
    /// Rate divisor while fading (deep-fade retransmission regime).
    pub fade_rate_div: f64,
    /// Propagation multiplier while fading.
    pub fade_prop_mul: f64,
    /// Per-MTU packet loss probability in the good state.
    pub loss_rate: f64,
    /// Per-MTU packet loss probability while fading.
    pub fade_loss_rate: f64,
}

impl NetworkProfile {
    pub fn new(kind: NetworkKind) -> NetworkProfile {
        match kind {
            FiveG => NetworkProfile {
                kind,
                up_bps: 300e6,
                down_bps: 600e6,
                sigma: 0.20,
                prop_ms: 18.0,
                prop_sigma: 0.10,
                p_enter_fade: 0.01,
                p_exit_fade: 0.60,
                fade_rate_div: 4.0,
                fade_prop_mul: 1.5,
                loss_rate: 0.002,
                fade_loss_rate: 0.02,
            },
            FourG => NetworkProfile {
                kind,
                up_bps: 50e6,
                down_bps: 100e6,
                sigma: 0.30,
                prop_ms: 95.0,
                prop_sigma: 0.15,
                p_enter_fade: 0.04,
                p_exit_fade: 0.45,
                fade_rate_div: 5.0,
                fade_prop_mul: 1.8,
                loss_rate: 0.08,
                fade_loss_rate: 0.20,
            },
            WifiWeak => NetworkProfile {
                kind,
                up_bps: 1.5e6,
                down_bps: 4e6,
                sigma: 0.55,
                prop_ms: 180.0,
                prop_sigma: 0.25,
                p_enter_fade: 0.10,
                p_exit_fade: 0.35,
                fade_rate_div: 8.0,
                fade_prop_mul: 2.5,
                loss_rate: 0.25,
                fade_loss_rate: 0.50,
            },
        }
    }

    pub fn channel(&self, seed: u64) -> StochasticChannel {
        StochasticChannel::new(self.clone(), seed)
    }

    /// Time (minutes) to push `bytes` over this link's mean downlink —
    /// Table I's model-synchronization cost.
    pub fn sync_minutes(&self, bytes: u64) -> f64 {
        (bytes as f64 * 8.0) / self.down_bps / 60.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Channel;

    #[test]
    fn parse_aliases() {
        assert_eq!(NetworkKind::parse("5G"), Some(FiveG));
        assert_eq!(NetworkKind::parse("lte"), Some(FourG));
        assert_eq!(NetworkKind::parse("wifi"), Some(WifiWeak));
        assert_eq!(NetworkKind::parse("zigbee"), None);
    }

    #[test]
    fn rates_order_across_profiles() {
        let g5 = NetworkProfile::new(FiveG);
        let g4 = NetworkProfile::new(FourG);
        let wf = NetworkProfile::new(WifiWeak);
        assert!(g5.up_bps > g4.up_bps && g4.up_bps > wf.up_bps);
        assert!(g5.prop_ms < g4.prop_ms && g4.prop_ms < wf.prop_ms);
    }

    #[test]
    fn sync_minutes_matches_table1_order() {
        // Table I: 3.2 GB draft model: WiFi(10Mbps there) ~48 min,
        // 4G(50) ~9.5 min, 5G(300) ~1.6 min. Our downlinks differ, but
        // the 4G/5G anchors must land close.
        let gb32: u64 = 3_200_000_000;
        let g4 = NetworkProfile::new(FourG).sync_minutes(gb32);
        let g5 = NetworkProfile::new(FiveG).sync_minutes(gb32);
        assert!((g4 - 4.3).abs() < 1.0, "4G {g4}"); // 100 Mbps downlink
        assert!(g5 < 1.0, "5G {g5}");
    }

    #[test]
    fn mean_sampled_rate_tracks_profile() {
        for kind in NetworkKind::all() {
            let p = NetworkProfile::new(kind);
            let mut c = p.channel(7);
            let n = 4000;
            let mean: f64 = (0..n).map(|i| c.sample(i as f64 * 100.0).up_bps).sum::<f64>() / n as f64;
            // within a factor ~2 of the median (shadowing + fades skew down)
            assert!(
                mean > p.up_bps * 0.3 && mean < p.up_bps * 2.0,
                "{kind:?}: mean {mean} vs {}",
                p.up_bps
            );
        }
    }
}
