//! Channel trace record/replay.
//!
//! A trace is the sequence of `ChannelState`s a stochastic channel
//! produced; replaying it gives bit-identical network conditions across
//! methods — how the experiment harness guarantees every baseline sees
//! the same wireless weather (the paper's per-figure comparisons assume
//! this implicitly).

use super::{Channel, ChannelState};
use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct ChannelTrace {
    pub name: String,
    pub states: Vec<ChannelState>,
}

impl ChannelTrace {
    /// Record `n` samples from any channel.
    pub fn record(chan: &mut dyn Channel, n: usize, dt_ms: f64) -> ChannelTrace {
        ChannelTrace {
            name: chan.name(),
            states: (0..n).map(|i| chan.sample(i as f64 * dt_ms)).collect(),
        }
    }

    /// CSV persistence: `up_bps,down_bps,prop_ms,fading,loss` per line.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut out = format!("# flexspec channel trace: {}\n", self.name);
        for s in &self.states {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                s.up_bps, s.down_bps, s.prop_ms, s.fading as u8, s.loss_rate
            ));
        }
        std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ChannelTrace> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let mut t = ChannelTrace {
            name: path.file_stem().unwrap_or_default().to_string_lossy().into(),
            states: Vec::new(),
        };
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != 5 {
                bail!("trace {path:?} line {}: expected 5 fields", i + 1);
            }
            t.states.push(ChannelState {
                up_bps: f[0].parse()?,
                down_bps: f[1].parse()?,
                prop_ms: f[2].parse()?,
                fading: f[3] == "1",
                loss_rate: f[4].parse()?,
            });
        }
        if t.states.is_empty() {
            bail!("trace {path:?} is empty");
        }
        Ok(t)
    }

    pub fn replay(&self) -> TraceChannel {
        TraceChannel {
            trace: self.clone(),
            idx: 0,
        }
    }
}

/// Replays a trace, looping if the run outlives it.
#[derive(Debug, Clone)]
pub struct TraceChannel {
    trace: ChannelTrace,
    idx: usize,
}

impl Channel for TraceChannel {
    fn sample(&mut self, _now_ms: f64) -> ChannelState {
        let s = self.trace.states[self.idx % self.trace.states.len()];
        self.idx += 1;
        s
    }

    fn name(&self) -> String {
        format!("trace:{}", self.trace.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::profiles::{NetworkKind, NetworkProfile};

    #[test]
    fn record_replay_roundtrip() {
        let mut c = NetworkProfile::new(NetworkKind::FourG).channel(9);
        let t = ChannelTrace::record(&mut c, 64, 100.0);
        let mut r = t.replay();
        for s in &t.states {
            assert_eq!(r.sample(0.0), *s);
        }
        // loops
        assert_eq!(r.sample(0.0), t.states[0]);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("flexspec_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut c = NetworkProfile::new(NetworkKind::WifiWeak).channel(1);
        let t = ChannelTrace::record(&mut c, 32, 50.0);
        t.save(&path).unwrap();
        let back = ChannelTrace::load(&path).unwrap();
        assert_eq!(back.states.len(), 32);
        for (a, b) in t.states.iter().zip(&back.states) {
            assert!((a.up_bps - b.up_bps).abs() < 1e-6);
            assert_eq!(a.fading, b.fading);
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("flexspec_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "1,2,3,4\n").unwrap();
        assert!(ChannelTrace::load(&path).is_err());
        std::fs::write(&path, "# only comments\n").unwrap();
        assert!(ChannelTrace::load(&path).is_err());
    }
}
