//! Wireless channel simulation (DESIGN.md S5).
//!
//! The paper evaluates three regimes — 5G (strong), 4G (average), weak
//! WiFi — and eq. (8) consumes exactly two quantities per round: the
//! instantaneous uplink rate `R_n` and the propagation delay `T_prop`.
//! The simulator reproduces those regimes with log-normal shadowing on
//! top of a Gilbert-Elliott good/bad burst process (deep fades in
//! elevators/subways), plus trace record/replay for reproducible runs.

pub mod fading;
pub mod profiles;
pub mod trace;

pub use fading::StochasticChannel;
pub use profiles::{NetworkProfile, NetworkKind};
pub use trace::{ChannelTrace, TraceChannel};

/// Instantaneous channel state observed by the edge at one decode round
/// (the paper's "Measure channel conditions" step in Algorithm 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChannelState {
    /// Achievable uplink rate in bits per second.
    pub up_bps: f64,
    /// Achievable downlink rate in bits per second.
    pub down_bps: f64,
    /// One-way propagation delay (ms) — half the RTT.
    pub prop_ms: f64,
    /// True while the Gilbert-Elliott process is in the deep-fade state.
    pub fading: bool,
    /// Per-MTU packet loss probability (drives ARQ retransmissions —
    /// the superlinear cost that makes big fixed-stride draft blocks
    /// time out on weak links, paper Fig. 5).
    pub loss_rate: f64,
}

/// Path MTU used for ARQ accounting.
pub const MTU_BYTES: f64 = 1500.0;
/// Mean retransmission timeout per lost packet, ms.
pub const RTO_MS: f64 = 600.0;

impl ChannelState {
    /// Uplink time for a payload of `bytes` (eq. 8 without T_prop),
    /// including the expected ARQ retransmission penalty:
    /// ceil(bytes/MTU) packets, each lost w.p. loss_rate, each loss
    /// costing one RTO. This is what makes K large payloads superlinearly
    /// expensive in weak signal.
    pub fn up_ms(&self, bytes: usize) -> f64 {
        let tx = (bytes as f64 * 8.0) / self.up_bps * 1e3;
        let packets = (bytes as f64 / MTU_BYTES).ceil();
        tx + packets * self.loss_rate * RTO_MS
    }

    pub fn down_ms(&self, bytes: usize) -> f64 {
        let tx = (bytes as f64 * 8.0) / self.down_bps * 1e3;
        let packets = (bytes as f64 / MTU_BYTES).ceil();
        tx + packets * (self.loss_rate * 0.5) * RTO_MS
    }
}

/// A channel model the coordinator can sample each round.
pub trait Channel {
    /// Sample the state at virtual time `now_ms`.
    fn sample(&mut self, now_ms: f64) -> ChannelState;

    /// Human-readable name for reports.
    fn name(&self) -> String;
}

/// Fixed channel (unit tests + analytic checks).
#[derive(Debug, Clone)]
pub struct ConstChannel(pub ChannelState);

impl Channel for ConstChannel {
    fn sample(&mut self, _now_ms: f64) -> ChannelState {
        self.0
    }

    fn name(&self) -> String {
        "const".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn up_ms_units() {
        let s = ChannelState {
            up_bps: 1e6, // 1 Mbps
            down_bps: 2e6,
            prop_ms: 10.0,
            fading: false,
            loss_rate: 0.0,
        };
        // 1000 bytes = 8000 bits over 1 Mbps = 8 ms
        assert!((s.up_ms(1000) - 8.0).abs() < 1e-9);
        assert!((s.down_ms(1000) - 4.0).abs() < 1e-9);
        // with loss: 3000 bytes = 2 packets, 10% loss, 300ms RTO -> +60ms
        let lossy = ChannelState { loss_rate: 0.1, ..s };
        assert!((lossy.up_ms(3000) - (24.0 + 120.0)).abs() < 1e-9);
    }

    #[test]
    fn const_channel_is_constant() {
        let st = ChannelState {
            up_bps: 5e6,
            down_bps: 5e6,
            prop_ms: 5.0,
            fading: false,
            loss_rate: 0.0,
        };
        let mut c = ConstChannel(st);
        assert_eq!(c.sample(0.0), st);
        assert_eq!(c.sample(1e6), st);
    }
}
