//! Stochastic channel: log-normal shadowing + Gilbert-Elliott bursts.
//!
//! Shadowing is sampled per round with temporal correlation (AR(1) on the
//! log-rate) so consecutive decode rounds see similar conditions — the
//! property the paper's EMA-based policy exploits. The Gilbert-Elliott
//! two-state chain produces the deep fades (elevator/subway) that make
//! fixed large strides time out in Fig. 5.

use super::profiles::NetworkProfile;
use super::{Channel, ChannelState};
use crate::util::rng::SplitMix64;

#[derive(Debug, Clone)]
pub struct StochasticChannel {
    profile: NetworkProfile,
    rng: SplitMix64,
    /// AR(1) state of the log-shadowing term.
    log_shadow: f64,
    /// AR(1) correlation between consecutive samples.
    rho: f64,
    fading: bool,
    samples: u64,
}

impl StochasticChannel {
    pub fn new(profile: NetworkProfile, seed: u64) -> StochasticChannel {
        StochasticChannel {
            rng: SplitMix64::new(seed ^ 0xC0DE_C0DE),
            log_shadow: 0.0,
            rho: 0.85,
            fading: false,
            samples: 0,
            profile,
        }
    }

    pub fn profile(&self) -> &NetworkProfile {
        &self.profile
    }
}

impl Channel for StochasticChannel {
    fn sample(&mut self, _now_ms: f64) -> ChannelState {
        let p = &self.profile;
        // AR(1) shadowing on log rate: stationary sigma == p.sigma
        let innov = (1.0 - self.rho * self.rho).sqrt() * p.sigma;
        self.log_shadow = self.rho * self.log_shadow + innov * self.rng.next_normal();
        // Gilbert-Elliott burst state
        if self.fading {
            if self.rng.chance(p.p_exit_fade) {
                self.fading = false;
            }
        } else if self.rng.chance(p.p_enter_fade) {
            self.fading = true;
        }
        let shadow = self.log_shadow.exp();
        let (rate_div, prop_mul) = if self.fading {
            (p.fade_rate_div, p.fade_prop_mul)
        } else {
            (1.0, 1.0)
        };
        let prop_jitter = self.rng.next_lognormal(0.0, p.prop_sigma);
        self.samples += 1;
        ChannelState {
            up_bps: (p.up_bps * shadow / rate_div).max(1e3),
            down_bps: (p.down_bps * shadow / rate_div).max(1e3),
            prop_ms: p.prop_ms * prop_jitter * prop_mul,
            fading: self.fading,
            loss_rate: if self.fading { p.fade_loss_rate } else { p.loss_rate },
        }
    }

    fn name(&self) -> String {
        format!("{:?}", self.profile.kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::profiles::NetworkKind;
    use crate::util::prop;

    fn chan(seed: u64) -> StochasticChannel {
        NetworkProfile::new(NetworkKind::WifiWeak).channel(seed)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = chan(3);
        let mut b = chan(3);
        for i in 0..50 {
            assert_eq!(a.sample(i as f64), b.sample(i as f64));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = chan(3);
        let mut b = chan(4);
        let same = (0..50)
            .filter(|&i| a.sample(i as f64) == b.sample(i as f64))
            .count();
        assert!(same < 5);
    }

    #[test]
    fn fades_occur_and_clear() {
        let mut c = chan(5);
        let states: Vec<bool> = (0..2000).map(|i| c.sample(i as f64).fading).collect();
        let fade_frac = states.iter().filter(|&&f| f).count() as f64 / states.len() as f64;
        // stationary fraction ~ p_enter/(p_enter+p_exit) = 0.10/0.45 ≈ 0.22
        assert!((0.1..0.4).contains(&fade_frac), "fade fraction {fade_frac}");
        // bursts: at least one entry AND one exit
        assert!(states.windows(2).any(|w| !w[0] && w[1]));
        assert!(states.windows(2).any(|w| w[0] && !w[1]));
    }

    #[test]
    fn rates_positive_and_correlated() {
        prop::check(20, |rng| {
            let mut c = chan(rng.next_u64());
            let xs: Vec<f64> = (0..200).map(|i| c.sample(i as f64).up_bps).collect();
            prop::assert_prop(xs.iter().all(|&x| x > 0.0), "nonpositive rate")?;
            // lag-1 autocorrelation of log-rate should be clearly positive
            let logs: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
            let mean = logs.iter().sum::<f64>() / logs.len() as f64;
            let var: f64 = logs.iter().map(|x| (x - mean) * (x - mean)).sum();
            let cov: f64 = logs
                .windows(2)
                .map(|w| (w[0] - mean) * (w[1] - mean))
                .sum();
            prop::assert_prop(cov / var > 0.3, format!("autocorr {}", cov / var))
        });
    }

    #[test]
    fn fading_state_degrades_rate() {
        let mut c = chan(11);
        let mut good = Vec::new();
        let mut bad = Vec::new();
        for i in 0..4000 {
            let s = c.sample(i as f64);
            if s.fading {
                bad.push(s.up_bps);
            } else {
                good.push(s.up_bps);
            }
        }
        let mg = good.iter().sum::<f64>() / good.len() as f64;
        let mb = bad.iter().sum::<f64>() / bad.len() as f64;
        assert!(mg > 3.0 * mb, "good {mg} vs bad {mb}");
    }
}
