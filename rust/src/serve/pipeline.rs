//! Pipelined speculative drafting with cancel-on-reject (PipeSD-style,
//! ROADMAP "Serving" item 1): the edge keeps up to `depth` rounds in
//! flight, drafting round r+1 from the OPTIMISTIC prefix (all of round
//! r accepted, bonus token predicted by the draft itself) while round r
//! verifies — hiding the uplink + verify + downlink round trip that
//! otherwise idles the edge whenever `T_fixed` dominates
//! `K * T_marginal`.
//!
//! # Why the committed sequence cannot change
//!
//! A speculative round is verified by the cloud ONLY if its basis —
//! `committed[..basis_len] ++ spec` — equals the cloud's actual
//! committed sequence at that round's turn (`DraftMsg::{basis_len,
//! spec}`, wire v3). Because every draft source used for pipelining is a
//! pure function of its context
//! ([`DraftSource::is_pure`](crate::coordinator::edge::DraftSource::is_pure)), a
//! basis-valid speculative draft is byte-identical to the draft a
//! sequential edge would have produced from the true committed prefix,
//! so its verdict — and the committed sequence — is byte-identical to
//! the sequential trajectory. A basis-broken draft is discarded by the
//! cloud autonomously and retracted by the edge with a
//! [`Cancel`](crate::protocol::frame::FrameKind::Cancel)
//! frame; the round is redrafted from the true prefix under the same
//! round number. The `Cancel` is therefore an advisory fast-path: a
//! dropped, delayed, or duplicated `Cancel` can never change a single
//! committed token (pinned by `tests/serve_faults.rs`).
//!
//! # Data flow (depth 2)
//!
//! ```text
//! edge                                   cloud
//!  Draft(r)            ─────────────▶    verify r ──┐ (window)
//!  Draft(r+1, spec=[draft_r ++ bonus]) ▶ queue r+1  │
//!          ◀──────────────── Verify(r) ◀────────────┘
//!  held? ──yes──▶ Draft(r+2, spec=...)   basis check on r+1:
//!        └─no──▶ Cancel(r+1)               valid → verify (pipelined)
//!                Draft(r+1) redraft        stale → discard (wasted)
//! ```
//!
//! The state machine below ([`PipelinedDrafter`]) is PURE — no sockets,
//! no clocks — and is driven by the edge session loop
//! (`edge::run_session_on` with `EdgeSessionConfig::pipeline_depth`),
//! while the virtual-clock simulator (`coordinator::scheduler`) mirrors
//! the same launch/validity rules, which is what keeps the serving
//! stack and the simulation committing identical sequences AND
//! identical pipeline counters for a fixed seed.

use super::session::SessionCore;
use crate::protocol::VerifyMsg;
use std::collections::VecDeque;

/// Hard ceiling on rounds in flight (also bounds the cloud's per-session
/// speculative queue). Depth beyond a few never pays: speculation must
/// survive `depth - 1` consecutive full acceptances WITH predicted
/// bonus tokens, a probability that decays like `gamma^{(K+1)(depth-1)}`.
pub const MAX_PIPELINE_DEPTH: usize = 4;

/// One round the edge has sent and not yet seen the verdict for.
#[derive(Debug, Clone)]
pub struct InflightRound {
    pub round: u32,
    /// Draft block sent to the cloud.
    pub tokens: Vec<i32>,
    /// The draft's own prediction of the round's correction/bonus token
    /// (the target commits tau + 1 tokens per round; speculation must
    /// predict the +1 too). `None` when the source could not extend —
    /// no further round can chain past this one.
    pub bonus: Option<i32>,
    /// True when drafted from a speculative (optimistic) prefix.
    pub speculative: bool,
    /// Uplink air bytes of the sent draft (for link stats on resolve).
    pub air_up: usize,
}

/// What to draft next, computed from the core's committed + speculative
/// state. `context` is what the draft source extends; `basis_len`/`spec`
/// go on the wire so the cloud can judge validity itself.
#[derive(Debug, Clone)]
pub struct LaunchPlan {
    pub round: u32,
    pub basis_len: u64,
    pub spec: Vec<i32>,
    pub context: Vec<i32>,
    pub speculative: bool,
}

/// Outcome of applying one verdict to the pipeline.
#[derive(Debug, Clone)]
pub struct Resolution {
    pub round: u32,
    /// Head round's draft size (for `AdaptivePolicy::observe`).
    pub k: usize,
    pub tau: usize,
    /// Session finished (eos or budget).
    pub finished: bool,
    /// The optimistic prefix survived: full acceptance AND the bonus
    /// token predicted exactly — surviving in-flight rounds stay valid.
    pub held: bool,
    /// First in-flight round voided by a broken prefix (or by session
    /// completion); the edge sends one `Cancel{round}` retracting it and
    /// everything after it.
    pub cancel_from: Option<u32>,
    /// Uplink air bytes the head draft cost (from [`InflightRound`]).
    pub air_up: usize,
}

/// Edge-side pipelined drafting state machine (pure; see module docs).
#[derive(Debug)]
pub struct PipelinedDrafter {
    /// Target rounds in flight (1 = sequential).
    pub depth: usize,
    inflight: VecDeque<InflightRound>,
    /// Verified rounds whose draft was launched speculatively and
    /// survived — the RTT-hiding wins.
    pub rounds_pipelined: usize,
    /// Speculative rounds retracted after a broken prefix.
    pub drafts_cancelled: usize,
    /// Draft tokens of retracted rounds (uplink bytes spent for nothing).
    pub draft_tokens_wasted: usize,
    /// Verdict waits with at least one more round already in flight —
    /// the RTT was overlapped with useful work.
    pub overlapped_waits: usize,
    /// Verdict waits with nothing else in flight (sequential mode: every
    /// wait; pipelined mode: pipeline restarts after a cancel/open).
    pub exposed_waits: usize,
}

impl PipelinedDrafter {
    pub fn new(depth: usize) -> PipelinedDrafter {
        PipelinedDrafter {
            depth: depth.clamp(1, MAX_PIPELINE_DEPTH),
            inflight: VecDeque::new(),
            rounds_pipelined: 0,
            drafts_cancelled: 0,
            draft_tokens_wasted: 0,
            overlapped_waits: 0,
            exposed_waits: 0,
        }
    }

    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Round number of the oldest in-flight draft — the verdict the edge
    /// must wait for next.
    pub fn head_round(&self) -> Option<u32> {
        self.inflight.front().map(|r| r.round)
    }

    /// May another round be launched right now? The head (non-
    /// speculative) launch is always allowed while the session lives;
    /// speculative launches additionally require the previous round's
    /// bonus prediction (the chain link) and head-room in the token
    /// budget under the optimistic assumption — a round that could only
    /// exist if speculation FAILS is drafted from a prefix that will
    /// never be valid, so launching it is pure waste.
    pub fn can_launch(&self, core: &SessionCore) -> bool {
        if core.done || self.inflight.len() >= self.depth {
            return false;
        }
        match self.inflight.back() {
            None => true,
            Some(last) => last.bonus.is_some() && core.optimistic_new_tokens() < core.max_new,
        }
    }

    /// The next launch's wire tags + draft context, or `None` when the
    /// pipe is full / blocked / the session is done.
    pub fn next_launch(&self, core: &SessionCore) -> Option<LaunchPlan> {
        if !self.can_launch(core) {
            return None;
        }
        Some(LaunchPlan {
            round: core.rounds as u32 + self.inflight.len() as u32,
            basis_len: core.committed.len() as u64,
            spec: core.speculated.clone(),
            context: core.optimistic_context(),
            speculative: !self.inflight.is_empty(),
        })
    }

    /// Record a sent draft. `bonus` chains the next speculative launch;
    /// when `Some`, the round's assumed outcome (tokens + bonus) joins
    /// the core's speculative suffix.
    pub fn launched(
        &mut self,
        core: &mut SessionCore,
        plan: &LaunchPlan,
        tokens: Vec<i32>,
        bonus: Option<i32>,
        air_up: usize,
    ) {
        if let Some(b) = bonus {
            let mut assumed = Vec::with_capacity(tokens.len() + 1);
            assumed.extend_from_slice(&tokens);
            assumed.push(b);
            core.speculate(&assumed);
        }
        self.inflight.push_back(InflightRound {
            round: plan.round,
            tokens,
            bonus,
            speculative: plan.speculative,
            air_up,
        });
    }

    /// Apply the head round's verdict: commit, then either confirm the
    /// surviving speculation or roll everything back (cancel-on-reject).
    /// Mirrors the cloud's basis check exactly — `held` here is true iff
    /// the cloud's `committed == basis ++ spec` test passes for the next
    /// in-flight round, so both sides always agree on which drafts died.
    pub fn resolve(&mut self, core: &mut SessionCore, v: &VerifyMsg) -> Resolution {
        let head = self
            .inflight
            .pop_front()
            .expect("resolve called with no round in flight");
        debug_assert_eq!(head.round, v.round, "verdict out of order");
        let k = head.tokens.len();
        let tau = (v.tau as usize).min(k);
        let finished = core.apply_verdict(&head.tokens, tau, v.correction, v.eos, false);
        let held = !finished && tau == k && head.bonus == Some(v.correction);
        let mut cancel_from = None;
        if held {
            core.confirm_speculation(k + 1);
            if self.inflight.front().is_some() {
                // the surviving next round WILL be verified from its
                // speculative draft — an RTT actually hidden
                self.rounds_pipelined += 1;
            }
        } else {
            core.rollback_speculation();
            cancel_from = self.inflight.front().map(|r| r.round);
            for r in self.inflight.drain(..) {
                self.drafts_cancelled += 1;
                self.draft_tokens_wasted += r.tokens.len();
            }
        }
        Resolution {
            round: v.round,
            k,
            tau,
            finished,
            held,
            cancel_from,
            air_up: head.air_up,
        }
    }

    /// Count one verdict wait as overlapped (something else in flight)
    /// or exposed (the pipe is empty behind the head — the full RTT
    /// stalls the edge, exactly the sequential-mode cost).
    pub fn note_wait(&mut self) {
        if self.inflight.len() >= 2 {
            self.overlapped_waits += 1;
        } else {
            self.exposed_waits += 1;
        }
    }

    /// The link died (or the session is being torn down): every
    /// in-flight round is void — no cancel owed (the cloud parks and the
    /// resume handshake re-synchronizes instead).
    pub fn reset(&mut self, core: &mut SessionCore) {
        core.rollback_speculation();
        self.inflight.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::edge::DraftSource;
    use crate::protocol::VerifyMode;
    use crate::serve::backend::{SyntheticDraft, SyntheticTarget, VerifyBackend};
    use crate::util::rng::SplitMix64;

    fn vmsg(round: u32, tau: usize, correction: i32, eos: bool) -> VerifyMsg {
        VerifyMsg {
            session: 1,
            round,
            tau: tau as u8,
            correction,
            eos,
            leaf: None,
        }
    }

    #[test]
    fn head_launch_then_speculative_chain() {
        let mut core = SessionCore::new(1, &[1, 10], 20);
        let mut p = PipelinedDrafter::new(3);

        // head round: non-speculative, empty spec
        let plan0 = p.next_launch(&core).unwrap();
        assert_eq!((plan0.round, plan0.speculative), (0, false));
        assert!(plan0.spec.is_empty());
        assert_eq!(plan0.context, vec![1, 10]);
        p.launched(&mut core, &plan0, vec![20, 21], Some(22), 10);

        // second round: speculative, spec = assumed outcome of round 0
        let plan1 = p.next_launch(&core).unwrap();
        assert_eq!((plan1.round, plan1.speculative), (1, true));
        assert_eq!(plan1.basis_len, 2);
        assert_eq!(plan1.spec, vec![20, 21, 22]);
        assert_eq!(plan1.context, vec![1, 10, 20, 21, 22]);
        p.launched(&mut core, &plan1, vec![30, 31], Some(32), 10);

        // third round chains once more, then the pipe is full
        let plan2 = p.next_launch(&core).unwrap();
        assert_eq!(plan2.spec, vec![20, 21, 22, 30, 31, 32]);
        p.launched(&mut core, &plan2, vec![40], None, 10);
        assert_eq!(p.inflight(), 3);
        assert!(p.next_launch(&core).is_none(), "depth 3 pipe is full");
        assert_eq!(p.head_round(), Some(0));
    }

    #[test]
    fn held_verdict_confirms_and_counts_pipelined_round() {
        let mut core = SessionCore::new(1, &[1, 10], 20);
        let mut p = PipelinedDrafter::new(2);
        let plan0 = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan0, vec![20, 21], Some(22), 7);
        let plan1 = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan1, vec![30, 31], Some(32), 7);

        // full acceptance + exact bonus: speculation holds
        let res = p.resolve(&mut core, &vmsg(0, 2, 22, false));
        assert!(res.held && !res.finished);
        assert_eq!(res.cancel_from, None);
        assert_eq!((res.k, res.tau, res.air_up), (2, 2, 7));
        assert_eq!(p.rounds_pipelined, 1);
        assert_eq!(core.committed, vec![1, 10, 20, 21, 22]);
        assert_eq!(core.speculated, vec![30, 31, 32]);
        assert_eq!(p.head_round(), Some(1));

        // next launch chains from the surviving speculation
        let plan2 = p.next_launch(&core).unwrap();
        assert_eq!(plan2.round, 2);
        assert_eq!(plan2.basis_len, 5);
        assert_eq!(plan2.spec, vec![30, 31, 32]);
    }

    #[test]
    fn broken_prefix_cancels_everything_in_flight() {
        let mut core = SessionCore::new(1, &[1, 10], 40);
        let mut p = PipelinedDrafter::new(3);
        let rounds = [
            (vec![20, 21], Some(22)),
            (vec![30, 31], Some(32)),
            (vec![40, 41], None),
        ];
        for (toks, bonus) in rounds {
            let plan = p.next_launch(&core).unwrap();
            p.launched(&mut core, &plan, toks, bonus, 5);
        }
        // partial acceptance: tau 1 < K 2 → rounds 1 and 2 are void
        let res = p.resolve(&mut core, &vmsg(0, 1, 99, false));
        assert!(!res.held);
        assert_eq!(res.cancel_from, Some(1));
        assert_eq!(p.inflight(), 0);
        assert_eq!(p.drafts_cancelled, 2);
        assert_eq!(p.draft_tokens_wasted, 4);
        assert!(core.speculated.is_empty());
        assert_eq!(core.committed, vec![1, 10, 20, 99]);
        // the redraft reuses the SAME round number from the true prefix
        let plan = p.next_launch(&core).unwrap();
        assert_eq!((plan.round, plan.speculative), (1, false));
        assert!(plan.spec.is_empty());
    }

    #[test]
    fn bonus_miss_alone_breaks_speculation() {
        let mut core = SessionCore::new(1, &[1, 10], 40);
        let mut p = PipelinedDrafter::new(2);
        let plan0 = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan0, vec![20, 21], Some(22), 5);
        let plan1 = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan1, vec![30], Some(31), 5);
        // full acceptance but the bonus token differs: still broken
        let res = p.resolve(&mut core, &vmsg(0, 2, 23, false));
        assert!(!res.held && res.cancel_from == Some(1));
        assert_eq!(p.drafts_cancelled, 1);
    }

    #[test]
    fn finish_voids_inflight_speculation() {
        let mut core = SessionCore::new(1, &[1, 10], 3);
        let mut p = PipelinedDrafter::new(2);
        let plan0 = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan0, vec![20, 21], Some(22), 5);
        // optimistic budget gate: 3 assumed tokens >= max_new 3 — the
        // next round could only exist if speculation fails
        assert!(p.next_launch(&core).is_none());

        // larger budget: launch one more, then eos kills it
        let mut core2 = SessionCore::new(2, &[1, 10], 20);
        let mut p2 = PipelinedDrafter::new(2);
        let a = p2.next_launch(&core2).unwrap();
        p2.launched(&mut core2, &a, vec![20, 21], Some(22), 5);
        let b = p2.next_launch(&core2).unwrap();
        p2.launched(&mut core2, &b, vec![30], Some(31), 5);
        let res = p2.resolve(&mut core2, &vmsg(0, 2, 22, true));
        assert!(res.finished && !res.held);
        assert_eq!(res.cancel_from, Some(1));
        assert!(core2.done);
    }

    #[test]
    fn wait_accounting_distinguishes_overlap_from_exposure() {
        let mut core = SessionCore::new(1, &[1, 10], 40);
        let mut p = PipelinedDrafter::new(2);
        let plan = p.next_launch(&core).unwrap();
        p.launched(&mut core, &plan, vec![20], None, 5);
        p.note_wait();
        assert_eq!((p.exposed_waits, p.overlapped_waits), (1, 0));
        let plan = LaunchPlan {
            round: 1,
            basis_len: 2,
            spec: vec![],
            context: vec![],
            speculative: true,
        };
        p.inflight.push_back(InflightRound {
            round: 1,
            tokens: vec![9],
            bonus: None,
            speculative: plan.speculative,
            air_up: 0,
        });
        p.note_wait();
        assert_eq!((p.exposed_waits, p.overlapped_waits), (1, 1));
    }

    /// End-to-end pure-state-machine check against the REAL synthetic
    /// draft/target pair: a pipelined decode driven entirely through
    /// `PipelinedDrafter` commits exactly the sequential trajectory.
    #[test]
    fn pipelined_trajectory_equals_sequential_with_drifted_target() {
        let seed = 23u64;
        let prompt = vec![1i32, 100, 103, 106, 109, 112];
        const MAX_NEW: usize = 24;
        const K: usize = 4;

        let mk_target = || {
            let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
            t.deploy("evolved").unwrap();
            t
        };
        let mut rng = SplitMix64::new(0);

        // --- sequential reference ------------------------------------
        let mut target = mk_target();
        let mut draft = SyntheticDraft::new(seed);
        target.start_session(1, &prompt).unwrap();
        let mut seq = SessionCore::new(1, &prompt, MAX_NEW);
        while !seq.done {
            let prop = draft.propose(&seq.committed, K, 0.0, 1.0, &mut rng).unwrap();
            let v = target
                .verify_block(
                    1,
                    &seq.committed,
                    &prop.tokens,
                    &[],
                    VerifyMode::Greedy,
                    0.0,
                    1.0,
                    &mut rng,
                )
                .unwrap();
            seq.apply_verdict(&prop.tokens, v.tau, v.correction, v.eos, false);
        }

        // --- pipelined (depth 2) over the same pure functions --------
        let mut target = mk_target();
        let mut draft = SyntheticDraft::new(seed);
        target.start_session(2, &prompt).unwrap();
        let mut core = SessionCore::new(2, &prompt, MAX_NEW);
        let mut p = PipelinedDrafter::new(2);
        // the "cloud": committed mirror + verdict function
        let mut cloud = SessionCore::new(2, &prompt, MAX_NEW);
        while !core.done {
            while let Some(plan) = p.next_launch(&core) {
                let prop = draft.propose(&plan.context, K, 0.0, 1.0, &mut rng).unwrap();
                let bonus = {
                    let mut ctx2 = plan.context.clone();
                    ctx2.extend_from_slice(&prop.tokens);
                    draft
                        .propose(&ctx2, 1, 0.0, 1.0, &mut rng)
                        .unwrap()
                        .tokens
                        .first()
                        .copied()
                };
                p.launched(&mut core, &plan, prop.tokens.clone(), bonus, 0);
            }
            p.note_wait();
            // cloud verifies the head round from ITS committed prefix —
            // only valid drafts get here, so tokens must match what a
            // sequential edge would send
            let head_tokens = p.inflight.front().unwrap().tokens.clone();
            let expect = draft
                .propose(&cloud.committed, head_tokens.len(), 0.0, 1.0, &mut rng)
                .unwrap();
            assert_eq!(
                expect.tokens, head_tokens,
                "speculative draft diverged from the sequential draft"
            );
            let v = target
                .verify_block(
                    2,
                    &cloud.committed,
                    &head_tokens,
                    &[],
                    VerifyMode::Greedy,
                    0.0,
                    1.0,
                    &mut rng,
                )
                .unwrap();
            let vm = vmsg(p.head_round().unwrap(), v.tau, v.correction, v.eos);
            cloud.apply_verdict(&head_tokens, v.tau, v.correction, v.eos, false);
            // a !held resolution drains the stale tail (the in-process
            // "cloud" queues nothing, so no Cancel frame is owed here)
            let _ = p.resolve(&mut core, &vm);
        }

        assert_eq!(core.committed, seq.committed, "pipelining changed tokens");
        assert_eq!(core.rounds, seq.rounds, "pipelining changed round count");
        // with drift 0.3 some speculation must fail AND some must land
        assert!(p.drafts_cancelled > 0, "drifted target must break some prefixes");
        assert!(p.rounds_pipelined > 0, "some speculation must survive");
        assert!(
            p.overlapped_waits > 0 && p.exposed_waits < seq.rounds,
            "pipelining must hide some RTTs ({} overlapped, {} exposed, {} rounds)",
            p.overlapped_waits,
            p.exposed_waits,
            seq.rounds
        );
    }
}
