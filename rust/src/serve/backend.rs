//! Pluggable cloud-side verification backends for the serving stack.
//!
//! `VerifyBackend` is the seam between the transport/session layer and
//! model execution. Three implementations:
//!
//! * `CloudEngine` (PJRT, artifact-gated) — the real evolving target with
//!   KV sessions and LoRA hot-swap; usable directly by the simulator.
//! * `EngineBackend` — `CloudEngine` + its `Registry`, which is what a
//!   server needs so `deploy` can hot-swap versions at runtime.
//! * `SyntheticTarget` — a *deterministic* pure-function target: the
//!   greedy next token is a hash of the recent context and the deployed
//!   version's drift parameter. It needs no artifacts, is independent of
//!   wall-clock timing and batching order, and therefore produces
//!   identical accepted-token counts over TCP, loopback, and the
//!   virtual-clock simulation — the property the serving tests pin.
//!
//! `SyntheticDraft` is the matching frozen edge draft: it always predicts
//! the *base* (drift-free) trajectory, so acceptance degrades exactly by
//! the deployed version's drift — the paper's frozen-draft-vs-evolving-
//! target story in miniature.
//!
//! # The `verify_batch` contract (batched verification executor)
//!
//! `VerifyBackend::verify_batch` is the entry point the verifier's
//! window close drives: ONE call per closed batch, covering every
//! member's draft, instead of per-session `verify_block` calls. The
//! contract:
//!
//! * **Request order is result order.** `reqs[i]` produces verdict `i`,
//!   regardless of how the implementation groups execution internally.
//! * **Session ids are distinct** within one call (the window holds at
//!   most one pending draft per session) — UNLESS the backend opts into
//!   tree rows (`supports_tree_rows`, wire v8): a tree draft expands
//!   into one row per root→leaf path, all sharing the session id. Such
//!   rows must be evaluated as INDEPENDENT pure functions of
//!   `(committed, draft)` — no per-row session state may be consumed —
//!   and the verifier re-asserts the session's true committed length
//!   afterwards via `note_committed` (row-order bookkeeping may have
//!   recorded a losing path's length last). Backends that keep per-row
//!   session state (the engine path consumes one KV per row) leave the
//!   default `false` and never see tree-expanded windows.
//! * **Byte-identical to the sequential loop.** For a deterministic
//!   backend, the verdicts (and all per-session bookkeeping) must equal
//!   what per-request `verify_block` calls in request order would have
//!   produced — batching is an execution optimization, never a
//!   semantics change. This is what keeps sim == serve committed
//!   sequences intact.
//! * **Bucketing + padding.** Implementations that stack rows group
//!   ragged draft lengths with [`plan_buckets`]: requests are bucketed
//!   by draft length rounded up to the next power of two, and shorter
//!   rows inside a bucket are PADDED up to the bucket's K (padding rows
//!   are masked out of the verdict — for the model path the per-call
//!   `block` padding already guarantees this). One stacked `[B, K]`
//!   forward per bucket amortizes the fixed per-call cost `T_base`
//!   across B members.
//! * **Regime B (stochastic).** The compact wire never ships full draft
//!   distributions; the backend reconstructs them cloud-side (point
//!   mass / its own forward pass — the documented Regime-B
//!   approximation, see `protocol` module docs). Stochastic
//!   verification draws from the SHARED sampling stream in request
//!   order, so implementations must either execute stochastic requests
//!   sequentially in request order or otherwise preserve the exact
//!   draw order; the provided engine path falls back to the sequential
//!   loop for stochastic batches for exactly this reason.
//!
//! The default trait implementation is the per-session fallback (a
//! plain loop over `verify_block`), so third-party backends keep
//! working unchanged and are free to override with a genuinely stacked
//! execution when they can.

use crate::coordinator::cloud::GreedyBatchReq;
use crate::coordinator::edge::{DraftSource, Proposal, TreeProposal};
use crate::coordinator::CloudEngine;
use crate::protocol::VerifyMode;
use crate::runtime::Registry;
use crate::util::rng::SplitMix64;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// One verification round's outcome, backend-agnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendVerdict {
    /// Accepted draft prefix length.
    pub tau: usize,
    /// Correction/bonus token the target commits after the prefix.
    pub correction: i32,
    /// True when the round emitted (or accepted) an end-of-sequence.
    pub eos: bool,
}

/// One member of a stacked verification batch (see the module docs for
/// the `verify_batch` contract). Borrows the session's committed
/// sequence and the pending draft — the planner never copies token
/// payloads.
#[derive(Debug, Clone, Copy)]
pub struct BatchVerifyReq<'a> {
    pub id: u32,
    /// Full committed sequence (prompt + generated) of the session.
    pub committed: &'a [i32],
    /// The draft block to verify against it.
    pub draft: &'a [i32],
    pub mode: VerifyMode,
}

/// Draft lengths are bucketed by rounding up to the next power of two,
/// so a window of ragged K ∈ 1..=8 drafts needs at most 4 stacked calls
/// (K ∈ {1, 2, 4, 8}) instead of one per distinct length.
pub fn bucket_k(k: usize) -> usize {
    if k == 0 {
        0
    } else {
        k.next_power_of_two()
    }
}

/// One stacked `[B, K]` execution unit the planner emits: every member's
/// draft is at most `k` tokens and is padded up to `k` inside the call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchBucket {
    /// Padded draft length of the stacked call.
    pub k: usize,
    /// Indices into the request slice, in request order.
    pub members: Vec<usize>,
}

/// Bucket ragged draft lengths into stacked execution units (ascending
/// K; members keep request order inside each bucket). Pure planning —
/// no tokens move.
pub fn plan_buckets(reqs: &[BatchVerifyReq<'_>]) -> Vec<BatchBucket> {
    // Bucketed K is always 0 or a power of two, so the class of a
    // request is `log2(k)` (offset by one to give k == 0 its own slot).
    // A fixed-size slot table makes the insert O(1) per request instead
    // of the linear `find` scan, which at large admission windows was
    // O(window × distinct-K). Buckets are still created in first-
    // appearance order and members keep request order, so the output is
    // byte-identical to the scanning version after the final sort.
    let mut slots = [usize::MAX; usize::BITS as usize + 1];
    let mut buckets: Vec<BatchBucket> = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        let k = bucket_k(r.draft.len());
        let slot = if k == 0 {
            0
        } else {
            1 + k.trailing_zeros() as usize
        };
        if slots[slot] == usize::MAX {
            slots[slot] = buckets.len();
            buckets.push(BatchBucket {
                k,
                members: Vec::new(),
            });
        }
        buckets[slots[slot]].members.push(i);
    }
    buckets.sort_by_key(|b| b.k);
    buckets
}

/// Cloud-side verification service: KV sessions + draft-block
/// verification + target-version hot-swap.
pub trait VerifyBackend {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()>;

    fn end_session(&mut self, id: u32);

    /// Verify one draft block against the session's committed sequence.
    #[allow(clippy::too_many_arguments)]
    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        draft_probs: &[Vec<f32>],
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<BackendVerdict>;

    /// Verify a whole window's drafts in ONE call (the batched
    /// verification executor's entry point — see the module docs for
    /// the full contract). Verdicts come back in request order and must
    /// be byte-identical to per-request `verify_block` calls in request
    /// order. The default implementation IS that sequential fallback,
    /// so third-party backends keep working without opting in.
    fn verify_batch(
        &mut self,
        reqs: &[BatchVerifyReq<'_>],
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<Vec<BackendVerdict>> {
        reqs.iter()
            .map(|r| {
                self.verify_block(
                    r.id,
                    r.committed,
                    r.draft,
                    &[],
                    r.mode,
                    temperature,
                    top_p,
                    rng,
                )
            })
            .collect()
    }

    /// Hot-swap the deployed target version without dropping sessions.
    /// Returns the new version sequence number.
    fn deploy(&mut self, version: &str) -> Result<u64> {
        bail!("backend '{}' does not support hot-swap (version '{version}')", self.label())
    }

    fn version_name(&self) -> String;

    fn version_seq(&self) -> u64;

    /// KV slots left for this session (0 when unknown session).
    fn remaining_capacity(&self, id: u32) -> usize;

    /// True when `verify_batch` evaluates every request row as an
    /// independent pure function of `(committed, draft)` — the
    /// precondition for tree-expanded windows, where several rows share
    /// one session id (see the module docs). Default `false`: the
    /// verifier keeps such a backend's drafts linear.
    fn supports_tree_rows(&self) -> bool {
        false
    }

    /// Re-assert a session's committed length after the verifier picks
    /// a tree round's winning path. Only meaningful for backends with
    /// `supports_tree_rows`; default no-op.
    fn note_committed(&mut self, _id: u32, _len: usize) {}

    fn label(&self) -> String {
        "backend".into()
    }
}

// ---------------------------------------------------------------------
// Real PJRT target (artifact-gated)
// ---------------------------------------------------------------------

impl VerifyBackend for CloudEngine {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
        CloudEngine::start_session(self, id, prompt)
    }

    fn end_session(&mut self, id: u32) {
        CloudEngine::end_session(self, id);
    }

    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        draft_probs: &[Vec<f32>],
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<BackendVerdict> {
        let v = CloudEngine::verify(
            self,
            id,
            committed,
            draft,
            draft_probs,
            mode,
            temperature,
            top_p,
            rng,
        )?;
        Ok(BackendVerdict {
            tau: v.outcome.tau,
            correction: v.outcome.correction,
            eos: v.eos,
        })
    }

    /// Stacked execution: one `[B, K]` runtime call per planner bucket
    /// (greedy). Stochastic batches fall back to the sequential loop —
    /// Regime-B sampling draws from the shared stream in request order,
    /// which stacked execution would not preserve.
    fn verify_batch(
        &mut self,
        reqs: &[BatchVerifyReq<'_>],
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<Vec<BackendVerdict>> {
        if reqs.iter().any(|r| r.mode == VerifyMode::Stochastic) {
            return reqs
                .iter()
                .map(|r| {
                    VerifyBackend::verify_block(
                        self,
                        r.id,
                        r.committed,
                        r.draft,
                        &[],
                        r.mode,
                        temperature,
                        top_p,
                        rng,
                    )
                })
                .collect();
        }
        let buckets = plan_buckets(reqs);
        let mut out: Vec<Option<BackendVerdict>> = vec![None; reqs.len()];
        for b in &buckets {
            let breqs: Vec<GreedyBatchReq> = b
                .members
                .iter()
                .map(|&i| GreedyBatchReq {
                    id: reqs[i].id,
                    committed: reqs[i].committed,
                    draft: reqs[i].draft,
                })
                .collect();
            let verdicts = self.verify_batch_greedy(&breqs)?;
            for (&i, v) in b.members.iter().zip(verdicts) {
                out[i] = Some(BackendVerdict {
                    tau: v.outcome.tau,
                    correction: v.outcome.correction,
                    eos: v.eos,
                });
            }
        }
        Ok(out
            .into_iter()
            .map(|v| v.expect("planner covers every request"))
            .collect())
    }

    fn version_name(&self) -> String {
        self.version.name.clone()
    }

    fn version_seq(&self) -> u64 {
        self.version.seq
    }

    fn remaining_capacity(&self, id: u32) -> usize {
        CloudEngine::remaining_capacity(self, id)
    }

    fn label(&self) -> String {
        format!("pjrt:{}", self.version.name)
    }
}

/// `CloudEngine` plus its registry — the deployable production backend.
/// `!Send` (PJRT handles are thread-pinned), so the server constructs it
/// *inside* the verifier thread via the `make_backend` closure.
pub struct EngineBackend {
    pub reg: Rc<Registry>,
    pub cloud: CloudEngine,
}

impl EngineBackend {
    pub fn new(reg: Rc<Registry>, version: &str, eos: i32) -> Result<EngineBackend> {
        let cloud = CloudEngine::new(&reg, version, eos)?;
        Ok(EngineBackend { reg, cloud })
    }
}

impl VerifyBackend for EngineBackend {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
        self.cloud.start_session(id, prompt)
    }

    fn end_session(&mut self, id: u32) {
        self.cloud.end_session(id);
    }

    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        draft_probs: &[Vec<f32>],
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<BackendVerdict> {
        VerifyBackend::verify_block(
            &mut self.cloud,
            id,
            committed,
            draft,
            draft_probs,
            mode,
            temperature,
            top_p,
            rng,
        )
    }

    fn verify_batch(
        &mut self,
        reqs: &[BatchVerifyReq<'_>],
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<Vec<BackendVerdict>> {
        VerifyBackend::verify_batch(&mut self.cloud, reqs, temperature, top_p, rng)
    }

    fn deploy(&mut self, version: &str) -> Result<u64> {
        self.cloud.deploy(&self.reg, version)?;
        Ok(self.cloud.version.seq)
    }

    fn version_name(&self) -> String {
        self.cloud.version.name.clone()
    }

    fn version_seq(&self) -> u64 {
        self.cloud.version.seq
    }

    fn remaining_capacity(&self, id: u32) -> usize {
        self.cloud.remaining_capacity(id)
    }

    fn label(&self) -> String {
        format!("engine:{}", self.cloud.version.name)
    }
}

// ---------------------------------------------------------------------
// Deterministic synthetic target + frozen synthetic draft
// ---------------------------------------------------------------------

/// Context window the synthetic token function hashes over.
const SYNTH_WINDOW: usize = 8;
/// Tokens 0..=2 are PAD/BOS/EOS — the synthetic trajectory avoids them
/// so runs are never cut short by a hash collision with EOS and token
/// counts stay exactly reproducible.
const SYNTH_RESERVED: i32 = 3;

fn ctx_hash(ctx: &[i32]) -> u64 {
    let tail = &ctx[ctx.len().saturating_sub(SYNTH_WINDOW)..];
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tail {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The frozen anchor's greedy prediction for the next token.
pub fn synth_base_token(seed: u64, vocab: i32, ctx: &[i32]) -> i32 {
    let mut r = SplitMix64::new(ctx_hash(ctx) ^ seed ^ 0xBA5E_70C5);
    SYNTH_RESERVED + r.next_range((vocab - SYNTH_RESERVED) as u64) as i32
}

/// How many candidate drifted continuations the synthetic family
/// exposes per context (see [`synth_alt_tokens`]).
pub const SYNTH_ALTS: usize = 8;

/// The eight candidate "drifted" continuations at a context: distinct
/// tokens, deterministically spread over the vocabulary, all different
/// from the base prediction. The evolved target commits ONE of them at
/// each drift position ([`synth_target_token`]); tree drafts hedge by
/// proposing the first `branching - 1` of them as alternate leaves, so
/// a comb of branching `b` catches a drift with probability
/// `(b - 1) / 8` — the mechanism behind the accepted-tokens-per-
/// dispatch gain the hetero bench cell gates.
///
/// Pure in `(seed, vocab, ctx)` and independent of the version salt, so
/// the frozen draft can compute the same hedge set without knowing
/// which target version is deployed.
pub fn synth_alt_tokens(seed: u64, vocab: i32, ctx: &[i32]) -> [i32; SYNTH_ALTS] {
    let base = synth_base_token(seed, vocab, ctx);
    let span = vocab - SYNTH_RESERVED;
    let step = ((span - 1) / SYNTH_ALTS as i32).max(1);
    let mut out = [0i32; SYNTH_ALTS];
    for (j, slot) in out.iter_mut().enumerate() {
        let jump = 1 + (j as i32 * step) % (span - 1).max(1);
        *slot = SYNTH_RESERVED + (base - SYNTH_RESERVED + jump).rem_euclid(span);
    }
    out
}

/// The deployed target version's greedy next token: equals the base
/// prediction except at (deterministic, context-keyed) drift positions,
/// where it commits one of the context's [`synth_alt_tokens`] instead —
/// chosen by the version-salted stream, so different versions drift to
/// different alternates but always within the hedgeable set.
pub fn synth_target_token(seed: u64, vocab: i32, version_salt: u64, drift: f64, ctx: &[i32]) -> i32 {
    let base = synth_base_token(seed, vocab, ctx);
    if drift <= 0.0 {
        return base;
    }
    let mut r = SplitMix64::new(
        ctx_hash(ctx) ^ seed ^ version_salt.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    if r.next_f64() < drift {
        let alts = synth_alt_tokens(seed, vocab, ctx);
        alts[r.next_range(SYNTH_ALTS as u64) as usize]
    } else {
        base
    }
}

fn name_salt(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A registered synthetic target version: name + how far it has evolved
/// away from the frozen draft's anchor (per-token disagreement rate).
#[derive(Debug, Clone)]
pub struct SyntheticVersion {
    pub name: String,
    pub drift: f64,
}

/// Deterministic verification backend (no artifacts, no clock, no
/// batching-order sensitivity — see module docs).
pub struct SyntheticTarget {
    pub seed: u64,
    pub vocab: i32,
    pub eos: i32,
    pub max_ctx: usize,
    versions: Vec<SyntheticVersion>,
    current: usize,
    seq: u64,
    /// id → committed length last seen (capacity accounting).
    sessions: HashMap<u32, usize>,
}

impl SyntheticTarget {
    pub fn new(seed: u64) -> SyntheticTarget {
        SyntheticTarget {
            seed,
            vocab: 512,
            eos: crate::workload::EOS,
            max_ctx: 4096,
            versions: vec![SyntheticVersion {
                name: "synthetic_base".into(),
                drift: 0.0,
            }],
            current: 0,
            seq: 1,
            sessions: HashMap::new(),
        }
    }

    /// Register a deployable version (builder-style).
    pub fn with_version(mut self, name: &str, drift: f64) -> SyntheticTarget {
        self.versions.push(SyntheticVersion {
            name: name.into(),
            drift: drift.clamp(0.0, 1.0),
        });
        self
    }

    pub fn current_version(&self) -> &SyntheticVersion {
        &self.versions[self.current]
    }

    fn target_token(&self, ctx: &[i32]) -> i32 {
        let v = self.current_version();
        synth_target_token(self.seed, self.vocab, name_salt(&v.name), v.drift, ctx)
    }

    /// Greedy verification against the deterministic trajectory — the
    /// shared core of `verify_block` and the batched path (stochastic
    /// mode degrades to greedy here by design: the synthetic target
    /// exists for reproducibility, not sampling).
    fn verify_one(&mut self, id: u32, committed: &[i32], draft: &[i32]) -> Result<BackendVerdict> {
        if !self.sessions.contains_key(&id) {
            bail!("no session {id}");
        }
        let mut ctx = committed.to_vec();
        let mut tau = draft.len();
        let mut correction = None;
        for (j, &d) in draft.iter().enumerate() {
            let t = self.target_token(&ctx);
            if d == t {
                ctx.push(d);
            } else {
                tau = j;
                correction = Some(t);
                break;
            }
        }
        let correction = correction.unwrap_or_else(|| self.target_token(&ctx));
        let eos = correction == self.eos || draft[..tau].contains(&self.eos);
        self.sessions.insert(id, committed.len() + tau + 1);
        Ok(BackendVerdict {
            tau,
            correction,
            eos,
        })
    }
}

impl VerifyBackend for SyntheticTarget {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
        if prompt.len() < 2 {
            bail!("prompt must have at least 2 tokens (BOS + 1)");
        }
        if self.sessions.contains_key(&id) {
            bail!("session {id} already open");
        }
        self.sessions.insert(id, prompt.len());
        Ok(())
    }

    fn end_session(&mut self, id: u32) {
        self.sessions.remove(&id);
    }

    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        _draft_probs: &[Vec<f32>],
        _mode: VerifyMode,
        _temperature: f32,
        _top_p: f32,
        _rng: &mut SplitMix64,
    ) -> Result<BackendVerdict> {
        self.verify_one(id, committed, draft)
    }

    /// Vectorized batched path. Each stacked row is an independent pure
    /// function of (context, version), so evaluating rows in request
    /// order IS the bucket-stacked computation — no reordering
    /// scaffolding needed — and the result is BYTE-IDENTICAL to the
    /// sequential fallback (the property the executor determinism tests
    /// pin). The override exists so the synthetic backend states its
    /// batching contract explicitly (and skips the unused
    /// mode/temperature/rng plumbing of `verify_block`).
    fn verify_batch(
        &mut self,
        reqs: &[BatchVerifyReq<'_>],
        _temperature: f32,
        _top_p: f32,
        _rng: &mut SplitMix64,
    ) -> Result<Vec<BackendVerdict>> {
        reqs.iter()
            .map(|r| self.verify_one(r.id, r.committed, r.draft))
            .collect()
    }

    fn deploy(&mut self, version: &str) -> Result<u64> {
        let idx = self
            .versions
            .iter()
            .position(|v| v.name == version)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown synthetic version '{version}' (have: {})",
                    self.versions
                        .iter()
                        .map(|v| v.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        self.current = idx;
        self.seq += 1;
        Ok(self.seq)
    }

    fn version_name(&self) -> String {
        self.current_version().name.clone()
    }

    fn version_seq(&self) -> u64 {
        self.seq
    }

    fn remaining_capacity(&self, id: u32) -> usize {
        self.sessions
            .get(&id)
            .map(|&len| self.max_ctx.saturating_sub(len))
            .unwrap_or(0)
    }

    /// Every row is a pure function of `(committed, draft)` — the
    /// synthetic target carries no per-row KV state — so tree-expanded
    /// windows (several root→leaf rows sharing one session id) are
    /// safe here.
    fn supports_tree_rows(&self) -> bool {
        true
    }

    fn note_committed(&mut self, id: u32, len: usize) {
        if let Some(s) = self.sessions.get_mut(&id) {
            *s = len;
        }
    }

    fn label(&self) -> String {
        format!("synthetic:{}", self.current_version().name)
    }
}

/// The frozen edge draft matching `SyntheticTarget`: always predicts the
/// drift-free base trajectory. Deterministic, `Send`, artifact-free.
#[derive(Debug, Clone)]
pub struct SyntheticDraft {
    pub seed: u64,
    pub vocab: i32,
}

impl SyntheticDraft {
    pub fn new(seed: u64) -> SyntheticDraft {
        SyntheticDraft { seed, vocab: 512 }
    }
}

impl DraftSource for SyntheticDraft {
    fn propose(
        &mut self,
        committed: &[i32],
        k: usize,
        _temperature: f32,
        _top_p: f32,
        _rng: &mut SplitMix64,
    ) -> Result<Proposal> {
        let mut prop = Proposal::default();
        let mut ctx = committed.to_vec();
        for _ in 0..k {
            let t = synth_base_token(self.seed, self.vocab, &ctx);
            prop.tokens.push(t);
            prop.chosen_probs.push(1.0);
            ctx.push(t);
        }
        prop.edge_tokens = k;
        Ok(prop)
    }

    /// Bucket-aligned comb (wire v8 tree speculation): the linear chain
    /// plus `branching - 1` single-token alternate leaves hedging the
    /// target's possible drifts ([`synth_alt_tokens`]) — but ONLY at
    /// chain positions `p` whose root→leaf path length falls in the
    /// SAME [`bucket_k`] class as the chain itself. Every tree row then
    /// rides a stacked dispatch the chain already pays for, so the tree
    /// adds zero bucket classes and any acceptance gain strictly
    /// increases accepted tokens per dispatch (the hetero bench gate).
    /// A full comb would instead scatter rows over the {1, 2, 4, ...}
    /// classes and inflate dispatch counts.
    fn propose_tree(
        &mut self,
        committed: &[i32],
        k: usize,
        branching: usize,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<TreeProposal> {
        let lin = self.propose(committed, k, temperature, top_p, rng)?;
        let b = branching.clamp(1, crate::device::MAX_BRANCHING);
        if b == 1 || lin.tokens.is_empty() {
            return Ok(TreeProposal {
                edge_tokens: lin.edge_tokens,
                tokens: lin.tokens,
                parents: Vec::new(),
            });
        }
        let k = lin.tokens.len();
        let kb = bucket_k(k);
        let mut tokens = lin.tokens.clone();
        let mut parents: Vec<u8> = (0..k as u8).collect();
        let mut ctx = committed.to_vec();
        for p in 1..=k {
            // an alternate replacing chain position p has path length p
            if bucket_k(p) == kb {
                let alts = synth_alt_tokens(self.seed, self.vocab, &ctx);
                for &alt in alts.iter().take(b - 1) {
                    tokens.push(alt);
                    parents.push((p - 1) as u8);
                }
            }
            ctx.push(lin.tokens[p - 1]);
        }
        let n_alt = tokens.len() - k;
        Ok(TreeProposal {
            tokens,
            parents,
            edge_tokens: lin.edge_tokens + n_alt,
        })
    }

    fn reset(&mut self) -> Result<()> {
        Ok(())
    }

    fn name(&self) -> String {
        "synthetic-draft".into()
    }

    fn is_pure(&self) -> bool {
        true // greedy hash chain over the context: pure by construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> SplitMix64 {
        SplitMix64::new(1)
    }

    fn run_rounds(t: &mut SyntheticTarget, d: &mut SyntheticDraft, rounds: usize, k: usize) -> (usize, usize) {
        let prompt = vec![1, 70, 80, 90];
        t.start_session(1, &prompt).unwrap();
        let mut committed = prompt;
        let (mut acc, mut drafted) = (0, 0);
        for _ in 0..rounds {
            let p = d
                .propose(&committed, k, 0.0, 1.0, &mut rng())
                .unwrap();
            let v = t
                .verify_block(1, &committed, &p.tokens, &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng())
                .unwrap();
            committed.extend_from_slice(&p.tokens[..v.tau]);
            committed.push(v.correction);
            acc += v.tau;
            drafted += p.tokens.len();
        }
        t.end_session(1);
        (acc, drafted)
    }

    #[test]
    fn base_version_accepts_everything() {
        let mut t = SyntheticTarget::new(7);
        let mut d = SyntheticDraft::new(7);
        let (acc, drafted) = run_rounds(&mut t, &mut d, 10, 4);
        assert_eq!(acc, drafted, "zero drift must accept every draft token");
    }

    #[test]
    fn drift_lowers_acceptance_deterministically() {
        let mut t = SyntheticTarget::new(7).with_version("evolved", 0.4);
        t.deploy("evolved").unwrap();
        assert_eq!(t.version_seq(), 2);
        let mut d = SyntheticDraft::new(7);
        let (acc1, drafted1) = run_rounds(&mut t, &mut d, 20, 4);
        assert!(acc1 < drafted1, "drift must reject some tokens");
        assert!(acc1 > 0, "drift 0.4 must still accept some tokens");

        // bit-identical on replay
        let mut t2 = SyntheticTarget::new(7).with_version("evolved", 0.4);
        t2.deploy("evolved").unwrap();
        let mut d2 = SyntheticDraft::new(7);
        assert_eq!(run_rounds(&mut t2, &mut d2, 20, 4), (acc1, drafted1));
    }

    #[test]
    fn verdicts_are_independent_of_round_partitioning() {
        // K=1 single-step rounds and K=4 rounds must walk the same
        // greedy trajectory (timing/batching invariance in miniature).
        let mk = || {
            let mut t = SyntheticTarget::new(3).with_version("v2", 0.3);
            t.deploy("v2").unwrap();
            t
        };
        let mut d = SyntheticDraft::new(3);
        let prompt = vec![1i32, 64, 65];

        let mut trajectory = |k: usize| {
            let mut t = mk();
            t.start_session(9, &prompt).unwrap();
            let mut committed = prompt.clone();
            while committed.len() < prompt.len() + 24 {
                let p = d.propose(&committed, k, 0.0, 1.0, &mut rng()).unwrap();
                let v = t
                    .verify_block(9, &committed, &p.tokens, &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng())
                    .unwrap();
                committed.extend_from_slice(&p.tokens[..v.tau]);
                committed.push(v.correction);
            }
            committed.truncate(prompt.len() + 24);
            committed
        };
        assert_eq!(trajectory(1), trajectory(4));
    }

    #[test]
    fn deploy_rejects_unknown_versions() {
        let mut t = SyntheticTarget::new(1);
        assert!(t.deploy("nope").is_err());
        assert_eq!(t.version_name(), "synthetic_base");
    }

    #[test]
    fn capacity_tracks_committed_length() {
        let mut t = SyntheticTarget::new(1);
        t.max_ctx = 10;
        t.start_session(1, &[1, 2, 3, 4]).unwrap();
        assert_eq!(t.remaining_capacity(1), 6);
        assert_eq!(t.remaining_capacity(99), 0);
    }

    // --- batched verification executor -------------------------------

    #[test]
    fn planner_buckets_ragged_draft_lengths() {
        let committed = vec![1, 70, 71];
        let drafts: Vec<Vec<i32>> = (1..=8).map(|k| vec![9; k]).collect();
        let reqs: Vec<BatchVerifyReq> = drafts
            .iter()
            .enumerate()
            .map(|(i, d)| BatchVerifyReq {
                id: i as u32 + 1,
                committed: &committed,
                draft: d,
                mode: VerifyMode::Greedy,
            })
            .collect();
        let buckets = plan_buckets(&reqs);
        // K ∈ 1..=8 collapses to the power-of-two classes {1, 2, 4, 8}
        assert_eq!(
            buckets.iter().map(|b| b.k).collect::<Vec<_>>(),
            vec![1, 2, 4, 8]
        );
        // K=1 → bucket 1; K=2 → 2; K∈{3,4} → 4; K∈{5..8} → 8
        assert_eq!(buckets[0].members, vec![0]);
        assert_eq!(buckets[1].members, vec![1]);
        assert_eq!(buckets[2].members, vec![2, 3]);
        assert_eq!(buckets[3].members, vec![4, 5, 6, 7]);
        // every request covered exactly once
        let mut all: Vec<usize> = buckets.iter().flat_map(|b| b.members.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        // degenerate plans
        assert!(plan_buckets(&[]).is_empty(), "empty window plans nothing");
        let single = [reqs[4]];
        let b = plan_buckets(&single);
        assert_eq!(b.len(), 1);
        assert_eq!((b[0].k, b[0].members.as_slice()), (8, &[0usize][..]));
        assert_eq!(bucket_k(0), 0, "empty draft stays in its own class");
    }

    /// Pin for the O(1)-slot planner rewrite: over ragged K (including
    /// the k == 0 empty-draft class) at window sizes 1, 64 and 1024 the
    /// emitted plan must be byte-identical to the original linear-scan
    /// planner — same bucket order, same member order.
    #[test]
    fn planner_matches_linear_scan_reference_at_scale() {
        // the pre-rewrite planner, kept inline as the oracle
        fn naive(reqs: &[BatchVerifyReq<'_>]) -> Vec<BatchBucket> {
            let mut buckets: Vec<BatchBucket> = Vec::new();
            for (i, r) in reqs.iter().enumerate() {
                let k = bucket_k(r.draft.len());
                match buckets.iter_mut().find(|b| b.k == k) {
                    Some(b) => b.members.push(i),
                    None => buckets.push(BatchBucket {
                        k,
                        members: vec![i],
                    }),
                }
            }
            buckets.sort_by_key(|b| b.k);
            buckets
        }

        let committed = vec![1, 70, 71];
        for &window in &[1usize, 64, 1024] {
            for &seed in &[3u64, 17, 42] {
                let mut r = SplitMix64::new(seed);
                // ragged draft lengths 0..=33: exercises the empty-draft
                // class, the non-power-of-two round-ups and a k beyond
                // the verifier's usual max_batch
                let drafts: Vec<Vec<i32>> = (0..window)
                    .map(|_| vec![9; r.next_range(34) as usize])
                    .collect();
                let reqs: Vec<BatchVerifyReq> = drafts
                    .iter()
                    .enumerate()
                    .map(|(i, d)| BatchVerifyReq {
                        id: i as u32 + 1,
                        committed: &committed,
                        draft: d,
                        mode: VerifyMode::Greedy,
                    })
                    .collect();
                assert_eq!(
                    plan_buckets(&reqs),
                    naive(&reqs),
                    "window {window} seed {seed}: plan diverged from the linear-scan oracle"
                );
            }
        }
    }

    /// Determinism pin: across seeds and drift levels, the vectorized
    /// `verify_batch` must produce verdicts and committed sequences
    /// BYTE-IDENTICAL to per-request `verify_block` calls in request
    /// order — for ragged K ∈ 1..=8, including drift-induced partial
    /// acceptances.
    #[test]
    fn batched_verdicts_match_sequential_across_seeds() {
        for &seed in &[3u64, 17, 42] {
            let mk = || {
                let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.35);
                t.deploy("evolved").unwrap();
                t
            };
            let mut seq_t = mk();
            let mut bat_t = mk();
            let mut d = SyntheticDraft::new(seed);
            let n = 6usize;
            let mut committed: Vec<Vec<i32>> = (0..n)
                .map(|i| vec![1, 70 + i as i32, 80 + 2 * i as i32])
                .collect();
            for (i, c) in committed.iter().enumerate() {
                seq_t.start_session(i as u32 + 1, c).unwrap();
                bat_t.start_session(i as u32 + 1, c).unwrap();
            }
            for round in 0..10 {
                // ragged strides, varying per session and round
                let drafts: Vec<Vec<i32>> = committed
                    .iter()
                    .enumerate()
                    .map(|(i, c)| {
                        let k = 1 + (i + round) % 8;
                        d.propose(c, k, 0.0, 1.0, &mut rng()).unwrap().tokens
                    })
                    .collect();
                let seq_verdicts: Vec<BackendVerdict> = committed
                    .iter()
                    .zip(&drafts)
                    .enumerate()
                    .map(|(i, (c, dr))| {
                        seq_t
                            .verify_block(
                                i as u32 + 1,
                                c,
                                dr,
                                &[],
                                VerifyMode::Greedy,
                                0.0,
                                1.0,
                                &mut rng(),
                            )
                            .unwrap()
                    })
                    .collect();
                let reqs: Vec<BatchVerifyReq> = committed
                    .iter()
                    .zip(&drafts)
                    .enumerate()
                    .map(|(i, (c, dr))| BatchVerifyReq {
                        id: i as u32 + 1,
                        committed: c,
                        draft: dr,
                        mode: VerifyMode::Greedy,
                    })
                    .collect();
                let bat_verdicts = bat_t
                    .verify_batch(&reqs, 0.0, 1.0, &mut rng())
                    .unwrap();
                assert_eq!(
                    seq_verdicts, bat_verdicts,
                    "batched != sequential verdicts (seed {seed}, round {round})"
                );
                drop(reqs);
                for ((c, dr), v) in committed.iter_mut().zip(&drafts).zip(&seq_verdicts) {
                    c.extend_from_slice(&dr[..v.tau]);
                    c.push(v.correction);
                }
                // both backends agree on per-session capacity, too
                for i in 0..n {
                    assert_eq!(
                        seq_t.remaining_capacity(i as u32 + 1),
                        bat_t.remaining_capacity(i as u32 + 1),
                        "capacity bookkeeping diverged (seed {seed})"
                    );
                }
            }
        }
    }

    // --- tree speculation (wire v8) -----------------------------------

    #[test]
    fn alt_tokens_are_distinct_and_cover_target_drift() {
        let ctx = vec![1, 70, 80, 90];
        let alts = synth_alt_tokens(7, 512, &ctx);
        let base = synth_base_token(7, 512, &ctx);
        let mut uniq: Vec<i32> = alts.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), SYNTH_ALTS, "alternates must be distinct");
        assert!(!alts.contains(&base), "alternates never equal the base");
        assert!(alts.iter().all(|&t| t >= SYNTH_RESERVED && t < 512));
        // drift = 1.0: the target ALWAYS lands inside the hedge set
        let mut t = SyntheticTarget::new(7).with_version("evolved", 1.0);
        t.deploy("evolved").unwrap();
        let mut c = ctx.clone();
        for _ in 0..32 {
            let drifted = t.target_token(&c);
            assert_ne!(drifted, synth_base_token(7, 512, &c));
            assert!(
                synth_alt_tokens(7, 512, &c).contains(&drifted),
                "drift target must be one of the 8 alternates"
            );
            c.push(drifted);
        }
    }

    #[test]
    fn comb_tree_is_bucket_aligned_and_linear_at_branching_one() {
        let mut d = SyntheticDraft::new(7);
        let committed = vec![1, 70, 80, 90];
        for k in 1..=8usize {
            let lin = d.propose(&committed, k, 0.0, 1.0, &mut rng()).unwrap();
            for b in 1..=4usize {
                let t = d
                    .propose_tree(&committed, k, b, 0.0, 1.0, &mut rng())
                    .unwrap();
                assert_eq!(&t.tokens[..k], &lin.tokens[..], "chain must equal propose()");
                if b == 1 {
                    assert!(t.is_linear(), "branching 1 is the linear wire form");
                    assert_eq!(t.edge_tokens, lin.edge_tokens);
                    continue;
                }
                // every alternate path length stays in the chain's
                // bucket class — trees never add dispatch classes
                let aligned = (1..=k).filter(|&p| bucket_k(p) == bucket_k(k)).count();
                assert_eq!(t.n_nodes(), k + aligned * (b - 1));
                assert_eq!(t.edge_tokens, lin.edge_tokens + aligned * (b - 1));
                for i in k..t.n_nodes() {
                    let path_len = t.parents[i] as usize + 1;
                    assert_eq!(
                        bucket_k(path_len),
                        bucket_k(k),
                        "k {k} b {b}: alternate path length {path_len} left the bucket"
                    );
                    // hedge token = one of the context's alternates
                    let mut ctx = committed.clone();
                    ctx.extend_from_slice(&lin.tokens[..t.parents[i] as usize]);
                    assert!(synth_alt_tokens(7, 512, &ctx).contains(&t.tokens[i]));
                }
                // chain prefix parents are the identity walk
                assert_eq!(&t.parents[..k], (0..k as u8).collect::<Vec<_>>().as_slice());
            }
        }
    }

    #[test]
    fn tree_rows_share_session_and_note_committed_reasserts_capacity() {
        let mut t = SyntheticTarget::new(7).with_version("evolved", 1.0);
        t.deploy("evolved").unwrap();
        assert!(t.supports_tree_rows());
        let committed = vec![1, 70, 80, 90];
        t.start_session(1, &committed).unwrap();
        // drift 1.0 breaks the chain at position 1; the hedge row
        // carrying the right alternate extends one token further
        let drifted = t.target_token(&committed);
        let base = synth_base_token(7, 512, &committed);
        let chain = vec![base, synth_base_token(7, 512, &[committed.clone(), vec![base]].concat())];
        let hedge = vec![drifted];
        let reqs = [
            BatchVerifyReq {
                id: 1,
                committed: &committed,
                draft: &chain,
                mode: VerifyMode::Greedy,
            },
            BatchVerifyReq {
                id: 1,
                committed: &committed,
                draft: &hedge,
                mode: VerifyMode::Greedy,
            },
        ];
        let verdicts = t.verify_batch(&reqs, 0.0, 1.0, &mut rng()).unwrap();
        assert_eq!(verdicts[0].tau, 0, "chain row rejects at the drift");
        assert_eq!(verdicts[0].correction, drifted);
        assert_eq!(verdicts[1].tau, 1, "hedge row rides through the drift");
        // row-order bookkeeping recorded the LAST row; the verifier
        // re-asserts the winning row's commit length
        let win_len = committed.len() + verdicts[1].tau + 1;
        t.note_committed(1, win_len);
        assert_eq!(t.remaining_capacity(1), t.max_ctx - win_len);
        t.note_committed(99, 1); // unknown session: ignored
        assert_eq!(t.remaining_capacity(99), 0);
    }

    #[test]
    fn verify_batch_rejects_unknown_session() {
        let mut t = SyntheticTarget::new(1);
        t.start_session(1, &[1, 2, 3]).unwrap();
        let committed = vec![1, 2, 3];
        let draft = vec![9, 9];
        let reqs = [
            BatchVerifyReq {
                id: 1,
                committed: &committed,
                draft: &draft,
                mode: VerifyMode::Greedy,
            },
            BatchVerifyReq {
                id: 99,
                committed: &committed,
                draft: &draft,
                mode: VerifyMode::Greedy,
            },
        ];
        assert!(t.verify_batch(&reqs, 0.0, 1.0, &mut rng()).is_err());
    }
}
