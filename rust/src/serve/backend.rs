//! Pluggable cloud-side verification backends for the serving stack.
//!
//! `VerifyBackend` is the seam between the transport/session layer and
//! model execution. Three implementations:
//!
//! * `CloudEngine` (PJRT, artifact-gated) — the real evolving target with
//!   KV sessions and LoRA hot-swap; usable directly by the simulator.
//! * `EngineBackend` — `CloudEngine` + its `Registry`, which is what a
//!   server needs so `deploy` can hot-swap versions at runtime.
//! * `SyntheticTarget` — a *deterministic* pure-function target: the
//!   greedy next token is a hash of the recent context and the deployed
//!   version's drift parameter. It needs no artifacts, is independent of
//!   wall-clock timing and batching order, and therefore produces
//!   identical accepted-token counts over TCP, loopback, and the
//!   virtual-clock simulation — the property the serving tests pin.
//!
//! `SyntheticDraft` is the matching frozen edge draft: it always predicts
//! the *base* (drift-free) trajectory, so acceptance degrades exactly by
//! the deployed version's drift — the paper's frozen-draft-vs-evolving-
//! target story in miniature.

use crate::coordinator::edge::{DraftSource, Proposal};
use crate::coordinator::CloudEngine;
use crate::protocol::VerifyMode;
use crate::runtime::Registry;
use crate::util::rng::SplitMix64;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// One verification round's outcome, backend-agnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendVerdict {
    /// Accepted draft prefix length.
    pub tau: usize,
    /// Correction/bonus token the target commits after the prefix.
    pub correction: i32,
    /// True when the round emitted (or accepted) an end-of-sequence.
    pub eos: bool,
}

/// Cloud-side verification service: KV sessions + draft-block
/// verification + target-version hot-swap.
pub trait VerifyBackend {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()>;

    fn end_session(&mut self, id: u32);

    /// Verify one draft block against the session's committed sequence.
    #[allow(clippy::too_many_arguments)]
    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        draft_probs: &[Vec<f32>],
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<BackendVerdict>;

    /// Hot-swap the deployed target version without dropping sessions.
    /// Returns the new version sequence number.
    fn deploy(&mut self, version: &str) -> Result<u64> {
        bail!("backend '{}' does not support hot-swap (version '{version}')", self.label())
    }

    fn version_name(&self) -> String;

    fn version_seq(&self) -> u64;

    /// KV slots left for this session (0 when unknown session).
    fn remaining_capacity(&self, id: u32) -> usize;

    fn label(&self) -> String {
        "backend".into()
    }
}

// ---------------------------------------------------------------------
// Real PJRT target (artifact-gated)
// ---------------------------------------------------------------------

impl VerifyBackend for CloudEngine {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
        CloudEngine::start_session(self, id, prompt)
    }

    fn end_session(&mut self, id: u32) {
        CloudEngine::end_session(self, id);
    }

    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        draft_probs: &[Vec<f32>],
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<BackendVerdict> {
        let v = CloudEngine::verify(
            self,
            id,
            committed,
            draft,
            draft_probs,
            mode,
            temperature,
            top_p,
            rng,
        )?;
        Ok(BackendVerdict {
            tau: v.outcome.tau,
            correction: v.outcome.correction,
            eos: v.eos,
        })
    }

    fn version_name(&self) -> String {
        self.version.name.clone()
    }

    fn version_seq(&self) -> u64 {
        self.version.seq
    }

    fn remaining_capacity(&self, id: u32) -> usize {
        CloudEngine::remaining_capacity(self, id)
    }

    fn label(&self) -> String {
        format!("pjrt:{}", self.version.name)
    }
}

/// `CloudEngine` plus its registry — the deployable production backend.
/// `!Send` (PJRT handles are thread-pinned), so the server constructs it
/// *inside* the verifier thread via the `make_backend` closure.
pub struct EngineBackend {
    pub reg: Rc<Registry>,
    pub cloud: CloudEngine,
}

impl EngineBackend {
    pub fn new(reg: Rc<Registry>, version: &str, eos: i32) -> Result<EngineBackend> {
        let cloud = CloudEngine::new(&reg, version, eos)?;
        Ok(EngineBackend { reg, cloud })
    }
}

impl VerifyBackend for EngineBackend {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
        self.cloud.start_session(id, prompt)
    }

    fn end_session(&mut self, id: u32) {
        self.cloud.end_session(id);
    }

    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        draft_probs: &[Vec<f32>],
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<BackendVerdict> {
        VerifyBackend::verify_block(
            &mut self.cloud,
            id,
            committed,
            draft,
            draft_probs,
            mode,
            temperature,
            top_p,
            rng,
        )
    }

    fn deploy(&mut self, version: &str) -> Result<u64> {
        self.cloud.deploy(&self.reg, version)?;
        Ok(self.cloud.version.seq)
    }

    fn version_name(&self) -> String {
        self.cloud.version.name.clone()
    }

    fn version_seq(&self) -> u64 {
        self.cloud.version.seq
    }

    fn remaining_capacity(&self, id: u32) -> usize {
        self.cloud.remaining_capacity(id)
    }

    fn label(&self) -> String {
        format!("engine:{}", self.cloud.version.name)
    }
}

// ---------------------------------------------------------------------
// Deterministic synthetic target + frozen synthetic draft
// ---------------------------------------------------------------------

/// Context window the synthetic token function hashes over.
const SYNTH_WINDOW: usize = 8;
/// Tokens 0..=2 are PAD/BOS/EOS — the synthetic trajectory avoids them
/// so runs are never cut short by a hash collision with EOS and token
/// counts stay exactly reproducible.
const SYNTH_RESERVED: i32 = 3;

fn ctx_hash(ctx: &[i32]) -> u64 {
    let tail = &ctx[ctx.len().saturating_sub(SYNTH_WINDOW)..];
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tail {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The frozen anchor's greedy prediction for the next token.
pub fn synth_base_token(seed: u64, vocab: i32, ctx: &[i32]) -> i32 {
    let mut r = SplitMix64::new(ctx_hash(ctx) ^ seed ^ 0xBA5E_70C5);
    SYNTH_RESERVED + r.next_range((vocab - SYNTH_RESERVED) as u64) as i32
}

/// The deployed target version's greedy next token: equals the base
/// prediction except at (deterministic, context-keyed) drift positions.
pub fn synth_target_token(seed: u64, vocab: i32, version_salt: u64, drift: f64, ctx: &[i32]) -> i32 {
    let base = synth_base_token(seed, vocab, ctx);
    if drift <= 0.0 {
        return base;
    }
    let mut r = SplitMix64::new(
        ctx_hash(ctx) ^ seed ^ version_salt.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    if r.next_f64() < drift {
        let span = (vocab - SYNTH_RESERVED) as u64;
        let jump = 1 + r.next_range(span - 1) as i32;
        SYNTH_RESERVED + (base - SYNTH_RESERVED + jump).rem_euclid(span as i32)
    } else {
        base
    }
}

fn name_salt(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A registered synthetic target version: name + how far it has evolved
/// away from the frozen draft's anchor (per-token disagreement rate).
#[derive(Debug, Clone)]
pub struct SyntheticVersion {
    pub name: String,
    pub drift: f64,
}

/// Deterministic verification backend (no artifacts, no clock, no
/// batching-order sensitivity — see module docs).
pub struct SyntheticTarget {
    pub seed: u64,
    pub vocab: i32,
    pub eos: i32,
    pub max_ctx: usize,
    versions: Vec<SyntheticVersion>,
    current: usize,
    seq: u64,
    /// id → committed length last seen (capacity accounting).
    sessions: HashMap<u32, usize>,
}

impl SyntheticTarget {
    pub fn new(seed: u64) -> SyntheticTarget {
        SyntheticTarget {
            seed,
            vocab: 512,
            eos: crate::workload::EOS,
            max_ctx: 4096,
            versions: vec![SyntheticVersion {
                name: "synthetic_base".into(),
                drift: 0.0,
            }],
            current: 0,
            seq: 1,
            sessions: HashMap::new(),
        }
    }

    /// Register a deployable version (builder-style).
    pub fn with_version(mut self, name: &str, drift: f64) -> SyntheticTarget {
        self.versions.push(SyntheticVersion {
            name: name.into(),
            drift: drift.clamp(0.0, 1.0),
        });
        self
    }

    pub fn current_version(&self) -> &SyntheticVersion {
        &self.versions[self.current]
    }

    fn target_token(&self, ctx: &[i32]) -> i32 {
        let v = self.current_version();
        synth_target_token(self.seed, self.vocab, name_salt(&v.name), v.drift, ctx)
    }
}

impl VerifyBackend for SyntheticTarget {
    fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
        if prompt.len() < 2 {
            bail!("prompt must have at least 2 tokens (BOS + 1)");
        }
        if self.sessions.contains_key(&id) {
            bail!("session {id} already open");
        }
        self.sessions.insert(id, prompt.len());
        Ok(())
    }

    fn end_session(&mut self, id: u32) {
        self.sessions.remove(&id);
    }

    fn verify_block(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        _draft_probs: &[Vec<f32>],
        _mode: VerifyMode,
        _temperature: f32,
        _top_p: f32,
        _rng: &mut SplitMix64,
    ) -> Result<BackendVerdict> {
        if !self.sessions.contains_key(&id) {
            bail!("no session {id}");
        }
        // Greedy verification against the deterministic trajectory
        // (stochastic mode degrades to greedy here by design — the
        // synthetic target exists for reproducibility, not sampling).
        let mut ctx = committed.to_vec();
        let mut tau = draft.len();
        let mut correction = None;
        for (j, &d) in draft.iter().enumerate() {
            let t = self.target_token(&ctx);
            if d == t {
                ctx.push(d);
            } else {
                tau = j;
                correction = Some(t);
                break;
            }
        }
        let correction = correction.unwrap_or_else(|| self.target_token(&ctx));
        let eos = correction == self.eos || draft[..tau].contains(&self.eos);
        self.sessions.insert(id, committed.len() + tau + 1);
        Ok(BackendVerdict {
            tau,
            correction,
            eos,
        })
    }

    fn deploy(&mut self, version: &str) -> Result<u64> {
        let idx = self
            .versions
            .iter()
            .position(|v| v.name == version)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown synthetic version '{version}' (have: {})",
                    self.versions
                        .iter()
                        .map(|v| v.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        self.current = idx;
        self.seq += 1;
        Ok(self.seq)
    }

    fn version_name(&self) -> String {
        self.current_version().name.clone()
    }

    fn version_seq(&self) -> u64 {
        self.seq
    }

    fn remaining_capacity(&self, id: u32) -> usize {
        self.sessions
            .get(&id)
            .map(|&len| self.max_ctx.saturating_sub(len))
            .unwrap_or(0)
    }

    fn label(&self) -> String {
        format!("synthetic:{}", self.current_version().name)
    }
}

/// The frozen edge draft matching `SyntheticTarget`: always predicts the
/// drift-free base trajectory. Deterministic, `Send`, artifact-free.
#[derive(Debug, Clone)]
pub struct SyntheticDraft {
    pub seed: u64,
    pub vocab: i32,
}

impl SyntheticDraft {
    pub fn new(seed: u64) -> SyntheticDraft {
        SyntheticDraft { seed, vocab: 512 }
    }
}

impl DraftSource for SyntheticDraft {
    fn propose(
        &mut self,
        committed: &[i32],
        k: usize,
        _temperature: f32,
        _top_p: f32,
        _rng: &mut SplitMix64,
    ) -> Result<Proposal> {
        let mut prop = Proposal::default();
        let mut ctx = committed.to_vec();
        for _ in 0..k {
            let t = synth_base_token(self.seed, self.vocab, &ctx);
            prop.tokens.push(t);
            prop.chosen_probs.push(1.0);
            ctx.push(t);
        }
        prop.edge_tokens = k;
        Ok(prop)
    }

    fn reset(&mut self) -> Result<()> {
        Ok(())
    }

    fn name(&self) -> String {
        "synthetic-draft".into()
    }

    fn is_pure(&self) -> bool {
        true // greedy hash chain over the context: pure by construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> SplitMix64 {
        SplitMix64::new(1)
    }

    fn run_rounds(t: &mut SyntheticTarget, d: &mut SyntheticDraft, rounds: usize, k: usize) -> (usize, usize) {
        let prompt = vec![1, 70, 80, 90];
        t.start_session(1, &prompt).unwrap();
        let mut committed = prompt;
        let (mut acc, mut drafted) = (0, 0);
        for _ in 0..rounds {
            let p = d
                .propose(&committed, k, 0.0, 1.0, &mut rng())
                .unwrap();
            let v = t
                .verify_block(1, &committed, &p.tokens, &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng())
                .unwrap();
            committed.extend_from_slice(&p.tokens[..v.tau]);
            committed.push(v.correction);
            acc += v.tau;
            drafted += p.tokens.len();
        }
        t.end_session(1);
        (acc, drafted)
    }

    #[test]
    fn base_version_accepts_everything() {
        let mut t = SyntheticTarget::new(7);
        let mut d = SyntheticDraft::new(7);
        let (acc, drafted) = run_rounds(&mut t, &mut d, 10, 4);
        assert_eq!(acc, drafted, "zero drift must accept every draft token");
    }

    #[test]
    fn drift_lowers_acceptance_deterministically() {
        let mut t = SyntheticTarget::new(7).with_version("evolved", 0.4);
        t.deploy("evolved").unwrap();
        assert_eq!(t.version_seq(), 2);
        let mut d = SyntheticDraft::new(7);
        let (acc1, drafted1) = run_rounds(&mut t, &mut d, 20, 4);
        assert!(acc1 < drafted1, "drift must reject some tokens");
        assert!(acc1 > 0, "drift 0.4 must still accept some tokens");

        // bit-identical on replay
        let mut t2 = SyntheticTarget::new(7).with_version("evolved", 0.4);
        t2.deploy("evolved").unwrap();
        let mut d2 = SyntheticDraft::new(7);
        assert_eq!(run_rounds(&mut t2, &mut d2, 20, 4), (acc1, drafted1));
    }

    #[test]
    fn verdicts_are_independent_of_round_partitioning() {
        // K=1 single-step rounds and K=4 rounds must walk the same
        // greedy trajectory (timing/batching invariance in miniature).
        let mk = || {
            let mut t = SyntheticTarget::new(3).with_version("v2", 0.3);
            t.deploy("v2").unwrap();
            t
        };
        let mut d = SyntheticDraft::new(3);
        let prompt = vec![1i32, 64, 65];

        let mut trajectory = |k: usize| {
            let mut t = mk();
            t.start_session(9, &prompt).unwrap();
            let mut committed = prompt.clone();
            while committed.len() < prompt.len() + 24 {
                let p = d.propose(&committed, k, 0.0, 1.0, &mut rng()).unwrap();
                let v = t
                    .verify_block(9, &committed, &p.tokens, &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng())
                    .unwrap();
                committed.extend_from_slice(&p.tokens[..v.tau]);
                committed.push(v.correction);
            }
            committed.truncate(prompt.len() + 24);
            committed
        };
        assert_eq!(trajectory(1), trajectory(4));
    }

    #[test]
    fn deploy_rejects_unknown_versions() {
        let mut t = SyntheticTarget::new(1);
        assert!(t.deploy("nope").is_err());
        assert_eq!(t.version_name(), "synthetic_base");
    }

    #[test]
    fn capacity_tracks_committed_length() {
        let mut t = SyntheticTarget::new(1);
        t.max_ctx = 10;
        t.start_session(1, &[1, 2, 3, 4]).unwrap();
        assert_eq!(t.remaining_capacity(1), 6);
        assert_eq!(t.remaining_capacity(99), 0);
    }
}
