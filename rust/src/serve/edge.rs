//! The edge client: drives one serving session over any `Transport`,
//! running the channel-aware adaptive stride policy (paper §IV-B)
//! against *measured* round-trip times instead of the simulator's
//! synthetic channel — the same `AdaptivePolicy`/`LatencyModel` code
//! path, fed by an EMA of observed RTT and effective goodput.

use super::session::SessionCore;
use super::transport::Transport;
use crate::channel::ChannelState;
use crate::coordinator::edge::DraftSource;
use crate::coordinator::policy::{AdaptivePolicy, LatencyModel};
use crate::devices::{CloudProfile, EdgeDevice, A800_70B, JETSON_ORIN};
use crate::protocol::frame::{Frame, FrameKind, Hello, HelloAck, OpenAck, OpenMsg, WIRE_VERSION};
use crate::protocol::{DraftMsg, VerifyMode, VerifyMsg, WireFormat};
use crate::util::rng::SplitMix64;
use crate::util::stats::{Ema, Summary};
use anyhow::{bail, Result};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct EdgeSessionConfig {
    pub mode: VerifyMode,
    pub temperature: f32,
    pub top_p: f32,
    pub max_new: usize,
    pub k_max: usize,
    /// Pin the stride (reproducibility runs, ablations); `None` runs the
    /// channel-aware adaptive policy on measured RTTs.
    pub fixed_k: Option<usize>,
    pub seed: u64,
    /// Device/cloud compute constants for the latency model's
    /// alpha_edge / T_base terms (the network terms are measured).
    pub device: &'static EdgeDevice,
    pub cloud: &'static CloudProfile,
}

impl Default for EdgeSessionConfig {
    fn default() -> Self {
        EdgeSessionConfig {
            mode: VerifyMode::Greedy,
            temperature: 0.0,
            top_p: 1.0,
            max_new: 32,
            k_max: 8,
            fixed_k: None,
            seed: 1,
            device: &JETSON_ORIN,
            cloud: &A800_70B,
        }
    }
}

/// Per-session client-side result.
#[derive(Debug, Clone)]
pub struct EdgeReport {
    pub session: u32,
    /// Target version sequence the cloud reported at open (observing
    /// cloud-side evolution without ever downloading weights).
    pub target_seq_at_open: u64,
    pub new_tokens: usize,
    pub accepted: usize,
    pub drafted: usize,
    pub rounds: usize,
    pub wall_ms: f64,
    /// Measured per-round RTT (draft sent → verdict decoded).
    pub rtt_ms: Summary,
    pub k_used: Summary,
    /// Full committed sequence (prompt + generated).
    pub committed: Vec<i32>,
}

impl EdgeReport {
    pub fn acceptance(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

async fn expect_frame<T: Transport>(t: &mut T, kind: FrameKind) -> Result<Frame> {
    match t.recv_frame().await? {
        Some(f) if f.kind == kind => Ok(f),
        Some(f) => bail!("expected {kind:?}, got {:?}", f.kind),
        None => bail!("connection closed while waiting for {kind:?}"),
    }
}

/// Run one full serving session: handshake, open, adaptive decode loop,
/// orderly Bye. Generic over transport AND draft source so the same
/// client serves TCP/loopback and model/model-free drafts.
pub async fn run_edge_session<T, D>(
    t: &mut T,
    draft: &mut D,
    prompt: &[i32],
    cfg: &EdgeSessionConfig,
) -> Result<EdgeReport>
where
    T: Transport,
    D: DraftSource + ?Sized,
{
    let t0 = Instant::now();

    // --- handshake ---------------------------------------------------
    let hello = Hello {
        wire_version: WIRE_VERSION,
        mode: cfg.mode,
        k_max: cfg.k_max.min(255) as u8,
    };
    t.send_frame(Frame::new(FrameKind::Hello, hello.encode()))
        .await?;
    let ack = HelloAck::decode(&expect_frame(t, FrameKind::HelloAck).await?.payload)?;
    if !ack.accepted {
        bail!("cloud rejected handshake: {}", ack.reason);
    }

    // --- open session ------------------------------------------------
    let open = OpenMsg {
        prompt: prompt.to_vec(),
        max_new: cfg.max_new as u32,
    };
    t.send_frame(Frame::new(FrameKind::Open, open.encode()))
        .await?;
    let ack = OpenAck::decode(&expect_frame(t, FrameKind::OpenAck).await?.payload)?;
    let id = ack.session;

    let mut core = SessionCore::new(id, prompt, cfg.max_new);
    draft.on_prompt(prompt.len());
    let mut policy = AdaptivePolicy::new(cfg.k_max.max(1), 0.15);
    let mut rng = SplitMix64::new(cfg.seed ^ (0x3000 + id as u64));

    // Measured link state. Seeded optimistically; the first rounds
    // correct it fast (EMA mu = 0.3).
    let mut rtt_ms = Ema::new(40.0, 0.3);
    let mut goodput_bps = Ema::new(10e6, 0.3);

    let mut rtt_summary = Summary::new();
    let mut k_summary = Summary::new();

    // --- decode loop -------------------------------------------------
    while !core.done {
        let k = match cfg.fixed_k {
            Some(k) => k.clamp(1, cfg.k_max.max(1)),
            None => {
                let state = ChannelState {
                    up_bps: goodput_bps.get().max(1e4),
                    down_bps: goodput_bps.get().max(1e4),
                    prop_ms: (rtt_ms.get() / 2.0).max(0.01),
                    fading: false,
                    loss_rate: 0.0,
                };
                let lat = LatencyModel::build(&state, cfg.device, cfg.cloud, WireFormat::Compact);
                policy.select_k(&lat)
            }
        };
        let prop = draft.propose(&core.committed, k, cfg.temperature, cfg.top_p, &mut rng)?;
        let msg = DraftMsg {
            session: id,
            round: core.rounds as u32,
            tokens: prop.tokens.clone(),
            chosen_probs: prop.chosen_probs,
            mode: cfg.mode,
            wire: WireFormat::Compact,
        };
        let sent = Instant::now();
        t.send_frame(Frame::new(FrameKind::Draft, msg.encode()))
            .await?;
        let v = VerifyMsg::decode(&expect_frame(t, FrameKind::Verify).await?.payload)?;

        // measure the link this round actually saw
        let rtt_now = sent.elapsed().as_secs_f64() * 1e3;
        rtt_ms.update(rtt_now);
        let bytes = (msg.air_bytes() + v.air_bytes()) as f64;
        goodput_bps.update(bytes * 8.0 / (rtt_now / 1e3).max(1e-6));
        rtt_summary.add(rtt_now);
        k_summary.add(prop.tokens.len() as f64);

        let tau = (v.tau as usize).min(prop.tokens.len());
        if !prop.tokens.is_empty() {
            policy.observe(tau, prop.tokens.len());
        }
        core.apply_verdict(&prop.tokens, tau, v.correction, v.eos, false);
    }
    t.send_frame(Frame::new(FrameKind::Bye, vec![])).await?;

    Ok(EdgeReport {
        session: id,
        target_seq_at_open: ack.target_seq,
        new_tokens: core.new_tokens,
        accepted: core.accepted,
        drafted: core.drafted,
        rounds: core.rounds,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        rtt_ms: rtt_summary,
        k_used: k_summary,
        committed: core.committed,
    })
}
