//! The edge client: drives one serving session over any `Transport`,
//! running the channel-aware adaptive stride policy (paper §IV-B)
//! against *measured* round-trip times instead of the simulator's
//! synthetic channel — the same `AdaptivePolicy`/`LatencyModel` code
//! path, fed by an EMA of observed RTT and effective goodput.
//!
//! # Surviving link drops
//!
//! The session loop is RESUMABLE: any transport error triggers
//! `Transport::reattach`, and on success the loop replays the resume
//! handshake (`Resume{token, committed_len}` → `ResumeAck{tail, ...}`),
//! fast-forwards its committed mirror with the tail the cloud applied
//! while the link was down, and keeps decoding from the committed
//! prefix — the frozen draft needs no retraining and no re-sync, only
//! the position. Two transports provide reattach:
//!
//! * [`ResumableTransport`] — one session per connection; reattach
//!   redials through a [`Reconnect`] factory and replays the `Hello`.
//! * [`mux::MuxStream`](super::mux::MuxStream) — many sessions per
//!   connection; reattach waits for the shared pump's redial.
//!
//! An `Open` whose ack was lost is retransmitted with the same client
//! nonce, so the cloud reattaches the half-created session instead of
//! leaking a second one. Transport-level duplicates of acks and
//! verdicts are skipped by round/kind filters on the receive path.

use super::pipeline::{PipelinedDrafter, MAX_PIPELINE_DEPTH};
use super::session::SessionCore;
use super::transport::{BoxFuture, Reconnect, Transport};
use crate::channel::ChannelState;
use crate::coordinator::edge::DraftSource;
use crate::coordinator::policy::{AdaptivePolicy, LatencyModel};
use crate::device::{DeviceProfile, SpecPlan};
use crate::devices::{CloudProfile, EdgeDevice, A800_70B, JETSON_ORIN};
use crate::energy::EnergyBudget;
use crate::protocol::frame::{
    BusyMsg, CancelMsg, Frame, FrameKind, Hello, HelloAck, OpenAck, OpenMsg, RedirectMsg,
    ReplicaInfoMsg, ResumeAck, ResumeMsg, MIN_WIRE_VERSION, WIRE_VERSION,
};
use crate::obs::{LatencySummary, SpanKind, Trace};
use crate::protocol::{DraftMsg, VerifyMode, VerifyMsg, WireFormat};
use crate::util::log::{log, Level};
use crate::util::rng::SplitMix64;
use crate::util::stats::{Ema, Summary};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Stream id a single-session connection uses for its one session.
pub const SESSION_STREAM: u32 = 1;

/// Upper bound on consecutive ignorable frames skipped while waiting
/// for a specific one (duplicate-retransmit tolerance, not an allowance
/// for protocol drift).
const SKIP_BUDGET: usize = 1024;

/// Give up on a round after this many consecutive `Busy` deferrals.
/// The cloud's queue drains every batching window, so a healthy server
/// answers within a handful of retries; exhausting the budget means the
/// cloud is persistently over capacity and the session should fail
/// loudly rather than spin forever.
pub const MAX_BUSY_RETRIES: usize = 64;

/// Ceiling on the per-retry backoff sleep (the suggested retry_after is
/// doubled per consecutive deferral up to this cap).
pub const BUSY_BACKOFF_CAP_MS: u64 = 500;

/// Backoff schedule for `Busy`-deferred drafts: the cloud's suggested
/// horizon doubled per consecutive deferral, `base * 2^attempt` capped
/// at [`BUSY_BACKOFF_CAP_MS`]. `attempt` counts completed deferrals of
/// this round, so the first retry (attempt 0) sleeps exactly the
/// suggested horizon and every further deferral doubles it. Pure so the
/// virtual-clock load harness can share the exact live schedule.
pub fn busy_backoff_ms(retry_after_ms: u32, attempt: usize) -> u64 {
    let base = retry_after_ms.max(1) as u64;
    // 2^attempt saturates well past the cap; clamp the shift so it
    // stays defined, then let `min` flatten everything at the ceiling.
    let doubled = if attempt >= u64::BITS as usize {
        u64::MAX
    } else {
        base.saturating_mul(1u64 << attempt)
    };
    doubled.min(BUSY_BACKOFF_CAP_MS)
}

/// Sleep before re-sending a `Busy`-deferred draft (see
/// [`busy_backoff_ms`] for the schedule).
async fn busy_backoff(retry_after_ms: u32, attempt: usize) {
    let ms = busy_backoff_ms(retry_after_ms, attempt);
    tokio::time::sleep(std::time::Duration::from_millis(ms)).await;
}

#[derive(Debug, Clone)]
pub struct EdgeSessionConfig {
    pub mode: VerifyMode,
    pub temperature: f32,
    pub top_p: f32,
    pub max_new: usize,
    pub k_max: usize,
    /// Pin the stride (reproducibility runs, ablations); `None` runs the
    /// channel-aware adaptive policy on measured RTTs.
    pub fixed_k: Option<usize>,
    /// Pipelined drafting (wire v3): rounds kept in flight. 1 =
    /// sequential (the classic lock-step loop); >= 2 overlaps round r+1
    /// drafting with round r verification, cancel-on-reject; 0 = AUTO —
    /// `AdaptivePolicy::select_pipeline_depth` picks per round from the
    /// measured channel (pipelining pays exactly when T_fixed dominates
    /// K * T_marginal). Requires a pure draft source
    /// (`DraftSource::is_pure`); impure sources fall back to sequential.
    pub pipeline_depth: usize,
    pub seed: u64,
    /// Give up after this many reattach attempts within one session.
    pub max_reattach: usize,
    /// Fleet fallback (wire v5): when a resume is rejected because the
    /// session's state is gone everywhere — its replica died before it
    /// could export — RE-ROOT instead of failing: open a FRESH session
    /// on the current (failed-over) replica with the committed prefix
    /// as the prompt and the remaining budget. A pure draft source and
    /// a deterministic target continue the trajectory byte-identically
    /// — the paper's thesis that the frozen draft needs nothing but the
    /// committed position, applied to replica death. Off by default:
    /// outside a fleet, a lost session should fail loudly.
    pub reroot_on_unknown_session: bool,
    /// QoS tier announced at `Open` (wire v7). Tier 1 (the default) is
    /// best-effort and encodes byte-identically to a v6 open; higher
    /// tiers bypass the verifier's `tier_reserve` admission headroom
    /// under overload (they still queue — tiers never change tokens).
    /// Clamped back to 1 on connections negotiated below v7.
    pub tier: u32,
    /// Device profile announced at `Open` (wire v8): compute tier,
    /// channel class, and remaining energy budget. `None` (the
    /// default) opens an unprofiled session whose bytes — and behavior
    /// — are identical to wire v7. Cleared on connections negotiated
    /// below v8 (the pre-v8 open decoder rejects the profile tail).
    pub profile: Option<DeviceProfile>,
    /// Ceiling on the draft-tree branching factor (wire v8). 1 (the
    /// default) keeps every draft a linear chain, byte-identical to
    /// v7; up to [`crate::device::MAX_BRANCHING`] lets
    /// `AdaptivePolicy::select_plan` hedge the chain with alternate
    /// leaves when the device tier and remaining energy allow.
    /// Unprofiled sessions stay linear regardless. Clamped back to 1
    /// on connections negotiated below v8.
    pub branching: usize,
    /// Device/cloud compute constants for the latency model's
    /// alpha_edge / T_base terms (the network terms are measured).
    pub device: &'static EdgeDevice,
    pub cloud: &'static CloudProfile,
    /// Edge-side trace journal (`Draft`/`Uplink`/`Downlink`/`Reroot`
    /// events per round). `None` (the default) keeps the decode loops
    /// free of any timing or recording work.
    pub trace: Option<Trace>,
}

impl Default for EdgeSessionConfig {
    fn default() -> Self {
        EdgeSessionConfig {
            mode: VerifyMode::Greedy,
            temperature: 0.0,
            top_p: 1.0,
            max_new: 32,
            k_max: 8,
            fixed_k: None,
            pipeline_depth: 1,
            seed: 1,
            max_reattach: 8,
            reroot_on_unknown_session: false,
            tier: 1,
            profile: None,
            branching: 1,
            device: &JETSON_ORIN,
            cloud: &A800_70B,
            trace: None,
        }
    }
}

/// Per-session client-side result.
#[derive(Debug, Clone)]
pub struct EdgeReport {
    pub session: u32,
    /// Target version sequence the cloud reported at open (observing
    /// cloud-side evolution without ever downloading weights).
    pub target_seq_at_open: u64,
    pub new_tokens: usize,
    pub accepted: usize,
    pub drafted: usize,
    pub rounds: usize,
    pub wall_ms: f64,
    /// Successful link reattaches this session survived.
    pub reattaches: usize,
    /// Successful resume handshakes (≤ reattaches; an open retransmit
    /// reattaches without a resume).
    pub resumes: usize,
    /// Measured per-round RTT (draft sent → verdict decoded).
    pub rtt_ms: Summary,
    pub k_used: Summary,
    /// Rounds whose draft was launched speculatively and survived —
    /// verify/downlink round trips hidden behind drafting (wire v3).
    pub rounds_pipelined: usize,
    /// Speculative rounds retracted after a broken optimistic prefix.
    pub drafts_cancelled: usize,
    /// Draft tokens of retracted rounds (uplink spent on speculation
    /// that did not land).
    pub draft_tokens_wasted: usize,
    /// Verdict waits with another round already in flight (hidden RTT).
    pub overlapped_waits: usize,
    /// Verdict waits with nothing else in flight — the full round trip
    /// stalls the edge. Sequential mode: every round is one of these.
    pub exposed_waits: usize,
    /// `Busy`-deferred drafts re-sent after backoff (admission control,
    /// wire v4). Each is one extra uplink of the same round; committed
    /// tokens never change.
    pub busy_retries: usize,
    /// Fleet `Redirect` frames honored (wire v5): handoffs this
    /// session survived — FOLLOWED to the named peer when the
    /// transport could retarget, or absorbed by a resume-in-place
    /// (the exporter re-imports) when it could not, e.g. a mux stream
    /// pinned to its shared connection. Committed tokens never change
    /// either way.
    pub redirects: usize,
    /// Re-roots after the session's state was lost fleet-wide (replica
    /// death before export): fresh sessions opened from the committed
    /// prefix on a surviving replica
    /// (`EdgeSessionConfig::reroot_on_unknown_session`).
    pub reroots: usize,
    /// Edge-observed latency histograms (`rtt_ms` populated; the
    /// queue/verify components live cloud-side in `ServingMetrics`).
    pub latency: LatencySummary,
    /// Full committed sequence (prompt + generated).
    pub committed: Vec<i32>,
}

impl EdgeReport {
    pub fn acceptance(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Process-unique open nonce (value is irrelevant to determinism; only
/// uniqueness matters, so OS-entropy hashing is fine).
fn fresh_nonce() -> u64 {
    use std::collections::hash_map::RandomState;
    use std::hash::{BuildHasher, Hasher};
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let mut h = RandomState::new().build_hasher();
    h.write_u64(COUNTER.fetch_add(1, Ordering::Relaxed));
    h.finish().max(1)
}

pub(crate) fn hello_for(cfg: &EdgeSessionConfig) -> Hello {
    Hello {
        wire_version: WIRE_VERSION,
        mode: cfg.mode,
        k_max: cfg.k_max.min(255) as u8,
    }
}

/// Run the connection-scoped `Hello` handshake (once per connection,
/// regardless of how many sessions it will carry). Returns the
/// NEGOTIATED wire version — below 3, pipelined drafting (spec-tagged
/// drafts + `Cancel`) must stay off on this connection.
pub async fn edge_handshake<T: Transport + ?Sized>(
    t: &mut T,
    cfg: &EdgeSessionConfig,
) -> Result<u16> {
    handshake_with(t, &hello_for(cfg)).await
}

pub(crate) async fn handshake_with<T: Transport + ?Sized>(
    t: &mut T,
    hello: &Hello,
) -> Result<u16> {
    t.send_frame(Frame::control(FrameKind::Hello, hello.encode()))
        .await?;
    let ack = HelloAck::decode(&await_kind(t, FrameKind::HelloAck).await?.payload)?;
    if !ack.accepted {
        bail!("cloud rejected handshake: {}", ack.reason);
    }
    Ok(ack.wire_version)
}

/// Wait for a frame of `want` kind, skipping harmless transport-level
/// duplicates of earlier acks/verdicts, replica telemetry, and stale
/// `Redirect` duplicates (a redirect already followed; the session's
/// current home answers the pending handshake).
async fn await_kind<T: Transport + ?Sized>(t: &mut T, want: FrameKind) -> Result<Frame> {
    for _ in 0..SKIP_BUDGET {
        match t.recv_frame().await? {
            None => bail!("connection closed while waiting for {want:?}"),
            Some(f) if f.kind == want => return Ok(f),
            Some(f) if f.kind == FrameKind::ReplicaInfo => {
                if let Ok(info) = ReplicaInfoMsg::decode(&f.payload) {
                    log(
                        Level::Debug,
                        "edge",
                        &format!(
                            "replica telemetry: version seq {} load {}",
                            info.version, info.load
                        ),
                    );
                }
            }
            Some(f)
                if matches!(
                    f.kind,
                    FrameKind::HelloAck
                        | FrameKind::OpenAck
                        | FrameKind::ResumeAck
                        | FrameKind::Verify
                        | FrameKind::Busy
                        | FrameKind::Redirect
                ) =>
            {
                log(
                    Level::Debug,
                    "edge",
                    &format!("skipping stale {:?} while waiting for {want:?}", f.kind),
                );
            }
            Some(f) => bail!("expected {want:?}, got {:?}", f.kind),
        }
    }
    bail!("no {want:?} frame within the skip budget")
}

/// The cloud's answer to one awaited round: a verdict, an
/// admission-control deferral (wire v4) telling the edge to re-send the
/// identical draft after a backoff, or a fleet handoff (wire v5)
/// telling the edge to resume the session on a peer replica.
enum RoundReply {
    Verdict(VerifyMsg),
    Busy(BusyMsg),
    Redirect(RedirectMsg),
}

/// Wait for THE reply of `round` — its verdict or its `Busy` deferral —
/// ignoring stale duplicates of earlier rounds (replays the cloud sent
/// to absorb retransmits) and stale `Busy` frames for rounds that have
/// since resolved.
async fn await_round_reply<T: Transport + ?Sized>(t: &mut T, round: u32) -> Result<RoundReply> {
    for _ in 0..SKIP_BUDGET {
        match t.recv_frame().await? {
            None => bail!("connection closed while waiting for round {round}"),
            Some(f) if f.kind == FrameKind::Verify => {
                let v = VerifyMsg::decode(&f.payload)?;
                if v.round == round {
                    return Ok(RoundReply::Verdict(v));
                }
                if v.round > round {
                    bail!("verdict for future round {} (expected {round})", v.round);
                }
                // stale duplicate of an already-applied round: ignore
            }
            Some(f) if f.kind == FrameKind::Busy => {
                let b = BusyMsg::decode(&f.payload)?;
                if b.round == round {
                    return Ok(RoundReply::Busy(b));
                }
                // a deferral for a round that already resolved (e.g. a
                // transport duplicate of a Busy we already acted on):
                // stale, ignore. Deferrals only ever target the
                // session's next expected round, so a future-round Busy
                // cannot occur on an ordered transport.
            }
            Some(f) if f.kind == FrameKind::Redirect => {
                // fleet handoff (wire v5): the session left this
                // replica. Not round-filtered — whatever round was in
                // flight, the right move is to resume at the target
                // (duplicates converge: the session's current home
                // answers the replayed resume).
                return Ok(RoundReply::Redirect(RedirectMsg::decode(&f.payload)?));
            }
            Some(f) if f.kind == FrameKind::ReplicaInfo => {
                log(
                    Level::Debug,
                    "edge",
                    "skipping replica telemetry while waiting for a verdict",
                );
            }
            Some(f)
                if matches!(
                    f.kind,
                    FrameKind::HelloAck | FrameKind::OpenAck | FrameKind::ResumeAck
                ) =>
            {
                log(
                    Level::Debug,
                    "edge",
                    &format!("skipping stale {:?} while waiting for round {round}", f.kind),
                );
            }
            Some(f) => bail!("expected Verify, got {:?}", f.kind),
        }
    }
    bail!("no verdict for round {round} within the skip budget")
}

/// Follow (or fall back on) a fleet `Redirect` (wire v5), shared by the
/// sequential and pipelined decode loops: adopt the handoff token,
/// point the transport's next reattach at the target (transports that
/// cannot move resume in place — the exporter re-imports), and hand
/// back the error that fails the attempt so the normal reattach path
/// replays the Resume wherever the session now lives. Any in-flight
/// drafts die with the attempt and are redrafted byte-identically from
/// the committed prefix.
async fn follow_redirect<T>(
    t: &mut T,
    stream: u32,
    st: &mut LiveSession,
    totals: &mut PipeTotals,
    r: RedirectMsg,
    context: &str,
) -> anyhow::Error
where
    T: Transport + ?Sized,
{
    totals.redirects += 1;
    st.token = r.resume_token;
    let moved = t.redirect(r.addr.clone()).await.unwrap_or(false);
    log(
        Level::Debug,
        "edge",
        &format!(
            "stream {stream}: redirected {context} to '{}' ({})",
            r.addr,
            if moved { "following" } else { "resuming in place" },
        ),
    );
    anyhow!("session handed off to '{}'", r.addr)
}

/// Rejections the cloud made deliberately (bad token, version gate):
/// reconnecting cannot change the verdict, so the session fails fast.
fn is_permanent_rejection(e: &anyhow::Error) -> bool {
    let msg = format!("{e:#}");
    msg.contains("cloud rejected resume") || msg.contains("cloud rejected handshake")
}

/// Counters carried across a RE-ROOT (replica death: the session's
/// state was lost fleet-wide, and a fresh wire session continues from
/// the committed prefix on a survivor). The fresh [`SessionCore`]
/// restarts its tallies; the report sums `base + core` so the request's
/// totals survive the identity change.
#[derive(Debug, Default, Clone, Copy)]
struct Carried {
    new_tokens: usize,
    accepted: usize,
    drafted: usize,
    rounds: usize,
}

impl Carried {
    fn absorb(&mut self, core: &SessionCore) {
        self.new_tokens += core.new_tokens;
        self.accepted += core.accepted;
        self.drafted += core.drafted;
        self.rounds += core.rounds;
    }
}

/// Session state that survives reattaches.
struct LiveSession {
    id: u32,
    token: u64,
    target_seq_at_open: u64,
    core: SessionCore,
    /// Totals absorbed from pre-re-root incarnations (zero until a
    /// replica death forces a re-root).
    base: Carried,
    /// Nonce of an in-flight re-root `Open` (0 = none). Minted ONCE
    /// per re-root and kept until its ack arrives, so a link drop
    /// mid-re-root retransmits the SAME nonce and the survivor's
    /// open-nonce dedup reattaches the half-created session instead of
    /// leaking a second one — the same idempotency the initial Open
    /// gets from `run_session_on`'s session-level nonce.
    reroot_nonce: u64,
}

/// Measured-link state + policy, persistent across reattaches.
struct LinkStats {
    policy: AdaptivePolicy,
    rtt_ms: Ema,
    goodput_bps: Ema,
    rtt_summary: Summary,
    k_summary: Summary,
    latency: LatencySummary,
    /// Session energy budget (wire v8): drained per drafted tree node,
    /// read by [`LinkStats::select_plan`] to step speculation down as
    /// the battery empties. Unmetered for unprofiled sessions.
    energy: EnergyBudget,
}

impl LinkStats {
    fn new(cfg: &EdgeSessionConfig) -> LinkStats {
        LinkStats {
            policy: AdaptivePolicy::new(cfg.k_max.max(1), 0.15),
            // seeded optimistically; the first rounds correct it fast
            rtt_ms: Ema::new(40.0, 0.3),
            goodput_bps: Ema::new(10e6, 0.3),
            rtt_summary: Summary::new(),
            k_summary: Summary::new(),
            latency: LatencySummary::new(),
            energy: cfg
                .profile
                .map_or(EnergyBudget::unmetered(), |p| EnergyBudget::new(p.energy_budget_j)),
        }
    }

    /// Joint per-round speculation plan (wire v8): profiled sessions
    /// run the resource-aware [`AdaptivePolicy::select_plan`] against
    /// the measured channel — stride capped by the device tier,
    /// branching a pure function of (tier, remaining energy, config
    /// cap). The unprofiled path reduces EXACTLY to the classic
    /// `select_k` with a linear chain, so pre-v8 sessions behave byte
    /// for byte as before. Tree rounds are greedy-only (the verifier
    /// rejects stochastic trees), so a stochastic config pins
    /// branching at 1.
    fn select_plan(&mut self, cfg: &EdgeSessionConfig) -> SpecPlan {
        let Some(profile) = cfg.profile else {
            return SpecPlan { k: self.select_k(cfg), depth: 1, branching: 1 };
        };
        let state = ChannelState {
            up_bps: self.goodput_bps.get().max(1e4),
            down_bps: self.goodput_bps.get().max(1e4),
            prop_ms: (self.rtt_ms.get() / 2.0).max(0.01),
            fading: false,
            loss_rate: 0.0,
        };
        let lat = LatencyModel::build(&state, cfg.device, cfg.cloud, WireFormat::Compact);
        let mut plan = self.policy.select_plan(
            &lat,
            profile.tier,
            self.energy.remaining_frac(),
            1, // the sequential loop owns this call; depth is decided upstream
            cfg.branching.max(1),
        );
        if let Some(k) = cfg.fixed_k {
            // the pinning/ablation knob overrides the stride but never
            // the branching (which stays tier- and energy-capped)
            plan.k = k.clamp(1, cfg.k_max.max(1));
        }
        if cfg.mode != VerifyMode::Greedy {
            plan.branching = 1;
        }
        plan
    }

    fn select_k(&mut self, cfg: &EdgeSessionConfig) -> usize {
        match cfg.fixed_k {
            Some(k) => k.clamp(1, cfg.k_max.max(1)),
            None => {
                let state = ChannelState {
                    up_bps: self.goodput_bps.get().max(1e4),
                    down_bps: self.goodput_bps.get().max(1e4),
                    prop_ms: (self.rtt_ms.get() / 2.0).max(0.01),
                    fading: false,
                    loss_rate: 0.0,
                };
                let lat = LatencyModel::build(&state, cfg.device, cfg.cloud, WireFormat::Compact);
                self.policy.select_k(&lat)
            }
        }
    }

    fn observe_round(&mut self, rtt_now_ms: f64, air_bytes: usize, k: usize) {
        self.rtt_ms.update(rtt_now_ms);
        self.goodput_bps
            .update(air_bytes as f64 * 8.0 / (rtt_now_ms / 1e3).max(1e-6));
        self.rtt_summary.add(rtt_now_ms);
        self.k_summary.add(k as f64);
        self.latency.rtt_ms.record(rtt_now_ms);
    }

    /// Rounds to keep in flight this instant: the configured depth, or
    /// the policy hook on the measured channel in AUTO mode
    /// (`pipeline_depth == 0`).
    fn select_depth(&mut self, cfg: &EdgeSessionConfig) -> usize {
        match cfg.pipeline_depth {
            0 => {
                let state = ChannelState {
                    up_bps: self.goodput_bps.get().max(1e4),
                    down_bps: self.goodput_bps.get().max(1e4),
                    prop_ms: (self.rtt_ms.get() / 2.0).max(0.01),
                    fading: false,
                    loss_rate: 0.0,
                };
                let lat = LatencyModel::build(&state, cfg.device, cfg.cloud, WireFormat::Compact);
                let k = cfg
                    .fixed_k
                    .unwrap_or_else(|| self.policy.select_k(&lat))
                    .clamp(1, cfg.k_max.max(1));
                let d = self.policy.select_pipeline_depth(&lat, k, MAX_PIPELINE_DEPTH);
                // a device profile caps AUTO depth at its tier ceiling
                // (an explicitly configured depth is an ablation knob
                // and stays untouched)
                match cfg.profile {
                    Some(p) => d.min(p.tier.plan_caps().depth).max(1),
                    None => d,
                }
            }
            d => d.min(MAX_PIPELINE_DEPTH),
        }
    }
}

/// Pipeline counters accumulated across reattaches (each attempt runs
/// its own [`PipelinedDrafter`]; a link drop must not lose the tally).
#[derive(Debug, Default, Clone, Copy)]
struct PipeTotals {
    rounds_pipelined: usize,
    drafts_cancelled: usize,
    draft_tokens_wasted: usize,
    overlapped_waits: usize,
    exposed_waits: usize,
    /// Busy-deferred drafts re-sent (accumulated across reattaches and
    /// both loop shapes — not pipeline-specific despite the host).
    busy_retries: usize,
    /// Fleet redirects followed (wire v5; same host rationale).
    redirects: usize,
    /// Re-roots after fleet-wide session loss (same host rationale).
    reroots: usize,
}

impl PipeTotals {
    fn merge(&mut self, p: &PipelinedDrafter) {
        self.rounds_pipelined += p.rounds_pipelined;
        self.drafts_cancelled += p.drafts_cancelled;
        self.draft_tokens_wasted += p.draft_tokens_wasted;
        self.overlapped_waits += p.overlapped_waits;
        self.exposed_waits += p.exposed_waits;
    }
}

/// Run one full serving session on an already-handshaked connection:
/// open (or resume, after reattaches), adaptive decode loop, orderly
/// Bye — all frames on the given stream. Generic over transport AND
/// draft source so the same client serves TCP/loopback/mux and
/// model/model-free drafts.
pub async fn run_session_on<T, D>(
    t: &mut T,
    stream: u32,
    draft: &mut D,
    prompt: &[i32],
    cfg: &EdgeSessionConfig,
) -> Result<EdgeReport>
where
    T: Transport + ?Sized,
    D: DraftSource + ?Sized,
{
    let t0 = Instant::now();
    let nonce = fresh_nonce();
    let mut sess: Option<LiveSession> = None;
    let mut stats = LinkStats::new(cfg);
    let mut rng = SplitMix64::new(cfg.seed ^ (0x3000 + stream as u64));
    let mut reattaches = 0usize;
    let mut resumes = 0usize;
    let mut pipe_totals = PipeTotals::default();

    loop {
        match attempt_session(
            t,
            stream,
            &mut sess,
            draft,
            prompt,
            cfg,
            nonce,
            &mut stats,
            &mut rng,
            &mut resumes,
            &mut pipe_totals,
        )
        .await
        {
            Ok(()) => break,
            Err(e) => {
                // permanent protocol rejections cannot be cured by a
                // fresh link: fail fast instead of hammering the server
                if is_permanent_rejection(&e) {
                    return Err(e);
                }
                reattaches += 1;
                if reattaches > cfg.max_reattach {
                    return Err(e.context(format!(
                        "giving up after {} reattach attempts",
                        cfg.max_reattach
                    )));
                }
                match t.reattach().await {
                    Ok(true) => {
                        log(
                            Level::Debug,
                            "edge",
                            &format!("stream {stream}: reattached after: {e:#}"),
                        );
                        continue;
                    }
                    // no reconnect support on this transport: the
                    // original link error stands
                    Ok(false) => return Err(e),
                    Err(re) => {
                        // the reattach itself died (a fault can land on
                        // the fresh link's handshake): retry within the
                        // same budget; exhaustion surfaces the error
                        log(
                            Level::Debug,
                            "edge",
                            &format!("stream {stream}: reattach failed, retrying: {re:#}"),
                        );
                        continue;
                    }
                }
            }
        }
    }

    let st = sess.ok_or_else(|| anyhow!("session finished without opening"))?;
    Ok(EdgeReport {
        session: st.id,
        target_seq_at_open: st.target_seq_at_open,
        new_tokens: st.base.new_tokens + st.core.new_tokens,
        accepted: st.base.accepted + st.core.accepted,
        drafted: st.base.drafted + st.core.drafted,
        rounds: st.base.rounds + st.core.rounds,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        reattaches,
        resumes,
        rtt_ms: stats.rtt_summary,
        k_used: stats.k_summary,
        rounds_pipelined: pipe_totals.rounds_pipelined,
        drafts_cancelled: pipe_totals.drafts_cancelled,
        draft_tokens_wasted: pipe_totals.draft_tokens_wasted,
        overlapped_waits: pipe_totals.overlapped_waits,
        exposed_waits: pipe_totals.exposed_waits,
        busy_retries: pipe_totals.busy_retries,
        redirects: pipe_totals.redirects,
        reroots: pipe_totals.reroots,
        latency: stats.latency,
        committed: st.core.committed,
    })
}

/// One attachment's worth of work: open or resume, then decode until
/// done (or until the link dies, in which case the caller reattaches
/// and calls again — `sess` carries the state across).
#[allow(clippy::too_many_arguments)]
async fn attempt_session<T, D>(
    t: &mut T,
    stream: u32,
    sess: &mut Option<LiveSession>,
    draft: &mut D,
    prompt: &[i32],
    cfg: &EdgeSessionConfig,
    nonce: u64,
    stats: &mut LinkStats,
    rng: &mut SplitMix64,
    resumes: &mut usize,
    pipe_totals: &mut PipeTotals,
) -> Result<()>
where
    T: Transport + ?Sized,
    D: DraftSource + ?Sized,
{
    match sess.as_mut() {
        None => {
            // --- open (idempotent via nonce) --------------------------
            let open = OpenMsg {
                prompt: prompt.to_vec(),
                max_new: cfg.max_new as u32,
                nonce,
                tier: cfg.tier,
                profile: cfg.profile.map(|p| p.to_wire(stats.energy.remaining_j())),
            };
            t.send_frame(Frame::on(stream, FrameKind::Open, open.encode()))
                .await?;
            let ack = OpenAck::decode(&await_kind(t, FrameKind::OpenAck).await?.payload)?;
            draft.on_prompt(prompt.len());
            // reseed per SERVER-assigned id so concurrent sessions draw
            // independent sampling streams regardless of their local
            // stream ids (every dedicated connection uses stream 1)
            *rng = SplitMix64::new(cfg.seed ^ (0x3000 + ack.session as u64));
            *sess = Some(LiveSession {
                id: ack.session,
                token: ack.resume_token,
                target_seq_at_open: ack.target_seq,
                core: SessionCore::new(ack.session, prompt, cfg.max_new),
                base: Carried::default(),
                reroot_nonce: 0,
            });
        }
        Some(st) => {
            // --- resume from the committed prefix ---------------------
            let msg = ResumeMsg {
                token: st.token,
                committed_len: st.core.committed.len() as u64,
            };
            t.send_frame(Frame::on(stream, FrameKind::Resume, msg.encode()))
                .await?;
            let ack = ResumeAck::decode(&await_kind(t, FrameKind::ResumeAck).await?.payload)?;
            if ack.accepted {
                *resumes += 1;
                // adopt the server-assigned id: after a fleet handoff
                // the importing replica mints a fresh one (the cloud
                // demux rewrites draft session ids anyway — this keeps
                // the report and logs truthful)
                if ack.session != 0 {
                    st.id = ack.session;
                    st.core.id = ack.session;
                }
                st.core.fast_forward(&ack.tail, ack.rounds as usize, ack.done);
            } else if cfg.reroot_on_unknown_session && ack.unknown_token {
                // fleet-wide session loss (the replica died before it
                // could export): RE-ROOT — open a fresh session on this
                // (failed-over) replica with the committed prefix as
                // the prompt and the remaining budget. The frozen draft
                // needs nothing but the position, so the trajectory
                // continues byte-identically; only the wire identity
                // (session id, round counter) restarts.
                let committed = st.core.committed.clone();
                let remaining = cfg
                    .max_new
                    .saturating_sub(st.base.new_tokens + st.core.new_tokens);
                if remaining == 0 || st.core.done {
                    st.core.done = true;
                } else {
                    // one nonce per re-root, held until its ack lands:
                    // a retransmit after a mid-re-root link drop must
                    // reattach, not leak a second session
                    if st.reroot_nonce == 0 {
                        st.reroot_nonce = fresh_nonce();
                    }
                    let open = OpenMsg {
                        prompt: committed.clone(),
                        max_new: remaining as u32,
                        nonce: st.reroot_nonce,
                        tier: cfg.tier,
                        // the re-rooted session inherits the device and
                        // whatever energy the first incarnation left
                        profile: cfg.profile.map(|p| p.to_wire(stats.energy.remaining_j())),
                    };
                    t.send_frame(Frame::on(stream, FrameKind::Open, open.encode()))
                        .await?;
                    let ack =
                        OpenAck::decode(&await_kind(t, FrameKind::OpenAck).await?.payload)?;
                    st.reroot_nonce = 0;
                    st.base.absorb(&st.core);
                    st.id = ack.session;
                    st.token = ack.resume_token;
                    st.core = SessionCore::new(ack.session, &committed, remaining);
                    pipe_totals.reroots += 1;
                    if let Some(tr) = &cfg.trace {
                        tr.record(ack.session, 0, SpanKind::Reroot, 0.0, committed.len() as u32, 0);
                    }
                    log(
                        Level::Warn,
                        "edge",
                        &format!(
                            "stream {stream}: session lost fleet-wide; re-rooted as \
                             session {} from {} committed tokens",
                            ack.session,
                            committed.len()
                        ),
                    );
                }
            } else {
                bail!("cloud rejected resume: {}", ack.reason);
            }
        }
    }

    // --- decode loop -------------------------------------------------
    let st = sess.as_mut().expect("session is live after open/resume");
    let pipelined = cfg.pipeline_depth != 1 && draft.is_pure();
    if cfg.pipeline_depth > 1 && !draft.is_pure() {
        log(
            Level::Warn,
            "edge",
            &format!(
                "stream {stream}: draft source '{}' is not pure; pipelining disabled",
                draft.name()
            ),
        );
    }
    if pipelined {
        let mut pipe = PipelinedDrafter::new(cfg.pipeline_depth.max(1));
        // any speculation a previous (dead-link) attempt left behind is
        // void; resume already fast-forwarded the committed prefix
        pipe.reset(&mut st.core);
        let res = pipelined_decode(
            t,
            stream,
            st,
            draft,
            cfg,
            stats,
            rng,
            &mut pipe,
            pipe_totals,
        )
        .await;
        // on a link error, whatever was in flight dies with the attempt
        pipe.reset(&mut st.core);
        pipe_totals.merge(&pipe);
        res?;
    } else {
        while !st.core.done {
            let plan = stats.select_plan(cfg);
            let t_draft = cfg.trace.as_ref().map(|_| Instant::now());
            // tree speculation (wire v8): a profiled session with
            // branching headroom hedges the chain with alternate
            // leaves; every other round takes the EXACT v7 linear path
            // (same calls, same rng draws, same bytes)
            let (tokens, chosen_probs, tree) = if plan.branching > 1 {
                let tp = draft.propose_tree(
                    &st.core.committed,
                    plan.k,
                    plan.branching,
                    cfg.temperature,
                    cfg.top_p,
                    rng,
                )?;
                (tp.tokens, vec![], tp.parents)
            } else {
                let p = draft.propose(&st.core.committed, plan.k, cfg.temperature, cfg.top_p, rng)?;
                (p.tokens, p.chosen_probs, vec![])
            };
            if let Some(p) = &cfg.profile {
                // every tree node is one draft forward pass; charging is
                // a pure function of (device, nodes) so the sim twin
                // drains budgets in lockstep
                stats.energy.charge_draft(p.device, tokens.len());
            }
            let round = st.core.rounds as u32;
            let msg = DraftMsg {
                session: st.id,
                round,
                tokens,
                chosen_probs,
                mode: cfg.mode,
                wire: WireFormat::Compact,
                basis_len: 0,
                spec: vec![],
                tree,
            };
            let air_up = msg.air_bytes();
            // recorded per LAUNCH — Busy retransmits of the identical
            // draft below add no Draft/Uplink events
            if let Some(tr) = &cfg.trace {
                let d_ms = t_draft.map(|t| t.elapsed().as_secs_f64() * 1e3).unwrap_or(0.0);
                tr.record(st.id, round, SpanKind::Draft, d_ms, msg.tokens.len() as u32, 0);
                tr.record(st.id, round, SpanKind::Uplink, 0.0, air_up as u32, 0);
            }
            let mut sent = Instant::now();
            t.send_frame(Frame::on(stream, FrameKind::Draft, msg.encode()))
                .await?;
            // sequential mode: every verdict wait exposes the full RTT
            pipe_totals.exposed_waits += 1;
            // Busy-deferral loop: re-send the IDENTICAL draft after the
            // suggested backoff until the cloud admits it. Identical
            // bytes + pure draft source ⇒ the retried round commits the
            // exact tokens an unsaturated cloud would have committed.
            // (Re-encoding only happens on the rare retry — the hot
            // path sends the frame without a clone.)
            let mut busy_attempts = 0usize;
            let v = loop {
                match await_round_reply(t, round).await? {
                    RoundReply::Verdict(v) => break v,
                    RoundReply::Busy(b) => {
                        busy_attempts += 1;
                        if busy_attempts > MAX_BUSY_RETRIES {
                            bail!(
                                "cloud stayed busy for round {round} after {MAX_BUSY_RETRIES} retries"
                            );
                        }
                        pipe_totals.busy_retries += 1;
                        // attempt counts COMPLETED deferrals: the first
                        // retry sleeps the suggested horizon as-is
                        busy_backoff(b.retry_after_ms, busy_attempts - 1).await;
                        // re-stamp so backoff sleeps never pollute the
                        // measured RTT the adaptive policy feeds on —
                        // the last attempt's round trip IS the link
                        sent = Instant::now();
                        t.send_frame(Frame::on(stream, FrameKind::Draft, msg.encode()))
                            .await?;
                    }
                    RoundReply::Redirect(r) => {
                        return Err(
                            follow_redirect(t, stream, st, pipe_totals, r, "mid-decode").await
                        );
                    }
                }
            };

            // a tree round's tau counts along the WINNING root→leaf
            // path, named by the verdict's leaf index; the edge
            // reconstructs the path from its own retained tree (only
            // the index crossed the air). Linear rounds apply the whole
            // chain exactly as before.
            let path: Vec<i32>;
            let applied: &[i32] = if msg.is_tree() {
                let leaf = v
                    .leaf
                    .ok_or_else(|| anyhow!("tree verdict for round {round} without a leaf"))?;
                if (leaf as usize) >= msg.tokens.len() {
                    bail!("tree verdict leaf {leaf} out of range for round {round}");
                }
                path = msg.tree_path(leaf);
                &path
            } else {
                &msg.tokens
            };

            // measure the link this round actually saw
            let rtt_now = sent.elapsed().as_secs_f64() * 1e3;
            stats.observe_round(rtt_now, air_up + v.air_bytes(), applied.len());
            if let Some(tr) = &cfg.trace {
                tr.record(st.id, round, SpanKind::Downlink, rtt_now, v.air_bytes() as u32, 0);
            }

            let tau = (v.tau as usize).min(applied.len());
            if !applied.is_empty() {
                stats.policy.observe(tau, applied.len());
            }
            st.core.apply_verdict(applied, tau, v.correction, v.eos, false);
        }
    }
    t.send_frame(Frame::on(stream, FrameKind::Bye, vec![]))
        .await?;
    Ok(())
}

/// Pipelined decode body (wire v3): keep the pipe topped up to `depth`
/// rounds in flight, await the head verdict, commit, and on a broken
/// optimistic prefix retract the stale tail with one `Cancel` and
/// redraft from the true prefix. See `serve::pipeline` for the state
/// machine and the determinism argument. A fleet `Redirect` (wire v5)
/// may land with rounds in flight: everything in the pipe dies with
/// the attempt (the caller resets it) and is redrafted byte-identically
/// from the committed prefix after the resume, wherever it happens.
#[allow(clippy::too_many_arguments)]
async fn pipelined_decode<T, D>(
    t: &mut T,
    stream: u32,
    st: &mut LiveSession,
    draft: &mut D,
    cfg: &EdgeSessionConfig,
    stats: &mut LinkStats,
    rng: &mut SplitMix64,
    pipe: &mut PipelinedDrafter,
    totals: &mut PipeTotals,
) -> Result<()>
where
    T: Transport + ?Sized,
    D: DraftSource + ?Sized,
{
    // send timestamps per in-flight round (pruned on cancel)
    let mut sent_at: VecDeque<(u32, Instant)> = VecDeque::new();
    // encoded frames per in-flight round, retained for Busy retransmits
    // (pruned on resolve/cancel; bounded by the pipeline depth)
    let mut inflight_frames: HashMap<u32, Frame> = HashMap::new();
    while !st.core.done {
        // --- top up the pipe -----------------------------------------
        loop {
            // the depth hook may widen/narrow the pipe round to round
            pipe.depth = stats.select_depth(cfg);
            let Some(plan) = pipe.next_launch(&st.core) else { break };
            let k = stats.select_k(cfg);
            let t_draft = cfg.trace.as_ref().map(|_| Instant::now());
            let prop = draft.propose(&plan.context, k, cfg.temperature, cfg.top_p, rng)?;
            if prop.tokens.is_empty() && plan.speculative {
                break; // nothing to speculate with this round
            }
            // the bonus prediction is the chain link for the NEXT
            // speculative launch — computed for EVERY round while
            // pipelining is on (the pipe may be full now, but this round
            // becomes the chain head once the verdict ahead of it
            // lands), skipped only in degenerate sequential mode
            let bonus = if pipe.depth > 1 && !prop.tokens.is_empty() {
                let mut ctx2 = plan.context.clone();
                ctx2.extend_from_slice(&prop.tokens);
                draft
                    .propose(&ctx2, 1, cfg.temperature, cfg.top_p, rng)?
                    .tokens
                    .first()
                    .copied()
            } else {
                None
            };
            if let Some(p) = &cfg.profile {
                stats.energy.charge_draft(p.device, prop.tokens.len());
            }
            let msg = DraftMsg {
                session: st.id,
                round: plan.round,
                tokens: prop.tokens.clone(),
                chosen_probs: prop.chosen_probs,
                mode: cfg.mode,
                wire: WireFormat::Compact,
                basis_len: plan.basis_len,
                spec: plan.spec.clone(),
                // pipelined rounds keep drafts linear: a retracted
                // speculative round would have drafted its tree from a
                // poisoned prefix (`select_plan` forces branching = 1
                // whenever depth > 1)
                tree: vec![],
            };
            let air_up = msg.air_bytes();
            // per LAUNCH (a cancelled round redrafted later records
            // again under the same round number; Busy retransmits of
            // the retained frame record nothing)
            if let Some(tr) = &cfg.trace {
                let d_ms = t_draft.map(|t| t.elapsed().as_secs_f64() * 1e3).unwrap_or(0.0);
                tr.record(st.id, plan.round, SpanKind::Draft, d_ms, prop.tokens.len() as u32, 0);
                tr.record(st.id, plan.round, SpanKind::Uplink, 0.0, air_up as u32, 0);
            }
            sent_at.push_back((plan.round, Instant::now()));
            let frame = Frame::on(stream, FrameKind::Draft, msg.encode());
            inflight_frames.insert(plan.round, frame.clone());
            t.send_frame(frame).await?;
            pipe.launched(&mut st.core, &plan, prop.tokens, bonus, air_up);
        }

        // --- await + resolve the head verdict ------------------------
        let head = pipe
            .head_round()
            .expect("head launch is always allowed while the session lives");
        pipe.note_wait();
        // admission control only ever defers the session's next
        // expected round — the head — so a Busy here is answered by
        // re-sending the head's retained frame after backoff
        let mut busy_attempts = 0usize;
        let v = loop {
            match await_round_reply(t, head).await? {
                RoundReply::Verdict(v) => break v,
                RoundReply::Busy(b) => {
                    busy_attempts += 1;
                    if busy_attempts > MAX_BUSY_RETRIES {
                        bail!(
                            "cloud stayed busy for round {head} after {MAX_BUSY_RETRIES} retries"
                        );
                    }
                    totals.busy_retries += 1;
                    // attempt counts COMPLETED deferrals: the first
                    // retry sleeps the suggested horizon as-is
                    busy_backoff(b.retry_after_ms, busy_attempts - 1).await;
                    let frame = inflight_frames
                        .get(&head)
                        .cloned()
                        .ok_or_else(|| anyhow!("no retained frame for deferred round {head}"))?;
                    // re-stamp the head's send time so backoff sleeps
                    // never pollute the measured RTT (see the
                    // sequential loop)
                    if let Some(entry) = sent_at.iter_mut().find(|(r, _)| *r == head) {
                        entry.1 = Instant::now();
                    }
                    t.send_frame(frame).await?;
                }
                RoundReply::Redirect(r) => {
                    return Err(
                        follow_redirect(t, stream, st, totals, r, "mid-pipeline").await
                    );
                }
            }
        };
        inflight_frames.remove(&head);
        let sent = loop {
            match sent_at.pop_front() {
                Some((r, at)) if r == head => break Some(at),
                Some(_) => continue, // timestamp of an earlier, cancelled round
                None => break None,
            }
        };
        let res = pipe.resolve(&mut st.core, &v);
        let mut rtt_now = 0.0;
        if let Some(at) = sent {
            // measured from ITS OWN send: a pipelined round's RTT
            // includes queueing behind the previous verify — that is the
            // latency the link actually exhibits to this round
            rtt_now = at.elapsed().as_secs_f64() * 1e3;
            stats.observe_round(rtt_now, res.air_up + v.air_bytes(), res.k.max(1));
        }
        if let Some(tr) = &cfg.trace {
            tr.record(st.id, head, SpanKind::Downlink, rtt_now, v.air_bytes() as u32, 0);
        }
        if res.k > 0 {
            stats.policy.observe(res.tau, res.k);
        }
        if let Some(from) = res.cancel_from {
            sent_at.retain(|(r, _)| *r < from);
            inflight_frames.retain(|r, _| *r < from);
            t.send_frame(Frame::on(
                stream,
                FrameKind::Cancel,
                CancelMsg { round: from }.encode(),
            ))
            .await?;
        }
    }
    Ok(())
}

/// Run one full serving session over a dedicated connection: `Hello`
/// handshake, then the (resumable) session loop on stream
/// [`SESSION_STREAM`].
pub async fn run_edge_session<T, D>(
    t: &mut T,
    draft: &mut D,
    prompt: &[i32],
    cfg: &EdgeSessionConfig,
) -> Result<EdgeReport>
where
    T: Transport + ?Sized,
    D: DraftSource + ?Sized,
{
    let negotiated = match edge_handshake(t, cfg).await {
        Ok(v) => v,
        Err(e) => {
            // a link fault during the very first handshake: one reattach
            // (which redials AND replays the Hello) before giving up.
            // The reattach negotiated its own version internally, which
            // we cannot see — assume the CONSERVATIVE floor so a
            // downgraded peer is never hit with v3 traffic (costs only
            // this session's pipelining, never correctness).
            if !t.reattach().await.unwrap_or(false) {
                return Err(e);
            }
            MIN_WIRE_VERSION
        }
    };
    // a v2-negotiated connection must never see spec-tagged drafts or
    // Cancel frames (force the sequential loop), a pre-v7 peer rejects
    // the Open tier tail (clamp back to the default tier), and a
    // pre-v8 peer rejects both the Open profile tail and tree-tagged
    // drafts (strip the profile, pin branching at 1)
    if (negotiated < 3 && cfg.pipeline_depth != 1)
        || (negotiated < 7 && cfg.tier != 1)
        || (negotiated < 8 && (cfg.profile.is_some() || cfg.branching != 1))
    {
        let downgraded = EdgeSessionConfig {
            pipeline_depth: if negotiated < 3 { 1 } else { cfg.pipeline_depth },
            tier: if negotiated < 7 { 1 } else { cfg.tier },
            profile: if negotiated < 8 { None } else { cfg.profile },
            branching: if negotiated < 8 { 1 } else { cfg.branching },
            ..cfg.clone()
        };
        return run_session_on(t, SESSION_STREAM, draft, prompt, &downgraded).await;
    }
    run_session_on(t, SESSION_STREAM, draft, prompt, cfg).await
}

// ---------------------------------------------------------------------
// Reconnect-capable single-connection transport
// ---------------------------------------------------------------------

/// A `Transport` that can replace its underlying connection: on
/// `reattach` it redials through the [`Reconnect`] factory and replays
/// the `Hello` handshake, after which the session loop replays its own
/// `Resume`. One session per connection (for many, use
/// [`mux::EdgeMux`](super::mux::EdgeMux), whose streams reattach
/// through the shared pump instead).
pub struct ResumableTransport {
    inner: Option<Box<dyn Transport>>,
    dial: Box<dyn Reconnect>,
    hello: Hello,
}

impl ResumableTransport {
    /// Adopt an already-connected (but not yet handshaked) transport;
    /// the session runner performs the first `Hello` as usual.
    pub fn new(
        initial: Box<dyn Transport>,
        dial: Box<dyn Reconnect>,
        cfg: &EdgeSessionConfig,
    ) -> ResumableTransport {
        ResumableTransport {
            inner: Some(initial),
            dial,
            hello: hello_for(cfg),
        }
    }

    /// Dial the first connection through the factory.
    pub async fn connect(
        mut dial: Box<dyn Reconnect>,
        cfg: &EdgeSessionConfig,
    ) -> Result<ResumableTransport> {
        let t = dial.connect().await?;
        Ok(ResumableTransport {
            inner: Some(t),
            dial,
            hello: hello_for(cfg),
        })
    }

    fn live(&mut self) -> Result<&mut Box<dyn Transport>> {
        self.inner
            .as_mut()
            .ok_or_else(|| anyhow!("link is down (reattach first)"))
    }
}

impl Transport for ResumableTransport {
    fn send_frame(&mut self, frame: Frame) -> BoxFuture<'_, Result<()>> {
        Box::pin(async move {
            let r = self.live()?.send_frame(frame).await;
            if r.is_err() {
                self.inner = None;
            }
            r
        })
    }

    fn recv_frame(&mut self) -> BoxFuture<'_, Result<Option<Frame>>> {
        Box::pin(async move {
            let r = self.live()?.recv_frame().await;
            if r.is_err() {
                self.inner = None;
            }
            r
        })
    }

    fn peer(&self) -> String {
        match &self.inner {
            Some(t) => format!("resumable:{}", t.peer()),
            None => "resumable:<down>".into(),
        }
    }

    fn reattach(&mut self) -> BoxFuture<'_, Result<bool>> {
        Box::pin(async move {
            self.inner = None;
            let mut t = self.dial.connect().await?;
            handshake_with(&mut *t, &self.hello).await?;
            self.inner = Some(t);
            Ok(true)
        })
    }

    /// Follow a fleet `Redirect`: point the dial factory at the
    /// handoff target and abandon the current connection (the
    /// exporting replica parks nothing — the session already left), so
    /// the next reattach redials there and the session loop replays
    /// its `Resume` against the importing replica. Single-target dial
    /// factories cannot retarget (their `set_target` returns false):
    /// the connection is left in place and `Ok(false)` tells the
    /// caller this degrades into a resume-in-place — still correct,
    /// the exporter re-imports.
    fn redirect(&mut self, addr: String) -> BoxFuture<'_, Result<bool>> {
        Box::pin(async move {
            let moved = self.dial.set_target(&addr);
            if moved {
                self.inner = None;
            }
            Ok(moved)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full backoff schedule, end to end: the first deferral
    /// (attempt 0) sleeps exactly the suggested horizon, every further
    /// deferral doubles it, and the cap flattens the tail. Attempts 0,
    /// 1 and 2 must all be distinct — the pre-fix schedule collapsed
    /// them onto at most two sleeps.
    #[test]
    fn busy_backoff_doubles_from_the_first_retry() {
        let schedule: Vec<u64> = (0..10).map(|a| busy_backoff_ms(7, a)).collect();
        assert_eq!(schedule, vec![7, 14, 28, 56, 112, 224, 448, 500, 500, 500]);

        // a zero suggested horizon still backs off from a 1 ms base
        let zero: Vec<u64> = (0..11).map(|a| busy_backoff_ms(0, a)).collect();
        assert_eq!(zero, vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 500, 500]);

        // the cap binds immediately when the suggestion exceeds it
        assert_eq!(busy_backoff_ms(10_000, 0), BUSY_BACKOFF_CAP_MS);

        // absurd attempt counts (shift ≥ 64) stay defined and capped
        assert_eq!(busy_backoff_ms(7, 63), BUSY_BACKOFF_CAP_MS);
        assert_eq!(busy_backoff_ms(7, 64), BUSY_BACKOFF_CAP_MS);
        assert_eq!(busy_backoff_ms(7, usize::MAX), BUSY_BACKOFF_CAP_MS);
    }
}
