//! Edge-side connection multiplexer: N sessions over ONE transport.
//!
//! [`EdgeMux`] owns the real connection through a background *pump* task
//! and hands out per-session [`MuxStream`] handles that implement
//! [`Transport`] themselves, so the per-session client code
//! (`edge::run_session_on`) is written once and runs identically over a
//! dedicated connection or a multiplexed one:
//!
//! ```text
//!  session task 1 ──┐ MuxStream (stream 1)
//!  session task 2 ──┤ MuxStream (stream 2)      ┌────────────────┐
//!        ...        ├──── out queue ───▶ pump ──┤ one Transport  │──▶ cloud
//!  session task N ──┘ ◀── per-stream in queues ─┤ (TCP/loopback) │
//!                                               └────────────────┘
//! ```
//!
//! The pump performs the connection-scoped `Hello` handshake once,
//! stamps outbound frames with their stream id, and demuxes inbound
//! frames by stream id. Outbound frames are staged on PER-STREAM queues
//! and drained in **weighted round-robin** order (`open_stream_tier`):
//! each pass grants every stream with queued frames up to its tier
//! weight of sends, so one chatty session's burst cannot starve its
//! siblings on the shared connection, and a premium tier gets
//! proportionally more of the uplink under contention. When the transport dies it (a) notifies every
//! stream with a generation-tagged reset, (b) redials through the
//! optional [`Reconnect`] factory and replays the handshake, and
//! (c) answers the streams' `reattach` requests once the new generation
//! is live — each session then replays its own `Resume` handshake.
//! Outbound frames are tagged with the generation current at send time;
//! frames queued against a dead generation are dropped instead of
//! leaking onto the new connection (they are "lost in flight", exactly
//! like bytes sitting in a dead socket's buffer).

use super::edge::{handshake_with, EdgeSessionConfig};
use super::transport::{BoxFuture, Reconnect, Transport};
use crate::protocol::frame::{Frame, FrameKind, Hello, StatsAckMsg, StatsMsg, CONTROL_STREAM};
use crate::util::log::{log, Level};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;
use tokio::sync::{mpsc, oneshot};

/// Redial attempts before the pump gives up on a dead link.
const MAX_REDIALS: usize = 8;

enum PumpCmd {
    Register {
        stream: u32,
        /// Tier weight: sends granted per weighted-round-robin pass.
        weight: u32,
        tx: mpsc::UnboundedSender<InEvent>,
    },
    Deregister {
        stream: u32,
    },
    /// Reply once a connection generation newer than `seen` is live.
    AwaitReattach {
        seen: u64,
        reply: oneshot::Sender<Result<u64>>,
    },
    /// Send a `Stats` request on the control stream (wire v6) and reply
    /// with the decoded `StatsAck` when the cloud answers.
    AwaitStats {
        nonce: u64,
        reply: oneshot::Sender<Result<StatsAckMsg>>,
    },
}

enum InEvent {
    Frame(Frame),
    /// The connection generation `.0` died; reattach to continue.
    Reset(u64),
}

/// Handle to a multiplexed connection. Dropping it (after all its
/// [`MuxStream`]s) shuts the pump down and closes the transport.
pub struct EdgeMux {
    cmd_tx: mpsc::UnboundedSender<PumpCmd>,
    out_tx: mpsc::UnboundedSender<(u64, Frame)>,
    gen_shared: Arc<AtomicU64>,
    next_stream: u32,
    next_stats_nonce: u64,
    /// Wire version negotiated on the first handshake. Sessions on this
    /// mux must keep `pipeline_depth == 1` when it is below 3 (no
    /// spec-tagged drafts, no `Cancel` on a v2 connection).
    wire_version: u16,
}

impl EdgeMux {
    /// Adopt a connected transport, run the `Hello` handshake on it, and
    /// spawn the pump. `reconnect` enables transparent redial +
    /// per-session resume after link drops; without it a dead link is
    /// fatal to its sessions.
    pub async fn connect(
        mut t: Box<dyn Transport>,
        reconnect: Option<Box<dyn Reconnect>>,
        cfg: &EdgeSessionConfig,
    ) -> Result<EdgeMux> {
        let hello = super::edge::hello_for(cfg);
        let wire_version = handshake_with(&mut *t, &hello).await?;
        let (cmd_tx, cmd_rx) = mpsc::unbounded_channel();
        let (out_tx, out_rx) = mpsc::unbounded_channel();
        let gen_shared = Arc::new(AtomicU64::new(1));
        let pump = Pump {
            t: Some(t),
            reconnect,
            hello,
            gen: 1,
            gen_shared: gen_shared.clone(),
            streams: HashMap::new(),
            cmd_rx,
            out_rx,
            waiting: Vec::new(),
            out_q: HashMap::new(),
            rr: Vec::new(),
            stats_waiters: VecDeque::new(),
        };
        tokio::spawn(run_pump(pump));
        Ok(EdgeMux {
            cmd_tx,
            out_tx,
            gen_shared,
            next_stream: 0,
            next_stats_nonce: 0,
            wire_version,
        })
    }

    /// Wire version negotiated on this connection (see the field docs).
    pub fn wire_version(&self) -> u16 {
        self.wire_version
    }

    /// Pull the cloud replica's metrics/latency snapshot over the shared
    /// connection (`Stats`/`StatsAck` control frames, wire v6). The
    /// request rides the normal outbound queue; the pump answers from
    /// the matching `StatsAck` by nonce, so concurrent fetches and
    /// session traffic interleave safely.
    pub async fn fetch_stats(&mut self) -> Result<StatsAckMsg> {
        if self.wire_version < 6 {
            bail!(
                "peer wire version {} predates the Stats frame (needs >= 6)",
                self.wire_version
            );
        }
        self.next_stats_nonce += 1;
        let (tx, rx) = oneshot::channel();
        self.cmd_tx
            .send(PumpCmd::AwaitStats {
                nonce: self.next_stats_nonce,
                reply: tx,
            })
            .map_err(|_| anyhow!("mux pump is gone"))?;
        rx.await
            .map_err(|_| anyhow!("mux pump dropped the stats request"))?
    }

    /// Allocate the next stream id and register it with the pump at the
    /// default tier (weight 1). The returned handle is a full
    /// [`Transport`] for one session.
    pub fn open_stream(&mut self) -> MuxStream {
        self.open_stream_tier(1)
    }

    /// Allocate a stream with an explicit TIER WEIGHT: the pump drains
    /// outbound frames in weighted round-robin order, granting each
    /// stream with queued frames up to `weight` sends per pass — so one
    /// chatty session's burst cannot starve its siblings on the shared
    /// connection, and a premium tier (higher weight) gets
    /// proportionally more of the uplink when it is contended.
    pub fn open_stream_tier(&mut self, weight: u32) -> MuxStream {
        self.next_stream += 1;
        let stream = self.next_stream;
        let (tx, rx) = mpsc::unbounded_channel();
        // the pump polls its command queue before the outbound queue, so
        // this registration is processed before any frame the session
        // sends on the new stream
        let _ = self.cmd_tx.send(PumpCmd::Register {
            stream,
            weight: weight.max(1),
            tx,
        });
        MuxStream {
            stream,
            seen_gen: 0,
            attached_gen: self.gen_shared.load(Ordering::Acquire),
            reset: false,
            out_tx: self.out_tx.clone(),
            in_rx: rx,
            cmd_tx: self.cmd_tx.clone(),
        }
    }
}

/// One session's view of the shared connection. Implements [`Transport`]:
/// sends are stamped with this stream's id and the generation the stream
/// is attached under. `reattach` waits until a connection generation
/// NEWER than the last one this stream observed dying is live; if the
/// stream never observed a reset (it errored for a non-link reason while
/// the shared connection stayed up), reattach returns immediately and
/// the session simply replays its `Resume` on the live connection — the
/// cloud handles an in-place resume on a bound stream correctly.
///
/// A fleet `Redirect` (wire v5) cannot be followed from here: one
/// stream cannot leave the shared connection, so `redirect` keeps the
/// trait default (`Ok(false)`) and the session resumes IN PLACE — the
/// exporting replica re-imports it from the shared ledger while the
/// SIBLING streams stay pinned to their connection, untouched
/// (`tests/serve_fleet.rs` pins this).
pub struct MuxStream {
    stream: u32,
    /// Latest generation this stream has observed dying (reset dedup).
    seen_gen: u64,
    /// Generation this stream is attached under (set at creation and on
    /// every successful reattach). Sends are stamped with THIS — not the
    /// pump's live generation — so a stream that has not yet observed a
    /// reset can never leak a frame onto a freshly redialed connection
    /// it has not resumed on (the pump drops the stale-tagged frame and
    /// resets the stream instead).
    attached_gen: u64,
    /// Sticky after a reset until `reattach` succeeds.
    reset: bool,
    out_tx: mpsc::UnboundedSender<(u64, Frame)>,
    in_rx: mpsc::UnboundedReceiver<InEvent>,
    cmd_tx: mpsc::UnboundedSender<PumpCmd>,
}

impl MuxStream {
    pub fn stream_id(&self) -> u32 {
        self.stream
    }
}

impl Drop for MuxStream {
    fn drop(&mut self) {
        let _ = self.cmd_tx.send(PumpCmd::Deregister {
            stream: self.stream,
        });
    }
}

impl Transport for MuxStream {
    fn send_frame(&mut self, mut frame: Frame) -> BoxFuture<'_, Result<()>> {
        Box::pin(async move {
            if self.reset {
                bail!("stream {}: link reset (reattach first)", self.stream);
            }
            // control frames stay on stream 0; everything else is ours
            if frame.stream != CONTROL_STREAM || !frame.kind.is_control() {
                frame.stream = self.stream;
            }
            self.out_tx
                .send((self.attached_gen, frame))
                .map_err(|_| anyhow!("stream {}: mux pump is gone", self.stream))
        })
    }

    fn recv_frame(&mut self) -> BoxFuture<'_, Result<Option<Frame>>> {
        Box::pin(async move {
            if self.reset {
                bail!("stream {}: link reset (reattach first)", self.stream);
            }
            loop {
                match self.in_rx.recv().await {
                    None => return Ok(None),
                    Some(InEvent::Frame(f)) => return Ok(Some(f)),
                    Some(InEvent::Reset(gen)) => {
                        if gen >= self.seen_gen {
                            self.seen_gen = gen;
                            self.reset = true;
                            bail!(
                                "stream {}: connection dropped (generation {gen})",
                                self.stream
                            );
                        }
                        // reset for a generation we already left: stale
                    }
                }
            }
        })
    }

    fn peer(&self) -> String {
        format!("mux-stream-{}", self.stream)
    }

    fn reattach(&mut self) -> BoxFuture<'_, Result<bool>> {
        Box::pin(async move {
            let (tx, rx) = oneshot::channel();
            self.cmd_tx
                .send(PumpCmd::AwaitReattach {
                    seen: self.seen_gen,
                    reply: tx,
                })
                .map_err(|_| anyhow!("stream {}: mux pump is gone", self.stream))?;
            let gen = rx
                .await
                .map_err(|_| anyhow!("stream {}: mux pump dropped the reattach", self.stream))??;
            self.seen_gen = gen;
            self.attached_gen = gen;
            self.reset = false;
            Ok(true)
        })
    }
}

// ---------------------------------------------------------------------
// The pump: one task owning the real transport
// ---------------------------------------------------------------------

struct StreamEntry {
    tx: mpsc::UnboundedSender<InEvent>,
    /// Sends granted per weighted-round-robin pass (tier weight).
    weight: u32,
}

struct Pump {
    t: Option<Box<dyn Transport>>,
    reconnect: Option<Box<dyn Reconnect>>,
    hello: Hello,
    gen: u64,
    gen_shared: Arc<AtomicU64>,
    streams: HashMap<u32, StreamEntry>,
    cmd_rx: mpsc::UnboundedReceiver<PumpCmd>,
    out_rx: mpsc::UnboundedReceiver<(u64, Frame)>,
    waiting: Vec<oneshot::Sender<Result<u64>>>,
    /// Per-stream outbound queues (FIFO within a stream) + the visit
    /// order for the weighted round-robin drain.
    out_q: HashMap<u32, VecDeque<(u64, Frame)>>,
    rr: Vec<u32>,
    /// Outstanding `Stats` requests awaiting their `StatsAck`, by nonce.
    stats_waiters: VecDeque<(u64, oneshot::Sender<Result<StatsAckMsg>>)>,
}

impl Pump {
    /// The link died: drop the transport (the peer sees EOF and parks
    /// our sessions) and tell every stream which generation it lost.
    fn link_down(&mut self) {
        if self.t.take().is_some() {
            let gen = self.gen;
            for e in self.streams.values() {
                let _ = e.tx.send(InEvent::Reset(gen));
            }
            // a stats reply in flight died with the connection; the
            // caller retries on the fresh link if it still cares
            for (_, reply) in self.stats_waiters.drain(..) {
                let _ = reply.send(Err(anyhow!("link dropped before the stats reply")));
            }
        }
    }

    /// Stage one outbound frame on its stream's queue (creating the
    /// queue and its round-robin slot on first use).
    fn enqueue_out(&mut self, gen: u64, frame: Frame) {
        let stream = frame.stream;
        let q = self.out_q.entry(stream).or_default();
        if q.is_empty() && !self.rr.contains(&stream) {
            self.rr.push(stream);
        }
        q.push_back((gen, frame));
    }

    /// Drain the staged outbound queues into the transport in WEIGHTED
    /// round-robin order: each pass grants every stream with queued
    /// frames up to its tier weight of sends, so one chatty session's
    /// burst cannot starve its siblings on the shared connection.
    /// Per-stream FIFO order is preserved; frames tagged with a dead
    /// generation are dropped with a Reset notification exactly like
    /// bytes in a dead socket's buffer. Stops (leaving the remainder
    /// queued) when the link is down or dies mid-drain.
    async fn flush_out(&mut self) {
        while !self.rr.is_empty() {
            let pass: Vec<u32> = self.rr.clone();
            for stream in pass {
                let weight = self
                    .streams
                    .get(&stream)
                    .map(|e| e.weight.max(1))
                    .unwrap_or(1) as usize;
                for _ in 0..weight {
                    let Some((gen, frame)) = self.out_q.get_mut(&stream).and_then(|q| q.pop_front())
                    else {
                        break;
                    };
                    if gen != self.gen {
                        // queued against a dead generation: lost in
                        // flight. Tell the sender (it may not have
                        // observed the reset yet) so it reattaches
                        // instead of waiting on a reply that can never
                        // come.
                        if let Some(e) = self.streams.get(&frame.stream) {
                            let _ = e.tx.send(InEvent::Reset(gen));
                        }
                        continue;
                    }
                    let sent = match self.t.as_mut() {
                        Some(t) => t.send_frame(frame).await,
                        // link down: the remainder waits for the redial
                        // (and dies there by generation check)
                        None => return,
                    };
                    if let Err(e) = sent {
                        log(Level::Debug, "mux", &format!("send failed: {e:#}"));
                        self.link_down();
                        return;
                    }
                }
            }
            let out_q = &self.out_q;
            self.rr
                .retain(|s| out_q.get(s).is_some_and(|q| !q.is_empty()));
        }
        self.out_q.retain(|_, q| !q.is_empty());
    }

    /// Redial + handshake until a new generation is live; notify waiting
    /// reattach requests.
    async fn ensure_link(&mut self) -> Result<()> {
        if self.t.is_some() {
            return Ok(());
        }
        let Some(dial) = self.reconnect.as_mut() else {
            bail!("mux link died and no reconnector is configured");
        };
        let mut last_err = anyhow!("link down");
        for attempt in 0..MAX_REDIALS {
            match dial.connect().await {
                Ok(mut t) => match handshake_with(&mut *t, &self.hello).await {
                    Ok(_) => {
                        self.t = Some(t);
                        self.gen += 1;
                        self.gen_shared.store(self.gen, Ordering::Release);
                        let gen = self.gen;
                        for reply in self.waiting.drain(..) {
                            let _ = reply.send(Ok(gen));
                        }
                        log(
                            Level::Debug,
                            "mux",
                            &format!("reconnected (generation {gen})"),
                        );
                        return Ok(());
                    }
                    Err(e) => last_err = e,
                },
                Err(e) => last_err = e,
            }
            tokio::time::sleep(Duration::from_millis(5 << attempt.min(6))).await;
        }
        Err(last_err.context(format!("redial failed {MAX_REDIALS} times")))
    }

    /// Returns `true` when the command staged an outbound frame the
    /// caller should flush.
    fn handle_cmd(&mut self, cmd: PumpCmd) -> bool {
        match cmd {
            PumpCmd::Register { stream, weight, tx } => {
                self.streams.insert(stream, StreamEntry { tx, weight });
            }
            PumpCmd::Deregister { stream } => {
                self.streams.remove(&stream);
                self.out_q.remove(&stream);
                self.rr.retain(|&s| s != stream);
            }
            PumpCmd::AwaitStats { nonce, reply } => {
                self.stats_waiters.push_back((nonce, reply));
                self.enqueue_out(
                    self.gen,
                    Frame::control(FrameKind::Stats, StatsMsg { nonce }.encode()),
                );
                return true;
            }
            PumpCmd::AwaitReattach { seen, reply } => {
                // `seen` is at most the current generation (it comes
                // from Resets/attachments the pump itself issued). With
                // the link up, gen == seen means the stream is retrying
                // in place on a HEALTHY connection (non-link error):
                // reply immediately — waiting for a bump that will
                // never come would hang the session. With the link
                // down, the redial at the loop top drains `waiting`.
                if self.t.is_some() && self.gen >= seen {
                    let _ = reply.send(Ok(self.gen));
                } else {
                    self.waiting.push(reply);
                }
            }
        }
        false
    }

    fn route(&mut self, f: Frame) {
        if f.stream == CONTROL_STREAM {
            // the one control frame expected outside the handshake: the
            // cloud's answer to a Stats request (wire v6)
            if f.kind == FrameKind::StatsAck {
                match StatsAckMsg::decode(&f.payload) {
                    Ok(ack) => {
                        match self.stats_waiters.iter().position(|(n, _)| *n == ack.nonce) {
                            Some(i) => {
                                let (_, reply) =
                                    self.stats_waiters.remove(i).expect("index from position");
                                let _ = reply.send(Ok(ack));
                            }
                            None => log(
                                Level::Debug,
                                "mux",
                                &format!("stale StatsAck nonce {}", ack.nonce),
                            ),
                        }
                    }
                    Err(e) => log(Level::Debug, "mux", &format!("bad StatsAck: {e:#}")),
                }
                return;
            }
            // duplicate HelloAck retransmits and the like: connection-
            // scoped, already handled at handshake time
            log(
                Level::Debug,
                "mux",
                &format!("ignoring control {:?} outside handshake", f.kind),
            );
            return;
        }
        match self.streams.get(&f.stream) {
            Some(e) => {
                let _ = e.tx.send(InEvent::Frame(f));
            }
            None => {
                // unknown stream: a late frame for a closed session
                log(
                    Level::Debug,
                    "mux",
                    &format!("dropping {:?} for unknown stream {}", f.kind, f.stream),
                );
            }
        }
    }

    /// Everything went away while the link was unrecoverable.
    fn fail_all(&mut self, err: anyhow::Error) {
        let msg = format!("{err:#}");
        for reply in self.waiting.drain(..) {
            let _ = reply.send(Err(anyhow!("{msg}")));
        }
        // streams still blocked in recv get a terminal reset, then EOF
        // when the pump (and their senders) drop
        self.link_down();
    }
}

async fn run_pump(mut p: Pump) {
    loop {
        if p.t.is_none() {
            match p.ensure_link().await {
                Ok(()) => {
                    // frames staged against the dead generation are
                    // drained (and dropped with Reset notifications)
                    // now, not on the next unrelated event
                    p.flush_out().await;
                }
                Err(e) => {
                    log(Level::Warn, "mux", &format!("pump stopping: {e:#}"));
                    p.fail_all(e);
                    return;
                }
            }
        }
        enum Step {
            Cmd(Option<PumpCmd>),
            Out(Option<(u64, Frame)>),
            In(Result<Option<Frame>>),
        }
        let step = {
            let t = p.t.as_mut().expect("link ensured above");
            tokio::select! {
                // commands first: a Register must land before the new
                // stream's first outbound frame is pumped
                biased;
                c = p.cmd_rx.recv() => Step::Cmd(c),
                o = p.out_rx.recv() => Step::Out(o),
                r = t.recv_frame() => Step::In(r),
            }
        };
        match step {
            // every EdgeMux and MuxStream handle is gone: orderly stop —
            // flush any queued frames (session Byes), then drop the
            // transport, which closes the connection
            Step::Cmd(None) | Step::Out(None) => {
                while let Ok((gen, frame)) = p.out_rx.try_recv() {
                    p.enqueue_out(gen, frame);
                }
                p.flush_out().await;
                return;
            }
            Step::Cmd(Some(cmd)) => {
                if p.handle_cmd(cmd) {
                    p.flush_out().await;
                }
            }
            Step::Out(Some((gen, frame))) => {
                // stage everything immediately available, THEN drain in
                // weighted round-robin order — this is where a burst
                // from one stream gets interleaved with (instead of
                // queued ahead of) its siblings' frames
                p.enqueue_out(gen, frame);
                while let Ok((g, f)) = p.out_rx.try_recv() {
                    p.enqueue_out(g, f);
                }
                p.flush_out().await;
            }
            Step::In(Ok(Some(f))) => p.route(f),
            Step::In(Ok(None)) => p.link_down(),
            Step::In(Err(e)) => {
                log(Level::Debug, "mux", &format!("recv failed: {e:#}"));
                p.link_down();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::frame::{hello_response, FrameKind, Hello};
    use crate::serve::transport::loopback_pair;

    fn rt() -> tokio::runtime::Runtime {
        tokio::runtime::Builder::new_current_thread()
            .enable_all()
            .build()
            .unwrap()
    }

    /// A minimal fake cloud: answer the Hello, then echo session frames.
    async fn echo_cloud(mut t: impl Transport) {
        let hello = match t.recv_frame().await.unwrap() {
            Some(f) if f.kind == FrameKind::Hello => Hello::decode(&f.payload).unwrap(),
            other => panic!("expected hello, got {other:?}"),
        };
        let ack = hello_response(&hello);
        t.send_frame(Frame::control(FrameKind::HelloAck, ack.encode()))
            .await
            .unwrap();
        while let Ok(Some(f)) = t.recv_frame().await {
            t.send_frame(f).await.unwrap();
        }
    }

    #[test]
    fn mux_streams_round_trip_independently() {
        rt().block_on(async {
            let (edge_t, cloud_t) = loopback_pair();
            tokio::spawn(echo_cloud(cloud_t));
            let mut mux = EdgeMux::connect(
                Box::new(edge_t),
                None,
                &crate::serve::EdgeSessionConfig::default(),
            )
            .await
            .unwrap();
            let mut a = mux.open_stream();
            let mut b = mux.open_stream();
            assert_ne!(a.stream_id(), b.stream_id());
            // interleave sends; each stream only sees its own echoes
            a.send_frame(Frame::on(99, FrameKind::Draft, vec![1]))
                .await
                .unwrap();
            b.send_frame(Frame::on(99, FrameKind::Draft, vec![2]))
                .await
                .unwrap();
            let fb = b.recv_frame().await.unwrap().unwrap();
            assert_eq!((fb.stream, fb.payload), (b.stream_id(), vec![2]));
            let fa = a.recv_frame().await.unwrap().unwrap();
            assert_eq!((fa.stream, fa.payload), (a.stream_id(), vec![1]));
        });
    }

    /// Satellite (admission/QoS): a burst from one chatty stream must
    /// not starve a sibling — the weighted round-robin drain interleaves
    /// the quiet stream's frame near the front instead of queuing it
    /// behind the whole burst.
    #[test]
    fn weighted_round_robin_prevents_starvation() {
        rt().block_on(async {
            let (edge_t, cloud_t) = loopback_pair();
            // record global arrival order cloud-side
            let order = std::sync::Arc::new(tokio::sync::Mutex::new(Vec::<u32>::new()));
            let ord = order.clone();
            tokio::spawn(async move {
                let mut t = cloud_t;
                let f = t.recv_frame().await.unwrap().unwrap();
                assert_eq!(f.kind, FrameKind::Hello);
                let ack = hello_response(&Hello::decode(&f.payload).unwrap());
                t.send_frame(Frame::control(FrameKind::HelloAck, ack.encode()))
                    .await
                    .unwrap();
                while let Ok(Some(f)) = t.recv_frame().await {
                    ord.lock().await.push(f.stream);
                }
            });
            let mut mux = EdgeMux::connect(
                Box::new(edge_t),
                None,
                &crate::serve::EdgeSessionConfig::default(),
            )
            .await
            .unwrap();
            let mut chatty = mux.open_stream(); // weight 1
            let mut premium = mux.open_stream_tier(3);
            let premium_id = premium.stream_id();
            // queue the whole burst without yielding to the pump: 8
            // chatty frames, THEN one premium frame — FIFO would put
            // the premium frame last
            for i in 0..8u8 {
                chatty
                    .send_frame(Frame::on(0, FrameKind::Draft, vec![i]))
                    .await
                    .unwrap();
            }
            premium
                .send_frame(Frame::on(0, FrameKind::Draft, vec![99]))
                .await
                .unwrap();
            // wait for the drain to complete cloud-side
            loop {
                if order.lock().await.len() >= 9 {
                    break;
                }
                tokio::time::sleep(Duration::from_millis(1)).await;
            }
            let got = order.lock().await.clone();
            let pos = got
                .iter()
                .position(|&s| s == premium_id)
                .expect("premium frame must arrive");
            assert!(
                pos <= 2,
                "premium frame starved behind the chatty burst (position {pos} in {got:?})"
            );
        });
    }

    /// Wire v6 `Stats` control frames round-trip through the pump while
    /// session traffic shares the connection.
    #[test]
    fn stats_fetch_round_trips_over_control_stream() {
        rt().block_on(async {
            let (edge_t, cloud_t) = loopback_pair();
            tokio::spawn(async move {
                let mut t = cloud_t;
                let f = t.recv_frame().await.unwrap().unwrap();
                assert_eq!(f.kind, FrameKind::Hello);
                let ack = hello_response(&Hello::decode(&f.payload).unwrap());
                t.send_frame(Frame::control(FrameKind::HelloAck, ack.encode()))
                    .await
                    .unwrap();
                while let Ok(Some(f)) = t.recv_frame().await {
                    if f.kind == FrameKind::Stats {
                        let req = StatsMsg::decode(&f.payload).unwrap();
                        let mut latency = crate::obs::LatencySummary::new();
                        latency.verify_ms.record(2.5);
                        let ack = StatsAckMsg {
                            nonce: req.nonce,
                            version: 3,
                            sessions_active: 1,
                            sessions_completed: 2,
                            rounds: 10,
                            batches: 4,
                            tokens_committed: 55,
                            latency,
                        };
                        t.send_frame(Frame::control(FrameKind::StatsAck, ack.encode()))
                            .await
                            .unwrap();
                    } else {
                        t.send_frame(f).await.unwrap();
                    }
                }
            });
            let mut mux = EdgeMux::connect(
                Box::new(edge_t),
                None,
                &crate::serve::EdgeSessionConfig::default(),
            )
            .await
            .unwrap();
            // a session frame in flight does not confuse the matcher
            let mut s = mux.open_stream();
            s.send_frame(Frame::on(0, FrameKind::Draft, vec![7]))
                .await
                .unwrap();
            let stats = mux.fetch_stats().await.unwrap();
            assert_eq!(stats.rounds, 10);
            assert_eq!(stats.tokens_committed, 55);
            assert_eq!(stats.latency.verify_ms.count(), 1);
            assert_eq!(s.recv_frame().await.unwrap().unwrap().payload, vec![7]);
        });
    }

    #[test]
    fn mux_reset_then_reattach_recovers() {
        rt().block_on(async {
            let (edge_t, cloud_t) = loopback_pair();
            // first cloud: handshake, echo exactly ONE frame, hang up
            tokio::spawn(async move {
                let mut t = cloud_t;
                let f = t.recv_frame().await.unwrap().unwrap();
                assert_eq!(f.kind, FrameKind::Hello);
                let ack = hello_response(&Hello::decode(&f.payload).unwrap());
                t.send_frame(Frame::control(FrameKind::HelloAck, ack.encode()))
                    .await
                    .unwrap();
                let f = t.recv_frame().await.unwrap().unwrap();
                t.send_frame(f).await.unwrap();
                // drop: edge sees EOF
            });
            let reconnect: Box<dyn Reconnect> = Box::new(move || -> BoxFuture<
                'static,
                Result<Box<dyn Transport>>,
            > {
                Box::pin(async move {
                    let (e, c) = loopback_pair();
                    tokio::spawn(echo_cloud(c));
                    Ok(Box::new(e) as Box<dyn Transport>)
                })
            });
            let mut mux = EdgeMux::connect(
                Box::new(edge_t),
                Some(reconnect),
                &crate::serve::EdgeSessionConfig::default(),
            )
            .await
            .unwrap();
            let mut s = mux.open_stream();
            // one round trip proves the stream is registered on gen 1...
            s.send_frame(Frame::on(0, FrameKind::Draft, vec![5]))
                .await
                .unwrap();
            assert_eq!(s.recv_frame().await.unwrap().unwrap().payload, vec![5]);
            // ...then the cloud hangs up and the reset surfaces
            let err = s.recv_frame().await;
            assert!(err.is_err(), "reset must surface as an error");
            // reattach waits for the redialed generation, then echoes work
            assert!(s.reattach().await.unwrap());
            s.send_frame(Frame::on(0, FrameKind::Draft, vec![9]))
                .await
                .unwrap();
            let f = s.recv_frame().await.unwrap().unwrap();
            assert_eq!(f.payload, vec![9]);
        });
    }
}
