//! `flexspec::serve` — async edge↔cloud transport & multi-session
//! serving subsystem.
//!
//! The simulator (`coordinator::scheduler`) proves the protocol under a
//! virtual clock; this module runs the SAME wire protocol
//! (`protocol::{DraftMsg, VerifyMsg}` in length-prefixed frames,
//! `protocol::frame`) over real connections:
//!
//! * [`transport`] — the object-safe [`Transport`] trait with two
//!   implementations: [`TcpTransport`] (real sockets, TCP_NODELAY) and
//!   [`LoopbackTransport`] (in-process pair, optionally metered through
//!   the deterministic wireless-channel simulation).
//! * [`session`] — transport-agnostic state machines shared with the
//!   simulator: [`BatchWindow`] (dynamic verification batching) and
//!   [`SessionCore`] (per-session commit bookkeeping both endpoints
//!   mirror).
//! * [`backend`] — pluggable cloud verification: the PJRT
//!   [`EngineBackend`] (KV sessions + LoRA hot-swap, artifact-gated) and
//!   the deterministic [`SyntheticTarget`]/[`SyntheticDraft`] pair whose
//!   verdicts are pure functions of (context, version) — timing- and
//!   batching-order-independent, which is what makes TCP, loopback and
//!   simulation runs byte-comparable.
//! * [`verifier`] — the cloud session manager + cross-connection batcher
//!   on a dedicated OS thread (PJRT handles are `!Send`), exposed to
//!   tokio through the async [`VerifierHandle`].
//! * [`cloud`] / [`edge`] — the accept loop + per-connection protocol
//!   (`handle_conn`, shared by TCP and loopback), and the edge client
//!   running the channel-aware adaptive stride policy against *measured*
//!   round-trip times.
//!
//! Determinism contract: with a [`SyntheticTarget`] backend and a fixed
//! stride, `serve_loopback`, the TCP path, and
//! `coordinator::scheduler::serve_with` commit identical per-session
//! token/acceptance counts for a fixed seed (pinned by
//! `tests/serve_loopback.rs` and `examples/serve_tcp.rs`).

pub mod backend;
pub mod cloud;
pub mod edge;
pub mod session;
pub mod transport;
pub mod verifier;

pub use backend::{
    BackendVerdict, EngineBackend, SyntheticDraft, SyntheticTarget, VerifyBackend,
};
pub use cloud::{handle_conn, serve_cloud, serve_loopback, ServerHandle};
pub use edge::{run_edge_session, EdgeReport, EdgeSessionConfig};
pub use session::{BatchDecision, BatchWindow, SessionCore, SessionOutcome};
pub use transport::{
    loopback_pair, loopback_pair_with_channel, AirtimeLedger, LoopbackTransport, TcpTransport,
    Transport,
};
pub use verifier::{VerifierConfig, VerifierCore, VerifierHandle};
