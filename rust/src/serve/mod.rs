//! `flexspec::serve` — async edge↔cloud transport & multi-session
//! serving subsystem.
//!
//! The simulator (`coordinator::scheduler`) proves the protocol under a
//! virtual clock; this module runs the SAME wire protocol
//! (`protocol::{DraftMsg, VerifyMsg}` in length-prefixed frames,
//! `protocol::frame`) over real connections:
//!
//! * [`transport`] — the object-safe [`Transport`] trait with two base
//!   implementations: [`TcpTransport`] (real sockets, TCP_NODELAY) and
//!   [`LoopbackTransport`] (in-process pair, optionally metered through
//!   the deterministic wireless-channel simulation) — plus the
//!   [`Reconnect`] connection-factory trait the resumable wrappers use.
//! * [`session`] — transport-agnostic state machines shared with the
//!   simulator: [`BatchWindow`] (close-the-window batching),
//!   [`SlotBatch`] (continuous rolling admission — see
//!   [`BatchMode`] and `docs/BATCHING.md`) and [`SessionCore`]
//!   (per-session commit bookkeeping both endpoints mirror, including
//!   the resume fast-forward).
//! * [`backend`] — pluggable cloud verification: the PJRT
//!   [`EngineBackend`] (KV sessions + LoRA hot-swap, artifact-gated) and
//!   the deterministic [`SyntheticTarget`]/[`SyntheticDraft`] pair whose
//!   verdicts are pure functions of (context, version) — timing- and
//!   batching-order-independent, which is what makes TCP, loopback and
//!   simulation runs byte-comparable.
//! * [`verifier`] — the cloud session manager + cross-connection batcher
//!   on a dedicated OS thread (PJRT handles are `!Send`), exposed to
//!   tokio through the async [`VerifierHandle`].
//! * [`cloud`] / [`edge`] — the accept loop + per-connection demux
//!   (`handle_conn`, shared by TCP and loopback), and the edge client
//!   running the channel-aware adaptive stride policy against *measured*
//!   round-trip times.
//! * [`mux`] / [`fault`] — the edge-side connection multiplexer and the
//!   deterministic fault-injection transport (below).
//! * [`pipeline`] — pipelined speculative drafting with cancel-on-reject
//!   (wire v3): the edge keeps up to `depth` rounds in flight, drafting
//!   round r+1 from the OPTIMISTIC prefix while round r verifies. See
//!   the pipeline data flow below.
//! * [`fleet`] — multi-replica serving (wire v5): the [`FleetRegistry`]
//!   control plane (replica endpoints, versions, load, health,
//!   staged/canary rollout via the per-replica hot-swap, drains), the
//!   shared [`SessionLedger`] handoff store, and the `Redirect` /
//!   `ReplicaInfo` frames that let a draining or saturated replica hand
//!   a live session to a peer mid-decode — committed sequences stay
//!   byte-identical across the move (`tests/serve_fleet.rs`).
//!
//! # Pipelined drafting data flow (wire v3)
//!
//! Sequentially, the edge idles for a full uplink + verify + downlink
//! round trip after every draft burst; at low rate the channel — not
//! the models — bounds throughput. With
//! `EdgeSessionConfig::pipeline_depth >= 2` the edge overlaps:
//!
//! ```text
//! edge                                     cloud
//!  Draft(r)                ────────────▶   verify r (batch window)
//!  Draft(r+1, basis+spec)  ────────────▶   queue r+1 behind r
//!          ◀─────────────────── Verify(r)
//!  prefix held? ──yes─▶ Draft(r+2, ...)    commit r, then basis-check r+1:
//!             └──no──▶ Cancel(r+1)           committed == basis ++ spec →
//!                      Draft(r+1) redraft      verify (rounds_pipelined++)
//!                                            else → discard (wasted)
//! ```
//!
//! `spec` is the optimistic suffix (in-flight draft blocks + their
//! predicted bonus tokens) the round was drafted from; validity is a
//! pure function of the committed sequence, so the edge's cancel
//! decision and the cloud's discard decision always agree and a lost
//! `Cancel` frame cannot change a single committed token. Pure draft
//! sources ([`crate::coordinator::edge::DraftSource::is_pure`]) make a
//! basis-valid speculative draft byte-identical to the sequential
//! draft, which is why `--pipeline-depth 2` serving commits EXACTLY the
//! sequential `serve_with` trajectory (pinned by
//! `tests/serve_loopback.rs` + the pipelined rows of the fault matrix).
//! The policy hook `AdaptivePolicy::select_pipeline_depth` enables the
//! overlap exactly when `T_fixed` dominates `K * T_marginal`.
//!
//! # Multiplexed wire format (wire v2)
//!
//! Every frame is `[len: u32 le][kind: u8][stream: u32 le][payload]`.
//! Stream 0 is reserved for connection control (`Hello`/`HelloAck`,
//! once per connection); each session binds one nonzero stream with
//! `Open` (or `Resume`) and all its `Draft`/`Verify`/`Bye` traffic
//! carries that id. The cloud demux (`cloud::handle_conn`) verifies
//! drafts from different streams CONCURRENTLY into the shared batching
//! window, so N sessions on one socket batch exactly like N sockets;
//! the edge-side [`EdgeMux`] hands out per-session [`MuxStream`]s that
//! implement [`Transport`], so session code is mux-agnostic.
//!
//! # Reconnect-and-resume state machine
//!
//! ```text
//! cloud session:  ATTACHED ──link died──▶ PARKED ──grace over──▶ EVICTED
//!                    ▲                      │
//!                    └──────Resume──────────┘        (KV state kept)
//!                 finished ──▶ RESIDUE(grace) — final tail still fetchable
//!
//! edge session:   decode ──error──▶ reattach ──▶ Resume{token, len}
//!                   ▲                                   │
//!                   └── fast_forward(tail, rounds) ◀────┘
//! ```
//!
//! `OpenAck` carries a resume token; on reconnect the edge replays
//! `Resume{token, committed_len}` and the cloud answers with the
//! committed TAIL it applied while the link was down (the server can
//! only ever be ahead). Decoding continues from the committed prefix —
//! no retraining, no re-sync: the paper's frozen-draft/evolving-target
//! decoupling applied to the link layer. Duplicate `Open`s are deduped
//! by client nonce; duplicate drafts are answered from the verifier's
//! per-session verdict cache; eviction uses a strict per-session
//! deadline so a resume inside the grace window can never lose the
//! race (pinned by `verifier::tests::reconnect_within_grace_cannot_
//! race_eviction`).
//!
//! # Fault-testing recipe
//!
//! Wrap any transport in a [`FaultTransport`] over a seeded, shared
//! [`FaultPlan`]: per frame event it delivers, duplicates, delays
//! (channel-model sampled), or kills the link (dropping the in-flight
//! frame). Schedules are deterministic per seed and span reconnects, so
//! `tests/serve_faults.rs` asserts that under forced disconnects the
//! committed token sequences stay IDENTICAL to the fault-free
//! `scheduler::serve_with` trajectory. E.g.:
//!
//! ```ignore
//! let plan = FaultPlan::shared(FaultConfig { seed, max_disconnects: 2,
//!     disconnect_on: FaultSide::Send, ..Default::default() }, channel);
//! let dial = move || -> BoxFuture<'static, Result<Box<dyn Transport>>> { /* fresh conn */ };
//! let mut t = ResumableTransport::connect(Box::new(dial), &ecfg).await?;
//! let report = run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await?;
//! assert_eq!(report.committed, fault_free_committed);
//! ```
//!
//! Determinism contract: with a [`SyntheticTarget`] backend and a fixed
//! stride, `serve_loopback`, `serve_loopback_mux`, the TCP path, and
//! `coordinator::scheduler::serve_with` commit identical per-session
//! token/acceptance counts for a fixed seed — and with a seeded
//! `FaultTransport` forcing disconnects, identical committed sequences
//! (pinned by `tests/serve_loopback.rs` and `tests/serve_faults.rs`).

pub mod backend;
pub mod cloud;
pub mod edge;
pub mod fault;
pub mod fleet;
pub mod mux;
pub mod pipeline;
pub mod session;
pub mod transport;
pub mod verifier;

pub use backend::{
    bucket_k, plan_buckets, BackendVerdict, BatchBucket, BatchVerifyReq, EngineBackend,
    SyntheticDraft, SyntheticTarget, VerifyBackend,
};
pub use cloud::{
    handle_conn, serve_cloud, serve_cloud_with, serve_loopback, serve_loopback_each,
    serve_loopback_mux, serve_loopback_mux_each, ServerHandle,
};
pub use edge::{
    busy_backoff_ms, edge_handshake, run_edge_session, run_session_on, EdgeReport,
    EdgeSessionConfig, ResumableTransport, BUSY_BACKOFF_CAP_MS, MAX_BUSY_RETRIES, SESSION_STREAM,
};
pub use fault::{loopback_fault_dial, FaultConfig, FaultOp, FaultPlan, FaultSide, FaultTransport};
pub use fleet::{
    tcp_fleet_dial, FleetDirectory, FleetRegistry, FleetReplica, FleetStats, PortableSession,
    SessionLedger,
};
pub use mux::{EdgeMux, MuxStream};
pub use pipeline::{
    InflightRound, LaunchPlan, PipelinedDrafter, Resolution, MAX_PIPELINE_DEPTH,
};
pub use session::{BatchDecision, BatchMode, BatchWindow, SessionCore, SessionOutcome, SlotBatch};
pub use transport::{
    loopback_pair, loopback_pair_with_channel, AirtimeLedger, LoopbackTransport, Reconnect,
    TcpTransport, Transport,
};
pub use verifier::{
    OpenInfo, ReplicaTelemetry, ResumeInfo, SubmitOutcome, VerifierConfig, VerifierCore,
    VerifierHandle, VerifyReply,
};
