//! The cloud-side verification service: session manager + cross-
//! connection dynamic verification batcher, generalizing the window
//! logic the simulator uses (`serve::session::BatchWindow`) to real
//! concurrent connections.
//!
//! Split in two layers:
//!
//! * `VerifierCore` — pure, synchronous state machine (sessions, open
//!   batch, backend, metrics). Unit-testable without threads or sockets;
//!   time is an opaque `f64` ms parameter.
//! * `VerifierHandle` — runs a `VerifierCore` on ONE dedicated OS thread
//!   and exposes async message-passing methods to the tokio side. The
//!   dedicated thread is not an implementation shortcut: the PJRT
//!   backend (`EngineBackend`) holds thread-pinned `Rc` handles, so the
//!   backend is *constructed inside* the thread via `make_backend` and
//!   never crosses a thread boundary. Batch-window deadlines map to
//!   `recv_timeout` on the command channel.

use super::backend::VerifyBackend;
use super::session::{BatchDecision, BatchWindow, SessionCore};
use crate::metrics::ServingMetrics;
use crate::protocol::{DraftMsg, VerifyMsg};
use crate::util::rng::SplitMix64;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::mpsc as std_mpsc;
use std::time::{Duration, Instant};
use tokio::sync::oneshot;

/// Verification service configuration (mirrors the simulator's
/// `ServeConfig` batching knobs).
#[derive(Debug, Clone)]
pub struct VerifierConfig {
    /// Batching window: how long the first request of a batch waits for
    /// company before verification runs.
    pub window_ms: f64,
    /// Close the window immediately at this many requests.
    pub max_batch: usize,
    pub temperature: f32,
    pub top_p: f32,
    pub seed: u64,
    /// End a session when fewer KV slots than this remain. MUST match
    /// `coordinator::ServeConfig::capacity_floor` for sim ↔ serve
    /// count equality.
    pub capacity_floor: usize,
}

impl Default for VerifierConfig {
    fn default() -> Self {
        VerifierConfig {
            window_ms: 12.0,
            max_batch: 8,
            temperature: 0.0,
            top_p: 1.0,
            seed: 1,
            capacity_floor: 10,
        }
    }
}

/// Transport-agnostic cloud session/batching state machine.
pub struct VerifierCore {
    pub cfg: VerifierConfig,
    backend: Box<dyn VerifyBackend>,
    sessions: HashMap<u32, SessionCore>,
    /// In-flight draft per session (protocol allows exactly one).
    pending: HashMap<u32, DraftMsg>,
    window: BatchWindow,
    next_id: u32,
    rng: SplitMix64,
    pub metrics: ServingMetrics,
}

impl VerifierCore {
    pub fn new(cfg: VerifierConfig, backend: Box<dyn VerifyBackend>) -> VerifierCore {
        let window = BatchWindow::new(cfg.window_ms, cfg.max_batch);
        let rng = SplitMix64::new(cfg.seed ^ 0x5E54_1CE5);
        VerifierCore {
            cfg,
            backend,
            sessions: HashMap::new(),
            pending: HashMap::new(),
            window,
            next_id: 1,
            rng,
            metrics: ServingMetrics::default(),
        }
    }

    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    pub fn backend_label(&self) -> String {
        self.backend.label()
    }

    /// Open a new KV session; returns (assigned id, target version seq).
    pub fn open_session(&mut self, prompt: &[i32], max_new: usize) -> Result<(u32, u64)> {
        let id = self.next_id;
        self.next_id += 1;
        self.backend.start_session(id, prompt)?;
        self.sessions
            .insert(id, SessionCore::new(id, prompt, max_new));
        self.metrics.sessions_opened += 1;
        Ok((id, self.backend.version_seq()))
    }

    /// Queue one draft block for batched verification.
    pub fn submit(&mut self, now_ms: f64, msg: DraftMsg) -> Result<BatchDecision> {
        let id = msg.session;
        if !self.sessions.contains_key(&id) {
            bail!("no session {id}");
        }
        if self.pending.contains_key(&id) {
            bail!("session {id} already has an in-flight draft (protocol violation)");
        }
        self.metrics.bytes_up += msg.air_bytes();
        self.pending.insert(id, msg);
        Ok(self.window.offer(now_ms, id))
    }

    /// Close the open window and verify its members as ONE batch
    /// (one amortized T_base on a real accelerator). Sessions that
    /// finish are torn down server-side; the verdict's `eos` flag tells
    /// the edge to stop.
    pub fn close_window(&mut self) -> Result<Vec<(u32, VerifyMsg)>> {
        let members = self.window.close();
        if members.is_empty() {
            return Ok(Vec::new());
        }
        self.metrics.note_batch(members.len());
        let mut out = Vec::with_capacity(members.len());
        for id in members {
            // aborted mid-window (client disconnect): nothing pending
            let Some(msg) = self.pending.remove(&id) else {
                continue;
            };
            let Some(core) = self.sessions.get_mut(&id) else {
                continue;
            };
            // Compact wire: full draft distributions never cross the
            // air — the backend reconstructs them cloud-side (point
            // mass / its own forward pass; see protocol module docs on
            // the documented Regime-B approximation).
            let v = self.backend.verify_block(
                id,
                &core.committed,
                &msg.tokens,
                &[],
                msg.mode,
                self.cfg.temperature,
                self.cfg.top_p,
                &mut self.rng,
            )?;
            let out_of_capacity =
                self.backend.remaining_capacity(id) <= self.cfg.capacity_floor;
            let finished =
                core.apply_verdict(&msg.tokens, v.tau, v.correction, v.eos, out_of_capacity);
            let vmsg = VerifyMsg {
                session: id,
                round: msg.round,
                tau: v.tau as u8,
                correction: v.correction,
                eos: finished,
            };
            self.metrics.note_round(msg.tokens.len(), v.tau);
            self.metrics.bytes_down += vmsg.air_bytes();
            if finished {
                self.metrics.finish_session(core);
                self.backend.end_session(id);
                self.sessions.remove(&id);
            }
            out.push((id, vmsg));
        }
        Ok(out)
    }

    /// Client went away: drop the session without counting completion.
    pub fn abort_session(&mut self, id: u32) {
        if self.sessions.remove(&id).is_some() {
            self.pending.remove(&id);
            self.backend.end_session(id);
            self.metrics.sessions_aborted += 1;
        }
    }

    /// Hot-swap the target version; live sessions keep their KV state.
    pub fn deploy(&mut self, version: &str) -> Result<u64> {
        let seq = self.backend.deploy(version)?;
        self.metrics.hot_swaps += 1;
        Ok(seq)
    }
}

// ---------------------------------------------------------------------
// Dedicated verifier thread + async handle
// ---------------------------------------------------------------------

enum VerifierCmd {
    Open {
        prompt: Vec<i32>,
        max_new: usize,
        reply: oneshot::Sender<Result<(u32, u64)>>,
    },
    Verify {
        id: u32,
        msg: DraftMsg,
        reply: oneshot::Sender<Result<VerifyMsg>>,
    },
    End {
        id: u32,
    },
    Deploy {
        version: String,
        reply: oneshot::Sender<Result<u64>>,
    },
    Stats {
        reply: oneshot::Sender<ServingMetrics>,
    },
    RejectedHandshake,
    Shutdown {
        reply: oneshot::Sender<ServingMetrics>,
    },
}

/// Cloneable async handle to the verifier thread. Dropping every handle
/// shuts the thread down (command channel disconnect).
#[derive(Clone)]
pub struct VerifierHandle {
    tx: std_mpsc::Sender<VerifierCmd>,
}

impl VerifierHandle {
    /// Spawn the verifier thread. `make_backend` runs ON the new thread,
    /// so `!Send` backends (PJRT) are constructed in place.
    pub fn spawn(
        cfg: VerifierConfig,
        make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    ) -> Result<VerifierHandle> {
        let (tx, rx) = std_mpsc::channel();
        let (ready_tx, ready_rx) = std_mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("flexspec-verifier".into())
            .spawn(move || {
                let backend = match make_backend() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                run_verifier(VerifierCore::new(cfg, backend), rx);
            })?;
        ready_rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow!("verifier thread failed to start"))??;
        Ok(VerifierHandle { tx })
    }

    fn post(&self, cmd: VerifierCmd) -> Result<()> {
        self.tx
            .send(cmd)
            .map_err(|_| anyhow!("verifier thread is gone"))
    }

    pub async fn open(&self, prompt: Vec<i32>, max_new: usize) -> Result<(u32, u64)> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Open {
            prompt,
            max_new,
            reply,
        })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))?
    }

    pub async fn verify(&self, id: u32, msg: DraftMsg) -> Result<VerifyMsg> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Verify { id, msg, reply })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))?
    }

    /// Fire-and-forget session teardown (client disconnect path).
    pub fn end(&self, id: u32) {
        let _ = self.post(VerifierCmd::End { id });
    }

    pub fn note_rejected_handshake(&self) {
        let _ = self.post(VerifierCmd::RejectedHandshake);
    }

    pub async fn deploy(&self, version: &str) -> Result<u64> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Deploy {
            version: version.to_string(),
            reply,
        })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))?
    }

    pub async fn stats(&self) -> Result<ServingMetrics> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Stats { reply })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))
    }

    /// Flush the open batch, stop the thread, return final metrics.
    pub async fn shutdown(&self) -> Result<ServingMetrics> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Shutdown { reply })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))
    }
}

fn run_verifier(mut core: VerifierCore, rx: std_mpsc::Receiver<VerifierCmd>) {
    let start = Instant::now();
    let now_ms = |start: &Instant| start.elapsed().as_secs_f64() * 1e3;
    let mut replies: HashMap<u32, oneshot::Sender<Result<VerifyMsg>>> = HashMap::new();
    let mut deadline: Option<f64> = None;

    fn flush(
        core: &mut VerifierCore,
        replies: &mut HashMap<u32, oneshot::Sender<Result<VerifyMsg>>>,
    ) {
        match core.close_window() {
            Ok(results) => {
                for (id, vmsg) in results {
                    if let Some(tx) = replies.remove(&id) {
                        let _ = tx.send(Ok(vmsg));
                    }
                }
            }
            Err(e) => {
                // a backend failure poisons the whole batch: every waiter
                // gets the error and the connection layer tears down
                let msg = format!("batch verification failed: {e:#}");
                for (_, tx) in replies.drain() {
                    let _ = tx.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }

    loop {
        // A queued command beats a zero timeout in recv_timeout, so an
        // expired window must be flushed HERE — not only in the Timeout
        // arm — or a busy command stream could hold it open forever.
        if let Some(d) = deadline {
            if now_ms(&start) >= d {
                deadline = None;
                flush(&mut core, &mut replies);
            }
        }
        let timeout = match deadline {
            Some(d) => Duration::from_secs_f64(((d - now_ms(&start)) / 1e3).max(0.0)),
            None => Duration::from_millis(200),
        };
        match rx.recv_timeout(timeout) {
            Ok(VerifierCmd::Open {
                prompt,
                max_new,
                reply,
            }) => {
                let _ = reply.send(core.open_session(&prompt, max_new));
            }
            Ok(VerifierCmd::Verify { id, msg, reply }) => {
                match core.submit(now_ms(&start), msg) {
                    Ok(decision) => {
                        replies.insert(id, reply);
                        match decision {
                            BatchDecision::CloseNow => {
                                deadline = None;
                                flush(&mut core, &mut replies);
                            }
                            BatchDecision::CloseAt(t) => deadline = Some(t),
                            BatchDecision::Queued => {}
                        }
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Ok(VerifierCmd::End { id }) => core.abort_session(id),
            Ok(VerifierCmd::Deploy { version, reply }) => {
                let _ = reply.send(core.deploy(&version));
            }
            Ok(VerifierCmd::Stats { reply }) => {
                let _ = reply.send(core.metrics.clone());
            }
            Ok(VerifierCmd::RejectedHandshake) => {
                core.metrics.handshakes_rejected += 1;
            }
            Ok(VerifierCmd::Shutdown { reply }) => {
                deadline = None;
                flush(&mut core, &mut replies);
                let _ = reply.send(core.metrics.clone());
                return;
            }
            // expiry handled at the top of the loop
            Err(std_mpsc::RecvTimeoutError::Timeout) => {}
            Err(std_mpsc::RecvTimeoutError::Disconnected) => {
                flush(&mut core, &mut replies);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{VerifyMode, WireFormat};
    use crate::serve::backend::{SyntheticDraft, SyntheticTarget};
    use crate::coordinator::edge::DraftSource;

    fn core(window_ms: f64, max_batch: usize) -> VerifierCore {
        let cfg = VerifierConfig {
            window_ms,
            max_batch,
            ..Default::default()
        };
        VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)))
    }

    fn draft_for(id: u32, round: u32, committed: &[i32], k: usize) -> DraftMsg {
        let mut d = SyntheticDraft::new(7);
        let mut rng = SplitMix64::new(0);
        let p = d.propose(committed, k, 0.0, 1.0, &mut rng).unwrap();
        DraftMsg {
            session: id,
            round,
            tokens: p.tokens,
            chosen_probs: p.chosen_probs,
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
        }
    }

    #[test]
    fn batches_verify_and_complete_sessions() {
        let mut c = core(10.0, 8);
        let prompt_a = vec![1, 70, 71];
        let prompt_b = vec![1, 80, 81];
        let (a, seq) = c.open_session(&prompt_a, 8).unwrap();
        let (b, _) = c.open_session(&prompt_b, 8).unwrap();
        assert_eq!((a, b, seq), (1, 2, 1));

        let mut committed_a = prompt_a.clone();
        let mut committed_b = prompt_b.clone();
        let mut finished = 0;
        let mut round = 0u32;
        while finished < 2 && round < 20 {
            if !c.sessions.contains_key(&a) && !c.sessions.contains_key(&b) {
                break;
            }
            for (&id, committed) in [(&a, &mut committed_a), (&b, &mut committed_b)] {
                if !c.sessions.contains_key(&id) {
                    continue;
                }
                let msg = draft_for(id, round, committed, 4);
                c.submit(round as f64, msg).unwrap();
            }
            for (id, vmsg) in c.close_window().unwrap() {
                let committed = if id == a { &mut committed_a } else { &mut committed_b };
                let msg_tokens = draft_for(id, round, committed, 4).tokens;
                committed.extend_from_slice(&msg_tokens[..vmsg.tau as usize]);
                committed.push(vmsg.correction);
                if vmsg.eos {
                    finished += 1;
                }
            }
            round += 1;
        }
        assert_eq!(finished, 2);
        assert_eq!(c.metrics.sessions_completed, 2);
        assert!(c.metrics.batches >= 2);
        assert!(c.metrics.mean_batch() > 1.0, "batched both sessions");
        assert_eq!(c.active_sessions(), 0);
        // zero drift synthetic target: everything accepted
        assert_eq!(c.metrics.accepted, c.metrics.drafted);
    }

    #[test]
    fn duplicate_inflight_draft_is_rejected() {
        let mut c = core(10.0, 8);
        let prompt = vec![1, 70, 71];
        let (id, _) = c.open_session(&prompt, 8).unwrap();
        c.submit(0.0, draft_for(id, 0, &prompt, 2)).unwrap();
        assert!(c.submit(0.1, draft_for(id, 0, &prompt, 2)).is_err());
    }

    #[test]
    fn abort_mid_window_skips_member() {
        let mut c = core(10.0, 8);
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let (a, _) = c.open_session(&pa, 8).unwrap();
        let (b, _) = c.open_session(&pb, 8).unwrap();
        c.submit(0.0, draft_for(a, 0, &pa, 2)).unwrap();
        c.submit(0.0, draft_for(b, 0, &pb, 2)).unwrap();
        c.abort_session(a);
        let out = c.close_window().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, b);
        assert_eq!(c.metrics.sessions_aborted, 1);
    }

    #[test]
    fn deploy_counts_hot_swap_and_keeps_sessions() {
        let cfg = VerifierConfig::default();
        let backend = SyntheticTarget::new(7).with_version("evolved", 0.3);
        let mut c = VerifierCore::new(cfg, Box::new(backend));
        let prompt = vec![1, 70, 71];
        let (id, seq1) = c.open_session(&prompt, 64).unwrap();
        let seq2 = c.deploy("evolved").unwrap();
        assert!(seq2 > seq1);
        assert_eq!(c.metrics.hot_swaps, 1);
        // the session survives and keeps decoding on the new version
        c.submit(0.0, draft_for(id, 0, &prompt, 4)).unwrap();
        let out = c.close_window().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(c.active_sessions(), 1);
    }
}
