//! The cloud-side verification service: session manager + cross-
//! connection dynamic verification batcher, generalizing the window
//! logic the simulator uses (`serve::session::BatchWindow`) to real
//! concurrent connections.
//!
//! Batch *formation* is pluggable (`VerifierConfig::batch_mode`,
//! `docs/BATCHING.md`):
//!
//! * **Windowed** (default) — close-the-window: the first draft arms a
//!   `window_ms` timer, the batch closes on the timer or `max_batch`.
//! * **Continuous** — rolling slot admission (`SlotBatch`): the batch
//!   is always open; an arriving draft takes a free slot immediately
//!   under a paged-KV lease (`runtime::KvBlockPool`, released on
//!   verdict), a zero-delay deadline coalesces same-burst arrivals,
//!   and freed slots are refilled from a strict FIFO of waiters.
//!
//! Either way a close is *plan → execute → apply* — one
//! `verify_batch` call, power-of-two `[B, K]` buckets, one stacked
//! engine dispatch per bucket — and committed sequences are
//! byte-identical across both modes and the simulator.
//!
//! Split in two layers:
//!
//! * `VerifierCore` — pure, synchronous state machine (sessions, open
//!   batch, backend, metrics). Unit-testable without threads or sockets;
//!   time is an opaque `f64` ms parameter.
//! * `VerifierHandle` — runs a `VerifierCore` on ONE dedicated OS thread
//!   and exposes async message-passing methods to the tokio side. The
//!   dedicated thread is not an implementation shortcut: the PJRT
//!   backend (`EngineBackend`) holds thread-pinned `Rc` handles, so the
//!   backend is *constructed inside* the thread via `make_backend` and
//!   never crosses a thread boundary. Batch-window deadlines map to
//!   `recv_timeout` on the command channel.
//!
//! # Session lifetime under transport faults
//!
//! A connection drop no longer aborts its sessions. The state machine is:
//!
//! ```text
//!            open                    detach (link died)
//!   (none) ───────▶ ATTACHED ─────────────────────────▶ PARKED
//!                      ▲                                  │   │
//!                      │        resume (token, pos)       │   │ grace
//!                      └──────────────────────────────────┘   │ expired
//!                      │                                      ▼
//!                   finished ──▶ RESIDUE (grace) ──▶ gone   EVICTED
//! ```
//!
//! * **Parked** sessions keep their KV state; the eviction sweep reaps
//!   them only STRICTLY after their per-session deadline, and `resume`
//!   never re-checks the clock — if the sweep has not actually reaped a
//!   session, a reconnect wins. Re-parking after a resume records a
//!   fresh deadline, so a stale timer armed for an earlier park can
//!   never evict early (the race `tests::reconnect_within_grace_cannot_
//!   race_eviction` pins).
//! * **Finished residues**: a session that completes while its link is
//!   down leaves (token → final committed tail) behind for one grace
//!   window, so a resume that missed the last verdict still converges.
//! * **Replay**: the last verdict per session is cached; a draft whose
//!   round was already verified (transport duplicate, reconnect
//!   retransmit) is answered from the cache instead of re-advancing the
//!   sequence, and `Open` retransmits are deduplicated by client nonce.

use super::backend::{bucket_k, BatchVerifyReq, VerifyBackend};
use super::fleet::{PortableSession, SessionLedger};
use super::session::{BatchDecision, BatchMode, BatchWindow, SessionCore, SlotBatch};
use crate::metrics::ServingMetrics;
use crate::runtime::{KvBlockPool, KvLease};
use crate::obs::{SpanKind, Trace};
use crate::protocol::frame::DeviceProfileMsg;
use crate::protocol::{DraftMsg, VerifyMode, VerifyMsg};
use crate::util::rng::SplitMix64;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::mpsc as std_mpsc;
use std::time::{Duration, Instant};
use tokio::sync::oneshot;

/// Verification service configuration (mirrors the simulator's
/// `ServeConfig` batching knobs).
#[derive(Debug, Clone)]
pub struct VerifierConfig {
    /// Batching window: how long the first request of a batch waits for
    /// company before verification runs.
    pub window_ms: f64,
    /// Close the window immediately at this many requests.
    pub max_batch: usize,
    pub temperature: f32,
    pub top_p: f32,
    pub seed: u64,
    /// End a session when fewer KV slots than this remain. MUST match
    /// `coordinator::ServeConfig::capacity_floor` for sim ↔ serve
    /// count equality.
    pub capacity_floor: usize,
    /// How long a parked session (and a finished residue) survives a
    /// dead link before eviction reclaims its KV state.
    pub resume_grace_ms: f64,
    /// Admission control: bound on drafts pending verification (the
    /// batcher's backlog across ALL sessions). A fresh head-round draft
    /// arriving at the bound is answered with `Busy { retry_after_ms }`
    /// instead of being queued — the edge retries it with backoff, and
    /// because drafts are pure functions of the committed prefix the
    /// deferral can never change a committed token.
    ///
    /// 0 (the default) = unbounded. EFFECTIVE values are
    /// `1..max_batch`: the window drains synchronously the moment it
    /// reaches `max_batch` members, so the backlog can never exceed
    /// `max_batch` and a larger bound is unreachable. Promotions of
    /// already-uploaded speculative rounds bypass the bound (turning
    /// them away would waste uplink the edge already spent), so it is
    /// a soft cap with overshoot bounded by the pipeline depth. Peers
    /// that negotiated wire < 4 are always admitted (they cannot parse
    /// `Busy`). Note for lossy deployments: a dropped `Busy` frame
    /// strands its round until the link-level disconnect/resume path
    /// kicks in, so pair a bound with the resume machinery (always on)
    /// rather than bare UDP-style transports.
    pub admission_queue: usize,
    /// Tier-aware admission (wire v7): how many `admission_queue` slots
    /// are RESERVED for priority traffic. Sessions opened with QoS tier
    /// > 1 (the edge mux's interactive tiers) may fill the whole queue;
    /// tier-1 (default/bulk) sessions are turned away `tier_reserve`
    /// slots early, so a flash crowd of bulk traffic cannot starve the
    /// interactive tiers out of admission. 0 (the default) = no
    /// reservation; clamped so at least one slot stays open to tier 1.
    /// Meaningless while `admission_queue == 0` (unbounded).
    pub tier_reserve: usize,
    /// TTL for fleet-ledger entries exported by THIS replica's sweeps:
    /// `evict_expired` ages out parked [`PortableSession`]s
    /// (`crate::serve::fleet::SessionLedger::expire_before`) older than
    /// this, covering exporters that died before their reap fired. The
    /// default (10 min) is far beyond any handoff grace window, so it
    /// only collects genuinely abandoned entries.
    pub ledger_ttl_ms: f64,
    /// Optional trace journal (`flexspec::obs`): when set, the verifier
    /// records the cloud half of every round's span chain — QueueWait,
    /// BucketPlan, VerifyBatch, Commit — plus fleet Export/Import
    /// events. `None` (the default) keeps the hot path untouched.
    pub trace: Option<Trace>,
    /// How batches form (see `docs/BATCHING.md`). `Windowed` (the
    /// default) is close-the-window: drafts wait up to `window_ms` for
    /// company. `Continuous` is rolling admission: a draft takes one of
    /// `max_batch` verification slots immediately (KV pages permitting)
    /// and the batch closes the moment the command queue drains, so no
    /// draft ever waits on a timer — verdicts free slots, a FIFO of
    /// waiters refills them. Greedy verdicts are pure functions of
    /// (context, draft), so the committed sequences are byte-identical
    /// across both modes (pinned by `tests::continuous_mode_commits_
    /// identical_sequences_across_seeds`).
    pub batch_mode: BatchMode,
    /// Continuous mode only: capacity of the paged KV block pool
    /// ([`crate::runtime::KvBlockPool`]) backing per-slot sequence
    /// state, in pages. Admission reserves pages for the whole row
    /// (committed prefix + draft + correction) and returns them with
    /// the verdict, so the pool bounds aggregate slot residency. Size
    /// it for at least `max_batch` maximum-length sequences; the
    /// default (4096 pages x 16 tokens) covers 8 slots of 4096-token
    /// rows with 2x headroom. Ignored in windowed mode.
    pub kv_pool_pages: usize,
    /// Continuous mode only: committed positions per KV pool page.
    pub kv_page_tokens: usize,
}

impl Default for VerifierConfig {
    fn default() -> Self {
        VerifierConfig {
            window_ms: 12.0,
            max_batch: 8,
            temperature: 0.0,
            top_p: 1.0,
            seed: 1,
            capacity_floor: 10,
            resume_grace_ms: 10_000.0,
            admission_queue: 0,
            tier_reserve: 0,
            ledger_ttl_ms: 600_000.0,
            trace: None,
            batch_mode: BatchMode::Windowed,
            kv_pool_pages: 4096,
            kv_page_tokens: 16,
        }
    }
}

/// What `submit` decided about one draft.
#[derive(Debug)]
pub enum SubmitOutcome {
    /// Fresh round: queued for batched verification.
    Queued(BatchDecision),
    /// Pipelined draft for a FUTURE round (wire v3): parked in the
    /// session's speculative queue until every earlier round commits;
    /// `promote_ready` then basis-checks it and either verifies it
    /// (rounds_pipelined) or discards it (drafts_cancelled). The caller
    /// keeps a reply waiter keyed by (session, round).
    Deferred,
    /// The round was already verified (duplicate / retransmit): answer
    /// with the cached verdict, do not advance the sequence.
    Replay(VerifyMsg),
    /// Duplicate of a round still in flight (in the window OR in the
    /// speculative queue): the round is already queued, but THIS caller
    /// becomes the reply waiter (the previous waiter may belong to a
    /// dead predecessor connection — the latest requester is the one
    /// that can still deliver the verdict).
    TakeOver,
    /// Stale retransmit of a round older than the cached verdict, a
    /// speculative draft whose basis no longer matches the committed
    /// prefix, or a draft from a stale attachment: no reply owed.
    Swallowed,
    /// Admission control (wire v4): the pending-draft queue is at its
    /// bound and this fresh round was NOT admitted. The caller answers
    /// with a `Busy` frame; the edge retries the identical draft after
    /// `retry_after_ms`. No state was recorded for the round.
    Busy {
        retry_after_ms: u32,
    },
    /// Fleet handoff (wire v5): this replica is draining (or the
    /// session was targeted for rebalance), so the session's state was
    /// EXPORTED to the shared [`SessionLedger`] and the caller answers
    /// with a `Redirect` frame instead of a verdict. The edge redials
    /// `addr` and replays the normal `Resume { resume_token }` there;
    /// the importing replica reconstructs the session from the ledger.
    /// The submitted draft left no state behind — a pure draft source
    /// re-produces byte-identical tokens from the committed prefix on
    /// the new replica, so a handoff can never change a committed
    /// token.
    Redirect {
        addr: String,
        resume_token: u64,
    },
}

/// One replica's instantaneous state, read by the fleet registry for
/// placement/health and announced on the wire as a `ReplicaInfo` frame
/// after a v5 handshake.
#[derive(Debug, Clone)]
pub struct ReplicaTelemetry {
    /// Deployed target version sequence number (what `OpenAck::
    /// target_seq` reports to edges).
    pub version_seq: u64,
    /// Deployed target version name.
    pub version_name: String,
    /// Live sessions (attached + parked).
    pub active_sessions: usize,
    /// Sessions parked awaiting a resume.
    pub parked_sessions: usize,
    /// Drafts pending verification right now (the admission queue's
    /// instantaneous depth).
    pub queue_len: usize,
    /// True when a drain target is set: every redirect-capable
    /// session's next head round is being handed off.
    pub draining: bool,
    /// Age of this snapshot in ms. The verifier itself always reports
    /// 0.0 (the snapshot is made on demand); the fleet registry and the
    /// autoscaler stamp/derive real ages so stale snapshots rank as
    /// unknown in placement.
    pub age_ms: f64,
}

impl ReplicaTelemetry {
    /// The scalar the registry's least-loaded placement (and the wire
    /// `ReplicaInfo::load` field) uses.
    pub fn load(&self) -> usize {
        self.active_sessions + self.queue_len
    }
}

/// The rejection `resume` raises when a token maps to nothing — no live
/// session, no finished residue, no fleet-ledger entry. One constant so
/// the connection layer's structured-rejection classification
/// (`ResumeAck::unknown_token`, what fleet edges key their re-root on)
/// can never drift from the error text.
pub const UNKNOWN_RESUME_TOKEN: &str = "unknown or expired resume token";

/// Everything a `ResumeAck` needs.
#[derive(Debug, Clone)]
pub struct ResumeInfo {
    pub session: u32,
    /// Attachment epoch of this (re)attachment — the connection passes
    /// it back in `detach` so a STALE connection's teardown can never
    /// park a session that a newer connection has since reattached.
    pub attachment: u64,
    /// Server-side committed length after `tail`.
    pub committed_len: usize,
    /// Committed suffix beyond the edge's reported position.
    pub tail: Vec<i32>,
    pub rounds: usize,
    pub target_seq: u64,
    /// True when the session finished while the link was down.
    pub done: bool,
}

/// Everything an `OpenAck` needs.
#[derive(Debug, Clone, Copy)]
pub struct OpenInfo {
    pub session: u32,
    pub target_seq: u64,
    pub resume_token: u64,
    /// Attachment epoch (see [`ResumeInfo::attachment`]).
    pub attachment: u64,
}

/// Final state a completed session leaves behind for the grace window.
#[derive(Debug, Clone)]
struct FinishedResidue {
    session: u32,
    committed: Vec<i32>,
    rounds: usize,
    deadline_ms: f64,
}

/// Transport-agnostic cloud session/batching state machine.
pub struct VerifierCore {
    pub cfg: VerifierConfig,
    backend: Box<dyn VerifyBackend>,
    sessions: HashMap<u32, SessionCore>,
    /// Draft currently offered to the batch window, per session (at most
    /// one: the session's NEXT round; later rounds wait in `queued`).
    pending: HashMap<u32, DraftMsg>,
    /// Admission time of each window-pending draft (queue-wait
    /// latency); maintained in lockstep with `pending`.
    arrived: HashMap<u32, f64>,
    /// Pipelined drafts for FUTURE rounds (wire v3), ascending round
    /// order. Basis-checked and promoted into the window by
    /// `promote_ready` once their turn comes; retracted by `cancel` or
    /// discarded when stale.
    queued: HashMap<u32, Vec<DraftMsg>>,
    /// Parked sessions: id → eviction deadline. Overlay on `sessions`
    /// (the core stays put; only attachment changes).
    parked: HashMap<u32, f64>,
    /// Last verdict per session for duplicate-round replay. Kept past
    /// completion (tombstone) until the finished residue expires.
    last_verdict: HashMap<u32, VerifyMsg>,
    /// Resume capability tokens.
    token_of: HashMap<u32, u64>,
    session_of_token: HashMap<u64, u32>,
    /// Open-nonce dedup (retransmitted `Open` reattaches, never leaks).
    open_nonces: HashMap<u64, u32>,
    nonce_of: HashMap<u32, u64>,
    finished: HashMap<u64, FinishedResidue>,
    /// Current attachment epoch per session (bumped on open AND resume);
    /// `detach` is a no-op unless the caller's epoch is still current.
    attachment_of: HashMap<u32, u64>,
    attach_seq: u64,
    /// Fleet handoff ledger (`None` outside fleet deployments): the
    /// shared store exported sessions travel through on their way to a
    /// peer replica (`serve::fleet`).
    ledger: Option<SessionLedger>,
    /// Drain target: when set, every redirect-capable (wire v5)
    /// session's next head round is answered with a `Redirect` to this
    /// address instead of a verdict.
    redirect_all_to: Option<String>,
    /// Targeted handoffs (load rebalance): session id → peer address,
    /// consumed when the session's next head round arrives.
    redirect_sessions: HashMap<u32, String>,
    /// Tombstones for exported sessions: id → grace deadline. Late
    /// in-flight drafts of a handed-off session are swallowed (wasted
    /// speculation, like a session finishing underneath its pipeline)
    /// instead of being treated as protocol errors.
    redirected_ids: HashMap<u32, f64>,
    /// Resume tokens this replica already redirected once → (grace
    /// deadline, ledger export stamp). A re-imported session — the
    /// edge could not follow the redirect and resumed in place — is
    /// admitted normally instead of being bounced again, which
    /// guarantees progress; when the deadline passes, the stamp lets
    /// the sweep reap an ABANDONED export (edge never resumed) from
    /// the shared ledger without racing a sibling's newer re-export.
    redirected_tokens: HashMap<u64, (f64, u64)>,
    /// Last negotiated wire version seen submitting for each live
    /// session (the attachment guard keeps stale connections out, so
    /// this is the CURRENT connection's version) — promotion-time
    /// redirects need it, because the deferred draft no longer carries
    /// its connection.
    wire_of: HashMap<u32, u16>,
    /// QoS tier per live session (wire v7 `Open::tier`; absent = tier
    /// 1). Tier > 1 sessions bypass the `tier_reserve` admission
    /// headroom — the cloud-side mirror of the edge mux's weighted
    /// tiers.
    tier_of: HashMap<u32, u32>,
    /// Device profile per live session (wire v8 `Open::profile`;
    /// absent for pre-v8 peers and unprofiled opens). The cloud keeps
    /// it for telemetry and capacity planning — the speculation policy
    /// that CONSUMES the profile runs edge-side, so committed bytes
    /// never depend on this map. Dropped with the session; a fleet
    /// handoff does not carry it (the edge re-announces on its next
    /// `Open`, and an imported session simply reads as unprofiled).
    profile_of: HashMap<u32, DeviceProfileMsg>,
    /// Earliest grace deadline among parked sessions and finished
    /// residues (+inf when none) — cheap gate so the per-iteration
    /// eviction sweep skips the map walks until something can expire.
    next_sweep_ms: f64,
    /// Same idea for the fleet-ledger TTL sweep
    /// ([`VerifierCore::sweep_ledger_ttl`]); separate gate because the
    /// shared store must be swept even when THIS replica has nothing
    /// parked. Starts at -inf so the first sweep observes the ledger
    /// and arms itself.
    next_ledger_sweep_ms: f64,
    window: BatchWindow,
    /// Continuous-mode slot table + admission FIFO (untouched in
    /// windowed mode); `batch_offer`/`batch_remove`/`batch_take`
    /// dispatch on `cfg.batch_mode`.
    slots: SlotBatch,
    /// Paged KV pool backing continuous-mode slot rows. A slot
    /// occupant's lease covers its full sequence (committed + draft +
    /// correction) and is released with the verdict, so pool residency
    /// is bounded by the CURRENT slot occupants, never by idle
    /// sessions — FIFO waiters are admitted as verdicts return pages.
    kv_pool: KvBlockPool,
    /// Live slot leases, keyed by session id (continuous mode only).
    kv_leases: HashMap<u32, KvLease>,
    next_id: u32,
    /// Verification sampling stream (stochastic mode).
    rng: SplitMix64,
    /// Separate stream for resume tokens so capability minting never
    /// perturbs the verification sampling sequence.
    token_rng: SplitMix64,
    pub metrics: ServingMetrics,
}

impl VerifierCore {
    pub fn new(cfg: VerifierConfig, backend: Box<dyn VerifyBackend>) -> VerifierCore {
        let window = BatchWindow::new(cfg.window_ms, cfg.max_batch);
        let slots = SlotBatch::new(cfg.max_batch);
        let kv_pool = KvBlockPool::new(cfg.kv_pool_pages, cfg.kv_page_tokens.max(1));
        let rng = SplitMix64::new(cfg.seed ^ 0x5E54_1CE5);
        let token_rng = SplitMix64::new(cfg.seed ^ 0x70CE_D117);
        VerifierCore {
            cfg,
            backend,
            sessions: HashMap::new(),
            pending: HashMap::new(),
            arrived: HashMap::new(),
            queued: HashMap::new(),
            parked: HashMap::new(),
            last_verdict: HashMap::new(),
            token_of: HashMap::new(),
            session_of_token: HashMap::new(),
            open_nonces: HashMap::new(),
            nonce_of: HashMap::new(),
            finished: HashMap::new(),
            attachment_of: HashMap::new(),
            attach_seq: 0,
            ledger: None,
            redirect_all_to: None,
            redirect_sessions: HashMap::new(),
            redirected_ids: HashMap::new(),
            redirected_tokens: HashMap::new(),
            wire_of: HashMap::new(),
            tier_of: HashMap::new(),
            profile_of: HashMap::new(),
            next_sweep_ms: f64::INFINITY,
            next_ledger_sweep_ms: f64::NEG_INFINITY,
            window,
            slots,
            kv_pool,
            kv_leases: HashMap::new(),
            next_id: 1,
            rng,
            token_rng,
            metrics: ServingMetrics::default(),
        }
    }

    /// Attach this replica to a fleet's shared handoff ledger
    /// (builder-style). Without a ledger the core never redirects and
    /// never imports — the pre-fleet single-replica behavior.
    pub fn with_ledger(mut self, ledger: SessionLedger) -> VerifierCore {
        self.ledger = Some(ledger);
        self
    }

    /// Start (or stop, with `None`) DRAINING: every redirect-capable
    /// session's next head round is answered with a `Redirect` to
    /// `target` — the staged-rollout / scale-down primitive. Sessions
    /// of peers below wire v5 keep decoding here (they cannot parse a
    /// redirect), and a session is bounced at most once per grace
    /// window so a peer that resumes in place always makes progress.
    pub fn set_redirect(&mut self, target: Option<String>) {
        self.redirect_all_to = target;
    }

    /// Target ONE session for handoff to `target` (load rebalance):
    /// its next head round is redirected; everything else on this
    /// replica is untouched.
    pub fn redirect_session(&mut self, id: u32, target: String) {
        self.redirect_sessions.insert(id, target);
    }

    /// Target up to `n` sessions for handoff to `target` — the
    /// autoscaler's bulk-rebalance actuator. Picks the LOWEST live
    /// session ids first (deterministic across runs), skipping
    /// sessions already marked for a redirect and sessions pinned to
    /// pre-v5 peers (they cannot parse the frame). Returns the ids
    /// actually marked.
    pub fn redirect_some(&mut self, n: usize, target: String) -> Vec<u32> {
        let mut ids: Vec<u32> = self
            .sessions
            .keys()
            .copied()
            .filter(|id| !self.redirect_sessions.contains_key(id))
            .filter(|id| self.wire_of.get(id).copied().unwrap_or(0) >= 5)
            .collect();
        ids.sort_unstable();
        ids.truncate(n);
        for &id in &ids {
            self.redirect_sessions.insert(id, target.clone());
        }
        ids
    }

    /// Instantaneous replica state for the fleet registry and the wire
    /// `ReplicaInfo` announcement.
    pub fn telemetry(&self) -> ReplicaTelemetry {
        ReplicaTelemetry {
            version_seq: self.backend.version_seq(),
            version_name: self.backend.version_name(),
            active_sessions: self.sessions.len(),
            parked_sessions: self.parked.len(),
            queue_len: self.pending.len(),
            draining: self.redirect_all_to.is_some(),
            age_ms: 0.0,
        }
    }

    /// Live sessions (attached + parked).
    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Sessions currently parked awaiting a resume.
    pub fn parked_sessions(&self) -> usize {
        self.parked.len()
    }

    /// The wire v8 device profile a live session announced at `Open`,
    /// if any. Fleet imports and pre-v8 peers read as unprofiled.
    pub fn device_profile(&self, id: u32) -> Option<&DeviceProfileMsg> {
        self.profile_of.get(&id)
    }

    pub fn backend_label(&self) -> String {
        self.backend.label()
    }

    fn next_attachment(&mut self, id: u32) -> u64 {
        self.attach_seq += 1;
        self.attachment_of.insert(id, self.attach_seq);
        self.attach_seq
    }

    /// Remove a session's window-pending draft during teardown
    /// (detach, resume-steal, evict, abort, export, open-reattach),
    /// counting it as swallowed so the draft conservation audit stays
    /// balanced: a received draft must end up in exactly one
    /// disposition bucket.
    fn drop_pending(&mut self, id: u32) {
        self.arrived.remove(&id);
        if self.pending.remove(&id).is_some() {
            self.metrics.drafts_swallowed += 1;
        }
    }

    /// Same, for the speculative queue: rounds parked behind a session
    /// being torn down die with it.
    fn drop_queued(&mut self, id: u32) {
        if let Some(q) = self.queued.remove(&id) {
            self.metrics.drafts_swallowed += q.len();
        }
    }

    /// Drafts received but not yet disposed (window-pending + parked
    /// speculative rounds) — the in-flight term of the draft
    /// conservation invariant.
    pub fn drafts_in_flight(&self) -> usize {
        self.pending.len() + self.queued.values().map(Vec::len).sum::<usize>()
    }

    /// Conservation audit at shutdown: warn-log and `debug_assert` on
    /// any counter imbalance (see `ServingMetrics::invariant_violations`).
    pub fn audit(&self) {
        self.metrics
            .check_invariants(self.sessions.len(), self.drafts_in_flight());
        // continuous mode: the paged-KV allocator must balance too —
        // pages never leaked, never aliased (trivially true windowed,
        // where the pool is never touched)
        if let Err(e) = self.kv_pool.audit() {
            debug_assert!(false, "kv pool conservation audit failed: {e}");
        }
        let leased: usize = self.kv_leases.values().map(|l| l.page_count()).sum();
        debug_assert!(
            leased == self.kv_pool.in_use(),
            "slot leases hold {leased} pages but the pool accounts {}",
            self.kv_pool.in_use()
        );
    }

    /// Open a new KV session. A nonzero `nonce` seen before reattaches
    /// the session it created (retransmitted `Open` whose ack was lost)
    /// instead of leaking a second one. Opens at the default QoS tier
    /// (1); wire-v7 peers carrying an explicit tier go through
    /// [`VerifierCore::open_session_tier`].
    pub fn open_session(&mut self, prompt: &[i32], max_new: usize, nonce: u64) -> Result<OpenInfo> {
        self.open_session_tier(prompt, max_new, nonce, 1)
    }

    /// [`VerifierCore::open_session`] with an explicit QoS tier (wire
    /// v7 `Open::tier`). Tier > 1 sessions bypass the
    /// [`VerifierConfig::tier_reserve`] admission headroom.
    pub fn open_session_tier(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        nonce: u64,
        tier: u32,
    ) -> Result<OpenInfo> {
        self.open_session_profile(prompt, max_new, nonce, tier, None)
    }

    /// [`VerifierCore::open_session_tier`] with the peer's wire v8
    /// device profile attached. The profile is bookkeeping, not policy:
    /// the cloud records it (telemetry, per-tier capacity accounting)
    /// while the resource-aware speculation plan that reads it runs on
    /// the edge — so a profile can never change committed bytes.
    pub fn open_session_profile(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        nonce: u64,
        tier: u32,
        profile: Option<DeviceProfileMsg>,
    ) -> Result<OpenInfo> {
        if nonce != 0 {
            if let Some(&id) = self.open_nonces.get(&nonce) {
                if self.sessions.contains_key(&id) {
                    self.parked.remove(&id);
                    self.drop_pending(id);
                    let resume_token = *self
                        .token_of
                        .get(&id)
                        .ok_or_else(|| anyhow!("session {id} has no resume token"))?;
                    return Ok(OpenInfo {
                        session: id,
                        target_seq: self.backend.version_seq(),
                        resume_token,
                        attachment: self.next_attachment(id),
                    });
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.backend.start_session(id, prompt)?;
        self.sessions
            .insert(id, SessionCore::new(id, prompt, max_new));
        let token = loop {
            let t = self.token_rng.next_u64();
            if t != 0 && !self.session_of_token.contains_key(&t) && !self.finished.contains_key(&t)
            {
                break t;
            }
        };
        self.token_of.insert(id, token);
        self.session_of_token.insert(token, id);
        if nonce != 0 {
            self.open_nonces.insert(nonce, id);
            self.nonce_of.insert(id, nonce);
        }
        if tier != 1 {
            self.tier_of.insert(id, tier);
        }
        if let Some(p) = profile {
            if let Some(slot) = self
                .metrics
                .sessions_by_device_tier
                .get_mut(p.compute_tier as usize)
            {
                *slot += 1;
            }
            self.profile_of.insert(id, p);
        }
        self.metrics.sessions_opened += 1;
        Ok(OpenInfo {
            session: id,
            target_seq: self.backend.version_seq(),
            resume_token: token,
            attachment: self.next_attachment(id),
        })
    }

    /// [`VerifierCore::submit_from`] with the peer capability expressed
    /// as the pre-fleet bool: `can_defer` maps to wire v4, everything
    /// else to the v2 floor (no `Busy`, no `Redirect`).
    pub fn submit(
        &mut self,
        now_ms: f64,
        attachment: u64,
        msg: DraftMsg,
        can_defer: bool,
    ) -> Result<SubmitOutcome> {
        self.submit_from(now_ms, attachment, msg, if can_defer { 4 } else { 2 })
    }

    /// Queue one draft block for batched verification — or recognize it
    /// as a duplicate/retransmit/speculative round and
    /// replay/defer/swallow it. `attachment` is the submitting
    /// connection's epoch: a draft from a STALE attachment (its session
    /// was stolen by a reconnect) is swallowed outright — it could
    /// neither deliver a verdict nor is one owed. `peer_wire` is the
    /// connection's NEGOTIATED wire version: peers >= 4 may be answered
    /// with a `Busy` deferral, peers >= 5 may be handed to a fleet
    /// sibling with a `Redirect`; older peers are always admitted
    /// because they could not act on either.
    pub fn submit_from(
        &mut self,
        now_ms: f64,
        attachment: u64,
        msg: DraftMsg,
        peer_wire: u16,
    ) -> Result<SubmitOutcome> {
        let can_defer = peer_wire >= 4;
        let id = msg.session;
        // Conservation audit: every draft entering here is counted
        // once, and every return path below lands it in exactly one
        // disposition bucket (rounds / cancelled / orphaned / busy /
        // replayed / swallowed / still in flight).
        self.metrics.drafts_received += 1;
        if self.attachment_of.contains_key(&id)
            && self.attachment_of.get(&id) != Some(&attachment)
        {
            self.metrics.drafts_swallowed += 1;
            return Ok(SubmitOutcome::Swallowed);
        }
        // already-verified round: replay the cached verdict (covers
        // transport duplicates AND post-resume retransmits, including
        // the final round of an already-finished session)
        if let Some(v) = self.last_verdict.get(&id) {
            if msg.round == v.round {
                self.metrics.verdicts_replayed += 1;
                return Ok(SubmitOutcome::Replay(v.clone()));
            }
            if msg.round < v.round {
                self.metrics.drafts_swallowed += 1;
                return Ok(SubmitOutcome::Swallowed);
            }
        }
        if !self.sessions.contains_key(&id) {
            // a speculative round overtaken by its session's completion
            // (the head verdict finished the session while this draft
            // was in flight) — or by its session's HANDOFF to a fleet
            // sibling: wasted speculation, not a protocol error — the
            // tombstones prove the session existed
            if self.last_verdict.contains_key(&id) || self.redirected_ids.contains_key(&id) {
                self.metrics.drafts_cancelled += 1;
                self.metrics.draft_tokens_wasted += msg.tokens.len();
                return Ok(SubmitOutcome::Swallowed);
            }
            // error dispositions: the draft dies with its connection
            self.metrics.drafts_swallowed += 1;
            bail!("no session {id}");
        }
        if self.parked.contains_key(&id) {
            self.metrics.drafts_swallowed += 1;
            bail!("session {id} is parked (reconnect pending)");
        }
        // wire v8 tree tail: structurally valid, and greedy-only — the
        // edge never trees a stochastic round (tree reduction needs
        // per-path determinism to commit the best root path)
        if msg.is_tree() {
            if !msg.tree_valid() {
                self.metrics.drafts_swallowed += 1;
                bail!(
                    "session {id}: malformed tree topology ({} parents for {} tokens)",
                    msg.tree.len(),
                    msg.tokens.len()
                );
            }
            if msg.mode != VerifyMode::Greedy {
                self.metrics.drafts_swallowed += 1;
                bail!("session {id}: tree drafts require greedy verification");
            }
        }
        // remember the live connection's wire version: deferred rounds
        // promoted later (promote_ready) have no connection in hand
        self.wire_of.insert(id, peer_wire);
        if let Some(p) = self.pending.get(&id) {
            if p.round == msg.round {
                if p.tokens == msg.tokens && p.spec == msg.spec && p.tree == msg.tree {
                    // duplicated while still queued: the round runs
                    // once, but the NEWEST requester takes over the
                    // reply slot (its predecessor may be a dead
                    // connection's task); the duplicate copy itself is
                    // swallowed
                    self.metrics.drafts_swallowed += 1;
                    return Ok(SubmitOutcome::TakeOver);
                }
                // same round, DIFFERENT payload: a stale speculative
                // draft racing its own redraft (the redraft was already
                // admitted — only basis-valid drafts reach the window,
                // so the mismatched copy is the cancelled speculation,
                // arriving late through a reordered verify task)
                self.metrics.drafts_cancelled += 1;
                self.metrics.draft_tokens_wasted += msg.tokens.len();
                return Ok(SubmitOutcome::Swallowed);
            }
            if msg.round < p.round {
                self.metrics.drafts_swallowed += 1;
                return Ok(SubmitOutcome::Swallowed);
            }
            // pipelined draft for a future round (wire v3): park it
            // until every earlier round commits
            return self.defer(id, msg);
        }
        let expected = self.sessions[&id].rounds as u32;
        if msg.round > expected {
            return self.defer(id, msg);
        }
        // the session's next round: basis-check speculative drafts
        // against the committed prefix before the window sees them
        if !self.basis_valid(id, &msg) {
            self.metrics.drafts_cancelled += 1;
            self.metrics.draft_tokens_wasted += msg.tokens.len();
            return Ok(SubmitOutcome::Swallowed);
        }
        // fleet handoff (wire v5): a draining replica — or a targeted
        // rebalance — answers the session's NEXT head round with a
        // Redirect instead of a verdict. Placed after the
        // dedup/staleness/basis filters (a swallowed stale copy must
        // never trigger an export) and before admission (a handoff
        // beats a deferral: it permanently sheds the load). The whole
        // session is exported to the shared ledger here, so whichever
        // replica sees the edge's Resume next — the redirect target,
        // or this one if the edge cannot follow — reconstructs it.
        if peer_wire >= 5 {
            if let Some(addr) = self.redirect_target(id) {
                let resume_token = self.export_session(now_ms, id)?;
                // the head draft is answered with the redirect, not a
                // verdict: the edge redrafts it at the new replica
                self.metrics.drafts_swallowed += 1;
                return Ok(SubmitOutcome::Redirect { addr, resume_token });
            }
        }
        // admission control: a fresh head round arriving at the backlog
        // bound is deferred (after the dedup/staleness filters above, so
        // a Busy is only ever sent for a round that would genuinely have
        // consumed a new queue slot). Tier-1 sessions stop
        // `tier_reserve` slots early so priority tiers keep admission
        // headroom under a bulk flash crowd (wire v7).
        if can_defer && self.cfg.admission_queue > 0 {
            let bound = self.admission_bound_for(id);
            if self.pending.len() >= bound {
                self.metrics.drafts_busy += 1;
                return Ok(SubmitOutcome::Busy {
                    retry_after_ms: self.busy_retry_after_ms(),
                });
            }
        }
        if !msg.spec.is_empty() {
            self.metrics.rounds_pipelined += 1;
        }
        self.metrics.bytes_up += msg.air_bytes();
        self.arrived.insert(id, now_ms);
        self.pending.insert(id, msg);
        Ok(SubmitOutcome::Queued(self.batch_offer(now_ms, id)))
    }

    // --- batcher dispatch (windowed vs continuous) --------------------

    /// True in continuous (rolling-admission) mode.
    fn continuous(&self) -> bool {
        self.cfg.batch_mode == BatchMode::Continuous
    }

    /// Route one admitted draft to the active batcher. Windowed mode is
    /// the classic close-the-window offer; continuous mode seats the
    /// draft in a free verification slot immediately (KV pages
    /// permitting) and otherwise parks it in the rolling FIFO until a
    /// verdict frees a slot.
    fn batch_offer(&mut self, now_ms: f64, id: u32) -> BatchDecision {
        if !self.continuous() {
            return self.window.offer(now_ms, id);
        }
        if self.slots.free_slots() > 0 && self.reserve_slot_kv(id) {
            self.slots.admit(now_ms, id)
        } else {
            self.slots.enqueue(id)
        }
    }

    /// Drop a voided member (dead link, reconnect takeover, abort,
    /// export) from whichever batcher holds it, returning its KV pages.
    fn batch_remove(&mut self, id: u32) {
        self.window.remove(id);
        self.slots.remove(id);
        self.release_slot_kv(id);
    }

    /// Take the batch a close should verify, in admission order.
    fn batch_take(&mut self) -> Vec<u32> {
        if self.continuous() {
            self.slots.take()
        } else {
            self.window.close()
        }
    }

    /// Continuous admission gate: reserve KV pool pages covering `id`'s
    /// full slot row — committed prefix + pending draft nodes + one
    /// correction token per root→leaf row (a wire v8 tree draft fans
    /// out into `n_leaves` verification rows, each of which may append
    /// its own correction; counting chains instead would under-reserve
    /// oversized tree admissions). A sequence larger than the ENTIRE
    /// pool is admitted unreserved (refusing it forever would wedge the
    /// session; the pool bounds aggregate residency, not one row's
    /// length).
    fn reserve_slot_kv(&mut self, id: u32) -> bool {
        let need = match (self.sessions.get(&id), self.pending.get(&id)) {
            (Some(core), Some(msg)) => {
                core.committed.len() + msg.tokens.len() + msg.n_leaves().max(1)
            }
            // nothing to back (defensive: offers always follow a
            // pending insert) — admit rather than wedge
            _ => return true,
        };
        if self.kv_pool.pages_for(need) > self.kv_pool.capacity() {
            return true;
        }
        let mut lease = match self.kv_leases.remove(&id) {
            Some(l) => l,
            None => self.kv_pool.lease(),
        };
        match self.kv_pool.grow(&mut lease, need) {
            Ok(()) => {
                self.kv_leases.insert(id, lease);
                true
            }
            Err(_) => {
                self.kv_pool.release(lease);
                false
            }
        }
    }

    /// Return `id`'s slot pages to the pool (verdict applied, or the
    /// draft was voided). No-op when the session holds no lease.
    fn release_slot_kv(&mut self, id: u32) {
        if let Some(lease) = self.kv_leases.remove(&id) {
            self.kv_pool.release(lease);
        }
    }

    /// Continuous mode: admit FIFO waiters into free verification slots,
    /// strictly in arrival order — stopping at the first whose KV
    /// reservation the pool cannot cover yet (skipping ahead would
    /// starve long sequences). Waiters whose draft was voided
    /// underneath them are discarded. Returns true when at least one
    /// waiter took a slot, i.e. the caller owes a flush.
    pub fn refill_slots(&mut self, now_ms: f64) -> bool {
        if !self.continuous() {
            return false;
        }
        let mut admitted = false;
        while self.slots.free_slots() > 0 {
            let Some(id) = self.slots.peek_waiter() else { break };
            if !self.pending.contains_key(&id) {
                self.slots.pop_waiter();
                continue;
            }
            if !self.reserve_slot_kv(id) {
                break;
            }
            self.slots.pop_waiter();
            let _ = self.slots.admit(now_ms, id);
            admitted = true;
        }
        admitted
    }

    /// Continuous mode: are there slot occupants a flush should verify
    /// now? (Windowed mode answers false — its deadlines drive closes.)
    pub fn batch_ready(&self) -> bool {
        self.continuous() && self.slots.occupied_len() > 0
    }

    /// Park a pipelined draft for a future round (ascending round
    /// order, retransmit-deduped, depth-bounded).
    fn defer(&mut self, id: u32, msg: DraftMsg) -> Result<SubmitOutcome> {
        let in_window = usize::from(self.pending.contains_key(&id));
        let q = self.queued.entry(id).or_default();
        if let Some(pos) = q.iter().position(|m| m.round == msg.round) {
            // identical payload: a transport retransmit — the round
            // stays queued once, the newest waiter takes the reply slot
            if q[pos].tokens == msg.tokens && q[pos].spec == msg.spec && q[pos].tree == msg.tree
            {
                q[pos] = msg;
                self.metrics.drafts_swallowed += 1;
                return Ok(SubmitOutcome::TakeOver);
            }
            // same round, DIFFERENT payload: a stale pre-cancel copy
            // racing the fresh redraft chain through reordered verify
            // tasks. basis_len is the committed length at launch and
            // committed is append-only, so the LARGER basis is the
            // later (fresh) launch — keep it, count the stale copy as
            // cancelled speculation either way.
            if msg.basis_len > q[pos].basis_len {
                self.metrics.drafts_cancelled += 1;
                self.metrics.draft_tokens_wasted += q[pos].tokens.len();
                q[pos] = msg;
                return Ok(SubmitOutcome::TakeOver);
            }
            self.metrics.drafts_cancelled += 1;
            self.metrics.draft_tokens_wasted += msg.tokens.len();
            return Ok(SubmitOutcome::Swallowed);
        }
        if q.len() + in_window >= super::pipeline::MAX_PIPELINE_DEPTH {
            self.metrics.drafts_swallowed += 1;
            bail!(
                "session {id}: more than {} rounds in flight (protocol violation)",
                super::pipeline::MAX_PIPELINE_DEPTH
            );
        }
        let pos = q
            .iter()
            .position(|m| m.round > msg.round)
            .unwrap_or(q.len());
        q.insert(pos, msg);
        Ok(SubmitOutcome::Deferred)
    }

    /// Wire-v3 basis check: a speculative draft is verifiable only when
    /// the committed sequence equals EXACTLY `committed[..basis_len] ++
    /// spec` — in which case, for a pure draft source, its tokens are
    /// byte-identical to the draft a sequential edge would have produced
    /// from the true committed prefix. Empty-spec drafts (v2 peers and
    /// head rounds) carry no assumption and pass trivially.
    fn basis_valid(&self, id: u32, msg: &DraftMsg) -> bool {
        if msg.spec.is_empty() {
            return true;
        }
        let Some(core) = self.sessions.get(&id) else {
            return false;
        };
        // subtract, never add: a hostile basis_len must not overflow
        // (debug panic) or wrap past the length check (release, then an
        // out-of-bounds slice) — either would kill the shared verifier
        // thread
        let basis = msg.basis_len as usize;
        basis <= core.committed.len()
            && core.committed.len() - basis == msg.spec.len()
            && core.committed[basis..] == msg.spec[..]
    }

    /// Where `id`'s next head round should be handed off, if anywhere:
    /// a targeted rebalance entry wins over the drain target; both need
    /// a ledger, and a token this replica already bounced once (within
    /// the grace window) is never bounced again — the edge may have
    /// resumed in place because it cannot follow redirects, and it must
    /// make progress.
    fn redirect_target(&self, id: u32) -> Option<String> {
        self.ledger.as_ref()?;
        let token = self.token_of.get(&id)?;
        if self.redirected_tokens.contains_key(token) {
            return None;
        }
        self.redirect_sessions
            .get(&id)
            .cloned()
            .or_else(|| self.redirect_all_to.clone())
    }

    /// Hand one live session off to the fleet: strip every local trace
    /// (backend session, token maps, window membership) and publish the
    /// portable remainder — committed sequence, prompt boundary,
    /// budget, counters — under its resume token in the shared ledger.
    /// Two tombstones stay behind for one grace window: the verdict
    /// replay cache (late duplicates of already-verified rounds still
    /// replay) and a redirected-id marker (in-flight speculative drafts
    /// of the handed-off session are swallowed, not fatal).
    fn export_session(&mut self, now_ms: f64, id: u32) -> Result<u64> {
        let ledger = self
            .ledger
            .clone()
            .ok_or_else(|| anyhow!("no fleet ledger configured"))?;
        let core = self
            .sessions
            .remove(&id)
            .ok_or_else(|| anyhow!("no session {id} to export"))?;
        let core_rounds = core.rounds;
        let token = self
            .token_of
            .remove(&id)
            .ok_or_else(|| anyhow!("session {id} has no resume token"))?;
        self.session_of_token.remove(&token);
        self.drop_pending(id);
        self.drop_queued(id);
        self.batch_remove(id);
        self.parked.remove(&id);
        if let Some(n) = self.nonce_of.remove(&id) {
            self.open_nonces.remove(&n);
        }
        self.attachment_of.remove(&id);
        self.redirect_sessions.remove(&id);
        self.wire_of.remove(&id);
        self.tier_of.remove(&id);
        self.profile_of.remove(&id);
        self.backend.end_session(id);
        let deadline = now_ms + self.cfg.resume_grace_ms;
        self.redirected_ids.insert(id, deadline);
        self.next_sweep_ms = self.next_sweep_ms.min(deadline);
        let seq = ledger.export(
            token,
            PortableSession {
                committed: core.committed,
                prompt_len: core.prompt_len,
                max_new: core.max_new,
                rounds: core.rounds,
                accepted: core.accepted,
                drafted: core.drafted,
                done: core.done,
            },
            now_ms,
        );
        self.redirected_tokens.insert(token, (deadline, seq));
        self.metrics.sessions_redirected += 1;
        if let Some(tr) = &self.cfg.trace {
            let round = core_rounds as u32;
            tr.record(id, round, SpanKind::Redirect, 0.0, 0, 0);
            tr.record(id, round, SpanKind::Export, 0.0, 0, 0);
        }
        Ok(token)
    }

    /// Reconstruct a handed-off session from its ledger state (the
    /// other half of [`VerifierCore::export_session`], running on the
    /// redirect target — or on the exporting replica itself when the
    /// edge resumed in place). A fresh local id and attachment epoch
    /// are minted; the resume token is preserved, so a second handoff
    /// keeps working. On any failure the entry is put back — with its
    /// ORIGINAL export timestamp, so repeated bad resumes cannot keep
    /// an abandoned entry's TTL fresh — so a bad resume position cannot
    /// destroy the only copy of the session.
    fn import_session(
        &mut self,
        token: u64,
        p: PortableSession,
        committed_len: usize,
        exported_at_ms: f64,
    ) -> Result<ResumeInfo> {
        let floor = p.prompt_len.min(p.committed.len());
        if committed_len < floor || committed_len > p.committed.len() {
            let range = format!("{floor}..={}", p.committed.len());
            if let Some(l) = &self.ledger {
                l.export(token, p, exported_at_ms);
            }
            bail!("resume position {committed_len} out of range ({range})");
        }
        if p.done {
            // finished before the handoff completed (only reachable
            // with an external ledger writer — in-tree exports are
            // always live): answer like a finished residue, and put
            // the entry BACK so a lost ResumeAck can be replayed — the
            // import above consumed the only copy, and unlike
            // close_window's FinishedResidue there is no clock here to
            // arm a local grace window with.
            self.metrics.sessions_imported += 1;
            self.metrics.sessions_imported_done += 1;
            self.metrics.sessions_resumed += 1;
            let info = ResumeInfo {
                session: 0,
                attachment: 0,
                committed_len: p.committed.len(),
                tail: p.committed[committed_len..].to_vec(),
                rounds: p.rounds,
                target_seq: self.backend.version_seq(),
                done: true,
            };
            if let Some(l) = &self.ledger {
                l.export(token, p, exported_at_ms);
            }
            return Ok(info);
        }
        let id = self.next_id;
        self.next_id += 1;
        if let Err(e) = self.backend.start_session(id, &p.committed) {
            if let Some(l) = &self.ledger {
                l.export(token, p, exported_at_ms);
            }
            return Err(e);
        }
        let tail = p.committed[committed_len..].to_vec();
        let info = ResumeInfo {
            session: id,
            attachment: 0,
            committed_len: p.committed.len(),
            tail,
            rounds: p.rounds,
            target_seq: self.backend.version_seq(),
            done: false,
        };
        self.sessions.insert(
            id,
            SessionCore::restore(
                id,
                p.committed,
                p.prompt_len,
                p.max_new,
                p.rounds,
                p.accepted,
                p.drafted,
                false,
            ),
        );
        self.token_of.insert(id, token);
        self.session_of_token.insert(token, id);
        self.metrics.sessions_imported += 1;
        self.metrics.sessions_resumed += 1;
        if let Some(tr) = &self.cfg.trace {
            tr.record(id, info.rounds as u32, SpanKind::Import, 0.0, 0, 0);
        }
        Ok(ResumeInfo {
            attachment: self.next_attachment(id),
            ..info
        })
    }

    /// After a window close committed fresh verdicts: basis-check each
    /// affected session's queued next round and promote the valid ones
    /// into the (new) batch window; a broken basis voids the round AND
    /// everything chained behind it. Returns the batch decisions the
    /// caller must schedule, the (session, round) keys of discarded
    /// drafts whose reply waiters are void, and the fleet handoffs
    /// (session, round, addr, resume_token) fired at promotion time —
    /// a saturated pipeline's next head round always arrives EARLY and
    /// parks in the speculative queue, so a drain that only checked
    /// `submit` could never shed it; the promotion point is the same
    /// head-round boundary, just reached from the queue.
    #[allow(clippy::type_complexity)]
    pub fn promote_ready(
        &mut self,
        now_ms: f64,
    ) -> (
        Vec<BatchDecision>,
        Vec<(u32, u32)>,
        Vec<(u32, u32, String, u64)>,
    ) {
        let mut decisions = Vec::new();
        let mut dropped = Vec::new();
        let mut redirects = Vec::new();
        let ids: Vec<u32> = self.queued.keys().copied().collect();
        for id in ids {
            if self.pending.contains_key(&id) || self.parked.contains_key(&id) {
                continue;
            }
            let mut q = self.queued.remove(&id).unwrap_or_default();
            let Some(expected) = self.sessions.get(&id).map(|c| c.rounds as u32) else {
                // the session finished (or was evicted) underneath its
                // speculative queue: every queued round is waste
                for m in q {
                    self.metrics.drafts_cancelled += 1;
                    self.metrics.draft_tokens_wasted += m.tokens.len();
                    dropped.push((id, m.round));
                }
                continue;
            };
            // duplicates of already-resolved rounds: quietly drop
            while q.first().is_some_and(|m| m.round < expected) {
                let m = q.remove(0);
                self.metrics.drafts_swallowed += 1;
                dropped.push((id, m.round));
            }
            if !q.first().is_some_and(|m| m.round == expected) {
                if !q.is_empty() {
                    self.queued.insert(id, q);
                }
                continue;
            }
            let msg = q.remove(0);
            if self.basis_valid(id, &msg) {
                // fleet drain at the promotion boundary: same gate as
                // submit (peer wire >= 5, once per grace window), same
                // export — the promoted draft and everything chained
                // behind it die with the handoff (the edge redrafts
                // byte-identically from the committed prefix after its
                // resume)
                if self.wire_of.get(&id).copied().unwrap_or(0) >= 5 {
                    if let Some(addr) = self.redirect_target(id) {
                        if let Ok(token) = self.export_session(now_ms, id) {
                            self.metrics.drafts_cancelled += 1;
                            self.metrics.draft_tokens_wasted += msg.tokens.len();
                            for m in q {
                                self.metrics.drafts_cancelled += 1;
                                self.metrics.draft_tokens_wasted += m.tokens.len();
                                dropped.push((id, m.round));
                            }
                            redirects.push((id, msg.round, addr, token));
                            continue;
                        }
                    }
                }
                if !msg.spec.is_empty() {
                    self.metrics.rounds_pipelined += 1;
                }
                self.metrics.bytes_up += msg.air_bytes();
                self.arrived.insert(id, now_ms);
                self.pending.insert(id, msg);
                decisions.push(self.batch_offer(now_ms, id));
                if !q.is_empty() {
                    self.queued.insert(id, q);
                }
            } else {
                // broken basis: this round and everything chained after
                // it were drafted from a prefix that will never exist
                self.metrics.drafts_cancelled += 1;
                self.metrics.draft_tokens_wasted += msg.tokens.len();
                dropped.push((id, msg.round));
                for m in q {
                    self.metrics.drafts_cancelled += 1;
                    self.metrics.draft_tokens_wasted += m.tokens.len();
                    dropped.push((id, m.round));
                }
            }
        }
        (decisions, dropped, redirects)
    }

    /// Edge `Cancel` (wire v3): retract queued speculative rounds
    /// `>= round`. Advisory — stale drafts are also discarded by the
    /// basis check — so a lost, late, or duplicated Cancel is harmless.
    /// Never touches the batch window: a round already admitted there
    /// passed its basis check, and the edge never cancels a valid
    /// round. Returns the (session, round) keys whose reply waiters are
    /// void.
    pub fn cancel(&mut self, id: u32, attachment: u64, round: u32) -> Vec<(u32, u32)> {
        if self.attachment_of.contains_key(&id)
            && self.attachment_of.get(&id) != Some(&attachment)
        {
            return Vec::new();
        }
        let mut dropped = Vec::new();
        if let Some(q) = self.queued.remove(&id) {
            let mut kept = Vec::with_capacity(q.len());
            for m in q {
                if m.round >= round {
                    self.metrics.drafts_cancelled += 1;
                    self.metrics.draft_tokens_wasted += m.tokens.len();
                    dropped.push((id, m.round));
                } else {
                    kept.push(m);
                }
            }
            if !kept.is_empty() {
                self.queued.insert(id, kept);
            }
        }
        dropped
    }

    /// Suggested retry horizon for a `Busy` deferral: queue-depth
    /// adaptive — one batching window per backlog's worth of
    /// `max_batch`, so backoff pressure tracks how long the queue will
    /// actually take to drain instead of a static one-window guess
    /// (`crate::autoscale::adaptive_retry_after_ms`; the load harness
    /// runs the identical formula).
    fn busy_retry_after_ms(&self) -> u32 {
        crate::autoscale::adaptive_retry_after_ms(
            self.cfg.window_ms,
            self.pending.len(),
            self.cfg.max_batch,
        )
    }

    /// Effective admission bound for one session: tier > 1 sessions may
    /// fill the whole queue; tier-1 traffic stops `tier_reserve` slots
    /// early (clamped so at least one slot always remains reachable by
    /// tier 1 — a reservation must shape pressure, not starve bulk
    /// traffic outright).
    fn admission_bound_for(&self, id: u32) -> usize {
        let cap = self.cfg.admission_queue;
        if self.tier_of.get(&id).copied().unwrap_or(1) > 1 {
            return cap;
        }
        let reserve = self.cfg.tier_reserve.min(cap.saturating_sub(1));
        cap - reserve
    }

    /// Close the open window and verify its members as ONE batch:
    /// **plan** (pull each member's pending draft + live session, count
    /// the orphans), **execute** (a single `verify_batch` call — the
    /// backend stacks planner buckets into `[B, K]` forwards, one
    /// amortized T_base per bucket on a real accelerator), **apply**
    /// (commit verdicts with exactly the eviction/residue/replay
    /// bookkeeping of the per-session path). Sessions that finish are
    /// torn down server-side (leaving a grace-window residue for late
    /// resumes); the verdict's `eos` flag tells the edge to stop.
    pub fn close_window(&mut self, now_ms: f64) -> Result<Vec<(u32, VerifyMsg)>> {
        let members = self.batch_take();
        if members.is_empty() {
            return Ok(Vec::new());
        }
        // ---- plan --------------------------------------------------
        self.metrics.queue_depth.add(self.pending.len() as f64);
        let mut jobs: Vec<(u32, DraftMsg, f64)> = Vec::with_capacity(members.len());
        for id in members {
            let arrived = self.arrived.remove(&id);
            // detached mid-window (link died) or torn down underneath
            // the window: nothing to verify — but never silently. The
            // orphan counter is the only trace these drafts leave.
            let Some(msg) = self.pending.remove(&id) else {
                self.metrics.drafts_orphaned += 1;
                self.release_slot_kv(id);
                continue;
            };
            if !self.sessions.contains_key(&id) {
                self.metrics.drafts_orphaned += 1;
                self.release_slot_kv(id);
                continue;
            }
            let wait_ms = (now_ms - arrived.unwrap_or(now_ms)).max(0.0);
            jobs.push((id, msg, wait_ms));
        }
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        let batch = jobs.len();
        let total_draft: usize = jobs.iter().map(|(_, m, _)| m.tokens.len()).sum();
        let max_k = jobs.iter().map(|(_, m, _)| m.tokens.len()).max().unwrap_or(0);
        // ---- expand: tree drafts fan out into ragged rows ------------
        // A wire v8 tree draft becomes one verification row per
        // root→leaf path, all sharing the session id — legal only
        // against backends whose rows are independent pure functions of
        // (committed, draft) (`supports_tree_rows`); everything else
        // verifies the first root path (the main chain — leaf node
        // indices ascend and the edge's comb appends alternates after
        // the chain) and stays effectively linear. Rows are contiguous
        // per job and in ascending leaf order, so the reduction below
        // walks them in one pass. `None` paths borrow the draft's own
        // token vector — linear drafts allocate nothing extra.
        let tree_ok = self.backend.supports_tree_rows();
        let mut rows: Vec<(usize, Option<u8>, Option<Vec<i32>>)> =
            Vec::with_capacity(jobs.len());
        for (ji, (_, msg, _)) in jobs.iter().enumerate() {
            if !msg.is_tree() {
                rows.push((ji, None, None));
            } else if tree_ok {
                for leaf in msg.tree_leaves() {
                    rows.push((ji, Some(leaf), Some(msg.tree_path(leaf))));
                }
            } else {
                let leaf = msg.tree_leaves()[0];
                rows.push((ji, Some(leaf), Some(msg.tree_path(leaf))));
            }
        }
        let n_rows = rows.len();
        // distinct planner bucket classes = stacked [B, K] device
        // dispatches this close (mirrors `plan_buckets`: every row pads
        // to the next power-of-two K and rides one stacked call per
        // class on the engine path). Counted over ROWS: a bucket-
        // aligned comb's alternate paths land in the chain's existing
        // classes, so tree speculation adds rows without adding
        // dispatches.
        let stacked = {
            let mut kinds: Vec<usize> = rows
                .iter()
                .map(|(ji, _, path)| {
                    bucket_k(path.as_ref().map_or(jobs[*ji].1.tokens.len(), Vec::len))
                })
                .collect();
            kinds.sort_unstable();
            kinds.dedup();
            kinds.len()
        };
        for (id, msg, wait_ms) in &jobs {
            self.metrics.latency.queue_ms.record(*wait_ms);
            if let Some(tr) = &self.cfg.trace {
                tr.record(*id, msg.round, SpanKind::QueueWait, *wait_ms, 0, 0);
                tr.record(
                    *id,
                    msg.round,
                    SpanKind::BucketPlan,
                    0.0,
                    batch as u32,
                    bucket_k(max_k) as u32,
                );
            }
        }

        // ---- execute: ONE stacked call over the whole window --------
        // Compact wire: full draft distributions never cross the air —
        // the backend reconstructs them cloud-side (point mass / its
        // own forward pass; see the verify_batch contract in
        // serve::backend on bucketing, padding and the Regime-B
        // distribution reconstruction).
        let reqs: Vec<BatchVerifyReq> = rows
            .iter()
            .map(|(ji, _, path)| {
                let (id, msg, _) = &jobs[*ji];
                BatchVerifyReq {
                    id: *id,
                    committed: &self.sessions[id].committed,
                    draft: path.as_deref().unwrap_or(&msg.tokens),
                    mode: msg.mode,
                }
            })
            .collect();
        let t_exec = Instant::now();
        let verdicts = self.backend.verify_batch(
            &reqs,
            self.cfg.temperature,
            self.cfg.top_p,
            &mut self.rng,
        )?;
        let verify_ms = t_exec.elapsed().as_secs_f64() * 1e3;
        drop(reqs);
        if verdicts.len() != n_rows {
            bail!(
                "backend returned {} verdicts for {} rows",
                verdicts.len(),
                n_rows
            );
        }
        // counted only once the backend actually produced verdicts, so
        // `batches` and the verify-latency histogram stay in lockstep
        // (the conservation audit pins them equal)
        self.metrics.note_batch(batch);
        self.metrics.stacked_dispatches += stacked;
        self.metrics.verify_rows += n_rows;
        self.metrics.latency.verify_ms.record(verify_ms);

        // ---- apply ------------------------------------------------
        // Reduce each job's rows to one verdict: the deepest accepted
        // prefix (max tau) wins; ties break toward the SMALLEST row
        // index. The main chain is always a tree job's first row, so a
        // tie — including every drift-free round — commits exactly the
        // linear path: branching never changes committed bytes unless
        // an alternate strictly beats the chain.
        let mut out = Vec::with_capacity(jobs.len());
        let mut row_iter = rows.into_iter().zip(verdicts).peekable();
        for (ji, (id, msg, wait_ms)) in jobs.into_iter().enumerate() {
            let mut winner = None;
            while row_iter.peek().map_or(false, |((rj, _, _), _)| *rj == ji) {
                let ((_, leaf, path), v) = row_iter.next().expect("peeked row");
                if winner.as_ref().map_or(true, |w: &(_, _, _)| v.tau > w.2.tau) {
                    winner = Some((leaf, path, v));
                }
            }
            let Some((leaf, path, v)) = winner else {
                continue; // unreachable: every job planned >= 1 row
            };
            let Some(core) = self.sessions.get_mut(&id) else {
                continue; // unreachable: planned against live sessions
            };
            let draft: &[i32] = path.as_deref().unwrap_or(&msg.tokens);
            if msg.is_tree() {
                self.metrics.tree_rounds += 1;
                // per-row bookkeeping in the backend left the LAST
                // row's acceptance as the session's length; re-assert
                // the winning path's before reading capacity
                self.backend
                    .note_committed(id, core.committed.len() + v.tau + 1);
            }
            let out_of_capacity =
                self.backend.remaining_capacity(id) <= self.cfg.capacity_floor;
            let finished = core.apply_verdict(draft, v.tau, v.correction, v.eos, out_of_capacity);
            let vmsg = VerifyMsg {
                session: id,
                round: msg.round,
                tau: v.tau as u8,
                correction: v.correction,
                eos: finished,
                leaf: if msg.is_tree() { leaf } else { None },
            };
            self.metrics.note_round(draft.len(), v.tau);
            self.metrics.bytes_down += vmsg.air_bytes();
            // cloud-observed round latency: admission → verdict ready
            self.metrics.latency.round_ms.record(wait_ms + verify_ms);
            if let Some(tr) = &self.cfg.trace {
                tr.record(
                    id,
                    msg.round,
                    SpanKind::VerifyBatch,
                    verify_ms,
                    batch as u32,
                    total_draft as u32,
                );
                tr.record(id, msg.round, SpanKind::Commit, 0.0, v.tau as u32 + 1, 0);
            }
            self.last_verdict.insert(id, vmsg.clone());
            if finished {
                self.metrics.finish_session(core);
                let residue = FinishedResidue {
                    session: id,
                    committed: core.committed.clone(),
                    rounds: core.rounds,
                    deadline_ms: now_ms + self.cfg.resume_grace_ms,
                };
                self.backend.end_session(id);
                self.sessions.remove(&id);
                self.parked.remove(&id);
                if let Some(tok) = self.token_of.remove(&id) {
                    self.session_of_token.remove(&tok);
                    self.next_sweep_ms = self.next_sweep_ms.min(residue.deadline_ms);
                    self.finished.insert(tok, residue);
                }
                if let Some(n) = self.nonce_of.remove(&id) {
                    self.open_nonces.remove(&n);
                }
                self.attachment_of.remove(&id);
                self.wire_of.remove(&id);
                self.tier_of.remove(&id);
                self.profile_of.remove(&id);
                self.redirect_sessions.remove(&id);
            }
            // continuous mode: the verdict frees the slot — its KV
            // pages go back to the pool before the FIFO refill below
            // (no-op in windowed mode, which holds no leases)
            self.release_slot_kv(id);
            out.push((id, vmsg));
        }
        if self.continuous() {
            // a close is the slot table's drain point: record how full
            // the stacked executor ran — in ROWS, since a tree draft's
            // leaves each occupy an executor row (counting chains would
            // under-report occupancy under tree speculation) — then
            // re-seat FIFO waiters with the pages the verdicts just
            // returned
            self.metrics.slot_occupancy.add(n_rows as f64);
            self.refill_slots(now_ms);
        }
        Ok(out)
    }

    /// The connection carrying this session died: PARK it for the grace
    /// window instead of aborting. `attachment` must be the epoch that
    /// connection was handed at open/resume — a stale connection's late
    /// teardown (its session was already stolen by a reconnect) is a
    /// no-op. Returns true when the session was newly parked.
    pub fn detach(&mut self, now_ms: f64, id: u32, attachment: u64) -> bool {
        if self.attachment_of.get(&id) != Some(&attachment) {
            return false; // a newer connection owns this session now
        }
        if !self.sessions.contains_key(&id) || self.parked.contains_key(&id) {
            return false;
        }
        // an in-flight draft whose reply can no longer be delivered is
        // void — the resume handshake re-synchronizes instead (and the
        // id leaves the open window so a resubmit cannot double-count);
        // queued speculative rounds from the dead link die with it
        self.drop_pending(id);
        self.drop_queued(id);
        self.batch_remove(id);
        let deadline = now_ms + self.cfg.resume_grace_ms;
        self.next_sweep_ms = self.next_sweep_ms.min(deadline);
        self.parked.insert(id, deadline);
        self.metrics.sessions_parked += 1;
        true
    }

    /// Reattach a session by resume token. Deliberately does NOT
    /// re-check the grace deadline: if the eviction sweep has not
    /// actually reaped the session yet, the reconnect wins (see module
    /// docs on the resume/eviction race).
    pub fn resume(&mut self, token: u64, committed_len: usize) -> Result<ResumeInfo> {
        if let Some(fin) = self.finished.get(&token) {
            if committed_len > fin.committed.len() {
                bail!(
                    "resume position {committed_len} beyond committed length {}",
                    fin.committed.len()
                );
            }
            self.metrics.sessions_resumed += 1;
            return Ok(ResumeInfo {
                session: fin.session,
                attachment: 0, // finished: nothing left to detach
                committed_len: fin.committed.len(),
                tail: fin.committed[committed_len..].to_vec(),
                rounds: fin.rounds,
                target_seq: self.backend.version_seq(),
                done: true,
            });
        }
        let Some(&id) = self.session_of_token.get(&token) else {
            // fleet handoff: the session may be parked in the shared
            // ledger — exported by a draining sibling whose Redirect
            // pointed here, or by THIS replica if the edge could not
            // follow the redirect and resumed in place
            if let Some((at, p)) = self.ledger.as_ref().and_then(|l| l.import_timed(token)) {
                return self.import_session(token, p, committed_len, at);
            }
            bail!(UNKNOWN_RESUME_TOKEN);
        };
        let core = self
            .sessions
            .get(&id)
            .ok_or_else(|| anyhow!("resume token maps to missing session {id}"))?;
        if committed_len < core.prompt_len || committed_len > core.committed.len() {
            bail!(
                "resume position {committed_len} out of range ({}..={})",
                core.prompt_len,
                core.committed.len()
            );
        }
        let mut info = ResumeInfo {
            session: id,
            attachment: 0,
            committed_len: core.committed.len(),
            tail: core.committed_tail(committed_len).to_vec(),
            rounds: core.rounds,
            target_seq: self.backend.version_seq(),
            done: false,
        };
        // un-park; also steals from a half-dead connection (new link
        // wins, and the bumped attachment epoch makes the old
        // connection's eventual detach a no-op); the old attachment's
        // speculative queue is void — the edge re-pipelines from the
        // committed prefix it just synced
        self.parked.remove(&id);
        self.drop_pending(id);
        self.drop_queued(id);
        self.batch_remove(id);
        info.attachment = self.next_attachment(id);
        self.metrics.sessions_resumed += 1;
        Ok(info)
    }

    /// Reap parked sessions and finished residues whose grace deadline
    /// is STRICTLY in the past. Attached sessions are never touched.
    /// O(1) until the earliest pending deadline passes (the verifier
    /// loop calls this every iteration). Also drives the fleet-ledger
    /// TTL sweep (own gate — it must fire even when nothing is parked
    /// locally).
    pub fn evict_expired(&mut self, now_ms: f64) -> usize {
        self.sweep_ledger_ttl(now_ms);
        if now_ms <= self.next_sweep_ms {
            return 0;
        }
        let expired: Vec<u32> = self
            .parked
            .iter()
            .filter(|&(_, &deadline)| now_ms > deadline)
            .map(|(&id, _)| id)
            .collect();
        for &id in &expired {
            self.parked.remove(&id);
            self.drop_pending(id);
            self.drop_queued(id);
            self.last_verdict.remove(&id);
            self.sessions.remove(&id);
            if let Some(tok) = self.token_of.remove(&id) {
                self.session_of_token.remove(&tok);
            }
            if let Some(n) = self.nonce_of.remove(&id) {
                self.open_nonces.remove(&n);
            }
            self.attachment_of.remove(&id);
            self.wire_of.remove(&id);
            self.tier_of.remove(&id);
            self.profile_of.remove(&id);
            self.redirect_sessions.remove(&id);
            self.release_slot_kv(id);
            self.backend.end_session(id);
            self.metrics.sessions_evicted += 1;
        }
        let expired_residues: Vec<u64> = self
            .finished
            .iter()
            .filter(|&(_, f)| now_ms > f.deadline_ms)
            .map(|(&t, _)| t)
            .collect();
        for t in expired_residues {
            if let Some(f) = self.finished.remove(&t) {
                self.last_verdict.remove(&f.session);
                self.metrics.residues_expired += 1;
            }
        }
        // fleet-handoff tombstones expire with the same grace window:
        // past it, a late draft for an exported session is a genuine
        // protocol error again, and a long-lived re-imported session
        // becomes eligible for one more handoff. The exported id's
        // verdict-replay tombstone goes with it — no other cleanup path
        // ever fires for an exported session, so forgetting it here
        // would leak one cached VerifyMsg per handoff forever — and an
        // export the edge NEVER resumed is reaped from the shared
        // ledger (stamp-checked: an imported or re-exported entry is
        // left alone), so abandoned handoffs cannot pin committed
        // sequences fleet-wide.
        let last_verdict = &mut self.last_verdict;
        self.redirected_ids.retain(|id, d| {
            if now_ms <= *d {
                true
            } else {
                last_verdict.remove(id);
                false
            }
        });
        let ledger = self.ledger.clone();
        self.redirected_tokens.retain(|token, (d, seq)| {
            if now_ms <= *d {
                true
            } else {
                if let Some(l) = &ledger {
                    l.reap(*token, *seq);
                }
                false
            }
        });
        // Defensive invariant sweep: every open-nonce entry must name a
        // LIVE session (finish/evict/abort all clean their nonce up).
        // Enforcing it here — on the same periodic timer — means a
        // future edit that forgets one cleanup path degrades into a
        // bounded map instead of an unbounded leak on an idle cloud.
        let sessions = &self.sessions;
        self.open_nonces.retain(|_, id| sessions.contains_key(id));
        self.nonce_of.retain(|id, _| sessions.contains_key(id));
        // recompute the gate from what survived (resumes may have left
        // it stale-early, which only costs one extra sweep)
        self.next_sweep_ms = self
            .parked
            .values()
            .copied()
            .chain(self.finished.values().map(|f| f.deadline_ms))
            .chain(self.redirected_ids.values().copied())
            .chain(self.redirected_tokens.values().map(|(d, _)| *d))
            .fold(f64::INFINITY, f64::min);
        expired.len()
    }

    /// Fleet-ledger TTL sweep (ROADMAP item 3 satellite): age out
    /// shared-store entries whose EXPORTER died before its grace-window
    /// reap fired — the stamp-checked reap in [`VerifierCore::
    /// evict_expired`] covers this replica's own exports; the TTL
    /// covers everyone else's orphans. Runs behind its own gate,
    /// independent of `next_sweep_ms` (an otherwise-idle replica must
    /// still collect a dead sibling's orphans). The gate is re-armed to
    /// min(earliest entry expiry, now + TTL) — never later than any
    /// live entry's deadline, including entries exported AFTER this
    /// sweep — so at most one ledger walk per TTL period when idle.
    pub fn sweep_ledger_ttl(&mut self, now_ms: f64) -> usize {
        let Some(l) = &self.ledger else { return 0 };
        if now_ms <= self.next_ledger_sweep_ms {
            return 0;
        }
        let n = l.expire_before(now_ms, self.cfg.ledger_ttl_ms);
        self.metrics.ledger_expired += n;
        self.next_ledger_sweep_ms = l
            .next_expiry(self.cfg.ledger_ttl_ms)
            .min(now_ms + self.cfg.ledger_ttl_ms);
        n
    }

    /// Client explicitly gave up: drop the session without counting
    /// completion (and without a resume residue).
    pub fn abort_session(&mut self, id: u32) {
        if self.sessions.remove(&id).is_some() {
            self.drop_pending(id);
            self.drop_queued(id);
            self.batch_remove(id);
            self.parked.remove(&id);
            self.last_verdict.remove(&id);
            if let Some(tok) = self.token_of.remove(&id) {
                self.session_of_token.remove(&tok);
            }
            if let Some(n) = self.nonce_of.remove(&id) {
                self.open_nonces.remove(&n);
            }
            self.attachment_of.remove(&id);
            self.wire_of.remove(&id);
            self.tier_of.remove(&id);
            self.profile_of.remove(&id);
            self.redirect_sessions.remove(&id);
            self.backend.end_session(id);
            self.metrics.sessions_aborted += 1;
        }
    }

    /// Hot-swap the target version; live sessions keep their KV state.
    pub fn deploy(&mut self, version: &str) -> Result<u64> {
        let seq = self.backend.deploy(version)?;
        self.metrics.hot_swaps += 1;
        Ok(seq)
    }
}

// ---------------------------------------------------------------------
// Dedicated verifier thread + async handle
// ---------------------------------------------------------------------

/// What the connection layer owes the edge for one submitted draft.
#[derive(Debug, Clone)]
pub enum VerifyReply {
    /// A verdict to deliver as a `Verify` frame.
    Verdict(VerifyMsg),
    /// Admission-control deferral to deliver as a `Busy` frame (wire
    /// v4): the round was not admitted; the edge retries it.
    Busy {
        round: u32,
        retry_after_ms: u32,
    },
    /// Fleet handoff to deliver as a `Redirect` frame (wire v5): the
    /// session was exported to the shared ledger; the edge resumes on
    /// `addr` with `resume_token`.
    Redirect {
        addr: String,
        resume_token: u64,
    },
}

enum VerifierCmd {
    Open {
        prompt: Vec<i32>,
        max_new: usize,
        nonce: u64,
        /// QoS tier (wire v7 `Open::tier`; 1 = default/bulk).
        tier: u32,
        /// Device profile (wire v8 `Open::profile`; absent below v8).
        profile: Option<DeviceProfileMsg>,
        reply: oneshot::Sender<Result<OpenInfo>>,
    },
    Verify {
        id: u32,
        attachment: u64,
        msg: DraftMsg,
        /// The connection's negotiated wire version (>= 4 understands
        /// `Busy` deferrals, >= 5 can follow a fleet `Redirect`).
        wire: u16,
        reply: oneshot::Sender<Result<Option<VerifyReply>>>,
    },
    SetRedirect {
        target: Option<String>,
    },
    RedirectSession {
        id: u32,
        target: String,
    },
    RedirectSome {
        n: usize,
        target: String,
        reply: oneshot::Sender<Vec<u32>>,
    },
    Info {
        reply: oneshot::Sender<ReplicaTelemetry>,
    },
    Cancel {
        id: u32,
        attachment: u64,
        round: u32,
    },
    Detach {
        id: u32,
        attachment: u64,
    },
    Resume {
        token: u64,
        committed_len: usize,
        reply: oneshot::Sender<Result<ResumeInfo>>,
    },
    End {
        id: u32,
    },
    Deploy {
        version: String,
        reply: oneshot::Sender<Result<u64>>,
    },
    Stats {
        reply: oneshot::Sender<ServingMetrics>,
    },
    RejectedHandshake,
    Shutdown {
        reply: oneshot::Sender<ServingMetrics>,
    },
}

/// Cloneable async handle to the verifier thread. Dropping every handle
/// shuts the thread down (command channel disconnect).
#[derive(Clone)]
pub struct VerifierHandle {
    tx: std_mpsc::Sender<VerifierCmd>,
}

impl VerifierHandle {
    /// Spawn the verifier thread. `make_backend` runs ON the new thread,
    /// so `!Send` backends (PJRT) are constructed in place.
    pub fn spawn(
        cfg: VerifierConfig,
        make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    ) -> Result<VerifierHandle> {
        VerifierHandle::spawn_inner(cfg, None, make_backend)
    }

    /// [`VerifierHandle::spawn`] with a fleet handoff ledger attached:
    /// the replica can export sessions on `Redirect` and import
    /// sessions a sibling (or itself) exported. Every replica of one
    /// fleet shares one ledger (`FleetRegistry` hands out clones).
    pub fn spawn_with_ledger(
        cfg: VerifierConfig,
        ledger: SessionLedger,
        make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    ) -> Result<VerifierHandle> {
        VerifierHandle::spawn_inner(cfg, Some(ledger), make_backend)
    }

    fn spawn_inner(
        cfg: VerifierConfig,
        ledger: Option<SessionLedger>,
        make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    ) -> Result<VerifierHandle> {
        let (tx, rx) = std_mpsc::channel();
        let (ready_tx, ready_rx) = std_mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("flexspec-verifier".into())
            .spawn(move || {
                let backend = match make_backend() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut core = VerifierCore::new(cfg, backend);
                if let Some(l) = ledger {
                    core = core.with_ledger(l);
                }
                run_verifier(core, rx);
            })?;
        ready_rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow!("verifier thread failed to start"))??;
        Ok(VerifierHandle { tx })
    }

    fn post(&self, cmd: VerifierCmd) -> Result<()> {
        self.tx
            .send(cmd)
            .map_err(|_| anyhow!("verifier thread is gone"))
    }

    pub async fn open(&self, prompt: Vec<i32>, max_new: usize, nonce: u64) -> Result<OpenInfo> {
        self.open_tier(prompt, max_new, nonce, 1).await
    }

    /// [`VerifierHandle::open`] with an explicit QoS tier (wire v7):
    /// tier > 1 sessions bypass the `tier_reserve` admission headroom.
    pub async fn open_tier(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        nonce: u64,
        tier: u32,
    ) -> Result<OpenInfo> {
        self.open_profile(prompt, max_new, nonce, tier, None).await
    }

    /// [`VerifierHandle::open_tier`] with the peer's wire v8 device
    /// profile attached (telemetry + per-tier capacity accounting; the
    /// resource-aware speculation policy itself runs edge-side).
    pub async fn open_profile(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        nonce: u64,
        tier: u32,
        profile: Option<DeviceProfileMsg>,
    ) -> Result<OpenInfo> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Open {
            prompt,
            max_new,
            nonce,
            tier,
            profile,
            reply,
        })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))?
    }

    /// Verify one draft. `Ok(None)` means no reply is owed on the wire:
    /// the draft was a swallowed duplicate, or this waiter was
    /// superseded by a later retransmit of the same round (the newest
    /// requester delivers the verdict) — a dropped reply channel is
    /// therefore benign, not an error. `Ok(Some(VerifyReply::Busy))`
    /// means the admission queue turned the round away (only possible
    /// for peers that negotiated `wire >= 4`);
    /// `Ok(Some(VerifyReply::Redirect))` hands the session to a fleet
    /// sibling (only for peers with `wire >= 5`).
    pub async fn verify(
        &self,
        id: u32,
        attachment: u64,
        msg: DraftMsg,
        wire: u16,
    ) -> Result<Option<VerifyReply>> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Verify {
            id,
            attachment,
            msg,
            wire,
            reply,
        })?;
        match rx.await {
            Ok(res) => res,
            Err(_) => Ok(None),
        }
    }

    /// Fire-and-forget drain toggle: `Some(addr)` starts redirecting
    /// every redirect-capable session's next head round to `addr`
    /// (staged rollout / scale-down); `None` stops.
    pub fn set_redirect(&self, target: Option<String>) {
        let _ = self.post(VerifierCmd::SetRedirect { target });
    }

    /// Fire-and-forget targeted handoff of ONE session (rebalance).
    pub fn redirect_session(&self, id: u32, target: String) {
        let _ = self.post(VerifierCmd::RedirectSession { id, target });
    }

    /// Bulk targeted handoff (the autoscaler's rebalance actuator):
    /// mark up to `n` redirect-capable sessions for `target`, lowest
    /// ids first. Returns the ids actually marked.
    pub async fn redirect_some(&self, n: usize, target: String) -> Result<Vec<u32>> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::RedirectSome { n, target, reply })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))
    }

    /// Instantaneous replica telemetry (version, load, drain state) —
    /// what the fleet registry polls and the `ReplicaInfo` frame ships.
    pub async fn info(&self) -> Result<ReplicaTelemetry> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Info { reply })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))
    }

    /// Fire-and-forget retraction of queued speculative rounds
    /// `>= round` (wire v3 `Cancel`). A stale attachment's cancel is
    /// ignored, like its drafts.
    pub fn cancel(&self, id: u32, attachment: u64, round: u32) {
        let _ = self.post(VerifierCmd::Cancel {
            id,
            attachment,
            round,
        });
    }

    /// Fire-and-forget park (connection died; session may resume).
    /// `attachment` is the epoch this connection was handed — a stale
    /// detach after a steal is ignored.
    pub fn detach(&self, id: u32, attachment: u64) {
        let _ = self.post(VerifierCmd::Detach { id, attachment });
    }

    /// Reattach a parked (or stolen) session by resume token.
    pub async fn resume(&self, token: u64, committed_len: usize) -> Result<ResumeInfo> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Resume {
            token,
            committed_len,
            reply,
        })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))?
    }

    /// Fire-and-forget session teardown (client Bye / explicit abort).
    pub fn end(&self, id: u32) {
        let _ = self.post(VerifierCmd::End { id });
    }

    pub fn note_rejected_handshake(&self) {
        let _ = self.post(VerifierCmd::RejectedHandshake);
    }

    pub async fn deploy(&self, version: &str) -> Result<u64> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Deploy {
            version: version.to_string(),
            reply,
        })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))?
    }

    pub async fn stats(&self) -> Result<ServingMetrics> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Stats { reply })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))
    }

    /// Flush the open batch, stop the thread, return final metrics.
    pub async fn shutdown(&self) -> Result<ServingMetrics> {
        let (reply, rx) = oneshot::channel();
        self.post(VerifierCmd::Shutdown { reply })?;
        rx.await.map_err(|_| anyhow!("verifier dropped the reply"))
    }
}

/// Upper bound on one verifier-loop wait: parked sessions, finished
/// residues and nonce orphans are reaped on THIS periodic timer even
/// when no traffic flows and no batch deadline is armed — an idle cloud
/// must not depend on the next frame happening to arrive to bound its
/// residue maps.
const SWEEP_INTERVAL: Duration = Duration::from_millis(200);

fn run_verifier(mut core: VerifierCore, rx: std_mpsc::Receiver<VerifierCmd>) {
    let start = Instant::now();
    let now_ms = |start: &Instant| start.elapsed().as_secs_f64() * 1e3;
    // keyed by (session, round): with pipelining a session can have
    // several rounds awaiting replies at once
    let mut replies: HashMap<(u32, u32), oneshot::Sender<Result<Option<VerifyReply>>>> =
        HashMap::new();
    let mut deadline: Option<f64> = None;

    // Close the window, deliver its verdicts, then promote queued
    // speculative rounds whose turn has come — looping when a promotion
    // fills a batch to capacity (CloseNow). Discarded stale drafts get
    // their waiters dropped, which the async side reads as "no reply
    // owed".
    fn flush(
        core: &mut VerifierCore,
        replies: &mut HashMap<(u32, u32), oneshot::Sender<Result<Option<VerifyReply>>>>,
        deadline: &mut Option<f64>,
        now: f64,
    ) {
        loop {
            match core.close_window(now) {
                Ok(results) => {
                    for (id, vmsg) in results {
                        if let Some(tx) = replies.remove(&(id, vmsg.round)) {
                            let _ = tx.send(Ok(Some(VerifyReply::Verdict(vmsg))));
                        }
                    }
                }
                Err(e) => {
                    // a backend failure poisons the whole batch: every
                    // waiter gets the error and the connection layer
                    // tears down
                    let msg = format!("batch verification failed: {e:#}");
                    for (_, tx) in replies.drain() {
                        let _ = tx.send(Err(anyhow!("{msg}")));
                    }
                    return;
                }
            }
            let (decisions, dropped, redirects) = core.promote_ready(now);
            for key in dropped {
                replies.remove(&key);
            }
            // promotion-time fleet handoffs: the promoted round's
            // waiter gets the Redirect; every other waiter of the
            // exported session can never be answered here
            for (id, round, addr, resume_token) in redirects {
                if let Some(tx) = replies.remove(&(id, round)) {
                    let _ = tx.send(Ok(Some(VerifyReply::Redirect {
                        addr,
                        resume_token,
                    })));
                }
                replies.retain(|key, _| key.0 != id);
            }
            let mut close_again = false;
            for d in decisions {
                match d {
                    BatchDecision::CloseNow => close_again = true,
                    BatchDecision::CloseAt(t) => {
                        *deadline = Some(match *deadline {
                            Some(d) => d.min(t),
                            None => t,
                        });
                    }
                    BatchDecision::Queued => {}
                }
            }
            // continuous mode: the close's verdicts freed slots and KV
            // pages, and promotions/refills may have re-seated
            // occupants — keep closing until the slot table drains
            // (each pass verifies its occupants, so this terminates)
            if core.batch_ready() {
                close_again = true;
            }
            if !close_again {
                return;
            }
            *deadline = None;
        }
    }

    loop {
        let now = now_ms(&start);
        // reap parked sessions whose grace window is strictly over; the
        // loop wakes at least every 200 ms, which bounds sweep latency
        core.evict_expired(now);
        // continuous mode: evictions/aborts since the last close may
        // have returned the KV pages a FIFO waiter was blocked on —
        // seat it and arm a zero-delay close (SWEEP_INTERVAL bounds
        // how stale this check can get)
        if core.refill_slots(now) {
            deadline = Some(deadline.map_or(now, |d: f64| d.min(now)));
        }
        // A queued command beats a zero timeout in recv_timeout, so an
        // expired WINDOW must be flushed HERE — not only in the Timeout
        // arm — or a busy command stream could hold it open forever.
        // Continuous mode wants the opposite: queued commands ARE the
        // burst its zero-delay deadline coalesces, so the rolling batch
        // closes from the Timeout arm (command queue drained) instead —
        // a busy stream cannot hold it open because filling the slot
        // table closes synchronously via CloseNow.
        if let Some(d) = deadline {
            if now >= d && core.cfg.batch_mode != BatchMode::Continuous {
                deadline = None;
                flush(&mut core, &mut replies, &mut deadline, now);
            }
        }
        // capped at SWEEP_INTERVAL so the eviction sweep above runs on a
        // periodic timer regardless of traffic or batch deadlines
        let timeout = match deadline {
            Some(d) => Duration::from_secs_f64(((d - now_ms(&start)) / 1e3).max(0.0))
                .min(SWEEP_INTERVAL),
            None => SWEEP_INTERVAL,
        };
        match rx.recv_timeout(timeout) {
            Ok(VerifierCmd::Open {
                prompt,
                max_new,
                nonce,
                tier,
                profile,
                reply,
            }) => {
                let _ =
                    reply.send(core.open_session_profile(&prompt, max_new, nonce, tier, profile));
            }
            Ok(VerifierCmd::Verify {
                id,
                attachment,
                msg,
                wire,
                reply,
            }) => {
                let round = msg.round;
                match core.submit_from(now_ms(&start), attachment, msg, wire) {
                    Ok(SubmitOutcome::Queued(decision)) => {
                        replies.insert((id, round), reply);
                        match decision {
                            BatchDecision::CloseNow => {
                                deadline = None;
                                let now = now_ms(&start);
                                flush(&mut core, &mut replies, &mut deadline, now);
                            }
                            BatchDecision::CloseAt(t) => deadline = Some(t),
                            BatchDecision::Queued => {}
                        }
                    }
                    // speculative round parked until its turn; the
                    // waiter is answered when the round promotes (or
                    // dropped when it dies — "no reply owed")
                    Ok(SubmitOutcome::Deferred) => {
                        replies.insert((id, round), reply);
                    }
                    Ok(SubmitOutcome::Replay(v)) => {
                        let _ = reply.send(Ok(Some(VerifyReply::Verdict(v))));
                    }
                    Ok(SubmitOutcome::TakeOver) => {
                        // replace the previous waiter; its dropped
                        // channel reads as "no reply owed" (benign —
                        // see VerifierHandle::verify)
                        replies.insert((id, round), reply);
                    }
                    Ok(SubmitOutcome::Swallowed) => {
                        let _ = reply.send(Ok(None));
                    }
                    // admission queue full: answer immediately, no
                    // waiter recorded (the round left no state behind)
                    Ok(SubmitOutcome::Busy { retry_after_ms }) => {
                        let _ = reply.send(Ok(Some(VerifyReply::Busy {
                            round,
                            retry_after_ms,
                        })));
                    }
                    // fleet handoff: the whole session left this
                    // replica — any other waiters it had (deferred
                    // speculative rounds) can never be answered here
                    Ok(SubmitOutcome::Redirect { addr, resume_token }) => {
                        replies.retain(|key, _| key.0 != id);
                        let _ = reply.send(Ok(Some(VerifyReply::Redirect {
                            addr,
                            resume_token,
                        })));
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Ok(VerifierCmd::SetRedirect { target }) => core.set_redirect(target),
            Ok(VerifierCmd::RedirectSession { id, target }) => {
                core.redirect_session(id, target)
            }
            Ok(VerifierCmd::RedirectSome { n, target, reply }) => {
                let _ = reply.send(core.redirect_some(n, target));
            }
            Ok(VerifierCmd::Info { reply }) => {
                let _ = reply.send(core.telemetry());
            }
            Ok(VerifierCmd::Cancel {
                id,
                attachment,
                round,
            }) => {
                for key in core.cancel(id, attachment, round) {
                    // dropping the waiter reads as "no reply owed"
                    replies.remove(&key);
                }
            }
            Ok(VerifierCmd::Detach { id, attachment }) => {
                if core.detach(now_ms(&start), id, attachment) {
                    // the dead connection's waiters (any round) can
                    // never deliver (guarded: a stale detach must not
                    // drop a live successor's waiters)
                    replies.retain(|key, _| key.0 != id);
                }
            }
            Ok(VerifierCmd::Resume {
                token,
                committed_len,
                reply,
            }) => {
                let res = core.resume(token, committed_len);
                if let Ok(info) = &res {
                    // a stolen session's old waiters can never deliver
                    let id = info.session;
                    replies.retain(|key, _| key.0 != id);
                }
                let _ = reply.send(res);
            }
            Ok(VerifierCmd::End { id }) => core.abort_session(id),
            Ok(VerifierCmd::Deploy { version, reply }) => {
                let _ = reply.send(core.deploy(&version));
            }
            Ok(VerifierCmd::Stats { reply }) => {
                let _ = reply.send(core.metrics.clone());
            }
            Ok(VerifierCmd::RejectedHandshake) => {
                core.metrics.handshakes_rejected += 1;
            }
            Ok(VerifierCmd::Shutdown { reply }) => {
                deadline = None;
                let now = now_ms(&start);
                flush(&mut core, &mut replies, &mut deadline, now);
                // conservation audit: every counter ledger must balance
                // once the final batch has flushed
                core.audit();
                let _ = reply.send(core.metrics.clone());
                // Drain-until-quiet: commands queued behind the
                // shutdown (a draft racing a replica teardown) must
                // learn the service is gone — an unanswered verify
                // would strand its edge on a healthy-looking
                // connection. Each straggler gets an error, which tears
                // its connection down; once traffic stops for one
                // interval the thread exits and later posts fail at the
                // sender.
                while let Ok(cmd) = rx.recv_timeout(Duration::from_millis(20)) {
                    let gone = || anyhow!("verifier is shutting down");
                    match cmd {
                        VerifierCmd::Verify { reply, .. } => {
                            let _ = reply.send(Err(gone()));
                        }
                        VerifierCmd::Open { reply, .. } => {
                            let _ = reply.send(Err(gone()));
                        }
                        VerifierCmd::Resume { reply, .. } => {
                            let _ = reply.send(Err(gone()));
                        }
                        VerifierCmd::Deploy { reply, .. } => {
                            let _ = reply.send(Err(gone()));
                        }
                        VerifierCmd::Stats { reply } | VerifierCmd::Shutdown { reply } => {
                            let _ = reply.send(core.metrics.clone());
                        }
                        VerifierCmd::Info { reply } => {
                            let _ = reply.send(core.telemetry());
                        }
                        VerifierCmd::RedirectSome { reply, .. } => {
                            let _ = reply.send(Vec::new());
                        }
                        VerifierCmd::Cancel { .. }
                        | VerifierCmd::Detach { .. }
                        | VerifierCmd::End { .. }
                        | VerifierCmd::SetRedirect { .. }
                        | VerifierCmd::RedirectSession { .. }
                        | VerifierCmd::RejectedHandshake => {}
                    }
                }
                return;
            }
            // windowed expiry handled at the top of the loop
            Err(std_mpsc::RecvTimeoutError::Timeout) => {
                // continuous mode: the command queue just drained — the
                // admission burst the zero-delay deadline was
                // coalescing is over; close the rolling batch
                if core.cfg.batch_mode == BatchMode::Continuous {
                    if let Some(d) = deadline {
                        let now = now_ms(&start);
                        if now >= d {
                            deadline = None;
                            flush(&mut core, &mut replies, &mut deadline, now);
                        }
                    }
                }
            }
            Err(std_mpsc::RecvTimeoutError::Disconnected) => {
                let now = now_ms(&start);
                flush(&mut core, &mut replies, &mut deadline, now);
                core.audit();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::edge::DraftSource;
    use crate::protocol::{VerifyMode, WireFormat};
    use crate::serve::backend::{SyntheticDraft, SyntheticTarget};

    fn rng() -> SplitMix64 {
        SplitMix64::new(0)
    }

    fn core(window_ms: f64, max_batch: usize) -> VerifierCore {
        let cfg = VerifierConfig {
            window_ms,
            max_batch,
            ..Default::default()
        };
        VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)))
    }

    fn core_with_grace(grace_ms: f64) -> VerifierCore {
        let cfg = VerifierConfig {
            resume_grace_ms: grace_ms,
            ..Default::default()
        };
        VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)))
    }

    fn draft_for(id: u32, round: u32, committed: &[i32], k: usize) -> DraftMsg {
        let mut d = SyntheticDraft::new(7);
        let mut rng = SplitMix64::new(0);
        let p = d.propose(committed, k, 0.0, 1.0, &mut rng).unwrap();
        DraftMsg {
            session: id,
            round,
            tokens: p.tokens,
            chosen_probs: p.chosen_probs,
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        }
    }

    fn queued(out: SubmitOutcome) -> BatchDecision {
        match out {
            SubmitOutcome::Queued(d) => d,
            other => panic!("expected Queued, got {other:?}"),
        }
    }

    /// A pipelined (wire v3) draft for `round`, drafted from the
    /// optimistic context `committed ++ spec`.
    fn spec_draft_for(id: u32, round: u32, committed: &[i32], spec: &[i32], k: usize) -> DraftMsg {
        let mut d = SyntheticDraft::new(7);
        let mut rng = SplitMix64::new(0);
        let mut ctx = committed.to_vec();
        ctx.extend_from_slice(spec);
        let p = d.propose(&ctx, k, 0.0, 1.0, &mut rng).unwrap();
        DraftMsg {
            session: id,
            round,
            tokens: p.tokens,
            chosen_probs: p.chosen_probs,
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: committed.len() as u64,
            spec: spec.to_vec(),
            tree: vec![],
        }
    }

    /// A wire v8 comb-tree draft for `round`: the synthetic edge's
    /// bucket-aligned tree proposal with branching `b`.
    fn tree_draft_for(id: u32, round: u32, committed: &[i32], k: usize, b: usize) -> DraftMsg {
        let mut d = SyntheticDraft::new(7);
        let mut rng = SplitMix64::new(0);
        let p = d.propose_tree(committed, k, b, 0.0, 1.0, &mut rng).unwrap();
        DraftMsg {
            session: id,
            round,
            tokens: p.tokens,
            chosen_probs: vec![],
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: p.parents,
        }
    }

    #[test]
    fn tree_round_with_no_drift_commits_the_linear_bytes() {
        // drift-free target: every alternate loses its tie against the
        // main chain, so branching must not change a single committed
        // byte — the degenerate-case equality the device-matrix suite
        // pins end to end.
        let mut lin = core(5.0, 4);
        let mut tre = core(5.0, 4);
        let prompt = vec![1, 2, 3];
        let ol = lin.open_session(&prompt, 64, 0).unwrap();
        let ot = tre.open_session(&prompt, 64, 0).unwrap();
        let mut want = prompt.clone();
        let mut got = prompt.clone();
        let mut expect_rows = 0usize;
        let mut rounds = 0usize;
        for round in 0..4u32 {
            let lm = draft_for(ol.session, round, &want, 4);
            let lt = lm.tokens.clone();
            queued(lin.submit(round as f64, ol.attachment, lm, false).unwrap());
            let lv = lin.close_window(round as f64).unwrap().remove(0).1;
            assert!(lv.leaf.is_none(), "linear rounds never name a leaf");
            want.extend_from_slice(&lt[..lv.tau as usize]);
            want.push(lv.correction);

            let tm = tree_draft_for(ot.session, round, &got, 4, 4);
            assert!(tm.is_tree() && tm.n_leaves() > 1);
            expect_rows += tm.n_leaves();
            rounds += 1;
            let chain: Vec<i32> = tm.tree_path(3);
            assert_eq!(chain, lt, "comb chain must equal the linear draft");
            queued(tre.submit(round as f64, ot.attachment, tm, false).unwrap());
            let tv = tre.close_window(round as f64).unwrap().remove(0).1;
            assert_eq!((tv.tau, tv.correction, tv.eos), (lv.tau, lv.correction, lv.eos));
            assert_eq!(tv.leaf, Some(3), "the tie must pick the chain leaf");
            got.extend_from_slice(&chain[..tv.tau as usize]);
            got.push(tv.correction);
            assert_eq!(got, want, "round {round}");
            if lv.eos {
                break;
            }
        }
        // bucket-aligned comb: extra rows, zero extra dispatch classes
        assert_eq!(tre.metrics.verify_rows, expect_rows);
        assert_eq!(tre.metrics.tree_rounds, rounds);
        assert_eq!(tre.metrics.stacked_dispatches, lin.metrics.stacked_dispatches);
        assert_eq!(lin.metrics.verify_rows, lin.metrics.rounds);
        tre.metrics.check_invariants(tre.sessions.len(), tre.drafts_in_flight());
    }

    #[test]
    fn tree_alternate_beats_the_chain_on_a_drifted_target() {
        let drifted = || {
            let mut t = SyntheticTarget::new(7).with_version("evolved", 1.0);
            t.deploy("evolved").unwrap();
            t
        };
        let prompt = vec![9, 8, 7];
        // discover the drifted continuation with a LINEAR probe
        let mut probe = VerifierCore::new(VerifierConfig::default(), Box::new(drifted()));
        let o = probe.open_session(&prompt, 64, 0).unwrap();
        let pm = draft_for(o.session, 0, &prompt, 4);
        let chain = pm.tokens.clone();
        queued(probe.submit(0.0, o.attachment, pm, false).unwrap());
        let pv = probe.close_window(0.0).unwrap().remove(0).1;
        let tau = pv.tau as usize;
        assert!(tau < 4, "full drift must break the pure chain");

        // same target, but the draft hedges: one alternate carrying the
        // drifted token, attached exactly where the chain broke
        let mut c = VerifierCore::new(VerifierConfig::default(), Box::new(drifted()));
        let o2 = c.open_session(&prompt, 64, 0).unwrap();
        let mut tokens = chain.clone();
        let mut parents: Vec<u8> = (0..chain.len() as u8).collect();
        tokens.push(pv.correction);
        parents.push(tau as u8);
        let msg = DraftMsg {
            session: o2.session,
            round: 0,
            tokens,
            chosen_probs: vec![],
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: parents,
        };
        assert!(msg.tree_valid());
        queued(c.submit(0.0, o2.attachment, msg, false).unwrap());
        let v = c.close_window(0.0).unwrap().remove(0).1;
        // the hedge row `chain[..tau] ++ correction` accepts one token
        // deeper than the chain row, and the verdict names its leaf
        assert_eq!(v.tau as usize, tau + 1);
        assert_eq!(v.leaf, Some(chain.len() as u8));
        let committed = &c.sessions[&o2.session].committed;
        assert_eq!(committed.len(), prompt.len() + tau + 2);
        let mut hedge = chain[..tau].to_vec();
        hedge.push(pv.correction);
        assert_eq!(committed[prompt.len()..prompt.len() + tau + 1], hedge[..]);
        assert_eq!(c.metrics.tree_rounds, 1);
        assert_eq!(c.metrics.verify_rows, 2);
        assert_eq!(c.metrics.rounds, 1);
        c.metrics.check_invariants(c.sessions.len(), c.drafts_in_flight());
    }

    #[test]
    fn malformed_and_stochastic_trees_are_rejected() {
        let mut c = core(5.0, 4);
        let o = c.open_session(&[1, 2], 64, 0).unwrap();
        let mut bad = draft_for(o.session, 0, &[1, 2], 3);
        bad.tree = vec![0]; // wrong arity: 1 parent for 3 tokens
        assert!(c.submit(0.0, o.attachment, bad, false).is_err());
        let mut stoch = tree_draft_for(o.session, 0, &[1, 2], 3, 4);
        stoch.mode = VerifyMode::Stochastic;
        assert!(c.submit(0.0, o.attachment, stoch, false).is_err());
        // the books still balance: both rejected drafts were swallowed
        c.metrics.check_invariants(c.sessions.len(), c.drafts_in_flight());
    }

    #[test]
    fn continuous_close_counts_tree_leaves_in_slot_occupancy() {
        let cfg = VerifierConfig {
            batch_mode: BatchMode::Continuous,
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let prompt = vec![4, 5, 6];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let msg = tree_draft_for(o.session, 0, &prompt, 4, 4);
        let leaves = msg.n_leaves();
        assert!(leaves > 1);
        queued(c.submit(0.0, o.attachment, msg, false).unwrap());
        let out = c.close_window(0.0).unwrap();
        assert_eq!(out.len(), 1);
        // occupancy is recorded in executor ROWS, one per tree leaf
        assert_eq!(c.metrics.slot_occupancy.count(), 1);
        assert!((c.metrics.slot_occupancy.mean() - leaves as f64).abs() < 1e-9);
        c.metrics.check_invariants(c.sessions.len(), c.drafts_in_flight());
    }

    #[test]
    fn open_with_profile_is_recorded_and_dropped_with_the_session() {
        let mut c = core(5.0, 4);
        let p = DeviceProfileMsg {
            compute_tier: 2,
            channel_class: 1,
            energy_mj: 12_000,
        };
        let o = c
            .open_session_profile(&[1, 2], 64, 0, 1, Some(p))
            .unwrap();
        assert_eq!(c.device_profile(o.session), Some(&p));
        assert_eq!(c.metrics.sessions_by_device_tier, [0, 0, 1]);
        c.abort_session(o.session);
        assert!(c.device_profile(o.session).is_none());
        // unprofiled opens land in no cell
        let o2 = c.open_session(&[3, 4], 64, 0).unwrap();
        assert!(c.device_profile(o2.session).is_none());
        assert_eq!(c.metrics.sessions_by_device_tier, [0, 0, 1]);
    }

    /// The synthetic draft's assumed outcome of a fully-accepted round:
    /// its own tokens plus its prediction of the bonus token.
    fn assumed_outcome(committed: &[i32], tokens: &[i32]) -> Vec<i32> {
        let mut d = SyntheticDraft::new(7);
        let mut rng = SplitMix64::new(0);
        let mut ctx = committed.to_vec();
        ctx.extend_from_slice(tokens);
        let bonus = d.propose(&ctx, 1, 0.0, 1.0, &mut rng).unwrap().tokens[0];
        let mut assumed = tokens.to_vec();
        assumed.push(bonus);
        assumed
    }

    #[test]
    fn batches_verify_and_complete_sessions() {
        let mut c = core(10.0, 8);
        let prompt_a = vec![1, 70, 71];
        let prompt_b = vec![1, 80, 81];
        let oa = c.open_session(&prompt_a, 8, 0).unwrap();
        let ob = c.open_session(&prompt_b, 8, 0).unwrap();
        let (a, b) = (oa.session, ob.session);
        assert_eq!((a, b, oa.target_seq), (1, 2, 1));
        assert_ne!(oa.resume_token, ob.resume_token, "resume tokens must be distinct");
        assert!(oa.resume_token != 0 && ob.resume_token != 0);

        let mut committed_a = prompt_a.clone();
        let mut committed_b = prompt_b.clone();
        let mut finished = 0;
        let mut round = 0u32;
        while finished < 2 && round < 20 {
            if !c.sessions.contains_key(&a) && !c.sessions.contains_key(&b) {
                break;
            }
            for (&id, att, committed) in
                [(&a, oa.attachment, &mut committed_a), (&b, ob.attachment, &mut committed_b)]
            {
                if !c.sessions.contains_key(&id) {
                    continue;
                }
                let msg = draft_for(id, round, committed, 4);
                c.submit(round as f64, att, msg, false).unwrap();
            }
            for (id, vmsg) in c.close_window(round as f64).unwrap() {
                let committed = if id == a { &mut committed_a } else { &mut committed_b };
                let msg_tokens = draft_for(id, round, committed, 4).tokens;
                committed.extend_from_slice(&msg_tokens[..vmsg.tau as usize]);
                committed.push(vmsg.correction);
                if vmsg.eos {
                    finished += 1;
                }
            }
            round += 1;
        }
        assert_eq!(finished, 2);
        assert_eq!(c.metrics.sessions_completed, 2);
        assert!(c.metrics.batches >= 2);
        assert!(c.metrics.mean_batch() > 1.0, "batched both sessions");
        assert_eq!(c.active_sessions(), 0);
        // zero drift synthetic target: everything accepted
        assert_eq!(c.metrics.accepted, c.metrics.drafted);
    }

    #[test]
    fn duplicate_inflight_draft_takes_over_and_conflicts_rejected() {
        let mut c = core(10.0, 8);
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 8, 0).unwrap();
        let id = o.session;
        queued(c.submit(0.0, o.attachment, draft_for(id, 0, &prompt, 2), false).unwrap());
        // byte-identical duplicate of the in-flight round: the round is
        // NOT double-queued, but the newest requester owns the reply
        // (its predecessor may be a dead connection's verify task)
        assert!(matches!(
            c.submit(0.1, o.attachment, draft_for(id, 0, &prompt, 2), false).unwrap(),
            SubmitOutcome::TakeOver
        ));
        // a draft from a STALE attachment epoch is swallowed outright
        assert!(matches!(
            c.submit(0.15, o.attachment + 99, draft_for(id, 0, &prompt, 2), false).unwrap(),
            SubmitOutcome::Swallowed
        ));
        // the round still runs exactly once
        let out = c.close_window(0.2).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(c.metrics.rounds, 1);
        // a DIFFERENT round while one is in flight is a protocol violation
        let v = &out[0].1;
        let mut committed = prompt.clone();
        committed.extend_from_slice(&draft_for(id, 0, &prompt, 2).tokens[..v.tau as usize]);
        committed.push(v.correction);
        queued(c.submit(0.3, o.attachment, draft_for(id, 1, &committed, 2), false).unwrap());
        assert!(c
            .submit(0.4, o.attachment, draft_for(id, 2, &committed, 2), false)
            .is_err());
    }

    #[test]
    fn verified_round_is_replayed_from_cache() {
        let mut c = core(10.0, 8);
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let id = o.session;
        queued(c.submit(0.0, o.attachment, draft_for(id, 0, &prompt, 2), false).unwrap());
        let out = c.close_window(0.0).unwrap();
        assert_eq!(out.len(), 1);
        let first = out[0].1.clone();
        // retransmit of the verified round: cached verdict, no advance
        let replay = match c.submit(1.0, o.attachment, draft_for(id, 0, &prompt, 2), false).unwrap() {
            SubmitOutcome::Replay(v) => v,
            other => panic!("expected Replay, got {other:?}"),
        };
        assert_eq!(replay, first);
        assert_eq!(c.metrics.verdicts_replayed, 1);
        assert_eq!(c.metrics.rounds, 1, "replay must not re-count the round");
        // ancient rounds are swallowed outright
        let mut committed = prompt.clone();
        committed.extend_from_slice(&draft_for(id, 0, &prompt, 2).tokens[..first.tau as usize]);
        committed.push(first.correction);
        queued(c.submit(2.0, o.attachment, draft_for(id, 1, &committed, 2), false).unwrap());
        let _ = c.close_window(2.0).unwrap();
        assert!(matches!(
            c.submit(3.0, o.attachment, draft_for(id, 0, &prompt, 2), false).unwrap(),
            SubmitOutcome::Swallowed
        ));
    }

    #[test]
    fn detach_resume_returns_missing_tail() {
        let mut c = core_with_grace(1_000.0);
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let (id, token) = (o.session, o.resume_token);
        // round 0 verified, verdict DELIVERED (edge applied it)
        queued(c.submit(0.0, o.attachment, draft_for(id, 0, &prompt, 4), false).unwrap());
        let v0 = c.close_window(0.0).unwrap().remove(0).1;
        let mut edge_committed = prompt.clone();
        edge_committed.extend_from_slice(&draft_for(id, 0, &prompt, 4).tokens[..v0.tau as usize]);
        edge_committed.push(v0.correction);
        // round 1 verified, reply LOST (link died in flight)
        queued(c.submit(1.0, o.attachment, draft_for(id, 1, &edge_committed, 4), false).unwrap());
        let _v1 = c.close_window(1.0).unwrap().remove(0).1;
        assert!(c.detach(2.0, id, o.attachment));
        assert_eq!(c.parked_sessions(), 1);
        // resume from the edge's (stale) position: tail = round 1's commit
        let info = c.resume(token, edge_committed.len()).unwrap();
        assert_eq!(info.session, id);
        assert!(!info.done);
        assert_eq!(info.rounds, 2);
        assert_eq!(info.tail.len(), 5, "K=4 accepted + correction");
        assert_eq!(
            info.committed_len,
            edge_committed.len() + info.tail.len()
        );
        assert_eq!(c.parked_sessions(), 0);
        assert_eq!(c.metrics.sessions_parked, 1);
        assert_eq!(c.metrics.sessions_resumed, 1);
        // bad positions are rejected
        assert!(c.resume(token, 1).is_err(), "before prompt end");
        assert!(c.resume(token, 10_000).is_err(), "beyond committed");
        assert!(c.resume(token ^ 1, prompt.len()).is_err(), "bad token");
    }

    #[test]
    fn finished_session_leaves_resumable_residue() {
        let mut c = core_with_grace(1_000.0);
        let prompt = vec![1, 70, 71];
        // max_new 5 : one K=4 round (+correction) finishes the session
        let o = c.open_session(&prompt, 5, 0).unwrap();
        let (id, token) = (o.session, o.resume_token);
        queued(c.submit(0.0, o.attachment, draft_for(id, 0, &prompt, 4), false).unwrap());
        let v = c.close_window(0.0).unwrap().remove(0).1;
        assert!(v.eos, "session must finish in one round");
        assert_eq!(c.active_sessions(), 0);
        // the edge missed the final verdict entirely: resume by token
        let info = c.resume(token, prompt.len()).unwrap();
        assert!(info.done);
        assert_eq!(info.session, id);
        assert_eq!(info.tail.len(), 5);
        // the residue (and its replay tombstone) expire with the grace
        c.evict_expired(1_500.0);
        assert!(c.resume(token, prompt.len()).is_err());
    }

    #[test]
    fn open_nonce_deduplicates_retransmitted_opens() {
        let mut c = core(10.0, 8);
        let prompt = vec![1, 70, 71];
        let o1 = c.open_session(&prompt, 8, 42).unwrap();
        // retransmitted Open (ack lost): same session, same token, but a
        // FRESH attachment epoch (the retransmit owns the session now)
        let o2 = c.open_session(&prompt, 8, 42).unwrap();
        assert_eq!(o1.session, o2.session);
        assert_eq!(o1.resume_token, o2.resume_token);
        assert!(o2.attachment > o1.attachment);
        assert_eq!(c.metrics.sessions_opened, 1, "no second session leaked");
        assert_eq!(c.active_sessions(), 1);
        // ...so the ORIGINAL connection's detach is stale and ignored
        assert!(!c.detach(0.0, o1.session, o1.attachment));
        assert_eq!(c.parked_sessions(), 0);
        // a different nonce is a genuinely new session
        let o3 = c.open_session(&prompt, 8, 43).unwrap();
        assert_ne!(o1.session, o3.session);
        assert_eq!(c.metrics.sessions_opened, 2);
    }

    /// Regression test for the resume/eviction race: a reconnect that
    /// lands within the grace window must never lose to the eviction
    /// timer — (a) sweeps strictly before or AT the deadline are no-ops,
    /// (b) resume succeeds whenever the session still exists without
    /// re-checking the clock, and (c) re-parking after a resume records
    /// a FRESH deadline so a stale sweep armed for the first park's
    /// deadline cannot evict early.
    #[test]
    fn reconnect_within_grace_cannot_race_eviction() {
        let prompt = vec![1, 70, 71];
        let mut c = core_with_grace(100.0);
        let o = c.open_session(&prompt, 8, 0).unwrap();
        let (id, token) = (o.session, o.resume_token);

        // attached sessions are never evicted, no matter the clock
        assert_eq!(c.evict_expired(1e12), 0);

        assert!(c.detach(0.0, id, o.attachment));
        // sweep strictly before the deadline: no-op
        assert_eq!(c.evict_expired(99.9), 0);
        // sweep exactly AT the deadline: still a no-op (strict `>`), so
        // a resume in the same tick wins the boundary
        assert_eq!(c.evict_expired(100.0), 0);
        let info = c.resume(token, prompt.len()).unwrap();
        assert_eq!(info.session, id);

        // re-park at t=120: deadline refreshes to 220. A stale sweep
        // armed for the FIRST deadline (100) fires late at t=140 and
        // must not evict the freshly parked session.
        assert!(c.detach(120.0, id, info.attachment));
        assert_eq!(c.evict_expired(140.0), 0, "stale timer evicted early");
        // the session is still resumable right up to its live deadline
        let info = c.resume(token, prompt.len()).unwrap();
        assert_eq!(info.session, id);

        // only a sweep strictly past the LIVE deadline reaps it
        assert!(c.detach(220.0, id, info.attachment));
        assert_eq!(c.evict_expired(320.0), 0);
        assert_eq!(c.evict_expired(320.1), 1);
        assert_eq!(c.metrics.sessions_evicted, 1);
        assert!(c.resume(token, prompt.len()).is_err(), "evicted for real");
        assert_eq!(c.active_sessions(), 0);
    }

    #[test]
    fn detached_member_is_skipped_by_window_close() {
        let mut c = core(10.0, 8);
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = c.open_session(&pa, 8, 0).unwrap();
        let ob = c.open_session(&pb, 8, 0).unwrap();
        let (a, b) = (oa.session, ob.session);
        queued(c.submit(0.0, oa.attachment, draft_for(a, 0, &pa, 2), false).unwrap());
        c.submit(0.0, ob.attachment, draft_for(b, 0, &pb, 2), false).unwrap();
        // link carrying session a dies mid-window: parked, not aborted
        assert!(c.detach(0.5, a, oa.attachment));
        let out = c.close_window(1.0).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, b);
        assert_eq!(c.metrics.sessions_parked, 1);
        assert_eq!(c.metrics.sessions_aborted, 0);
        // session a is still alive and resumable
        assert_eq!(c.active_sessions(), 2);
    }

    #[test]
    fn abort_mid_window_skips_member() {
        let mut c = core(10.0, 8);
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = c.open_session(&pa, 8, 0).unwrap();
        let ob = c.open_session(&pb, 8, 0).unwrap();
        let (a, b) = (oa.session, ob.session);
        c.submit(0.0, oa.attachment, draft_for(a, 0, &pa, 2), false).unwrap();
        c.submit(0.0, ob.attachment, draft_for(b, 0, &pb, 2), false).unwrap();
        c.abort_session(a);
        let out = c.close_window(0.0).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, b);
        assert_eq!(c.metrics.sessions_aborted, 1);
    }

    #[test]
    fn speculative_round_defers_then_promotes_and_pipelines() {
        let mut c = core(10.0, 8);
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let id = o.session;
        let d0 = draft_for(id, 0, &prompt, 4);
        queued(c.submit(0.0, o.attachment, d0.clone(), false).unwrap());

        // the edge pipelines round 1 from the optimistic prefix
        let assumed = assumed_outcome(&prompt, &d0.tokens);
        let d1 = spec_draft_for(id, 1, &prompt, &assumed, 4);
        assert!(matches!(
            c.submit(0.1, o.attachment, d1.clone(), false).unwrap(),
            SubmitOutcome::Deferred
        ));
        // a retransmit of the queued round takes over, not double-queues
        assert!(matches!(
            c.submit(0.2, o.attachment, d1, false).unwrap(),
            SubmitOutcome::TakeOver
        ));

        // round 0 verifies: zero drift -> full acceptance, exact bonus
        let out = c.close_window(0.3).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.tau as usize, 4);

        // promotion basis-checks and admits round 1 into the window
        let (decisions, dropped, _) = c.promote_ready(0.4);
        assert_eq!(decisions.len(), 1);
        assert!(dropped.is_empty());
        assert_eq!(c.metrics.rounds_pipelined, 1);
        assert_eq!(c.metrics.drafts_cancelled, 0);
        let out = c.close_window(0.5).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.round, 1);
        assert_eq!(c.metrics.rounds, 2, "pipelined round verified exactly once");
    }

    #[test]
    fn stale_speculative_round_is_discarded_at_promotion() {
        // full drift: the target rejects every draft token, so every
        // optimistic prefix breaks
        let mut backend = SyntheticTarget::new(7).with_version("evolved", 1.0);
        backend.deploy("evolved").unwrap();
        let mut c = VerifierCore::new(VerifierConfig::default(), Box::new(backend));
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let id = o.session;
        let d0 = draft_for(id, 0, &prompt, 4);
        queued(c.submit(0.0, o.attachment, d0.clone(), false).unwrap());
        let assumed = assumed_outcome(&prompt, &d0.tokens);
        let d1 = spec_draft_for(id, 1, &prompt, &assumed, 4);
        assert!(matches!(
            c.submit(0.1, o.attachment, d1, false).unwrap(),
            SubmitOutcome::Deferred
        ));

        let out = c.close_window(0.3).unwrap();
        assert_eq!(out[0].1.tau, 0, "full drift must reject everything");
        let correction = out[0].1.correction;

        // the queued speculative round is stale: discarded, counted
        let (decisions, dropped, _) = c.promote_ready(0.4);
        assert!(decisions.is_empty());
        assert_eq!(dropped, vec![(id, 1)]);
        assert_eq!(c.metrics.drafts_cancelled, 1);
        assert_eq!(c.metrics.draft_tokens_wasted, 4);
        assert_eq!(c.metrics.rounds_pipelined, 0);

        // the redraft from the TRUE prefix (same round number) verifies
        let mut committed = prompt.clone();
        committed.push(correction);
        queued(c.submit(0.5, o.attachment, draft_for(id, 1, &committed, 4), false).unwrap());
        let out = c.close_window(0.6).unwrap();
        assert_eq!(out[0].1.round, 1);
        assert_eq!(c.metrics.rounds, 2);
    }

    #[test]
    fn cancel_retracts_queued_rounds_and_bounds_depth() {
        let mut c = core(10.0, 8);
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let id = o.session;
        let d0 = draft_for(id, 0, &prompt, 4);
        queued(c.submit(0.0, o.attachment, d0.clone(), false).unwrap());
        let assumed = assumed_outcome(&prompt, &d0.tokens);
        let d1 = spec_draft_for(id, 1, &prompt, &assumed, 4);
        assert!(matches!(c.submit(0.1, o.attachment, d1, false).unwrap(), SubmitOutcome::Deferred));
        let mut spec2 = assumed.clone();
        let chained = assumed_outcome(&prompt, &spec2);
        spec2.extend(chained);
        let d2 = spec_draft_for(id, 2, &prompt, &spec2, 4);
        assert!(matches!(c.submit(0.2, o.attachment, d2, false).unwrap(), SubmitOutcome::Deferred));
        // depth bound: pending(1) + queued(2) + one more deferred = 4 ok,
        // a fifth in-flight round is a protocol violation
        let d3 = spec_draft_for(id, 3, &prompt, &spec2, 4);
        assert!(matches!(c.submit(0.3, o.attachment, d3, false).unwrap(), SubmitOutcome::Deferred));
        let d4 = spec_draft_for(id, 4, &prompt, &spec2, 4);
        assert!(c.submit(0.35, o.attachment, d4, false).is_err());

        // a stale attachment's cancel is ignored
        assert!(c.cancel(id, o.attachment + 9, 1).is_empty());
        // the edge retracts rounds >= 1
        let dropped = c.cancel(id, o.attachment, 1);
        assert_eq!(dropped, vec![(id, 1), (id, 2), (id, 3)]);
        assert_eq!(c.metrics.drafts_cancelled, 3);
        assert_eq!(c.metrics.draft_tokens_wasted, 12);
        // idempotent (duplicate Cancel frames are absorbed)
        assert!(c.cancel(id, o.attachment, 1).is_empty());

        // the head round in the window is untouched and still verifies
        let out = c.close_window(1.0).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.round, 0);
    }

    #[test]
    fn speculative_draft_after_session_finish_is_swallowed_and_counted() {
        let mut c = core_with_grace(1_000.0);
        let prompt = vec![1, 70, 71];
        // max_new 5: one K=4 round (+ bonus) finishes the session
        let o = c.open_session(&prompt, 5, 0).unwrap();
        let id = o.session;
        let d0 = draft_for(id, 0, &prompt, 4);
        queued(c.submit(0.0, o.attachment, d0.clone(), false).unwrap());
        let v = c.close_window(0.1).unwrap().remove(0).1;
        assert!(v.eos, "session must finish in one round");

        // the in-flight speculative round 1 lands after the finish:
        // wasted speculation, not a protocol error
        let assumed = assumed_outcome(&prompt, &d0.tokens);
        let d1 = spec_draft_for(id, 1, &prompt, &assumed, 4);
        assert!(matches!(
            c.submit(0.2, o.attachment, d1, false).unwrap(),
            SubmitOutcome::Swallowed
        ));
        assert_eq!(c.metrics.drafts_cancelled, 1);
        assert_eq!(c.metrics.draft_tokens_wasted, 4);
        // ...and a duplicate of the FINAL round still replays its verdict
        assert!(matches!(
            c.submit(0.3, o.attachment, d0, false).unwrap(),
            SubmitOutcome::Replay(_)
        ));
    }

    #[test]
    fn queued_rounds_die_with_the_session_at_promotion() {
        let mut c = core_with_grace(1_000.0);
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 5, 0).unwrap();
        let id = o.session;
        let d0 = draft_for(id, 0, &prompt, 4);
        queued(c.submit(0.0, o.attachment, d0.clone(), false).unwrap());
        // speculative round 1 queued BEFORE the finishing verdict
        let assumed = assumed_outcome(&prompt, &d0.tokens);
        let d1 = spec_draft_for(id, 1, &prompt, &assumed, 4);
        assert!(matches!(c.submit(0.1, o.attachment, d1, false).unwrap(), SubmitOutcome::Deferred));
        let v = c.close_window(0.2).unwrap().remove(0).1;
        assert!(v.eos);
        // promotion sees the dead session and voids the queue
        let (decisions, dropped, _) = c.promote_ready(0.3);
        assert!(decisions.is_empty());
        assert_eq!(dropped, vec![(id, 1)]);
        assert_eq!(c.metrics.drafts_cancelled, 1);
        assert_eq!(c.metrics.draft_tokens_wasted, 4);
    }

    // --- batched verification executor -------------------------------

    /// Delegates to a `SyntheticTarget` but deliberately does NOT
    /// override `verify_batch`, so `close_window` runs the default
    /// per-session fallback — the reference trajectory the batched
    /// override is pinned against.
    struct SequentialOnly(SyntheticTarget);

    impl VerifyBackend for SequentialOnly {
        fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
            self.0.start_session(id, prompt)
        }

        fn end_session(&mut self, id: u32) {
            self.0.end_session(id);
        }

        #[allow(clippy::too_many_arguments)]
        fn verify_block(
            &mut self,
            id: u32,
            committed: &[i32],
            draft: &[i32],
            draft_probs: &[Vec<f32>],
            mode: VerifyMode,
            temperature: f32,
            top_p: f32,
            rng: &mut SplitMix64,
        ) -> Result<crate::serve::backend::BackendVerdict> {
            self.0
                .verify_block(id, committed, draft, draft_probs, mode, temperature, top_p, rng)
        }

        fn deploy(&mut self, version: &str) -> Result<u64> {
            self.0.deploy(version)
        }

        fn version_name(&self) -> String {
            self.0.version_name()
        }

        fn version_seq(&self) -> u64 {
            self.0.version_seq()
        }

        fn remaining_capacity(&self, id: u32) -> usize {
            self.0.remaining_capacity(id)
        }
    }

    /// Drive a core with lock-step rounds (ragged strides chosen by
    /// `k_of(session_index, round)`) until every session finishes;
    /// returns the per-session committed sequences.
    fn drive(
        c: &mut VerifierCore,
        seed: u64,
        users: usize,
        max_new: usize,
        k_of: impl Fn(usize, usize) -> usize,
    ) -> Vec<Vec<i32>> {
        let prompts: Vec<Vec<i32>> = (0..users)
            .map(|i| vec![1, 70 + i as i32, 90 + 2 * i as i32])
            .collect();
        let opens: Vec<OpenInfo> = prompts
            .iter()
            .map(|p| c.open_session(p, max_new, 0).unwrap())
            .collect();
        let mut committed = prompts;
        let mut rounds_ctr = vec![0u32; users];
        let mut done = vec![false; users];
        let mut iter = 0usize;
        while done.iter().any(|d| !d) && iter < 64 {
            let mut sent: Vec<Option<Vec<i32>>> = vec![None; users];
            for i in 0..users {
                if done[i] {
                    continue;
                }
                let mut d = SyntheticDraft::new(seed);
                let k = k_of(i, iter).clamp(1, 8);
                let p = d.propose(&committed[i], k, 0.0, 1.0, &mut rng()).unwrap();
                let msg = DraftMsg {
                    session: opens[i].session,
                    round: rounds_ctr[i],
                    tokens: p.tokens.clone(),
                    chosen_probs: p.chosen_probs,
                    mode: VerifyMode::Greedy,
                    wire: WireFormat::Compact,
                    basis_len: 0,
                    spec: vec![],
                    tree: vec![],
                };
                queued(c.submit(iter as f64, opens[i].attachment, msg, false).unwrap());
                sent[i] = Some(p.tokens);
            }
            for (id, vmsg) in c.close_window(iter as f64).unwrap() {
                let i = opens.iter().position(|o| o.session == id).unwrap();
                let toks = sent[i].take().unwrap();
                committed[i].extend_from_slice(&toks[..vmsg.tau as usize]);
                committed[i].push(vmsg.correction);
                rounds_ctr[i] += 1;
                if vmsg.eos {
                    done[i] = true;
                }
            }
            iter += 1;
        }
        assert!(done.iter().all(|&d| d), "sessions failed to finish");
        committed
    }

    /// Tentpole determinism pin: the batched `close_window` (planner
    /// buckets → one `verify_batch` call) commits sequences
    /// BYTE-IDENTICAL to the per-session fallback, for ragged strides
    /// K ∈ 1..=8 and seeds [3, 17, 42] against a drifted target.
    #[test]
    fn batched_close_window_matches_per_session_fallback_across_seeds() {
        for &seed in &[3u64, 17, 42] {
            let mk = || {
                let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
                t.deploy("evolved").unwrap();
                t
            };
            let cfg = || VerifierConfig {
                window_ms: 10.0,
                max_batch: 8,
                ..Default::default()
            };
            let mut batched = VerifierCore::new(cfg(), Box::new(mk()));
            let mut fallback = VerifierCore::new(cfg(), Box::new(SequentialOnly(mk())));
            let k_of = |i: usize, r: usize| 1 + (i + r) % 8;
            let a = drive(&mut batched, seed, 5, 20, k_of);
            let b = drive(&mut fallback, seed, 5, 20, k_of);
            assert_eq!(
                a, b,
                "batched close_window diverged from the per-session fallback (seed {seed})"
            );
            assert_eq!(batched.metrics.rounds, fallback.metrics.rounds);
            assert_eq!(batched.metrics.accepted, fallback.metrics.accepted);
            assert_eq!(batched.metrics.drafted, fallback.metrics.drafted);
            assert_eq!(
                batched.metrics.tokens_committed,
                fallback.metrics.tokens_committed
            );
        }
    }

    // --- continuous (rolling-admission) batching ----------------------

    /// Tentpole determinism pin: continuous batching (slot table + KV
    /// block pool + FIFO refill) commits sequences BYTE-IDENTICAL to
    /// the windowed path, for ragged strides K ∈ 1..=8 and seeds
    /// [3, 17, 42] against a drifted target — batch formation timing
    /// is never allowed to change a committed token.
    #[test]
    fn continuous_mode_commits_identical_sequences_across_seeds() {
        for &seed in &[3u64, 17, 42] {
            let mk = || {
                let mut t = SyntheticTarget::new(seed).with_version("evolved", 0.3);
                t.deploy("evolved").unwrap();
                t
            };
            let cfg = |mode: BatchMode| VerifierConfig {
                window_ms: 10.0,
                max_batch: 8,
                batch_mode: mode,
                ..Default::default()
            };
            let mut windowed = VerifierCore::new(cfg(BatchMode::Windowed), Box::new(mk()));
            let mut rolling = VerifierCore::new(cfg(BatchMode::Continuous), Box::new(mk()));
            let k_of = |i: usize, r: usize| 1 + (i + r) % 8;
            let a = drive(&mut windowed, seed, 5, 20, k_of);
            let b = drive(&mut rolling, seed, 5, 20, k_of);
            assert_eq!(
                a, b,
                "continuous batching diverged from the windowed path (seed {seed})"
            );
            assert_eq!(windowed.metrics.rounds, rolling.metrics.rounds);
            assert_eq!(windowed.metrics.accepted, rolling.metrics.accepted);
            assert_eq!(windowed.metrics.drafted, rolling.metrics.drafted);
            assert_eq!(
                windowed.metrics.tokens_committed,
                rolling.metrics.tokens_committed
            );
            // identical batch composition → identical stacked dispatch
            // plans; only continuous mode records slot occupancy
            assert_eq!(
                windowed.metrics.stacked_dispatches,
                rolling.metrics.stacked_dispatches
            );
            assert_eq!(windowed.metrics.slot_occupancy.count(), 0);
            assert_eq!(
                rolling.metrics.slot_occupancy.count(),
                rolling.metrics.batches
            );
            assert!(rolling.metrics.stacked_dispatches >= rolling.metrics.batches);
            assert!(rolling.metrics.stacked_dispatches <= rolling.metrics.rounds);
            // every lease returned: finished sessions drained the pool
            assert!(rolling.kv_leases.is_empty(), "leases leaked (seed {seed})");
            assert_eq!(rolling.kv_pool.free_pages(), rolling.kv_pool.capacity());
            windowed.audit();
            rolling.audit();
        }
    }

    /// Continuous admission never arms a `window_ms` timer: a draft
    /// either takes a slot (zero-delay close), fills the table
    /// (CloseNow), or waits in the FIFO for a verdict to free a slot.
    #[test]
    fn continuous_slots_roll_admission_without_window_timers() {
        let cfg = VerifierConfig {
            window_ms: 12.0,
            max_batch: 2,
            batch_mode: BatchMode::Continuous,
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![1, 70 + i, 90 + 2 * i]).collect();
        let opens: Vec<OpenInfo> = prompts
            .iter()
            .map(|p| c.open_session(p, 16, 0).unwrap())
            .collect();
        let offer = |c: &mut VerifierCore, i: usize, prompts: &[Vec<i32>]| {
            let msg = draft_for(opens[i].session, 0, &prompts[i], 2);
            queued(c.submit(3.0, opens[i].attachment, msg, false).unwrap())
        };
        // slot 1: zero-delay deadline at NOW, not now + window_ms
        assert_eq!(offer(&mut c, 0, &prompts), BatchDecision::CloseAt(3.0));
        // slot 2 fills the table: close immediately
        assert_eq!(offer(&mut c, 1, &prompts), BatchDecision::CloseNow);
        // the rest wait in the FIFO
        assert_eq!(offer(&mut c, 2, &prompts), BatchDecision::Queued);
        assert_eq!(offer(&mut c, 3, &prompts), BatchDecision::Queued);

        // first close verifies the two slot occupants (admission order)
        // and its verdicts refill the slots from the FIFO
        let out = c.close_window(4.0).unwrap();
        assert_eq!(
            out.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
            vec![opens[0].session, opens[1].session]
        );
        assert!(c.batch_ready(), "refill must re-seat the FIFO waiters");
        let out = c.close_window(4.1).unwrap();
        assert_eq!(
            out.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
            vec![opens[2].session, opens[3].session]
        );
        assert!(!c.batch_ready());
        assert_eq!(c.metrics.batches, 2);
        assert_eq!(c.metrics.slot_occupancy.count(), 2);
        assert!((c.metrics.slot_occupancy.mean() - 2.0).abs() < 1e-12);
        c.audit();
    }

    /// An exhausted KV block pool parks admissions in the FIFO even
    /// while slots are free; verdict-released pages re-admit them in
    /// arrival order. No Busy, no drop — just rolling backpressure.
    #[test]
    fn continuous_kv_exhaustion_parks_waiters_until_pages_return() {
        let cfg = VerifierConfig {
            max_batch: 8,
            batch_mode: BatchMode::Continuous,
            // 2 pages x 4 tokens: exactly one prompt(3) + K(2) + 1 row
            kv_pool_pages: 2,
            kv_page_tokens: 4,
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = c.open_session(&pa, 16, 0).unwrap();
        let ob = c.open_session(&pb, 16, 0).unwrap();
        let da = queued(c.submit(0.0, oa.attachment, draft_for(oa.session, 0, &pa, 2), false).unwrap());
        assert_eq!(da, BatchDecision::CloseAt(0.0));
        assert_eq!(c.kv_pool.free_pages(), 0, "first row takes the whole pool");
        // plenty of free slots, but no pages: b waits in the FIFO
        let db = queued(c.submit(0.1, ob.attachment, draft_for(ob.session, 0, &pb, 2), false).unwrap());
        assert_eq!(db, BatchDecision::Queued);

        // a's verdict returns its pages; the refill seats b
        let out = c.close_window(1.0).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, oa.session);
        assert!(c.batch_ready());
        assert_eq!(c.kv_pool.free_pages(), 0, "b's row now holds the pool");
        let out = c.close_window(1.1).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, ob.session);
        assert_eq!(c.kv_pool.free_pages(), c.kv_pool.capacity());
        assert_eq!(c.metrics.batches, 2);
        c.audit();
    }

    /// A row larger than the ENTIRE pool is admitted unreserved instead
    /// of waiting forever — the pool bounds aggregate residency, not a
    /// single sequence's length.
    #[test]
    fn continuous_oversized_row_is_admitted_unreserved() {
        let cfg = VerifierConfig {
            max_batch: 4,
            batch_mode: BatchMode::Continuous,
            kv_pool_pages: 1,
            kv_page_tokens: 4, // pool covers 4 tokens; the row needs 6
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let p = vec![1, 70, 71];
        let o = c.open_session(&p, 16, 0).unwrap();
        let d = queued(c.submit(0.0, o.attachment, draft_for(o.session, 0, &p, 2), false).unwrap());
        assert_eq!(d, BatchDecision::CloseAt(0.0), "oversized row still admits");
        assert!(c.kv_leases.is_empty(), "no reservation for an oversized row");
        assert_eq!(c.close_window(0.5).unwrap().len(), 1);
        c.audit();
    }

    /// Voided slot occupants and FIFO waiters (detach, abort) return
    /// their pages without a verdict.
    #[test]
    fn continuous_teardown_releases_slot_pages() {
        let cfg = VerifierConfig {
            max_batch: 2,
            batch_mode: BatchMode::Continuous,
            kv_pool_pages: 8,
            kv_page_tokens: 4,
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = c.open_session(&pa, 16, 0).unwrap();
        let ob = c.open_session(&pb, 16, 0).unwrap();
        queued(c.submit(0.0, oa.attachment, draft_for(oa.session, 0, &pa, 2), false).unwrap());
        queued(c.submit(0.1, ob.attachment, draft_for(ob.session, 0, &pb, 2), false).unwrap());
        let held = c.kv_pool.in_use();
        assert!(held > 0);
        // a's link dies mid-slot: its draft is void, pages come back
        assert!(c.detach(1.0, oa.session, oa.attachment));
        assert!(c.kv_pool.in_use() < held, "detach must return a's pages");
        // b aborts outright from its slot
        c.abort_session(ob.session);
        assert_eq!(c.kv_pool.in_use(), 0);
        assert!(c.kv_leases.is_empty());
        assert_eq!(c.close_window(2.0).unwrap().len(), 0);
        c.audit();
    }

    #[test]
    fn capacity_floor_finishes_session_mid_batch() {
        let cfg = VerifierConfig {
            capacity_floor: 10,
            ..Default::default()
        };
        let mut backend = SyntheticTarget::new(7);
        backend.max_ctx = 20;
        let mut c = VerifierCore::new(cfg, Box::new(backend));
        // session a's long prompt puts it near the KV ceiling; b is far
        let pa: Vec<i32> = (0..10).map(|i| 1 + i).collect();
        let pb = vec![1, 80, 81];
        let oa = c.open_session(&pa, 64, 0).unwrap();
        let ob = c.open_session(&pb, 64, 0).unwrap();
        let (a, b) = (oa.session, ob.session);
        queued(c.submit(0.0, oa.attachment, draft_for(a, 0, &pa, 4), false).unwrap());
        queued(c.submit(0.0, ob.attachment, draft_for(b, 0, &pb, 4), false).unwrap());
        let out = c.close_window(0.1).unwrap();
        assert_eq!(out.len(), 2);
        let va = &out.iter().find(|(id, _)| *id == a).unwrap().1;
        let vb = &out.iter().find(|(id, _)| *id == b).unwrap().1;
        // zero drift: both fully accepted — but a crossed the capacity
        // floor mid-batch and is finished + torn down, b decodes on
        assert_eq!(va.tau, 4);
        assert!(va.eos, "capacity floor must finish the session");
        assert!(!vb.eos);
        assert_eq!(c.active_sessions(), 1);
        assert_eq!(c.metrics.sessions_completed, 1);
        // the survivor's next round still verifies in a fresh batch
        let mut committed_b = pb.clone();
        let toks = draft_for(b, 0, &pb, 4).tokens;
        committed_b.extend_from_slice(&toks[..vb.tau as usize]);
        committed_b.push(vb.correction);
        queued(c.submit(1.0, ob.attachment, draft_for(b, 1, &committed_b, 4), false).unwrap());
        assert_eq!(c.close_window(1.1).unwrap().len(), 1);
    }

    #[test]
    fn orphaned_pending_drafts_are_counted_not_silent() {
        let mut c = core(10.0, 8);
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = c.open_session(&pa, 8, 0).unwrap();
        let ob = c.open_session(&pb, 8, 0).unwrap();
        let (a, b) = (oa.session, ob.session);
        queued(c.submit(0.0, oa.attachment, draft_for(a, 0, &pa, 2), false).unwrap());
        queued(c.submit(0.0, ob.attachment, draft_for(b, 0, &pb, 2), false).unwrap());
        // a's pending draft vanishes behind the window's back (the
        // defensive branch a future lifecycle edit could reach)
        c.pending.remove(&a);
        let out = c.close_window(0.5).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, b);
        assert_eq!(c.metrics.drafts_orphaned, 1);
        // ...and a session torn down under its pending draft
        let mut committed_b = pb.clone();
        let toks = draft_for(b, 0, &pb, 2).tokens;
        committed_b.extend_from_slice(&toks[..out[0].1.tau as usize]);
        committed_b.push(out[0].1.correction);
        queued(c.submit(1.0, ob.attachment, draft_for(b, 1, &committed_b, 2), false).unwrap());
        c.sessions.remove(&b);
        let out = c.close_window(1.5).unwrap();
        assert!(out.is_empty(), "orphaned member must produce no verdict");
        assert_eq!(c.metrics.drafts_orphaned, 2);
    }

    #[test]
    fn conservation_audit_balances_after_mixed_lifecycle() {
        let mut c = core(10.0, 8);
        let trace = Trace::wall();
        c.cfg.trace = Some(trace.clone());
        let p = vec![1, 70, 71];
        let o = c.open_session(&p, 8, 0).unwrap();
        let id = o.session;
        queued(c.submit(0.0, o.attachment, draft_for(id, 0, &p, 2), false).unwrap());
        // duplicate while queued: the copy is swallowed, reply taken over
        match c.submit(0.1, o.attachment, draft_for(id, 0, &p, 2), false).unwrap() {
            SubmitOutcome::TakeOver => {}
            other => panic!("expected TakeOver, got {other:?}"),
        }
        let out = c.close_window(0.5).unwrap();
        assert_eq!(out.len(), 1);
        // retransmit of the verified round: replayed from the cache
        match c.submit(1.0, o.attachment, draft_for(id, 0, &p, 2), false).unwrap() {
            SubmitOutcome::Replay(_) => {}
            other => panic!("expected Replay, got {other:?}"),
        }
        assert_eq!(c.metrics.drafts_received, 3);
        assert_eq!(c.metrics.drafts_swallowed, 1);
        assert_eq!(c.metrics.verdicts_replayed, 1);
        assert_eq!(c.metrics.rounds, 1);
        assert_eq!(c.drafts_in_flight(), 0);
        c.audit(); // must not panic: every ledger balances
        // the cloud half of the round's span chain is in the journal
        for kind in [
            SpanKind::QueueWait,
            SpanKind::BucketPlan,
            SpanKind::VerifyBatch,
            SpanKind::Commit,
        ] {
            assert_eq!(trace.count(id, kind), 1, "{kind:?}");
        }
        // latency books move in lockstep with the round/batch counters
        assert_eq!(c.metrics.latency.verify_ms.count(), 1);
        assert_eq!(c.metrics.latency.queue_ms.count(), 1);
        assert_eq!(c.metrics.latency.round_ms.count(), 1);
    }

    #[test]
    fn admission_queue_defers_fresh_rounds_with_busy() {
        let cfg = VerifierConfig {
            window_ms: 10.0,
            max_batch: 8,
            admission_queue: 1,
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = c.open_session(&pa, 64, 0).unwrap();
        let ob = c.open_session(&pb, 64, 0).unwrap();
        let (a, b) = (oa.session, ob.session);
        queued(c.submit(0.0, oa.attachment, draft_for(a, 0, &pa, 2), true).unwrap());
        // b's fresh round hits the bound: deferred with a retry hint
        match c.submit(0.1, ob.attachment, draft_for(b, 0, &pb, 2), true).unwrap() {
            SubmitOutcome::Busy { retry_after_ms } => assert!(retry_after_ms >= 1),
            other => panic!("expected Busy, got {other:?}"),
        }
        assert_eq!(c.metrics.drafts_busy, 1);
        // a duplicate of the ADMITTED round is deduped before admission
        // (TakeOver, never Busy) — dedup filters run first
        assert!(matches!(
            c.submit(0.15, oa.attachment, draft_for(a, 0, &pa, 2), true).unwrap(),
            SubmitOutcome::TakeOver
        ));
        // a legacy peer (wire < 4) is always admitted
        queued(c.submit(0.2, ob.attachment, draft_for(b, 0, &pb, 2), false).unwrap());
        let out = c.close_window(0.3).unwrap();
        assert_eq!(out.len(), 2, "legacy round must verify alongside a's");
        // the queue drained: b's retried NEXT round is admitted
        let vb = &out.iter().find(|(id, _)| *id == b).unwrap().1;
        let mut committed_b = pb.clone();
        let toks = draft_for(b, 0, &pb, 2).tokens;
        committed_b.extend_from_slice(&toks[..vb.tau as usize]);
        committed_b.push(vb.correction);
        queued(c.submit(1.0, ob.attachment, draft_for(b, 1, &committed_b, 2), true).unwrap());
        assert_eq!(c.metrics.drafts_busy, 1, "admission after drain must not defer");
    }

    /// Tier-aware admission (wire v7): tier-1 traffic is turned away
    /// `tier_reserve` slots early, priority tiers may fill the whole
    /// queue — and nothing more: the cap still binds for every tier.
    #[test]
    fn tier_reserve_holds_admission_headroom_for_priority_tiers() {
        let cfg = VerifierConfig {
            window_ms: 10.0,
            max_batch: 8,
            admission_queue: 2,
            tier_reserve: 1,
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let pc = vec![1, 90, 91];
        let pd = vec![1, 60, 61];
        let oa = c.open_session(&pa, 64, 0).unwrap();
        let ob = c.open_session(&pb, 64, 0).unwrap();
        let oc = c.open_session_tier(&pc, 64, 0, 3).unwrap();
        let od = c.open_session_tier(&pd, 64, 0, 5).unwrap();
        // first tier-1 round fits under the reserved bound (2 - 1 = 1)
        queued(c.submit(0.0, oa.attachment, draft_for(oa.session, 0, &pa, 2), true).unwrap());
        // second tier-1 round hits the reserved bound: deferred
        assert!(matches!(
            c.submit(0.1, ob.attachment, draft_for(ob.session, 0, &pb, 2), true).unwrap(),
            SubmitOutcome::Busy { .. }
        ));
        // a priority tier sails past the reservation into the last slot
        queued(c.submit(0.2, oc.attachment, draft_for(oc.session, 0, &pc, 2), true).unwrap());
        // but the cap itself still binds for every tier
        assert!(matches!(
            c.submit(0.3, od.attachment, draft_for(od.session, 0, &pd, 2), true).unwrap(),
            SubmitOutcome::Busy { .. }
        ));
        assert_eq!(c.metrics.drafts_busy, 2);
    }

    /// The `Busy` retry hint scales with queue depth: a backlog of
    /// `queue / max_batch` windows quotes that many window periods, not
    /// the static one-window guess (autoscale satellite).
    #[test]
    fn busy_retry_hint_scales_with_queue_depth() {
        let cfg = VerifierConfig {
            window_ms: 10.0,
            max_batch: 1,
            admission_queue: 3,
            ..Default::default()
        };
        let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)));
        let prompts = [vec![1, 70, 71], vec![1, 80, 81], vec![1, 90, 91], vec![1, 60, 61]];
        let opens: Vec<_> =
            prompts.iter().map(|p| c.open_session(p, 64, 0).unwrap()).collect();
        for (o, p) in opens.iter().zip(&prompts).take(3) {
            queued(c.submit(0.0, o.attachment, draft_for(o.session, 0, p, 2), true).unwrap());
        }
        // 3 pending / max_batch 1 = 3 extra windows behind the current
        // one: the hint quotes 4 window periods of 10ms
        match c
            .submit(0.1, opens[3].attachment, draft_for(opens[3].session, 0, &prompts[3], 2), true)
            .unwrap()
        {
            SubmitOutcome::Busy { retry_after_ms } => assert_eq!(retry_after_ms, 40),
            other => panic!("expected Busy, got {other:?}"),
        }
    }

    #[test]
    fn idle_cloud_sweeps_residues_on_the_timer() {
        let rt = tokio::runtime::Builder::new_current_thread()
            .enable_all()
            .build()
            .unwrap();
        rt.block_on(async {
            let cfg = VerifierConfig {
                window_ms: 1.0,
                resume_grace_ms: 50.0,
                ..Default::default()
            };
            let h = VerifierHandle::spawn(cfg, || {
                Ok(Box::new(SyntheticTarget::new(7)) as Box<dyn VerifyBackend>)
            })
            .unwrap();
            let prompt = vec![1, 70, 71];
            // max_new 5: one K=4 round (+ bonus) finishes the session
            let o = h.open(prompt.clone(), 5, 0).await.unwrap();
            let msg = draft_for(o.session, 0, &prompt, 4);
            match h.verify(o.session, o.attachment, msg, 2).await.unwrap() {
                Some(VerifyReply::Verdict(v)) => assert!(v.eos),
                other => panic!("expected a verdict, got {other:?}"),
            }
            // NO further traffic: the periodic sweep alone must reap
            // the finished residue once its grace window expires
            tokio::time::sleep(Duration::from_millis(600)).await;
            let stats = h.stats().await.unwrap();
            assert_eq!(stats.sessions_completed, 1);
            assert_eq!(
                stats.residues_expired, 1,
                "idle cloud kept its residue past the grace window"
            );
            h.shutdown().await.unwrap();
        });
    }

    #[test]
    fn deploy_counts_hot_swap_and_keeps_sessions() {
        let cfg = VerifierConfig::default();
        let backend = SyntheticTarget::new(7).with_version("evolved", 0.3);
        let mut c = VerifierCore::new(cfg, Box::new(backend));
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let (id, seq1) = (o.session, o.target_seq);
        let seq2 = c.deploy("evolved").unwrap();
        assert!(seq2 > seq1);
        assert_eq!(c.metrics.hot_swaps, 1);
        // the session survives and keeps decoding on the new version
        c.submit(0.0, o.attachment, draft_for(id, 0, &prompt, 4), false).unwrap();
        let out = c.close_window(0.0).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(c.active_sessions(), 1);
    }

    // --- fleet handoff (serve::fleet, wire v5) ------------------------

    use crate::serve::fleet::SessionLedger;

    fn evolved_core(ledger: &SessionLedger) -> VerifierCore {
        let mut t = SyntheticTarget::new(7).with_version("evolved", 0.3);
        t.deploy("evolved").unwrap();
        VerifierCore::new(VerifierConfig::default(), Box::new(t)).with_ledger(ledger.clone())
    }

    /// Drive one verification round on `c` as a wire-v5 peer: submit
    /// the pure draft for `round` from `committed`, close the window,
    /// apply the verdict to the edge mirror.
    fn drive_round(
        c: &mut VerifierCore,
        att: u64,
        id: u32,
        round: u32,
        committed: &mut Vec<i32>,
    ) -> VerifyMsg {
        let msg = draft_for(id, round, committed, 4);
        let toks = msg.tokens.clone();
        queued(c.submit_from(round as f64, att, msg, 5).unwrap());
        let v = c.close_window(round as f64).unwrap().remove(0).1;
        committed.extend_from_slice(&toks[..v.tau as usize]);
        committed.push(v.correction);
        v
    }

    /// Tentpole (core level): draining replica A exports mid-decode,
    /// replica B imports from the shared ledger on the edge's Resume,
    /// and the committed trajectory is byte-identical to one replica
    /// decoding uninterrupted — the frozen-draft/evolving-target
    /// decoupling applied across the fleet.
    #[test]
    fn drain_hands_session_to_peer_with_identical_trajectory() {
        // reference: one replica, uninterrupted, 6 rounds
        let mut reference = evolved_core(&SessionLedger::new());
        let prompt = vec![1, 70, 71];
        let o = reference.open_session(&prompt, 256, 0).unwrap();
        let mut want = prompt.clone();
        for round in 0..6 {
            drive_round(&mut reference, o.attachment, o.session, round, &mut want);
        }

        // fleet: 3 rounds on A, drain, resume on B, 3 rounds there
        let ledger = SessionLedger::new();
        let mut a = evolved_core(&ledger);
        let mut b = evolved_core(&ledger);
        let oa = a.open_session(&prompt, 256, 0).unwrap();
        let mut committed = prompt.clone();
        for round in 0..3 {
            drive_round(&mut a, oa.attachment, oa.session, round, &mut committed);
        }
        a.set_redirect(Some("replica-b".into()));
        assert!(a.telemetry().draining);
        let (addr, token) = match a
            .submit_from(3.0, oa.attachment, draft_for(oa.session, 3, &committed, 4), 5)
            .unwrap()
        {
            SubmitOutcome::Redirect { addr, resume_token } => (addr, resume_token),
            other => panic!("expected Redirect, got {other:?}"),
        };
        assert_eq!(addr, "replica-b");
        assert_eq!(token, oa.resume_token);
        assert_eq!(a.active_sessions(), 0, "session must leave the exporter");
        assert_eq!(ledger.len(), 1);

        // the edge resumes on B with its committed position
        let info = b.resume(token, committed.len()).unwrap();
        assert!(!info.done);
        assert!(info.tail.is_empty(), "edge was up to date at handoff");
        assert_eq!(info.rounds, 3, "round counter travels with the session");
        assert!(ledger.is_empty(), "import consumes the ledger entry");
        for round in 3..6 {
            drive_round(&mut b, info.attachment, info.session, round, &mut committed);
        }
        assert_eq!(committed, want, "handoff changed a committed token");
        assert_eq!(a.metrics.sessions_redirected, 1);
        assert_eq!(b.metrics.sessions_imported, 1);
        assert_eq!(b.metrics.sessions_resumed, 1);
        // a second handoff of the SAME session keeps working (token is
        // preserved across the import)
        b.set_redirect(Some("replica-c".into()));
        match b
            .submit_from(6.0, info.attachment, draft_for(info.session, 6, &committed, 4), 5)
            .unwrap()
        {
            SubmitOutcome::Redirect { addr, resume_token } => {
                assert_eq!(addr, "replica-c");
                assert_eq!(resume_token, token);
            }
            other => panic!("expected second Redirect, got {other:?}"),
        }
    }

    /// Ledger TTL satellite: an export the edge NEVER resumes ages out
    /// of the shared ledger on the TTL sweep — before the exporter's
    /// own grace-window reap, and even though nothing is parked locally
    /// (the sweep runs behind its own gate, not the residue gate).
    #[test]
    fn ledger_ttl_sweep_collects_abandoned_exports() {
        let ledger = SessionLedger::new();
        let mut t = SyntheticTarget::new(7).with_version("evolved", 0.3);
        t.deploy("evolved").unwrap();
        let cfg = VerifierConfig {
            ledger_ttl_ms: 50.0,
            resume_grace_ms: 10_000.0,
            ..Default::default()
        };
        let mut a = VerifierCore::new(cfg, Box::new(t)).with_ledger(ledger.clone());
        let prompt = vec![1, 70, 71];
        let o = a.open_session(&prompt, 256, 0).unwrap();
        let mut committed = prompt.clone();
        drive_round(&mut a, o.attachment, o.session, 0, &mut committed);
        a.set_redirect(Some("replica-b".into()));
        assert!(matches!(
            a.submit_from(1.0, o.attachment, draft_for(o.session, 1, &committed, 4), 5)
                .unwrap(),
            SubmitOutcome::Redirect { .. }
        ));
        assert_eq!(ledger.len(), 1);
        // within the TTL the entry stays resumable
        a.evict_expired(40.0);
        assert_eq!(ledger.len(), 1);
        assert_eq!(a.metrics.ledger_expired, 0);
        // past it the abandoned export is collected and counted
        a.evict_expired(60.0);
        assert!(ledger.is_empty(), "TTL sweep must reap the abandoned export");
        assert_eq!(a.metrics.ledger_expired, 1);
        // the exporter swept its own orphan: the ledger-conservation
        // invariant (expired <= redirected) holds
        assert!(a.metrics.invariant_violations(a.active_sessions(), 0).is_empty());
    }

    /// Satellite (fleet edge cases): after a session is exported, a
    /// late `Cancel` and in-flight speculative drafts from the old
    /// attachment are absorbed — swallowed or replayed, never fatal —
    /// pinning the redirect-races-cancel corner at the core level.
    #[test]
    fn cancel_and_late_drafts_after_export_are_absorbed() {
        let ledger = SessionLedger::new();
        let mut a = evolved_core(&ledger);
        let prompt = vec![1, 70, 71];
        let o = a.open_session(&prompt, 256, 0).unwrap();
        let mut committed = prompt.clone();
        let v0 = drive_round(&mut a, o.attachment, o.session, 0, &mut committed);
        a.set_redirect(Some("replica-b".into()));
        let head = draft_for(o.session, 1, &committed, 4);
        assert!(matches!(
            a.submit_from(1.0, o.attachment, head, 5).unwrap(),
            SubmitOutcome::Redirect { .. }
        ));
        // a Cancel racing the redirect (retracting the speculative round
        // the edge had in flight): no-op, not a panic
        assert!(a.cancel(o.session, o.attachment, 2).is_empty());
        // the in-flight speculative round itself straggles in: wasted
        // speculation, swallowed
        let spec = spec_draft_for(o.session, 2, &committed, &[9, 9, 9], 4);
        let cancelled_before = a.metrics.drafts_cancelled;
        assert!(matches!(
            a.submit_from(1.1, o.attachment, spec, 5).unwrap(),
            SubmitOutcome::Swallowed
        ));
        assert_eq!(a.metrics.drafts_cancelled, cancelled_before + 1);
        // a duplicate of the last VERIFIED round still replays from the
        // tombstoned verdict cache
        let d0 = draft_for(o.session, 0, &prompt, 4);
        match a.submit_from(1.2, o.attachment, d0, 5).unwrap() {
            SubmitOutcome::Replay(v) => assert_eq!(v, v0),
            other => panic!("expected Replay, got {other:?}"),
        }
    }

    /// A peer below wire v5 is never redirected, and without a ledger
    /// even a v5 peer is admitted — draining degrades to serving.
    #[test]
    fn redirect_needs_wire_v5_and_a_ledger() {
        let ledger = SessionLedger::new();
        let mut a = evolved_core(&ledger);
        a.set_redirect(Some("replica-b".into()));
        let prompt = vec![1, 70, 71];
        let o = a.open_session(&prompt, 64, 0).unwrap();
        // v4 peer: admitted (it could not parse a Redirect)
        queued(
            a.submit_from(0.0, o.attachment, draft_for(o.session, 0, &prompt, 4), 4)
                .unwrap(),
        );
        assert_eq!(a.metrics.sessions_redirected, 0);

        // no ledger: even a v5 peer is admitted (export is impossible)
        let mut c = VerifierCore::new(
            VerifierConfig::default(),
            Box::new(SyntheticTarget::new(7)),
        );
        c.set_redirect(Some("replica-b".into()));
        let o2 = c.open_session(&prompt, 64, 0).unwrap();
        queued(
            c.submit_from(0.0, o2.attachment, draft_for(o2.session, 0, &prompt, 4), 5)
                .unwrap(),
        );
        assert_eq!(c.metrics.sessions_redirected, 0);
    }

    /// Progress guarantee: an edge that cannot follow the redirect
    /// resumes in place, the replica re-imports its own export, and the
    /// session is NOT bounced again while the drain continues.
    #[test]
    fn reimported_session_is_not_bounced_again() {
        let ledger = SessionLedger::new();
        let mut a = evolved_core(&ledger);
        a.set_redirect(Some("replica-b".into()));
        let prompt = vec![1, 70, 71];
        let o = a.open_session(&prompt, 256, 0).unwrap();
        let mut committed = prompt.clone();
        let token = match a
            .submit_from(0.0, o.attachment, draft_for(o.session, 0, &prompt, 4), 5)
            .unwrap()
        {
            SubmitOutcome::Redirect { resume_token, .. } => resume_token,
            other => panic!("expected Redirect, got {other:?}"),
        };
        // the edge resumes HERE (e.g. a mux stream pinned to its
        // connection); A re-imports its own export
        let info = a.resume(token, committed.len()).unwrap();
        assert!(ledger.is_empty());
        // still draining, but this session now makes progress
        drive_round(&mut a, info.attachment, info.session, 0, &mut committed);
        assert_eq!(a.metrics.sessions_redirected, 1);
        assert_eq!(a.metrics.sessions_imported, 1);
        // the tombstones expire with the grace window, after which the
        // session becomes eligible for one more handoff
        a.evict_expired(a.cfg.resume_grace_ms * 2.0 + 1.0);
        match a
            .submit_from(100.0, info.attachment, draft_for(info.session, 1, &committed, 4), 5)
            .unwrap()
        {
            SubmitOutcome::Redirect { .. } => {}
            other => panic!("expected post-grace Redirect, got {other:?}"),
        }
    }

    /// A saturated pipeline's next head round arrives EARLY and parks
    /// in the speculative queue — the drain must fire at PROMOTION
    /// time, or a continuously-pipelined session could never be shed.
    #[test]
    fn drain_redirects_promoted_speculative_round() {
        let ledger = SessionLedger::new();
        // zero drift: the speculation always holds, so the queued
        // round reaches the promotion (not the basis-discard) path
        let mut a = VerifierCore::new(
            VerifierConfig::default(),
            Box::new(SyntheticTarget::new(7)),
        )
        .with_ledger(ledger.clone());
        let prompt = vec![1, 70, 71];
        let o = a.open_session(&prompt, 256, 0).unwrap();
        let d0 = draft_for(o.session, 0, &prompt, 4);
        let assumed = assumed_outcome(&prompt, &d0.tokens);
        queued(a.submit_from(0.0, o.attachment, d0, 5).unwrap());
        let d1 = spec_draft_for(o.session, 1, &prompt, &assumed, 4);
        assert!(matches!(
            a.submit_from(0.1, o.attachment, d1, 5).unwrap(),
            SubmitOutcome::Deferred
        ));
        // the drain starts with both rounds in flight: round 0 (already
        // admitted) verifies normally...
        a.set_redirect(Some("replica-b".into()));
        let out = a.close_window(0.2).unwrap();
        assert_eq!(out.len(), 1);
        // ...and round 1's promotion becomes the handoff point
        let (decisions, _dropped, redirects) = a.promote_ready(0.3);
        assert!(decisions.is_empty(), "nothing may enter the window");
        assert_eq!(redirects.len(), 1);
        let (id, round, addr, token) = redirects[0].clone();
        assert_eq!(id, o.session);
        assert_eq!(round, 1);
        assert_eq!(addr, "replica-b");
        assert_eq!(token, o.resume_token);
        assert_eq!(a.active_sessions(), 0, "session must leave the exporter");
        assert_eq!(ledger.len(), 1);
        assert_eq!(a.metrics.sessions_redirected, 1);
        assert!(a.metrics.drafts_cancelled >= 1, "the promoted draft is waste");
    }

    /// Targeted rebalance: `redirect_session` moves exactly one
    /// session; its siblings on the same replica are untouched.
    #[test]
    fn targeted_redirect_moves_one_session_only() {
        let ledger = SessionLedger::new();
        let mut a = evolved_core(&ledger);
        let pa = vec![1, 70, 71];
        let pb = vec![1, 80, 81];
        let oa = a.open_session(&pa, 64, 0).unwrap();
        let ob = a.open_session(&pb, 64, 0).unwrap();
        a.redirect_session(oa.session, "replica-b".into());
        assert!(matches!(
            a.submit_from(0.0, oa.attachment, draft_for(oa.session, 0, &pa, 4), 5)
                .unwrap(),
            SubmitOutcome::Redirect { .. }
        ));
        queued(
            a.submit_from(0.1, ob.attachment, draft_for(ob.session, 0, &pb, 4), 5)
                .unwrap(),
        );
        assert_eq!(a.metrics.sessions_redirected, 1);
        assert_eq!(a.active_sessions(), 1, "sibling stays");
        assert!(!a.telemetry().draining, "targeted move is not a drain");
    }

    // --- eviction-sweep gate under load-harness churn -----------------

    /// Earliest pending deadline across every residue map the sweep is
    /// responsible for (the oracle the `next_sweep_ms` gate must never
    /// exceed).
    fn earliest_pending_deadline(c: &VerifierCore) -> f64 {
        c.parked
            .values()
            .copied()
            .chain(c.finished.values().map(|f| f.deadline_ms))
            .chain(c.redirected_ids.values().copied())
            .chain(c.redirected_tokens.values().map(|(d, _)| *d))
            .fold(f64::INFINITY, f64::min)
    }

    /// The gate is allowed to be stale-EARLY (costs one extra sweep)
    /// but never stale-LATE (a residue would be reaped after its grace)
    /// and never `INFINITY` while residues are pending.
    fn assert_gate_fresh(c: &VerifierCore) {
        let min = earliest_pending_deadline(c);
        assert!(
            c.next_sweep_ms <= min,
            "sweep gate {} lags earliest pending deadline {min}",
            c.next_sweep_ms
        );
    }

    /// Load-harness churn: thousands of randomized open / round /
    /// detach / resume / finish / redirect-export / evict cycles
    /// (seeds [3, 17, 42]). After EVERY operation the sweep gate must
    /// cover the earliest pending deadline, and after every sweep no
    /// expired residue may survive. Drains to empty at the end: all
    /// four residue maps empty and the gate back at `INFINITY`.
    #[test]
    fn sweep_gate_survives_randomized_churn() {
        for &seed in &[3u64, 17, 42] {
            let ledger = SessionLedger::new();
            let cfg = VerifierConfig {
                resume_grace_ms: 50.0,
                ..Default::default()
            };
            let mut c = VerifierCore::new(cfg, Box::new(SyntheticTarget::new(7)))
                .with_ledger(ledger.clone());
            let mut r = SplitMix64::new(seed);
            let prompt = vec![1, 70, 71];
            // (id, attachment, resume token, committed mirror, next round)
            let mut live: Vec<(u32, u64, u64, Vec<i32>, u32)> = Vec::new();
            // (resume token, committed mirror at detach time)
            let mut detached: Vec<(u64, Vec<i32>)> = Vec::new();
            let mut t = 0.0;

            for cycle in 0..2000 {
                t = cycle as f64 * 7.0;
                match r.next_range(6) {
                    0 => {
                        let o = c.open_session(&prompt, 8, 0).unwrap();
                        live.push((o.session, o.attachment, o.resume_token, prompt.clone(), 0));
                    }
                    1 if !live.is_empty() => {
                        // one verification round; eos leaves a finished
                        // residue behind
                        let i = r.next_range(live.len() as u64) as usize;
                        let (id, att, token, mut committed, round) = live.swap_remove(i);
                        let msg = draft_for(id, round, &committed, 4);
                        let tokens = msg.tokens.clone();
                        match c.submit(t, att, msg, false).unwrap() {
                            SubmitOutcome::Queued(_) => {
                                let mut finished = false;
                                for (vid, vmsg) in c.close_window(t).unwrap() {
                                    assert_eq!(vid, id);
                                    committed.extend_from_slice(&tokens[..vmsg.tau as usize]);
                                    committed.push(vmsg.correction);
                                    finished = vmsg.eos;
                                }
                                if finished {
                                    detached.push((token, committed));
                                } else {
                                    live.push((id, att, token, committed, round + 1));
                                }
                            }
                            SubmitOutcome::Busy { .. } => {
                                live.push((id, att, token, committed, round));
                            }
                            other => panic!("unexpected outcome {other:?}"),
                        }
                    }
                    2 if !live.is_empty() => {
                        let i = r.next_range(live.len() as u64) as usize;
                        let (id, att, token, committed, _) = live.swap_remove(i);
                        assert!(c.detach(t, id, att), "detach of a live session");
                        detached.push((token, committed));
                    }
                    3 if !detached.is_empty() => {
                        // resume (may race eviction and lose: the token
                        // is simply gone, which is fine)
                        let i = r.next_range(detached.len() as u64) as usize;
                        let (token, committed) = detached.swap_remove(i);
                        if let Ok(info) = c.resume(token, committed.len()) {
                            if !info.done {
                                // the mirror held the full sequence, so
                                // the resume tail must be empty
                                assert!(info.tail.is_empty());
                                live.push((
                                    info.session,
                                    info.attachment,
                                    token,
                                    committed,
                                    info.rounds as u32,
                                ));
                            }
                        }
                    }
                    4 if !live.is_empty() => {
                        // targeted redirect: the next draft exports the
                        // session into the shared ledger
                        let i = r.next_range(live.len() as u64) as usize;
                        let (id, att, token, committed, round) = live.swap_remove(i);
                        c.redirect_session(id, "replica-b".into());
                        match c.submit_from(t, att, draft_for(id, round, &committed, 4), 5) {
                            Ok(SubmitOutcome::Redirect { .. }) => {
                                detached.push((token, committed));
                            }
                            Ok(SubmitOutcome::Busy { .. }) => {
                                // deferred before the export could fire;
                                // the session stays redirect-marked, so
                                // drop it rather than draft from it again
                                c.abort_session(id);
                                let _ = (token, committed);
                            }
                            other => panic!("unexpected outcome {other:?}"),
                        }
                    }
                    _ => {
                        c.evict_expired(t);
                        assert!(
                            earliest_pending_deadline(&c) >= t,
                            "sweep at {t} left an expired residue behind"
                        );
                    }
                }
                assert_gate_fresh(&c);
            }

            // drain: everything pending expires, one sweep reaps it all
            let t_end = t + 10_000.0;
            c.evict_expired(t_end);
            assert!(c.parked.is_empty(), "seed {seed}: parked drained");
            assert!(c.finished.is_empty(), "seed {seed}: residues drained");
            assert!(c.redirected_ids.is_empty(), "seed {seed}: tombstones drained");
            assert!(c.redirected_tokens.is_empty(), "seed {seed}: exports drained");
            assert_eq!(
                c.next_sweep_ms,
                f64::INFINITY,
                "seed {seed}: empty sweep state must disarm the gate"
            );
        }
    }

    /// Tight park/resume cycles: the gate tracks each fresh park
    /// exactly, a resume may leave it stale-early but never stuck — the
    /// next sweep past the stale deadline reaps nothing, resets the
    /// gate to `INFINITY`, and the resumed session survives.
    #[test]
    fn park_resume_cycles_never_wedge_the_sweep_gate() {
        let mut c = core_with_grace(50.0);
        let prompt = vec![1, 70, 71];
        let o = c.open_session(&prompt, 64, 0).unwrap();
        let (id, token) = (o.session, o.resume_token);
        let mut att = o.attachment;
        for i in 0..2000 {
            let t_park = i as f64 * 100.0;
            assert!(c.detach(t_park, id, att));
            assert_eq!(c.next_sweep_ms, t_park + 50.0, "fresh park arms the gate");
            let info = c.resume(token, prompt.len()).unwrap();
            att = info.attachment;
            // stale-early is allowed...
            assert!(c.next_sweep_ms <= t_park + 50.0);
            // ...but one sweep past the stale deadline must reset it
            assert_eq!(c.evict_expired(t_park + 50.1), 0);
            assert_eq!(c.next_sweep_ms, f64::INFINITY, "cycle {i}: gate stuck");
            assert!(c.sessions.contains_key(&id), "resumed session reaped");
        }

        // eviction timing is exact: the deadline itself is still within
        // grace, the first instant strictly past it reaps
        let t_park = 1_000_000.0;
        assert!(c.detach(t_park, id, att));
        assert_eq!(c.evict_expired(t_park + 50.0), 0, "deadline is inclusive");
        assert!(c.parked.contains_key(&id));
        assert_eq!(c.evict_expired(t_park + 50.1), 1, "strictly past: reaped");
        assert!(c.parked.is_empty());
        assert!(!c.sessions.contains_key(&id));
        assert_eq!(c.next_sweep_ms, f64::INFINITY);
    }
}
