//! Transport-agnostic session + batching state machines, shared by the
//! virtual-clock simulator (`coordinator::scheduler`) and the real
//! tokio server (`serve::cloud`). Factoring them out is what guarantees
//! the loopback serving path and the simulation commit byte-identical
//! token trajectories for a fixed seed.

/// Dynamic verification batching window (vLLM-style continuous batching
/// applied to verification blocks). Time is an opaque `f64` in ms — the
/// simulator feeds virtual time, the server feeds a monotonic clock.
#[derive(Debug, Clone)]
pub struct BatchWindow {
    pub window_ms: f64,
    pub max_batch: usize,
    members: Vec<u32>,
    window_open: bool,
    /// Bumped on every `close()`. A scheduled close timer records the
    /// epoch it was armed for; if the window was already drained (e.g.
    /// by a `CloseNow`), the stale timer sees a newer epoch and must
    /// not close the next window early.
    epoch: u64,
}

/// What the caller must do after offering a request to the window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchDecision {
    /// Batch reached `max_batch`: close it immediately.
    CloseNow,
    /// First request of a fresh window: schedule a close at this time.
    CloseAt(f64),
    /// A window is already pending; nothing to schedule.
    Queued,
}

impl BatchWindow {
    pub fn new(window_ms: f64, max_batch: usize) -> BatchWindow {
        BatchWindow {
            window_ms,
            max_batch: max_batch.max(1),
            members: Vec::new(),
            window_open: false,
            epoch: 0,
        }
    }

    /// Current window generation (see `epoch` field docs).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Add a verify request to the open batch.
    pub fn offer(&mut self, now_ms: f64, id: u32) -> BatchDecision {
        self.members.push(id);
        if self.members.len() >= self.max_batch {
            BatchDecision::CloseNow
        } else if !self.window_open {
            self.window_open = true;
            BatchDecision::CloseAt(now_ms + self.window_ms)
        } else {
            BatchDecision::Queued
        }
    }

    /// Drop a pending member whose draft was voided (link died, session
    /// stolen by a reconnect, or aborted) — without this, a fast
    /// resume-and-resubmit within the same window would enter the id
    /// twice, inflating occupancy and closing windows early.
    pub fn remove(&mut self, id: u32) {
        self.members.retain(|&m| m != id);
    }

    /// Close the window and take its members (may be empty if a timer
    /// fired after a `CloseNow` already drained it — callers skip those).
    pub fn close(&mut self) -> Vec<u32> {
        self.window_open = false;
        self.epoch += 1;
        std::mem::take(&mut self.members)
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// How the verifier forms cross-connection batches
/// (`VerifierConfig::batch_mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchMode {
    /// Close-the-window batching ([`BatchWindow`]): the first draft of a
    /// fresh window arms a `window_ms` timer and the batch closes on the
    /// timer or on reaching `max_batch` — whichever comes first. Simple
    /// and well-amortized, but every draft pays up to `window_ms` of
    /// queue latency waiting for the edge to arrive.
    #[default]
    Windowed,
    /// Continuous (rolling-admission) batching ([`SlotBatch`]): the
    /// batch is always open. An arriving draft takes a free verification
    /// slot immediately (KV pages permitting) and the batch closes as
    /// soon as the command queue drains — or instantly when the slots
    /// fill. Verdicts free slots, which are refilled from a FIFO of
    /// waiters, so admission rolls instead of quantizing on window
    /// edges. Greedy verdicts are pure functions of (context, draft),
    /// so committed sequences stay byte-identical to the windowed path.
    Continuous,
}

impl BatchMode {
    /// Parse a CLI value (`--batch-mode window|continuous`).
    pub fn parse(s: &str) -> Option<BatchMode> {
        match s {
            "window" | "windowed" => Some(BatchMode::Windowed),
            "continuous" | "cont" => Some(BatchMode::Continuous),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BatchMode::Windowed => "window",
            BatchMode::Continuous => "continuous",
        }
    }
}

/// Rolling-admission slot state for continuous batching
/// ([`BatchMode::Continuous`]): `slots` concurrent verification rows
/// (the stacked executor's maximum B) plus a FIFO of admitted-but-
/// unslotted waiters. Pure bookkeeping over session ids — the verifier
/// layers KV-page leasing on top and decides *when* a waiter may take a
/// slot; this struct only guarantees admission order.
#[derive(Debug, Clone)]
pub struct SlotBatch {
    slots: usize,
    /// Slot occupants in admission order — the next close verifies
    /// exactly these, in this order (determinism contract).
    occupied: Vec<u32>,
    /// Waiters parked behind a full slot table (or an exhausted KV
    /// pool), admitted strictly first-in-first-out as slots free.
    fifo: std::collections::VecDeque<u32>,
}

impl SlotBatch {
    pub fn new(slots: usize) -> SlotBatch {
        SlotBatch {
            slots: slots.max(1),
            occupied: Vec::new(),
            fifo: std::collections::VecDeque::new(),
        }
    }

    /// Free verification slots right now.
    pub fn free_slots(&self) -> usize {
        self.slots - self.occupied.len()
    }

    /// Seat `id` in a free slot (caller has checked `free_slots() > 0`
    /// and reserved its KV pages). Filling the last slot demands an
    /// immediate close; otherwise the batch should close as soon as the
    /// caller's command queue drains — `CloseAt(now_ms)`, a zero-delay
    /// deadline that still coalesces a burst of already-queued drafts.
    pub fn admit(&mut self, now_ms: f64, id: u32) -> BatchDecision {
        debug_assert!(self.occupied.len() < self.slots, "admit into a full slot table");
        self.occupied.push(id);
        if self.occupied.len() >= self.slots {
            BatchDecision::CloseNow
        } else {
            BatchDecision::CloseAt(now_ms)
        }
    }

    /// Park `id` behind the full slot table (or an exhausted KV pool).
    pub fn enqueue(&mut self, id: u32) -> BatchDecision {
        self.fifo.push_back(id);
        BatchDecision::Queued
    }

    /// Next waiter in line, if any (admission stays FIFO: callers peek,
    /// check the KV reservation, then [`pop_waiter`](Self::pop_waiter)).
    pub fn peek_waiter(&self) -> Option<u32> {
        self.fifo.front().copied()
    }

    pub fn pop_waiter(&mut self) -> Option<u32> {
        self.fifo.pop_front()
    }

    /// Take the current slot occupants for verification, in admission
    /// order. Waiters stay parked — the verifier refills after the
    /// verdicts free slots (and KV pages).
    pub fn take(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.occupied)
    }

    /// Drop a voided member (link died, session stolen by a reconnect,
    /// or aborted) from its slot or the waiting line.
    pub fn remove(&mut self, id: u32) {
        self.occupied.retain(|&m| m != id);
        self.fifo.retain(|&m| m != id);
    }

    /// Occupied slots (the batch a close would verify).
    pub fn occupied_len(&self) -> usize {
        self.occupied.len()
    }

    /// FIFO waiters without a slot yet.
    pub fn waiting_len(&self) -> usize {
        self.fifo.len()
    }

    /// Total admitted drafts (slotted + waiting).
    pub fn len(&self) -> usize {
        self.occupied.len() + self.fifo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.occupied.is_empty() && self.fifo.is_empty()
    }
}

/// Per-session decoding progress — the part of Algorithm 2's state that
/// both endpoints must agree on. The cloud keeps one per KV session; the
/// edge keeps its own mirror and both advance it with `apply_verdict`,
/// so the committed sequence can never diverge silently.
#[derive(Debug, Clone)]
pub struct SessionCore {
    pub id: u32,
    /// Full committed sequence: prompt + generated tokens.
    pub committed: Vec<i32>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub new_tokens: usize,
    pub rounds: usize,
    pub accepted: usize,
    pub drafted: usize,
    pub done: bool,
    /// Pipelined drafting: OPTIMISTIC tokens assumed committed beyond
    /// `committed` — the in-flight rounds' draft blocks plus their
    /// predicted bonus tokens. The edge drafts round r+1 from
    /// `committed ++ speculated` while round r verifies; a verdict that
    /// breaks the assumption rolls the whole suffix back
    /// ([`SessionCore::rollback_speculation`]). Always empty in
    /// sequential mode and on the cloud side.
    pub speculated: Vec<i32>,
}

impl SessionCore {
    pub fn new(id: u32, prompt: &[i32], max_new: usize) -> SessionCore {
        SessionCore {
            id,
            committed: prompt.to_vec(),
            prompt_len: prompt.len(),
            max_new,
            new_tokens: 0,
            rounds: 0,
            accepted: 0,
            drafted: 0,
            done: false,
            speculated: Vec::new(),
        }
    }

    /// Reconstruct a session mid-decode from portable state (a fleet
    /// handoff import, `serve::fleet`): the full committed sequence,
    /// the ORIGINAL prompt boundary, and the counters accumulated so
    /// far. The invariant `new_tokens == committed.len() - prompt_len`
    /// is restored from the arguments, so a resumed-on-another-replica
    /// session is indistinguishable from one that decoded here all
    /// along — which is what keeps fleet trajectories byte-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        id: u32,
        committed: Vec<i32>,
        prompt_len: usize,
        max_new: usize,
        rounds: usize,
        accepted: usize,
        drafted: usize,
        done: bool,
    ) -> SessionCore {
        let prompt_len = prompt_len.min(committed.len());
        SessionCore {
            id,
            new_tokens: committed.len() - prompt_len,
            committed,
            prompt_len,
            max_new,
            rounds,
            accepted,
            drafted,
            done,
            speculated: Vec::new(),
        }
    }

    // --- speculative-prefix bookkeeping (pipelined drafting) ----------

    /// Optimistic decode context: the committed prefix plus every
    /// in-flight round's assumed outcome. What the NEXT speculative
    /// round drafts from.
    pub fn optimistic_context(&self) -> Vec<i32> {
        let mut ctx = Vec::with_capacity(self.committed.len() + self.speculated.len());
        ctx.extend_from_slice(&self.committed);
        ctx.extend_from_slice(&self.speculated);
        ctx
    }

    /// Optimistically generated tokens if every in-flight round lands
    /// fully accepted — gates further speculative launches against
    /// `max_new`.
    pub fn optimistic_new_tokens(&self) -> usize {
        self.committed.len() + self.speculated.len() - self.prompt_len
    }

    /// Record one in-flight round's assumed outcome (its draft block +
    /// predicted bonus token) on the speculative suffix.
    pub fn speculate(&mut self, assumed: &[i32]) {
        self.speculated.extend_from_slice(assumed);
    }

    /// A verdict confirmed the head in-flight assumption exactly: its
    /// `n` tokens moved from speculation to the committed sequence
    /// (via [`SessionCore::apply_verdict`]); drop them from the suffix.
    pub fn confirm_speculation(&mut self, n: usize) {
        let n = n.min(self.speculated.len());
        self.speculated.drain(..n);
    }

    /// A verdict broke the optimistic prefix (partial acceptance, or a
    /// bonus-token miss): every in-flight round beyond it was drafted
    /// from a context that will never exist. Drop the whole suffix;
    /// returns how many speculative tokens were thrown away (the
    /// `draft_tokens_wasted` contribution includes these minus the
    /// bonus predictions, which the caller tracks per round).
    pub fn rollback_speculation(&mut self) -> usize {
        let n = self.speculated.len();
        self.speculated.clear();
        n
    }

    /// Commit one round's outcome: accepted prefix + correction/bonus
    /// token. Returns true when the session just finished.
    pub fn apply_verdict(
        &mut self,
        draft: &[i32],
        tau: usize,
        correction: i32,
        eos: bool,
        out_of_capacity: bool,
    ) -> bool {
        debug_assert!(tau <= draft.len(), "tau {tau} > draft {}", draft.len());
        let tau = tau.min(draft.len());
        self.committed.extend_from_slice(&draft[..tau]);
        self.committed.push(correction);
        self.new_tokens += tau + 1;
        self.accepted += tau;
        self.drafted += draft.len();
        self.rounds += 1;
        if eos || self.new_tokens >= self.max_new || out_of_capacity {
            self.done = true;
        }
        self.done
    }

    /// Committed length (prompt + generated) — the position a resume
    /// handshake reports to the cloud.
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// Committed tokens beyond `from` — what a resuming peer is missing.
    /// The cloud's sequence can only be AHEAD of the edge's (verdicts
    /// applied whose replies were lost), so this suffix is exactly the
    /// catch-up payload of a `ResumeAck`.
    pub fn committed_tail(&self, from: usize) -> &[i32] {
        &self.committed[from.min(self.committed.len())..]
    }

    /// Fast-forward the mirror with a `ResumeAck`: append the committed
    /// tail the cloud applied while the link was down and sync the round
    /// counter, preserving the invariant
    /// `new_tokens == committed.len() - prompt_len`. Acceptance counters
    /// cannot be reconstructed for lost rounds and are left as-is (the
    /// committed sequence, not the counters, is the correctness
    /// contract under faults). Returns true when the session is done.
    pub fn fast_forward(&mut self, tail: &[i32], rounds: usize, done: bool) -> bool {
        // any in-flight speculation died with the old link
        self.speculated.clear();
        self.committed.extend_from_slice(tail);
        self.new_tokens = self.committed.len() - self.prompt_len;
        self.rounds = rounds;
        if done || self.new_tokens >= self.max_new {
            self.done = true;
        }
        self.done
    }

    /// Acceptance rate over the session so far.
    pub fn acceptance(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    pub fn outcome(&self) -> SessionOutcome {
        SessionOutcome {
            id: self.id,
            new_tokens: self.new_tokens,
            accepted: self.accepted,
            drafted: self.drafted,
            rounds: self.rounds,
        }
    }
}

/// Final per-session counters (comparable across sim / loopback / TCP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionOutcome {
    pub id: u32,
    pub new_tokens: usize,
    pub accepted: usize,
    pub drafted: usize,
    pub rounds: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_closes_on_capacity_or_timer() {
        let mut w = BatchWindow::new(10.0, 3);
        assert_eq!(w.offer(0.0, 1), BatchDecision::CloseAt(10.0));
        assert_eq!(w.offer(2.0, 2), BatchDecision::Queued);
        assert_eq!(w.offer(3.0, 3), BatchDecision::CloseNow);
        assert_eq!(w.close(), vec![1, 2, 3]);
        assert!(w.is_empty());
        // fresh window after close
        assert_eq!(w.offer(20.0, 4), BatchDecision::CloseAt(30.0));
        assert_eq!(w.close(), vec![4]);
    }

    #[test]
    fn removed_member_leaves_window_open_for_the_rest() {
        let mut w = BatchWindow::new(10.0, 3);
        assert_eq!(w.offer(0.0, 1), BatchDecision::CloseAt(10.0));
        assert_eq!(w.offer(1.0, 2), BatchDecision::Queued);
        w.remove(1);
        // re-offer after a resume does not double-count the session
        assert_eq!(w.offer(2.0, 1), BatchDecision::Queued);
        assert_eq!(w.close(), vec![2, 1]);
    }

    #[test]
    fn spurious_timer_close_is_empty() {
        let mut w = BatchWindow::new(5.0, 2);
        let _ = w.offer(0.0, 1);
        let _ = w.offer(0.0, 2); // CloseNow drained by caller:
        assert_eq!(w.close(), vec![1, 2]);
        // the originally scheduled 5.0 ms timer still fires:
        assert!(w.close().is_empty());
    }

    #[test]
    fn epoch_detects_stale_close_timers() {
        let mut w = BatchWindow::new(10.0, 2);
        // window 1 opens; its timer records epoch 0
        assert_eq!(w.offer(0.0, 1), BatchDecision::CloseAt(10.0));
        let timer1_epoch = w.epoch();
        // fills to max -> CloseNow drains it before the timer
        assert_eq!(w.offer(1.0, 2), BatchDecision::CloseNow);
        assert_eq!(w.close(), vec![1, 2]);
        // window 2 opens at t=5
        assert_eq!(w.offer(5.0, 3), BatchDecision::CloseAt(15.0));
        // window 1's timer fires at t=10: stale, must be skipped
        assert_ne!(timer1_epoch, w.epoch());
        // window 2's own timer is current
        assert_eq!(w.epoch(), 1);
    }

    #[test]
    fn batch_mode_parses_cli_values() {
        assert_eq!(BatchMode::parse("window"), Some(BatchMode::Windowed));
        assert_eq!(BatchMode::parse("windowed"), Some(BatchMode::Windowed));
        assert_eq!(BatchMode::parse("continuous"), Some(BatchMode::Continuous));
        assert_eq!(BatchMode::parse("nope"), None);
        assert_eq!(BatchMode::default().as_str(), "window");
        assert_eq!(BatchMode::Continuous.as_str(), "continuous");
    }

    #[test]
    fn slot_batch_rolls_admission_in_fifo_order() {
        let mut s = SlotBatch::new(2);
        // first admission wants a close as soon as the queue drains
        assert_eq!(s.admit(5.0, 1), BatchDecision::CloseAt(5.0));
        // filling the last slot closes immediately
        assert_eq!(s.admit(6.0, 2), BatchDecision::CloseNow);
        assert_eq!(s.free_slots(), 0);
        // overflow parks in the FIFO
        assert_eq!(s.enqueue(3), BatchDecision::Queued);
        assert_eq!(s.enqueue(4), BatchDecision::Queued);
        assert_eq!((s.occupied_len(), s.waiting_len(), s.len()), (2, 2, 4));

        // close takes the slot occupants in admission order, waiters stay
        assert_eq!(s.take(), vec![1, 2]);
        assert_eq!((s.occupied_len(), s.waiting_len()), (0, 2));
        // refill strictly first-in-first-out
        assert_eq!(s.peek_waiter(), Some(3));
        assert_eq!(s.pop_waiter(), Some(3));
        assert_eq!(s.admit(9.0, 3), BatchDecision::CloseAt(9.0));
        assert_eq!(s.pop_waiter(), Some(4));
        assert_eq!(s.admit(9.0, 4), BatchDecision::CloseNow);
        assert_eq!(s.take(), vec![3, 4]);
        assert!(s.is_empty());
    }

    #[test]
    fn slot_batch_remove_drops_slot_or_waiter() {
        let mut s = SlotBatch::new(2);
        let _ = s.admit(0.0, 1);
        let _ = s.admit(0.0, 2);
        let _ = s.enqueue(3);
        // a voided slot occupant frees its slot without a verdict
        s.remove(1);
        assert_eq!((s.free_slots(), s.occupied_len()), (1, 1));
        // a voided waiter leaves the line
        s.remove(3);
        assert_eq!(s.waiting_len(), 0);
        assert_eq!(s.take(), vec![2]);
    }

    #[test]
    fn session_core_commits_accepted_prefix_plus_correction() {
        let mut s = SessionCore::new(1, &[1, 10, 11], 6);
        let fin = s.apply_verdict(&[20, 21, 22], 2, 30, false, false);
        assert!(!fin);
        assert_eq!(s.committed, vec![1, 10, 11, 20, 21, 30]);
        assert_eq!((s.new_tokens, s.accepted, s.drafted, s.rounds), (3, 2, 3, 1));
        // second round reaches max_new
        let fin = s.apply_verdict(&[40, 41], 2, 42, false, false);
        assert!(fin && s.done);
        assert_eq!(s.new_tokens, 6);
        assert!((s.acceptance() - 4.0 / 5.0).abs() < 1e-12);
        let o = s.outcome();
        assert_eq!(o.new_tokens, 6);
        assert_eq!(o.accepted, 4);
    }

    #[test]
    fn fast_forward_preserves_mirror_invariant() {
        let mut edge = SessionCore::new(1, &[1, 10], 6);
        let mut cloud = SessionCore::new(1, &[1, 10], 6);
        // round 0 verdict applied on both sides
        edge.apply_verdict(&[20, 21], 2, 30, false, false);
        cloud.apply_verdict(&[20, 21], 2, 30, false, false);
        // round 1 verdict applied cloud-side only (reply lost in flight)
        cloud.apply_verdict(&[40], 1, 41, false, false);
        assert!(cloud.committed.len() > edge.committed.len());
        // resume: edge fast-forwards with the tail it missed
        let tail = cloud.committed_tail(edge.committed_len()).to_vec();
        assert_eq!(tail, vec![40, 41]);
        let done = edge.fast_forward(&tail, cloud.rounds, false);
        assert_eq!(edge.committed, cloud.committed);
        assert_eq!(edge.new_tokens, cloud.new_tokens);
        assert_eq!(edge.rounds, cloud.rounds);
        // 5 of max_new 6 committed: not done yet
        assert!(!done && !edge.done);
        // a tail that reaches max_new finishes the session
        let mut edge2 = SessionCore::new(2, &[1, 10], 3);
        assert!(edge2.fast_forward(&[5, 6, 7], 2, false));
        // an explicit done flag finishes regardless of budget
        let mut edge3 = SessionCore::new(3, &[1, 10], 100);
        assert!(edge3.fast_forward(&[5], 1, true));
    }

    #[test]
    fn speculation_confirm_and_rollback() {
        let mut s = SessionCore::new(1, &[1, 10], 20);
        // round 0 in flight, assumed outcome [20, 21, 22] (K=2 + bonus)
        s.speculate(&[20, 21, 22]);
        // round 1 launched from the optimistic prefix
        assert_eq!(s.optimistic_context(), vec![1, 10, 20, 21, 22]);
        assert_eq!(s.optimistic_new_tokens(), 3);
        s.speculate(&[30, 31, 32]);
        assert_eq!(s.optimistic_new_tokens(), 6);

        // round 0 verdict confirms the assumption exactly
        s.apply_verdict(&[20, 21], 2, 22, false, false);
        s.confirm_speculation(3);
        assert_eq!(s.speculated, vec![30, 31, 32]);
        assert_eq!(s.optimistic_context(), vec![1, 10, 20, 21, 22, 30, 31, 32]);

        // round 1 verdict REJECTS at position 1: everything speculative
        // beyond it is void
        s.apply_verdict(&[30, 31], 1, 99, false, false);
        assert_eq!(s.rollback_speculation(), 3);
        assert!(s.speculated.is_empty());
        assert_eq!(s.committed, vec![1, 10, 20, 21, 22, 30, 99]);

        // a resume fast-forward also clears speculation
        s.speculate(&[40, 41]);
        s.fast_forward(&[50], s.rounds, false);
        assert!(s.speculated.is_empty());
        assert!(s.committed.ends_with(&[50]));
    }

    #[test]
    fn restore_rebuilds_mid_decode_state() {
        // a session decoded to [prompt(2) + 5 generated] hands off
        let mut orig = SessionCore::new(1, &[1, 10], 12);
        orig.apply_verdict(&[20, 21], 2, 30, false, false);
        orig.apply_verdict(&[40], 1, 41, false, false);
        let back = SessionCore::restore(
            7,
            orig.committed.clone(),
            orig.prompt_len,
            orig.max_new,
            orig.rounds,
            orig.accepted,
            orig.drafted,
            orig.done,
        );
        assert_eq!(back.id, 7);
        assert_eq!(back.committed, orig.committed);
        assert_eq!(back.new_tokens, orig.new_tokens);
        assert_eq!(back.rounds, orig.rounds);
        assert!(!back.done);
        // decoding continues exactly where the original stopped
        let mut a = orig.clone();
        let mut b = back.clone();
        assert_eq!(
            a.apply_verdict(&[50, 51], 2, 52, false, false),
            b.apply_verdict(&[50, 51], 2, 52, false, false)
        );
        assert_eq!(a.committed, b.committed);
        assert_eq!(a.new_tokens, b.new_tokens);
    }

    #[test]
    fn session_core_stops_on_eos_and_capacity() {
        let mut s = SessionCore::new(1, &[1, 2], 100);
        assert!(s.apply_verdict(&[5], 1, 2, true, false));
        let mut s2 = SessionCore::new(2, &[1, 2], 100);
        assert!(s2.apply_verdict(&[5], 1, 7, false, true));
    }
}
