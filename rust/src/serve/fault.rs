//! Deterministic fault injection for any [`Transport`]: seeded
//! drop/delay/duplicate/disconnect schedules, so every link failure mode
//! the resume machinery must survive is reproducible in-process.
//!
//! `FaultTransport` wraps one endpoint (conventionally the edge end) of
//! a transport and consults a shared, seeded [`FaultPlan`] on every
//! frame event (each send and each delivery):
//!
//! * **Deliver** — pass through untouched.
//! * **Duplicate** — deliver the frame twice (a transport-level
//!   retransmit; the protocol's round/nonce dedup must absorb it).
//! * **Delay** — hold the frame for a `StochasticChannel`-sampled air
//!   time before delivering (ordering is preserved; batching windows
//!   shift, token trajectories must not).
//! * **DropAndDisconnect** — the link dies *here*: the in-flight frame
//!   is lost and the underlying transport is dropped, so the peer sees
//!   EOF and parks the connection's sessions while this side surfaces an
//!   error on its next operation — exactly the shape of a mid-round
//!   link drop ("drop mid-draft" when it lands on a send, "drop
//!   mid-verify-reply" when it lands on a delivery).
//!
//! The plan is SHARED across reconnects (`Arc<Mutex<FaultPlan>>`): an
//! edge-side reconnector wraps each fresh connection in a new
//! `FaultTransport` over the same plan, so disconnect schedules span the
//! whole session ("force ≥1 disconnect, then let it finish") and the
//! whole run replays bit-identically for a fixed seed.

use super::transport::{loopback_pair, BoxFuture, Reconnect, Transport};
use super::verifier::VerifierHandle;
use crate::channel::{Channel, StochasticChannel};
use crate::protocol::frame::{Frame, FRAME_HEAD};
use crate::util::rng::SplitMix64;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// What happens to one frame event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOp {
    Deliver,
    Duplicate,
    Delay,
    DropAndDisconnect,
}

/// Which frame events a scheduled disconnect may land on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSide {
    /// Only on sends — the classic "draft lost in flight".
    Send,
    /// Only on deliveries — "verify reply lost in flight".
    Recv,
    /// Whichever event the countdown expires on.
    Any,
}

/// Seeded fault schedule configuration.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    pub seed: u64,
    /// P(duplicate) per frame event.
    pub dup_p: f64,
    /// P(channel-sampled delay) per frame event.
    pub delay_p: f64,
    /// Forced disconnects across the whole plan; after the quota the
    /// link stays clean so sessions always finish.
    pub max_disconnects: usize,
    /// Each disconnect fires a seeded number of frame events after the
    /// previous one, drawn uniformly from this inclusive range. Keep the
    /// lower bound ≥ 4 to let the open handshake (Hello/HelloAck/Open/
    /// OpenAck) land at least once before the first drop.
    pub disconnect_gap: (usize, usize),
    /// Restrict which event kind disconnects land on.
    pub disconnect_on: FaultSide,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 1,
            dup_p: 0.0,
            delay_p: 0.0,
            max_disconnects: 1,
            disconnect_gap: (5, 24),
            disconnect_on: FaultSide::Any,
        }
    }
}

/// Deterministic schedule shared by every connection of one edge.
pub struct FaultPlan {
    cfg: FaultConfig,
    rng: SplitMix64,
    chan: StochasticChannel,
    /// Frame events remaining until the next scheduled disconnect
    /// (`None` once the quota is exhausted).
    until_disconnect: Option<usize>,
    /// Total frame events observed (drives channel sampling times).
    events: u64,
    /// Disconnects injected so far.
    pub disconnects: usize,
    /// Duplicates injected so far.
    pub duplicates: usize,
    /// Delays injected so far.
    pub delays: usize,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig, chan: StochasticChannel) -> FaultPlan {
        let mut rng = SplitMix64::new(cfg.seed ^ 0xFA_017_FA_017);
        let until_disconnect = if cfg.max_disconnects > 0 {
            Some(draw_gap(&mut rng, cfg.disconnect_gap))
        } else {
            None
        };
        FaultPlan {
            cfg,
            rng,
            chan,
            until_disconnect,
            events: 0,
            disconnects: 0,
            duplicates: 0,
            delays: 0,
        }
    }

    /// Shared handle for wiring one plan through many reconnects.
    pub fn shared(cfg: FaultConfig, chan: StochasticChannel) -> Arc<Mutex<FaultPlan>> {
        Arc::new(Mutex::new(FaultPlan::new(cfg, chan)))
    }

    /// Decide the fate of one frame event on the given side.
    fn next_op(&mut self, send: bool) -> FaultOp {
        self.events += 1;
        if let Some(n) = self.until_disconnect {
            let side_ok = match self.cfg.disconnect_on {
                FaultSide::Send => send,
                FaultSide::Recv => !send,
                FaultSide::Any => true,
            };
            if n == 0 && side_ok {
                self.disconnects += 1;
                self.until_disconnect = if self.disconnects < self.cfg.max_disconnects {
                    Some(draw_gap(&mut self.rng, self.cfg.disconnect_gap))
                } else {
                    None
                };
                return FaultOp::DropAndDisconnect;
            }
            self.until_disconnect = Some(n.saturating_sub(1));
        }
        if self.rng.chance(self.cfg.dup_p) {
            self.duplicates += 1;
            FaultOp::Duplicate
        } else if self.rng.chance(self.cfg.delay_p) {
            self.delays += 1;
            FaultOp::Delay
        } else {
            FaultOp::Deliver
        }
    }

    /// Injected delay for one frame, from the wireless-channel model
    /// (capped so tests stay fast; the value, not the cap, is seeded).
    fn delay_ms(&mut self, bytes: usize) -> f64 {
        let st = self.chan.sample(self.events as f64);
        (st.prop_ms + st.up_ms(bytes)).min(4.0)
    }
}

/// On-the-wire size of a frame without encoding it: length prefix (4)
/// + frame head + payload (kept in lockstep with the codec via
/// `FRAME_HEAD`).
fn wire_len(f: &Frame) -> usize {
    4 + FRAME_HEAD + f.payload.len()
}

/// A [`Reconnect`] factory producing fresh in-process loopback
/// connections to `verifier` — each served by the REAL connection
/// handler (`cloud::handle_conn`) — with the edge end wrapped in a
/// [`FaultTransport`] over the SHARED plan, so disconnect schedules
/// span reconnects. This is the standard wiring for fault-injection
/// tests and demos (`tests/serve_faults.rs`, `examples/serve_tcp.rs`).
pub fn loopback_fault_dial(
    verifier: VerifierHandle,
    plan: Arc<Mutex<FaultPlan>>,
) -> Box<dyn Reconnect> {
    Box::new(move || -> BoxFuture<'static, Result<Box<dyn Transport>>> {
        let v = verifier.clone();
        let plan = plan.clone();
        Box::pin(async move {
            let (edge_t, cloud_t) = loopback_pair();
            tokio::spawn(async move {
                // conn errors under injected faults are expected; the
                // verifier parks the sessions and the edge resumes
                let _ = super::cloud::handle_conn(cloud_t, v).await;
            });
            Ok(Box::new(FaultTransport::new(Box::new(edge_t), plan)) as Box<dyn Transport>)
        })
    })
}

fn draw_gap(rng: &mut SplitMix64, (lo, hi): (usize, usize)) -> usize {
    let hi = hi.max(lo);
    lo + rng.next_range((hi - lo + 1) as u64) as usize
}

/// A [`Transport`] wrapper that injects the plan's faults.
pub struct FaultTransport {
    inner: Option<Box<dyn Transport>>,
    plan: Arc<Mutex<FaultPlan>>,
    /// Copy of the last delivered frame pending re-delivery.
    pending_dup: Option<Frame>,
    /// Frame held across an injected inbound delay. `recv_frame` may be
    /// polled inside `select!` (the mux pump does); if the future is
    /// cancelled mid-sleep the frame survives here and is delivered by
    /// the next call instead of being silently lost.
    pending_delay: Option<Frame>,
    label: String,
}

impl FaultTransport {
    pub fn new(inner: Box<dyn Transport>, plan: Arc<Mutex<FaultPlan>>) -> FaultTransport {
        let label = format!("faulty:{}", inner.peer());
        FaultTransport {
            inner: Some(inner),
            plan,
            pending_dup: None,
            pending_delay: None,
            label,
        }
    }
}

impl Transport for FaultTransport {
    fn send_frame(&mut self, frame: Frame) -> BoxFuture<'_, Result<()>> {
        Box::pin(async move {
            let Some(inner) = self.inner.as_mut() else {
                bail!("{}: link is down (injected disconnect)", self.label);
            };
            let (op, delay) = {
                let mut p = self.plan.lock().expect("fault plan poisoned");
                let op = p.next_op(true);
                let delay = if op == FaultOp::Delay {
                    p.delay_ms(wire_len(&frame))
                } else {
                    0.0
                };
                (op, delay)
            };
            match op {
                FaultOp::Deliver => inner.send_frame(frame).await,
                FaultOp::Duplicate => {
                    inner.send_frame(frame.clone()).await?;
                    inner.send_frame(frame).await
                }
                FaultOp::Delay => {
                    tokio::time::sleep(std::time::Duration::from_secs_f64(delay / 1e3)).await;
                    inner.send_frame(frame).await
                }
                FaultOp::DropAndDisconnect => {
                    // the frame is lost in flight and the link dies:
                    // dropping the inner transport shows the peer EOF;
                    // locally the write "succeeded" (like a socket whose
                    // buffer took the bytes) and death surfaces on the
                    // next receive
                    self.inner = None;
                    Ok(())
                }
            }
        })
    }

    fn recv_frame(&mut self) -> BoxFuture<'_, Result<Option<Frame>>> {
        Box::pin(async move {
            if let Some(f) = self.pending_dup.take() {
                return Ok(Some(f));
            }
            if let Some(f) = self.pending_delay.take() {
                // a previous delayed delivery was cancelled mid-sleep:
                // the frame is overdue, deliver it immediately
                return Ok(Some(f));
            }
            let Some(inner) = self.inner.as_mut() else {
                bail!("{}: link is down (injected disconnect)", self.label);
            };
            let Some(frame) = inner.recv_frame().await? else {
                return Ok(None);
            };
            let (op, delay) = {
                let mut p = self.plan.lock().expect("fault plan poisoned");
                let op = p.next_op(false);
                let delay = if op == FaultOp::Delay {
                    p.delay_ms(wire_len(&frame))
                } else {
                    0.0
                };
                (op, delay)
            };
            match op {
                FaultOp::Deliver => Ok(Some(frame)),
                FaultOp::Duplicate => {
                    self.pending_dup = Some(frame.clone());
                    Ok(Some(frame))
                }
                FaultOp::Delay => {
                    // cancellation-safe: the frame lives in self while we
                    // sleep, so a select! cancelling this future cannot
                    // lose it
                    self.pending_delay = Some(frame);
                    tokio::time::sleep(std::time::Duration::from_secs_f64(delay / 1e3)).await;
                    Ok(self.pending_delay.take())
                }
                FaultOp::DropAndDisconnect => {
                    // the delivery is lost and the link dies on the spot
                    self.inner = None;
                    bail!("{}: link dropped while receiving (injected)", self.label);
                }
            }
        })
    }

    fn peer(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{NetworkKind, NetworkProfile};
    use crate::protocol::frame::FrameKind;
    use crate::serve::transport::loopback_pair;

    fn rt() -> tokio::runtime::Runtime {
        tokio::runtime::Builder::new_current_thread()
            .enable_all()
            .build()
            .unwrap()
    }

    fn chan(seed: u64) -> StochasticChannel {
        NetworkProfile::new(NetworkKind::FourG).channel(seed)
    }

    #[test]
    fn schedules_are_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut p = FaultPlan::new(
                FaultConfig {
                    seed,
                    dup_p: 0.2,
                    delay_p: 0.2,
                    max_disconnects: 3,
                    disconnect_gap: (2, 9),
                    disconnect_on: FaultSide::Any,
                },
                chan(seed),
            );
            (0..200)
                .map(|i| p.next_op(i % 2 == 0))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5), "same seed must replay the same schedule");
        assert_ne!(run(5), run(6), "different seeds must differ");
        let ops = run(5);
        assert_eq!(
            ops.iter().filter(|o| **o == FaultOp::DropAndDisconnect).count(),
            3,
            "exactly max_disconnects disconnects"
        );
        assert!(ops.iter().any(|o| *o == FaultOp::Duplicate));
    }

    #[test]
    fn disconnect_side_restriction_is_honored() {
        let mut p = FaultPlan::new(
            FaultConfig {
                seed: 9,
                max_disconnects: 2,
                disconnect_gap: (0, 0),
                disconnect_on: FaultSide::Recv,
                ..Default::default()
            },
            chan(9),
        );
        // countdown expires immediately but the next events are sends:
        // the disconnect must wait for a recv event
        assert_eq!(p.next_op(true), FaultOp::Deliver);
        assert_eq!(p.next_op(true), FaultOp::Deliver);
        assert_eq!(p.next_op(false), FaultOp::DropAndDisconnect);
    }

    #[test]
    fn drop_on_send_loses_frame_and_shows_peer_eof() {
        rt().block_on(async {
            let (edge, mut cloud) = loopback_pair();
            let plan = FaultPlan::shared(
                FaultConfig {
                    seed: 1,
                    max_disconnects: 1,
                    disconnect_gap: (1, 1),
                    disconnect_on: FaultSide::Send,
                    ..Default::default()
                },
                chan(1),
            );
            let mut faulty = FaultTransport::new(Box::new(edge), plan.clone());
            // event 1: delivered; event 2: dropped + link death
            faulty
                .send_frame(Frame::on(1, FrameKind::Draft, vec![1]))
                .await
                .unwrap();
            faulty
                .send_frame(Frame::on(1, FrameKind::Draft, vec![2]))
                .await
                .unwrap(); // lost in flight, no local error yet
            assert!(faulty.recv_frame().await.is_err(), "link must be down");
            assert!(faulty
                .send_frame(Frame::on(1, FrameKind::Draft, vec![3]))
                .await
                .is_err());
            // the peer got frame 1 and then a clean EOF
            let got = cloud.recv_frame().await.unwrap().unwrap();
            assert_eq!(got.payload, vec![1]);
            assert!(cloud.recv_frame().await.unwrap().is_none());
            assert_eq!(plan.lock().unwrap().disconnects, 1);
        });
    }

    #[test]
    fn duplicate_on_recv_delivers_twice() {
        rt().block_on(async {
            let (mut edge, cloud) = loopback_pair();
            let plan = FaultPlan::shared(
                FaultConfig {
                    seed: 2,
                    dup_p: 1.0,
                    max_disconnects: 0,
                    ..Default::default()
                },
                chan(2),
            );
            let mut faulty = FaultTransport::new(Box::new(cloud), plan);
            edge.send_frame(Frame::on(1, FrameKind::Verify, vec![7]))
                .await
                .unwrap();
            let a = faulty.recv_frame().await.unwrap().unwrap();
            let b = faulty.recv_frame().await.unwrap().unwrap();
            assert_eq!(a, b, "duplicate must be byte-identical");
            assert_eq!(a.payload, vec![7]);
        });
    }
}
