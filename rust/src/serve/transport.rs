//! Frame transports: real TCP sockets and an in-process loopback.
//!
//! `Transport` is object-safe (methods return boxed `Send` futures) so
//! the connection handler, the edge client, and the tests are written
//! once and run over either implementation:
//!
//! * `TcpTransport` — length-prefixed frames over `tokio::net::TcpStream`
//!   with `TCP_NODELAY` (a draft block is one small write; Nagle would
//!   serialize the whole decode loop on the ACK clock). Sends are
//!   vectored `[head, payload]` writes (`Frame::encode_head`), so the
//!   payload bytes are never copied into a contiguous scratch buffer.
//! * `LoopbackTransport` — an in-process channel pair. It optionally
//!   wraps the deterministic wireless-channel simulation: every frame is
//!   metered through a `StochasticChannel` into a shared `AirtimeLedger`,
//!   so experiments keep byte-accurate *virtual* air time while bytes
//!   move instantly — runs stay reproducible for a fixed seed.

use crate::channel::{Channel, StochasticChannel};
use crate::protocol::frame::{Frame, FrameDecoder};
use anyhow::{bail, Context, Result};
use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Mutex};
use tokio::io::{AsyncReadExt, AsyncWriteExt};
use tokio::net::TcpStream;
use tokio::sync::mpsc;

pub type BoxFuture<'a, T> = Pin<Box<dyn Future<Output = T> + Send + 'a>>;

/// A reliable, ordered frame pipe between one edge and the cloud.
pub trait Transport: Send {
    /// Send one frame (completes when handed to the OS / peer queue).
    fn send_frame(&mut self, frame: Frame) -> BoxFuture<'_, Result<()>>;

    /// Receive the next frame; `Ok(None)` on orderly end-of-stream.
    fn recv_frame(&mut self) -> BoxFuture<'_, Result<Option<Frame>>>;

    /// Peer label for logs.
    fn peer(&self) -> String;

    /// Try to re-establish the link after a send/recv failure.
    /// `Ok(false)` means this transport has no reconnect support (the
    /// default); `Ok(true)` means the link is live again and the caller
    /// should replay its resume handshake. `edge::ResumableTransport`
    /// (fresh dial + Hello) and `mux::MuxStream` (wait for the shared
    /// connection pump to reconnect) override this.
    fn reattach(&mut self) -> BoxFuture<'_, Result<bool>> {
        Box::pin(async { Ok(false) })
    }

    /// Point the NEXT reattach at a different peer (a fleet `Redirect`,
    /// wire v5). `Ok(false)` means this transport cannot move — the
    /// default, and the right answer for [`mux::MuxStream`]: a
    /// per-session stream cannot leave its shared connection, so the
    /// session resumes in place and the exporting replica re-imports it
    /// from the fleet ledger. `Ok(true)` means the target was switched
    /// and the current link (if any) was abandoned; the caller should
    /// fail its attempt so the normal reattach path redials the new
    /// target and replays the resume handshake there.
    /// `edge::ResumableTransport` overrides this by forwarding the
    /// address to its [`Reconnect`] factory.
    ///
    /// [`mux::MuxStream`]: super::mux::MuxStream
    fn redirect(&mut self, addr: String) -> BoxFuture<'_, Result<bool>> {
        let _ = addr;
        Box::pin(async { Ok(false) })
    }
}

/// Async connection factory used by the reconnect-capable wrappers
/// (`edge::ResumableTransport`, `mux::EdgeMux`): dials a fresh
/// underlying transport after a link failure. Closures returning boxed
/// `'static` futures implement it directly.
pub trait Reconnect: Send {
    fn connect(&mut self) -> BoxFuture<'_, Result<Box<dyn Transport>>>;

    /// Retarget future `connect` calls at a different peer (a fleet
    /// `Redirect`). Returns whether the retarget took effect: the
    /// default ignores the address and returns false — single-target
    /// dialers (plain closures) keep redialing their one peer, which
    /// degrades a redirect into a resume-in-place (the exporting
    /// replica re-imports the session from the fleet ledger). Fleet
    /// dialers ([`crate::serve::fleet`]) override this to follow the
    /// handoff and return true.
    fn set_target(&mut self, addr: &str) -> bool {
        let _ = addr;
        false
    }
}

impl<F> Reconnect for F
where
    F: FnMut() -> BoxFuture<'static, Result<Box<dyn Transport>>> + Send,
{
    fn connect(&mut self) -> BoxFuture<'_, Result<Box<dyn Transport>>> {
        (self)()
    }
}

// ---------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------

pub struct TcpTransport {
    stream: TcpStream,
    decoder: FrameDecoder,
    peer: String,
}

impl TcpTransport {
    pub fn new(stream: TcpStream, peer: String) -> TcpTransport {
        let _ = stream.set_nodelay(true);
        TcpTransport {
            stream,
            decoder: FrameDecoder::new(),
            peer,
        }
    }

    pub async fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)
            .await
            .with_context(|| format!("connecting to cloud at {addr}"))?;
        Ok(TcpTransport::new(stream, addr.to_string()))
    }
}

impl Transport for TcpTransport {
    fn send_frame(&mut self, frame: Frame) -> BoxFuture<'_, Result<()>> {
        Box::pin(async move {
            // Vectored write of [head, payload]: the payload never gets
            // copied into a fresh contiguous buffer. Partial writes are
            // advanced by hand because writev has no write_all analogue.
            let head = frame.encode_head();
            let total = frame.encoded_len();
            let mut written = 0usize;
            while written < total {
                let bufs = [
                    std::io::IoSlice::new(&head[written.min(head.len())..]),
                    std::io::IoSlice::new(&frame.payload[written.saturating_sub(head.len())..]),
                ];
                let n = self
                    .stream
                    .write_vectored(&bufs)
                    .await
                    .with_context(|| format!("writing frame to {}", self.peer))?;
                if n == 0 {
                    bail!("{}: connection closed mid-frame write", self.peer);
                }
                written += n;
            }
            Ok(())
        })
    }

    fn recv_frame(&mut self) -> BoxFuture<'_, Result<Option<Frame>>> {
        Box::pin(async move {
            loop {
                if let Some(f) = self.decoder.next_frame()? {
                    return Ok(Some(f));
                }
                let mut buf = [0u8; 8192];
                let n = self
                    .stream
                    .read(&mut buf)
                    .await
                    .with_context(|| format!("reading from {}", self.peer))?;
                if n == 0 {
                    if self.decoder.pending_bytes() > 0 {
                        bail!("{}: connection closed mid-frame", self.peer);
                    }
                    return Ok(None);
                }
                self.decoder.push(&buf[..n]);
            }
        })
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

// ---------------------------------------------------------------------
// Loopback
// ---------------------------------------------------------------------

/// Byte-accurate *virtual* air-time accounting for loopback runs,
/// driven by the deterministic wireless-channel simulation.
#[derive(Debug)]
pub struct AirtimeLedger {
    chan: StochasticChannel,
    /// Virtual clock advanced by metered frames (ms).
    pub now_ms: f64,
    pub frames: usize,
    pub bytes: usize,
    pub air_ms: f64,
}

impl AirtimeLedger {
    pub fn new(chan: StochasticChannel) -> AirtimeLedger {
        AirtimeLedger {
            chan,
            now_ms: 0.0,
            frames: 0,
            bytes: 0,
            air_ms: 0.0,
        }
    }

    fn meter(&mut self, bytes: usize, uplink: bool) {
        let state = self.chan.sample(self.now_ms);
        let t = state.prop_ms
            + if uplink {
                state.up_ms(bytes)
            } else {
                state.down_ms(bytes)
            };
        self.now_ms += t;
        self.air_ms += t;
        self.frames += 1;
        self.bytes += bytes;
    }
}

/// One end of an in-process frame pipe.
pub struct LoopbackTransport {
    tx: mpsc::UnboundedSender<Frame>,
    rx: mpsc::UnboundedReceiver<Frame>,
    label: &'static str,
    /// Set on the edge end when the pair was built with a channel model.
    ledger: Option<Arc<Mutex<AirtimeLedger>>>,
    /// True on the edge end (its sends are uplink frames).
    uplink: bool,
}

/// A connected loopback pair: (edge end, cloud end).
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    loopback_pair_inner(None).0
}

/// A loopback pair whose frames are metered through the deterministic
/// wireless-channel simulation. Returns the shared ledger for reports.
pub fn loopback_pair_with_channel(
    chan: StochasticChannel,
) -> (LoopbackTransport, LoopbackTransport, Arc<Mutex<AirtimeLedger>>) {
    let ((a, b), ledger) = loopback_pair_inner(Some(chan));
    (a, b, ledger.expect("ledger present when channel given"))
}

#[allow(clippy::type_complexity)]
fn loopback_pair_inner(
    chan: Option<StochasticChannel>,
) -> (
    (LoopbackTransport, LoopbackTransport),
    Option<Arc<Mutex<AirtimeLedger>>>,
) {
    let (tx_a, rx_b) = mpsc::unbounded_channel();
    let (tx_b, rx_a) = mpsc::unbounded_channel();
    let ledger = chan.map(|c| Arc::new(Mutex::new(AirtimeLedger::new(c))));
    let edge = LoopbackTransport {
        tx: tx_a,
        rx: rx_a,
        label: "loopback-edge",
        ledger: ledger.clone(),
        uplink: true,
    };
    let cloud = LoopbackTransport {
        tx: tx_b,
        rx: rx_b,
        label: "loopback-cloud",
        ledger: ledger.clone(),
        uplink: false,
    };
    ((edge, cloud), ledger)
}

impl Transport for LoopbackTransport {
    fn send_frame(&mut self, frame: Frame) -> BoxFuture<'_, Result<()>> {
        Box::pin(async move {
            if let Some(ledger) = &self.ledger {
                // Metered from the layout, not a throwaway encode().
                let bytes = frame.encoded_len();
                ledger
                    .lock()
                    .expect("airtime ledger poisoned")
                    .meter(bytes, self.uplink);
            }
            self.tx
                .send(frame)
                .map_err(|_| anyhow::anyhow!("{}: peer hung up", self.label))
        })
    }

    fn recv_frame(&mut self) -> BoxFuture<'_, Result<Option<Frame>>> {
        Box::pin(async move { Ok(self.rx.recv().await) })
    }

    fn peer(&self) -> String {
        self.label.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{NetworkKind, NetworkProfile};
    use crate::protocol::frame::FrameKind;

    fn rt() -> tokio::runtime::Runtime {
        tokio::runtime::Builder::new_current_thread()
            .enable_all()
            .build()
            .unwrap()
    }

    #[test]
    fn loopback_delivers_frames_in_order() {
        rt().block_on(async {
            let (mut edge, mut cloud) = loopback_pair();
            for i in 0..5u8 {
                edge.send_frame(Frame::on(1, FrameKind::Draft, vec![i]))
                    .await
                    .unwrap();
            }
            drop(edge);
            for i in 0..5u8 {
                let f = cloud.recv_frame().await.unwrap().unwrap();
                assert_eq!(f.payload, vec![i]);
            }
            assert!(cloud.recv_frame().await.unwrap().is_none());
        });
    }

    #[test]
    fn metered_loopback_accounts_deterministic_airtime() {
        let run = || {
            rt().block_on(async {
                let chan = NetworkProfile::new(NetworkKind::FourG).channel(9);
                let (mut edge, mut cloud, ledger) = loopback_pair_with_channel(chan);
                for _ in 0..8 {
                    edge.send_frame(Frame::on(1, FrameKind::Draft, vec![0; 64]))
                        .await
                        .unwrap();
                    let f = cloud.recv_frame().await.unwrap().unwrap();
                    cloud.send_frame(f).await.unwrap();
                    edge.recv_frame().await.unwrap().unwrap();
                }
                let l = ledger.lock().unwrap();
                assert_eq!(l.frames, 16);
                assert!(l.air_ms > 0.0);
                (l.frames, l.bytes, l.air_ms)
            })
        };
        assert_eq!(run(), run(), "virtual airtime must be reproducible");
    }

    #[test]
    fn tcp_transport_roundtrips_over_localhost() {
        rt().block_on(async {
            let listener = tokio::net::TcpListener::bind("127.0.0.1:0").await.unwrap();
            let addr = listener.local_addr().unwrap();
            let server = tokio::spawn(async move {
                let (s, peer) = listener.accept().await.unwrap();
                let mut t = TcpTransport::new(s, peer.to_string());
                while let Some(f) = t.recv_frame().await.unwrap() {
                    if f.kind == FrameKind::Bye {
                        break;
                    }
                    t.send_frame(f).await.unwrap(); // echo
                }
            });
            let mut c = TcpTransport::connect(&addr.to_string()).await.unwrap();
            let payload: Vec<u8> = (0..200).map(|i| (i % 251) as u8).collect();
            c.send_frame(Frame::on(1, FrameKind::Draft, payload.clone()))
                .await
                .unwrap();
            let back = c.recv_frame().await.unwrap().unwrap();
            assert_eq!(back.payload, payload);
            c.send_frame(Frame::on(1, FrameKind::Bye, vec![])).await.unwrap();
            server.await.unwrap();
        });
    }
}
