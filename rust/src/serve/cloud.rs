//! The cloud verification server: a tokio accept loop feeding the
//! dedicated verifier thread (`serve::verifier`).
//!
//! One connection carries MANY sessions (wire v2): after a single
//! `Hello` handshake on the control stream, the per-connection demux
//! (`handle_conn`) binds each nonzero stream id to a KV session on
//! `Open`/`Resume` and routes `Draft`/`Verify` frames per stream into
//! the existing cross-connection verification batcher. Draft
//! verifications run as concurrent tasks feeding one writer queue, so
//! eight multiplexed sessions batch exactly like eight connections. The
//! handler is written against the `Transport` trait, so the TCP server
//! and the in-process loopback harness (`serve_loopback`,
//! `serve_loopback_mux`) share it verbatim — the loopback path is not a
//! mock, it is the same server minus the socket.
//!
//! Operational properties the tests pin:
//! * cross-connection AND cross-stream dynamic batching (the verifier
//!   thread closes one window over requests from many connections);
//! * target-version hot-swap (`ServerHandle::deploy`) without dropping
//!   live sessions;
//! * a dead transport PARKS its sessions for the resume grace window
//!   (`verifier.detach`) instead of aborting them; a reconnecting edge
//!   reattaches per session via `Resume` and decoding continues from
//!   the committed prefix;
//! * transport-level duplicates are absorbed: handshake/open/resume
//!   acks are replayed from per-stream caches, duplicate drafts are
//!   answered from the verifier's verdict cache;
//! * graceful shutdown: stop accepting, drain active connections, flush
//!   the open batch, report final `ServingMetrics`.

use super::backend::VerifyBackend;
use super::edge::{run_edge_session, EdgeReport, EdgeSessionConfig};
use super::mux::EdgeMux;
use super::transport::{loopback_pair, TcpTransport, Transport};
use super::verifier::{VerifierConfig, VerifierHandle, VerifyReply};
use crate::coordinator::edge::DraftSource;
use crate::metrics::ServingMetrics;
use crate::protocol::frame::{
    check_stream, hello_response, BusyMsg, CancelMsg, Frame, FrameKind, Hello, OpenAck, OpenMsg,
    RedirectMsg, ReplicaInfoMsg, ResumeAck, ResumeMsg, StatsAckMsg, StatsMsg, CONTROL_STREAM,
};
use crate::protocol::DraftMsg;
use crate::util::log::{log, Level};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::net::SocketAddr;
use std::time::Duration;
use tokio::net::TcpListener;
use tokio::sync::{mpsc, watch};
use tokio::task::JoinSet;

/// How long `shutdown` waits for in-flight sessions before aborting
/// their connections.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(30);

/// Running server handle. Dropping it stops accepting new connections
/// (the shutdown watch closes) but skips the graceful drain — call
/// `shutdown` to flush the open batch and collect final metrics.
pub struct ServerHandle {
    pub addr: SocketAddr,
    verifier: VerifierHandle,
    shutdown: watch::Sender<bool>,
    accept: tokio::task::JoinHandle<()>,
}

impl ServerHandle {
    /// Hot-swap the deployed target version; live sessions keep their
    /// KV state and simply see different verification verdicts.
    pub async fn deploy(&self, version: &str) -> Result<u64> {
        self.verifier.deploy(version).await
    }

    /// Snapshot of the serving counters.
    pub async fn stats(&self) -> Result<ServingMetrics> {
        self.verifier.stats().await
    }

    /// A handle to the verification service (e.g. to share it with a
    /// loopback harness next to the TCP listener).
    pub fn verifier(&self) -> VerifierHandle {
        self.verifier.clone()
    }

    /// Graceful shutdown: stop accepting, wait up to `SHUTDOWN_GRACE`
    /// for active connections to finish (stragglers are aborted), flush
    /// the open batch, return final metrics.
    pub async fn shutdown(self) -> Result<ServingMetrics> {
        let _ = self.shutdown.send(true);
        let _ = self.accept.await;
        self.verifier.shutdown().await
    }
}

/// Bind a TCP verification server. `make_backend` runs on the verifier
/// thread (so `!Send` PJRT backends work); pass port 0 to let the OS
/// pick one (`handle.addr` has the result).
pub async fn serve_cloud(
    bind: &str,
    vcfg: VerifierConfig,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
) -> Result<ServerHandle> {
    serve_cloud_with(bind, vcfg, None, make_backend).await
}

/// [`serve_cloud`] with an optional fleet handoff ledger: one replica
/// of an N-replica fleet (`serve::fleet`) — sessions can be exported to
/// (and imported from) siblings sharing the same ledger.
pub async fn serve_cloud_with(
    bind: &str,
    vcfg: VerifierConfig,
    ledger: Option<crate::serve::fleet::SessionLedger>,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
) -> Result<ServerHandle> {
    let verifier = match ledger {
        Some(l) => VerifierHandle::spawn_with_ledger(vcfg, l, make_backend)?,
        None => VerifierHandle::spawn(vcfg, make_backend)?,
    };
    let listener = TcpListener::bind(bind)
        .await
        .with_context(|| format!("binding cloud server to {bind}"))?;
    let addr = listener.local_addr()?;
    let (shutdown, mut shutdown_rx) = watch::channel(false);
    let vh = verifier.clone();
    let accept = tokio::spawn(async move {
        let mut conns: JoinSet<()> = JoinSet::new();
        loop {
            tokio::select! {
                res = listener.accept() => match res {
                    Ok((stream, peer)) => {
                        let t = TcpTransport::new(stream, peer.to_string());
                        let v = vh.clone();
                        conns.spawn(async move {
                            let peer = t.peer();
                            if let Err(e) = handle_conn(t, v).await {
                                log(Level::Warn, "serve", &format!("{peer}: {e:#}"));
                            }
                        });
                    }
                    Err(e) => {
                        // transient (EMFILE, ECONNABORTED bursts): log,
                        // breathe, keep accepting — only shutdown ends
                        // the loop
                        log(Level::Warn, "serve", &format!("accept failed: {e}"));
                        tokio::time::sleep(std::time::Duration::from_millis(50)).await;
                    }
                },
                _ = shutdown_rx.changed() => break,
            }
        }
        // graceful drain: let in-flight sessions run to completion,
        // bounded so one stalled connection cannot hang shutdown forever
        let drain = async {
            while conns.join_next().await.is_some() {}
        };
        if tokio::time::timeout(SHUTDOWN_GRACE, drain).await.is_err() {
            log(
                Level::Warn,
                "serve",
                "shutdown grace period expired; aborting remaining connections",
            );
            conns.abort_all();
            while conns.join_next().await.is_some() {}
        }
    });
    Ok(ServerHandle {
        addr,
        verifier,
        shutdown,
        accept,
    })
}

/// A stream bound to a live session, with its cached handshake ack for
/// duplicate replay.
struct Bound {
    id: u32,
    /// Attachment epoch handed out at open/resume — passed back in
    /// `detach` so a stale connection can never park a stolen session.
    attachment: u64,
    ack: Frame,
}

/// Events the per-draft verify tasks feed back to the connection writer.
enum OutEvent {
    Frame(Frame),
    Fatal(String),
}

/// Serve one connection: version handshake → multiplexed demux loop.
/// Transport-generic so TCP and loopback share it. When the transport
/// dies with sessions still bound, they are PARKED for the resume grace
/// window rather than aborted.
pub async fn handle_conn<T: Transport>(mut t: T, verifier: VerifierHandle) -> Result<()> {
    // --- wire-format version handshake (control stream) --------------
    let hello = match t.recv_frame().await? {
        None => return Ok(()),
        Some(f) if f.kind == FrameKind::Hello => {
            check_stream(f.kind, f.stream, |_| false)?;
            Hello::decode(&f.payload)?
        }
        Some(f) => bail!("expected Hello, got {:?}", f.kind),
    };
    let ack = hello_response(&hello);
    let accepted = ack.accepted;
    // negotiated wire version: v3-only traffic (speculative drafts,
    // Cancel) is a protocol violation on a v2-negotiated connection
    let negotiated = ack.wire_version;
    let hello_ack = Frame::control(FrameKind::HelloAck, ack.encode());
    t.send_frame(hello_ack.clone()).await?;
    if !accepted {
        verifier.note_rejected_handshake();
        return Ok(());
    }
    // --- multiplexed session demux -----------------------------------
    let mut bound: HashMap<u32, Bound> = HashMap::new();
    let result = mux_loop(&mut t, &verifier, &mut bound, hello_ack, negotiated).await;
    // the transport is gone: park every session this connection still
    // carried so a reconnecting edge can resume it within the grace
    // window (orderly completions already unbound their streams, and a
    // stale attachment epoch makes this a no-op after a steal)
    for b in bound.values() {
        verifier.detach(b.id, b.attachment);
    }
    result
}

async fn mux_loop<T: Transport>(
    t: &mut T,
    verifier: &VerifierHandle,
    bound: &mut HashMap<u32, Bound>,
    hello_ack: Frame,
    negotiated: u16,
) -> Result<()> {
    let (out_tx, mut out_rx) = mpsc::unbounded_channel::<OutEvent>();
    // fleet telemetry (wire v5): announce the deployed target version +
    // current load once per connection. Fetched OFF the connection's
    // critical path — the verifier thread may be mid-batch, and a
    // reconnect storm must not queue its handshakes behind
    // verification; the frame rides the writer queue whenever the
    // snapshot arrives. Informational — edges absorb it at any point;
    // fleet registries read the same numbers via `VerifierHandle::info`.
    if negotiated >= 5 {
        let v = verifier.clone();
        let out = out_tx.clone();
        tokio::spawn(async move {
            if let Ok(info) = v.info().await {
                let m = ReplicaInfoMsg {
                    version: info.version_seq,
                    load: info.load().min(u32::MAX as usize) as u32,
                };
                let _ = out.send(OutEvent::Frame(Frame::control(
                    FrameKind::ReplicaInfo,
                    m.encode(),
                )));
            }
        });
    }
    loop {
        // Stage the winning event, then act with the select borrows
        // released (recv_frame holds &mut t while polled).
        enum Step {
            In(Option<Frame>),
            Out(Option<OutEvent>),
        }
        let step = tokio::select! {
            r = t.recv_frame() => Step::In(r?),
            ev = out_rx.recv() => Step::Out(ev),
        };
        match step {
            // we hold an out_tx, so the channel can never report closed
            Step::Out(None) => continue,
            Step::Out(Some(OutEvent::Frame(f))) => t.send_frame(f).await?,
            Step::Out(Some(OutEvent::Fatal(msg))) => bail!("{msg}"),
            // peer hung up: the caller parks whatever is still bound
            Step::In(None) => return Ok(()),
            Step::In(Some(f)) => {
                handle_frame(t, verifier, bound, &out_tx, &hello_ack, negotiated, f).await?
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
async fn handle_frame<T: Transport>(
    t: &mut T,
    verifier: &VerifierHandle,
    bound: &mut HashMap<u32, Bound>,
    out_tx: &mpsc::UnboundedSender<OutEvent>,
    hello_ack: &Frame,
    negotiated: u16,
    f: Frame,
) -> Result<()> {
    match f.kind {
        // transport-level retransmit of the greeting: replay the ack
        FrameKind::Hello => {
            check_stream(f.kind, f.stream, |_| false)?;
            t.send_frame(hello_ack.clone()).await
        }
        FrameKind::Open => {
            check_stream(f.kind, f.stream, |s| bound.contains_key(&s))?;
            if let Some(b) = bound.get(&f.stream) {
                if b.ack.kind == FrameKind::OpenAck {
                    // duplicate Open on a bound stream: replay the ack
                    return t.send_frame(b.ack.clone()).await;
                }
                // bound via Resume: fall through — the open-nonce dedup
                // reattaches rather than leaking a session
            }
            let open = OpenMsg::decode(&f.payload)?;
            // a pre-v8 edge cannot encode the profile tail; one arriving
            // on a downgraded connection is a protocol violation, same
            // class as spec-tagged drafts below v3
            if open.profile.is_some() && negotiated < 8 {
                bail!(
                    "device profile on a wire v{negotiated} connection (stream {})",
                    f.stream
                );
            }
            let info = verifier
                .open_profile(
                    open.prompt,
                    open.max_new as usize,
                    open.nonce,
                    open.tier,
                    open.profile,
                )
                .await?;
            let ack = Frame::on(
                f.stream,
                FrameKind::OpenAck,
                OpenAck {
                    session: info.session,
                    target_seq: info.target_seq,
                    resume_token: info.resume_token,
                }
                .encode(),
            );
            bound.insert(
                f.stream,
                Bound {
                    id: info.session,
                    attachment: info.attachment,
                    ack: ack.clone(),
                },
            );
            t.send_frame(ack).await
        }
        FrameKind::Resume => {
            check_stream(f.kind, f.stream, |s| bound.contains_key(&s))?;
            if let Some(b) = bound.get(&f.stream) {
                if b.ack.kind == FrameKind::ResumeAck {
                    // duplicate Resume: replay the cached ack
                    return t.send_frame(b.ack.clone()).await;
                }
                // stream bound via Open but the edge is resuming ON the
                // same connection (e.g. a mux stream retrying without a
                // redial): process it fresh so the reply is a genuine
                // ResumeAck, not a replayed OpenAck of the wrong kind
            }
            let msg = ResumeMsg::decode(&f.payload)?;
            let (ack, live_session) =
                match verifier.resume(msg.token, msg.committed_len as usize).await {
                    Ok(info) => (
                        ResumeAck {
                            accepted: true,
                            done: info.done,
                            unknown_token: false,
                            session: info.session,
                            committed_len: info.committed_len as u64,
                            rounds: info.rounds as u64,
                            target_seq: info.target_seq,
                            tail: info.tail,
                            reason: String::new(),
                        },
                        (!info.done).then_some((info.session, info.attachment)),
                    ),
                    Err(e) => {
                        let text = format!("{e:#}");
                        let mut ack = ResumeAck::rejected(text.clone());
                        // structured rejection class (wire v5): the
                        // token maps to nothing anywhere this replica
                        // can see — fleet edges key their re-root on
                        // this bit, never on the reason text. Peers
                        // below v5 reject unknown flag bits, so the
                        // bit stays clear for them.
                        ack.unknown_token = negotiated >= 5
                            && text.contains(crate::serve::verifier::UNKNOWN_RESUME_TOKEN);
                        (ack, None)
                    }
                };
            let frame = Frame::on(f.stream, FrameKind::ResumeAck, ack.encode());
            if let Some((id, attachment)) = live_session {
                bound.insert(
                    f.stream,
                    Bound {
                        id,
                        attachment,
                        ack: frame.clone(),
                    },
                );
            }
            t.send_frame(frame).await
        }
        FrameKind::Draft => {
            check_stream(f.kind, f.stream, |s| bound.contains_key(&s))?;
            let (id, attachment) = {
                let b = &bound[&f.stream];
                (b.id, b.attachment)
            };
            let mut msg = DraftMsg::decode(&f.payload)?;
            if !msg.spec.is_empty() && negotiated < 3 {
                bail!(
                    "speculative draft on a wire v{negotiated} connection (stream {})",
                    f.stream
                );
            }
            if msg.is_tree() && negotiated < 8 {
                bail!(
                    "tree draft on a wire v{negotiated} connection (stream {})",
                    f.stream
                );
            }
            // the server-assigned session id is authoritative
            msg.session = id;
            // verify concurrently so other streams keep feeding the
            // batcher while this round waits for its window; peers
            // below wire v4 cannot parse a Busy deferral (always
            // admitted) and peers below v5 cannot follow a fleet
            // Redirect (never handed off) — the verifier gates both on
            // the negotiated version we pass through
            let v = verifier.clone();
            let out = out_tx.clone();
            let stream = f.stream;
            tokio::spawn(async move {
                match v.verify(id, attachment, msg, negotiated).await {
                    Ok(Some(VerifyReply::Verdict(vmsg))) => {
                        let _ = out.send(OutEvent::Frame(Frame::on(
                            stream,
                            FrameKind::Verify,
                            vmsg.encode(),
                        )));
                    }
                    // admission queue full: tell the edge to retry
                    Ok(Some(VerifyReply::Busy {
                        round,
                        retry_after_ms,
                    })) => {
                        let _ = out.send(OutEvent::Frame(Frame::on(
                            stream,
                            FrameKind::Busy,
                            BusyMsg {
                                round,
                                retry_after_ms,
                            }
                            .encode(),
                        )));
                    }
                    // fleet handoff: the session was exported — tell
                    // the edge where to resume
                    Ok(Some(VerifyReply::Redirect { addr, resume_token })) => {
                        let _ = out.send(OutEvent::Frame(Frame::on(
                            stream,
                            FrameKind::Redirect,
                            RedirectMsg { addr, resume_token }.encode(),
                        )));
                    }
                    // duplicate swallowed by the verifier: no reply owed
                    Ok(None) => {}
                    Err(e) => {
                        let _ = out.send(OutEvent::Fatal(format!(
                            "verify failed on stream {stream}: {e:#}"
                        )));
                    }
                }
            });
            Ok(())
        }
        FrameKind::Cancel => {
            if negotiated < 3 {
                bail!("Cancel frame on a wire v{negotiated} connection");
            }
            if f.stream == CONTROL_STREAM {
                bail!("Cancel on reserved control stream 0");
            }
            // retract queued speculative rounds; a Cancel for an
            // unknown stream is a harmless late retransmit (the session
            // closed underneath it)
            if let Some(b) = bound.get(&f.stream) {
                let c = CancelMsg::decode(&f.payload)?;
                verifier.cancel(b.id, b.attachment, c.round);
            }
            Ok(())
        }
        FrameKind::Bye => {
            if f.stream == CONTROL_STREAM {
                bail!("Bye on reserved control stream 0");
            }
            // orderly end of ONE session; a Bye for an unknown stream is
            // a harmless transport-level duplicate
            if let Some(b) = bound.remove(&f.stream) {
                verifier.end(b.id);
            }
            Ok(())
        }
        // wire-level stats pull (wire v6): an edge or fleet registry
        // asks for this replica's counter + latency-histogram snapshot.
        // Answered off the critical path like the ReplicaInfo
        // announcement — the verifier thread may be mid-batch — and
        // read-only: a lost or reordered Stats exchange can never
        // affect a committed token.
        FrameKind::Stats => {
            if negotiated < 6 {
                bail!("Stats frame on a wire v{negotiated} connection");
            }
            check_stream(f.kind, f.stream, |_| false)?;
            let req = StatsMsg::decode(&f.payload)?;
            let v = verifier.clone();
            let out = out_tx.clone();
            tokio::spawn(async move {
                let (m, info) = match (v.stats().await, v.info().await) {
                    (Ok(m), Ok(i)) => (m, i),
                    _ => return, // verifier shutting down: no reply owed
                };
                let ack = StatsAckMsg {
                    nonce: req.nonce,
                    version: info.version_seq,
                    sessions_active: info.active_sessions.min(u32::MAX as usize) as u32,
                    sessions_completed: m.sessions_completed as u64,
                    rounds: m.rounds as u64,
                    batches: m.batches as u64,
                    tokens_committed: m.tokens_committed as u64,
                    latency: m.latency,
                };
                let _ = out.send(OutEvent::Frame(Frame::control(
                    FrameKind::StatsAck,
                    ack.encode(),
                )));
            });
            Ok(())
        }
        FrameKind::HelloAck
        | FrameKind::OpenAck
        | FrameKind::ResumeAck
        | FrameKind::Verify
        | FrameKind::Busy
        | FrameKind::Redirect
        | FrameKind::ReplicaInfo
        | FrameKind::StatsAck => {
            bail!("unexpected {:?} frame from edge", f.kind)
        }
    }
}

/// Run a full multi-session serve over in-process loopback transports,
/// ONE CONNECTION PER SESSION: same verifier thread, same `handle_conn`,
/// no sockets. Sessions run concurrently; reports come back in input
/// order. This is the deterministic twin of the TCP path (with a
/// deterministic backend and a fixed stride it reproduces the
/// simulator's token counts exactly).
pub async fn serve_loopback(
    vcfg: VerifierConfig,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)>,
    ecfg: EdgeSessionConfig,
) -> Result<(Vec<EdgeReport>, ServingMetrics)> {
    let edges = edges
        .into_iter()
        .map(|(d, p)| (d, p, ecfg.clone()))
        .collect();
    serve_loopback_each(vcfg, make_backend, edges).await
}

/// [`serve_loopback`] with a PER-SESSION edge config — how the hetero
/// device-matrix suite runs unlike devices (profile, branching, stride)
/// side by side against one verifier (wire v8).
pub async fn serve_loopback_each(
    vcfg: VerifierConfig,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>, EdgeSessionConfig)>,
) -> Result<(Vec<EdgeReport>, ServingMetrics)> {
    let verifier = VerifierHandle::spawn(vcfg, make_backend)?;
    let mut tasks = Vec::new();
    for (draft, prompt, ecfg) in edges {
        let (edge_t, cloud_t) = loopback_pair();
        let v = verifier.clone();
        tokio::spawn(async move {
            if let Err(e) = handle_conn(cloud_t, v).await {
                log(Level::Warn, "serve", &format!("loopback conn: {e:#}"));
            }
        });
        tasks.push(tokio::spawn(async move {
            let mut draft = draft;
            let mut t = edge_t;
            run_edge_session(&mut t, draft.as_mut(), &prompt, &ecfg).await
        }));
    }
    let mut reports = Vec::new();
    for task in tasks {
        reports.push(
            task.await
                .map_err(|e| anyhow!("edge session task failed: {e}"))??,
        );
    }
    let metrics = verifier.shutdown().await?;
    Ok((reports, metrics))
}

/// Run a full multi-session serve with ALL sessions MULTIPLEXED over ONE
/// loopback connection via the edge-side mux: one `Hello`, one transport,
/// one stream id per session. With a deterministic backend and a fixed
/// stride this commits the same per-session token counts as
/// [`serve_loopback`] and the virtual-clock simulator — the equivalence
/// `tests/serve_loopback.rs` pins.
pub async fn serve_loopback_mux(
    vcfg: VerifierConfig,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)>,
    ecfg: EdgeSessionConfig,
) -> Result<(Vec<EdgeReport>, ServingMetrics)> {
    let edges = edges
        .into_iter()
        .map(|(d, p)| (d, p, ecfg.clone()))
        .collect();
    serve_loopback_mux_each(vcfg, make_backend, edges).await
}

/// [`serve_loopback_mux`] with a PER-SESSION edge config (wire v8
/// hetero populations). The shared connection's `Hello` uses the first
/// session's mode with the largest `k_max` across sessions; per-session
/// knobs that would violate the negotiated version (pipelining below
/// v3, profiles/branching below v8) are clamped per session, mirroring
/// `run_edge_session`'s own downgrade path.
pub async fn serve_loopback_mux_each(
    vcfg: VerifierConfig,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>, EdgeSessionConfig)>,
) -> Result<(Vec<EdgeReport>, ServingMetrics)> {
    let verifier = VerifierHandle::spawn(vcfg, make_backend)?;
    let (edge_t, cloud_t) = loopback_pair();
    let v = verifier.clone();
    tokio::spawn(async move {
        if let Err(e) = handle_conn(cloud_t, v).await {
            log(Level::Warn, "serve", &format!("loopback mux conn: {e:#}"));
        }
    });
    let mut hello_cfg = edges
        .first()
        .map(|(_, _, c)| c.clone())
        .unwrap_or_default();
    hello_cfg.k_max = edges
        .iter()
        .map(|(_, _, c)| c.k_max)
        .max()
        .unwrap_or(hello_cfg.k_max);
    let mut mux = EdgeMux::connect(Box::new(edge_t), None, &hello_cfg).await?;
    let wire = mux.wire_version();
    let mut tasks = Vec::new();
    for (draft, prompt, ecfg) in edges {
        let stream = mux.open_stream();
        // belt-and-braces: clamp anything the negotiated version cannot
        // carry (the mux streams skip run_edge_session's own gate)
        let ecfg = EdgeSessionConfig {
            pipeline_depth: if wire < 3 { 1 } else { ecfg.pipeline_depth },
            tier: if wire < 7 { 1 } else { ecfg.tier },
            profile: if wire < 8 { None } else { ecfg.profile },
            branching: if wire < 8 { 1 } else { ecfg.branching },
            ..ecfg
        };
        tasks.push(tokio::spawn(async move {
            let mut draft = draft;
            let mut t = stream;
            let stream_id = t.stream_id();
            super::edge::run_session_on(&mut t, stream_id, draft.as_mut(), &prompt, &ecfg).await
        }));
    }
    let mut reports = Vec::new();
    for task in tasks {
        reports.push(
            task.await
                .map_err(|e| anyhow!("edge mux session task failed: {e}"))??,
        );
    }
    drop(mux);
    let metrics = verifier.shutdown().await?;
    Ok((reports, metrics))
}
