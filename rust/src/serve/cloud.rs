//! The cloud verification server: a tokio accept loop feeding the
//! dedicated verifier thread (`serve::verifier`).
//!
//! One connection carries one KV session. The per-connection protocol
//! (`handle_conn`) is written against the `Transport` trait, so the TCP
//! server and the in-process loopback harness (`serve_loopback`) share
//! it verbatim — the loopback path is not a mock, it is the same server
//! minus the socket.
//!
//! Operational properties the tests pin:
//! * cross-connection dynamic batching (the verifier thread closes one
//!   window over requests from many connections);
//! * target-version hot-swap (`ServerHandle::deploy`) without dropping
//!   live sessions;
//! * graceful shutdown: stop accepting, drain active connections, flush
//!   the open batch, report final `ServingMetrics`.

use super::backend::VerifyBackend;
use super::edge::{run_edge_session, EdgeReport, EdgeSessionConfig};
use super::transport::{loopback_pair, TcpTransport, Transport};
use super::verifier::{VerifierConfig, VerifierHandle};
use crate::coordinator::edge::DraftSource;
use crate::metrics::ServingMetrics;
use crate::protocol::frame::{hello_response, Frame, FrameKind, Hello, OpenAck, OpenMsg};
use crate::protocol::DraftMsg;
use crate::util::log::{log, Level};
use anyhow::{anyhow, bail, Context, Result};
use std::net::SocketAddr;
use std::time::Duration;
use tokio::net::TcpListener;
use tokio::sync::watch;
use tokio::task::JoinSet;

/// How long `shutdown` waits for in-flight sessions before aborting
/// their connections.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(30);

/// Running server handle. Dropping it stops accepting new connections
/// (the shutdown watch closes) but skips the graceful drain — call
/// `shutdown` to flush the open batch and collect final metrics.
pub struct ServerHandle {
    pub addr: SocketAddr,
    verifier: VerifierHandle,
    shutdown: watch::Sender<bool>,
    accept: tokio::task::JoinHandle<()>,
}

impl ServerHandle {
    /// Hot-swap the deployed target version; live sessions keep their
    /// KV state and simply see different verification verdicts.
    pub async fn deploy(&self, version: &str) -> Result<u64> {
        self.verifier.deploy(version).await
    }

    /// Snapshot of the serving counters.
    pub async fn stats(&self) -> Result<ServingMetrics> {
        self.verifier.stats().await
    }

    /// A handle to the verification service (e.g. to share it with a
    /// loopback harness next to the TCP listener).
    pub fn verifier(&self) -> VerifierHandle {
        self.verifier.clone()
    }

    /// Graceful shutdown: stop accepting, wait up to `SHUTDOWN_GRACE`
    /// for active connections to finish (stragglers are aborted), flush
    /// the open batch, return final metrics.
    pub async fn shutdown(self) -> Result<ServingMetrics> {
        let _ = self.shutdown.send(true);
        let _ = self.accept.await;
        self.verifier.shutdown().await
    }
}

/// Bind a TCP verification server. `make_backend` runs on the verifier
/// thread (so `!Send` PJRT backends work); pass port 0 to let the OS
/// pick one (`handle.addr` has the result).
pub async fn serve_cloud(
    bind: &str,
    vcfg: VerifierConfig,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
) -> Result<ServerHandle> {
    let verifier = VerifierHandle::spawn(vcfg, make_backend)?;
    let listener = TcpListener::bind(bind)
        .await
        .with_context(|| format!("binding cloud server to {bind}"))?;
    let addr = listener.local_addr()?;
    let (shutdown, mut shutdown_rx) = watch::channel(false);
    let vh = verifier.clone();
    let accept = tokio::spawn(async move {
        let mut conns: JoinSet<()> = JoinSet::new();
        loop {
            tokio::select! {
                res = listener.accept() => match res {
                    Ok((stream, peer)) => {
                        let t = TcpTransport::new(stream, peer.to_string());
                        let v = vh.clone();
                        conns.spawn(async move {
                            let peer = t.peer();
                            if let Err(e) = handle_conn(t, v).await {
                                log(Level::Warn, "serve", &format!("{peer}: {e:#}"));
                            }
                        });
                    }
                    Err(e) => {
                        // transient (EMFILE, ECONNABORTED bursts): log,
                        // breathe, keep accepting — only shutdown ends
                        // the loop
                        log(Level::Warn, "serve", &format!("accept failed: {e}"));
                        tokio::time::sleep(std::time::Duration::from_millis(50)).await;
                    }
                },
                _ = shutdown_rx.changed() => break,
            }
        }
        // graceful drain: let in-flight sessions run to completion,
        // bounded so one stalled connection cannot hang shutdown forever
        let drain = async {
            while conns.join_next().await.is_some() {}
        };
        if tokio::time::timeout(SHUTDOWN_GRACE, drain).await.is_err() {
            log(
                Level::Warn,
                "serve",
                "shutdown grace period expired; aborting remaining connections",
            );
            conns.abort_all();
            while conns.join_next().await.is_some() {}
        }
    });
    Ok(ServerHandle {
        addr,
        verifier,
        shutdown,
        accept,
    })
}

/// Serve one connection: version handshake → session open → decode loop.
/// Transport-generic so TCP and loopback share it.
pub async fn handle_conn<T: Transport>(mut t: T, verifier: VerifierHandle) -> Result<()> {
    // --- wire-format version handshake -------------------------------
    let hello = match t.recv_frame().await? {
        None => return Ok(()),
        Some(f) if f.kind == FrameKind::Hello => Hello::decode(&f.payload)?,
        Some(f) => bail!("expected Hello, got {:?}", f.kind),
    };
    let ack = hello_response(&hello);
    let accepted = ack.accepted;
    t.send_frame(Frame::new(FrameKind::HelloAck, ack.encode()))
        .await?;
    if !accepted {
        verifier.note_rejected_handshake();
        return Ok(());
    }

    // --- session open ------------------------------------------------
    let open = match t.recv_frame().await? {
        None => return Ok(()),
        Some(f) if f.kind == FrameKind::Open => OpenMsg::decode(&f.payload)?,
        Some(f) => bail!("expected Open, got {:?}", f.kind),
    };
    let (id, target_seq) = verifier.open(open.prompt, open.max_new as usize).await?;
    t.send_frame(Frame::new(
        FrameKind::OpenAck,
        OpenAck {
            session: id,
            target_seq,
        }
        .encode(),
    ))
    .await?;

    // --- decode loop -------------------------------------------------
    let result = conn_loop(&mut t, &verifier, id).await;
    // idempotent: no-op if the session completed naturally; counts an
    // abort if the client vanished mid-session
    verifier.end(id);
    result
}

async fn conn_loop<T: Transport>(t: &mut T, verifier: &VerifierHandle, id: u32) -> Result<()> {
    loop {
        match t.recv_frame().await? {
            None
            | Some(Frame {
                kind: FrameKind::Bye,
                ..
            }) => return Ok(()),
            Some(f) if f.kind == FrameKind::Draft => {
                let mut msg = DraftMsg::decode(&f.payload)?;
                // the server-assigned session id is authoritative
                msg.session = id;
                let vmsg = verifier.verify(id, msg).await?;
                t.send_frame(Frame::new(FrameKind::Verify, vmsg.encode()))
                    .await?;
            }
            Some(f) => bail!("unexpected {:?} frame in session {id}", f.kind),
        }
    }
}

/// Run a full multi-session serve over in-process loopback transports:
/// same verifier thread, same `handle_conn`, no sockets. Sessions run
/// concurrently; reports come back in input order. This is the
/// deterministic twin of the TCP path (with a deterministic backend and
/// a fixed stride it reproduces the simulator's token counts exactly).
pub async fn serve_loopback(
    vcfg: VerifierConfig,
    make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)>,
    ecfg: EdgeSessionConfig,
) -> Result<(Vec<EdgeReport>, ServingMetrics)> {
    let verifier = VerifierHandle::spawn(vcfg, make_backend)?;
    let mut tasks = Vec::new();
    for (draft, prompt) in edges {
        let (edge_t, cloud_t) = loopback_pair();
        let v = verifier.clone();
        tokio::spawn(async move {
            if let Err(e) = handle_conn(cloud_t, v).await {
                log(Level::Warn, "serve", &format!("loopback conn: {e:#}"));
            }
        });
        let ecfg = ecfg.clone();
        tasks.push(tokio::spawn(async move {
            let mut draft = draft;
            let mut t = edge_t;
            run_edge_session(&mut t, draft.as_mut(), &prompt, &ecfg).await
        }));
    }
    let mut reports = Vec::new();
    for task in tasks {
        reports.push(
            task.await
                .map_err(|e| anyhow!("edge session task failed: {e}"))??,
        );
    }
    let metrics = verifier.shutdown().await?;
    Ok((reports, metrics))
}
