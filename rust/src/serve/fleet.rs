//! `serve::fleet` — multi-replica serving for one frozen edge draft
//! against a FLEET of evolving cloud targets (wire v5).
//!
//! The paper's thesis is that a frozen draft stays compatible with a
//! *family* of evolving targets; this module makes the family literal:
//! N verification replicas, each its own [`VerifierHandle`] (own
//! backend, own deployed target version), stitched together by two
//! small pieces of shared state:
//!
//! * [`SessionLedger`] — the handoff store. A replica that wants to
//!   shed a session (drain for a staged rollout, targeted rebalance)
//!   EXPORTS the session's portable remainder — committed sequence,
//!   prompt boundary, budget, counters, keyed by its resume token —
//!   and answers the session's next head round with a `Redirect
//!   { addr, resume_token }` frame instead of a verdict. Whichever
//!   replica sees the edge's `Resume` next IMPORTS the entry and
//!   decoding continues from the committed prefix. Because drafts are
//!   pure functions of the committed prefix and synthetic verdicts are
//!   pure functions of (context, version), the handed-off session
//!   commits byte-identical tokens (`tests/serve_fleet.rs` pins this
//!   across seeds [3, 17, 42], sequential / muxed / pipelined).
//! * [`FleetRegistry`] — the control plane. Tracks replica endpoints,
//!   deployed version, load (active sessions + pending drafts, from
//!   [`ReplicaTelemetry`]) and health; performs staged/canary rollout
//!   ([`FleetRegistry::advance_version`] reuses the existing per-
//!   replica hot-swap) and rollback; starts/stops drains; and hands
//!   out fleet-aware dialers.
//!
//! # Handoff state machine
//!
//! ```text
//!  replica A (draining)                edge                  replica B
//!   submit(head round r) ──▶ export to ledger
//!            Redirect{B, token} ──▶ retarget dial at B
//!                                   reattach ─── Resume{token} ──▶ import
//!            ◀── (old conn dies; stale detach is a no-op)  ResumeAck{rounds: r}
//!                                   redraft round r ── Draft(r) ──▶ verify
//! ```
//!
//! Degraded paths, all loss-tolerant:
//! * The edge cannot follow (a mux stream is pinned to its shared
//!   connection): it resumes IN PLACE and A re-imports its own export;
//!   a once-per-grace-window guard stops A from bouncing the session
//!   again, so it always makes progress.
//! * A duplicated `Redirect` frame re-triggers a resume that finds the
//!   session live at its current home — absorbed like any duplicate.
//! * A replica dies before it can export: the edge's resume is
//!   rejected everywhere, and an edge with
//!   `EdgeSessionConfig::reroot_on_unknown_session` re-opens on a
//!   surviving replica with its committed prefix as the prompt — the
//!   frozen draft needs nothing but the position, so the trajectory is
//!   still byte-identical.
//!
//! Peers that negotiated wire < 5 are never redirected (they cannot
//! parse the frame) — a drain degrades to serving them in place.
//!
//! The virtual-clock twin lives in `coordinator::scheduler`
//! ([`crate::coordinator::ServeConfig`]`::fleet`): the simulator
//! replays the redirect schedule under virtual time and commits the
//! identical tokens, which is what keeps sim == serve determinism at
//! fleet scale.

use super::cloud::handle_conn;
use super::fault::{FaultPlan, FaultTransport};
use super::transport::{loopback_pair, BoxFuture, Reconnect, TcpTransport, Transport};
use super::verifier::{ReplicaTelemetry, VerifierConfig, VerifierHandle};
use crate::obs::LatencySummary;
use crate::serve::backend::VerifyBackend;
use crate::util::log::{log, Level};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

// ---------------------------------------------------------------------
// Portable session state + the shared handoff ledger
// ---------------------------------------------------------------------

/// Everything a session needs to continue decoding on another replica
/// — for synthetic/pure backends this IS the whole session (the KV
/// cache is a deterministic function of `committed`; PJRT KV migration
/// is the documented open item). Token payloads only; no handles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PortableSession {
    /// Full committed sequence (prompt + generated).
    pub committed: Vec<i32>,
    /// Original prompt boundary (acceptance metrics and resume-position
    /// validation need it; `committed[..prompt_len]` is the prompt).
    pub prompt_len: usize,
    /// Original per-session generation budget.
    pub max_new: usize,
    /// Verified rounds so far — the wire round counter continues from
    /// here on the importing replica.
    pub rounds: usize,
    /// Accepted draft tokens so far (metrics continuity).
    pub accepted: usize,
    /// Drafted tokens so far (metrics continuity).
    pub drafted: usize,
    /// True when the session finished before the handoff completed.
    pub done: bool,
}

/// The fleet's shared handoff store: resume token → [`PortableSession`].
///
/// Cheap to clone (an `Arc` around a mutexed map); every replica of one
/// fleet holds a clone. Entries are WRITTEN by `export` (a draining
/// replica, inside its verifier thread) and CONSUMED by `import` (the
/// replica that sees the session's `Resume`), so an entry lives exactly
/// as long as the session is in flight between replicas. In-process
/// fleets (loopback replicas, or several TCP listeners in one server
/// process) share it directly; a cross-process deployment would back
/// the same two calls with an external store — the interface is the
/// contract, deliberately tiny.
#[derive(Clone, Default)]
pub struct SessionLedger {
    inner: Arc<Mutex<LedgerInner>>,
}

#[derive(Default)]
struct LedgerInner {
    /// Monotonic export sequence: every export gets a fresh stamp, so
    /// an exporter can later [`SessionLedger::reap`] exactly the entry
    /// IT wrote — never a newer re-export of the same token by a
    /// sibling (a multi-hop handoff within one grace window).
    seq: u64,
    /// token → (export stamp, export timestamp ms, session). The
    /// timestamp feeds the TTL sweep ([`SessionLedger::expire_before`]):
    /// an exporter can die before its reap fires, so the store itself
    /// must be able to age entries out.
    entries: HashMap<u64, (u64, f64, PortableSession)>,
}

impl SessionLedger {
    pub fn new() -> SessionLedger {
        SessionLedger::default()
    }

    /// Park a session under its resume token (overwrites a stale entry
    /// for the same token — the newest export is the truth). `now_ms`
    /// stamps the entry for the TTL sweep. Returns the entry's export
    /// stamp; the exporter passes it back to [`SessionLedger::reap`]
    /// when its grace window expires, so an abandoned handoff (the edge
    /// never resumes anywhere) cannot pin the committed sequence in the
    /// shared store forever.
    pub fn export(&self, token: u64, session: PortableSession, now_ms: f64) -> u64 {
        let mut inner = self.inner.lock().expect("session ledger poisoned");
        inner.seq += 1;
        let seq = inner.seq;
        inner.entries.insert(token, (seq, now_ms, session));
        seq
    }

    /// Take a session out (consuming its entry), if it is parked here.
    pub fn import(&self, token: u64) -> Option<PortableSession> {
        self.import_timed(token).map(|(_, p)| p)
    }

    /// [`SessionLedger::import`] plus the entry's export timestamp: an
    /// importer that has to put a FAILED import back re-exports with
    /// the ORIGINAL timestamp, so a bad resume cannot refresh an
    /// abandoned entry's TTL forever.
    pub fn import_timed(&self, token: u64) -> Option<(f64, PortableSession)> {
        self.inner
            .lock()
            .expect("session ledger poisoned")
            .entries
            .remove(&token)
            .map(|(_, at, p)| (at, p))
    }

    /// Remove `token`'s entry iff it still carries the exporter's
    /// stamp: a no-op when the entry was imported (gone) or re-exported
    /// by a later hop (newer stamp). Called by the exporting replica
    /// when its handoff grace window expires.
    pub fn reap(&self, token: u64, seq: u64) {
        let mut inner = self.inner.lock().expect("session ledger poisoned");
        if inner.entries.get(&token).is_some_and(|(s, _, _)| *s == seq) {
            inner.entries.remove(&token);
        }
    }

    /// TTL sweep: drop every entry exported more than `ttl_ms` before
    /// `now_ms` and return how many were dropped. The reap path covers
    /// a live exporter; this covers the exporter that died (or was
    /// retired by the autoscaler) before its grace window fired —
    /// without it the shared store grows forever. Virtual-clock
    /// friendly: the caller supplies the clock.
    pub fn expire_before(&self, now_ms: f64, ttl_ms: f64) -> usize {
        let mut inner = self.inner.lock().expect("session ledger poisoned");
        let before = inner.entries.len();
        inner.entries.retain(|_, (_, at, _)| now_ms - *at <= ttl_ms);
        before - inner.entries.len()
    }

    /// Earliest TTL deadline over the parked entries (`f64::INFINITY`
    /// when empty) — joins the verifier's next-sweep fold so the sweep
    /// stays event-driven instead of polling.
    pub fn next_expiry(&self, ttl_ms: f64) -> f64 {
        self.inner
            .lock()
            .expect("session ledger poisoned")
            .entries
            .values()
            .map(|(_, at, _)| at + ttl_ms)
            .fold(f64::INFINITY, f64::min)
    }

    /// Sessions currently in flight between replicas.
    pub fn len(&self) -> usize {
        self.inner
            .lock()
            .expect("session ledger poisoned")
            .entries
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------
// The fleet registry (control plane)
// ---------------------------------------------------------------------

/// Shared address book for in-process fleets: address label →
/// verifier. Fleet dialers resolve redirect targets (and fail over on
/// replica death) through it; removing an entry
/// ([`FleetRegistry::mark_dead`]) makes dials skip the replica.
pub type FleetDirectory = Arc<Mutex<HashMap<String, VerifierHandle>>>;

/// One replica's registry entry: endpoint + the last refreshed
/// telemetry snapshot.
#[derive(Clone)]
pub struct FleetReplica {
    /// Registry-assigned replica id (stable across refreshes).
    pub id: u32,
    /// Endpoint: a TCP `host:port` or an in-process registry label.
    pub addr: String,
    /// Handle to the replica's verification service.
    pub verifier: VerifierHandle,
    /// False once a refresh failed to reach the replica (or
    /// [`FleetRegistry::mark_dead`] was called) — dead replicas are
    /// never picked as redirect targets.
    pub healthy: bool,
    /// Sticky operator verdict ([`FleetRegistry::mark_dead`]): a
    /// quarantined replica is skipped by refresh entirely — it can
    /// never be resurrected into the dial directory or the peer pool
    /// behind the operator's back. Cleared only by
    /// [`FleetRegistry::revive`].
    pub quarantined: bool,
    /// True while a drain is active on this replica.
    pub draining: bool,
    /// Last telemetry snapshot ([`FleetRegistry::refresh`]).
    pub last: Option<ReplicaTelemetry>,
    /// When the last SUCCESSFUL refresh stamped `last` (`None` before
    /// the first). A replica whose refreshes have stopped keeps its
    /// old snapshot, so placement must judge the snapshot's AGE, not
    /// just its presence.
    pub refreshed_at_ms: Option<f64>,
}

impl FleetReplica {
    /// Load scalar for least-loaded placement (`usize::MAX` before the
    /// first refresh, so unknown replicas are never preferred).
    pub fn load(&self) -> usize {
        self.last.as_ref().map(|t| t.load()).unwrap_or(usize::MAX)
    }

    /// Age of the telemetry snapshot at `now_ms` (`f64::INFINITY`
    /// before the first refresh).
    pub fn age_ms(&self, now_ms: f64) -> f64 {
        self.refreshed_at_ms
            .map(|at| now_ms - at)
            .unwrap_or(f64::INFINITY)
    }

    /// Load as placement sees it at `now_ms`: a snapshot older than
    /// `staleness_ms` is UNKNOWN (`usize::MAX`, never preferred), same
    /// as a replica that was never refreshed — a stale low number must
    /// not win placement over a fresh honest one.
    pub fn effective_load(&self, now_ms: f64, staleness_ms: f64) -> usize {
        if self.age_ms(now_ms) > staleness_ms {
            usize::MAX
        } else {
            self.load()
        }
    }
}

/// Default telemetry staleness window
/// ([`FleetRegistry::staleness_ms`]): 10 missed 200ms refresh beats.
pub const DEFAULT_STALENESS_MS: f64 = 2000.0;

/// Cloud-side replica registry: endpoints, versions, load, health,
/// staged rollout, drains, and fleet-aware dialers. See the module docs
/// for the data flow.
pub struct FleetRegistry {
    ledger: SessionLedger,
    directory: FleetDirectory,
    replicas: Vec<FleetReplica>,
    next_id: u32,
    /// Telemetry snapshots older than this are treated as unknown by
    /// [`FleetRegistry::pick_peer`] and the autoscaler.
    pub staleness_ms: f64,
}

impl Default for FleetRegistry {
    fn default() -> FleetRegistry {
        FleetRegistry {
            ledger: SessionLedger::default(),
            directory: FleetDirectory::default(),
            replicas: Vec::new(),
            next_id: 0,
            staleness_ms: DEFAULT_STALENESS_MS,
        }
    }
}

impl FleetRegistry {
    pub fn new() -> FleetRegistry {
        FleetRegistry::default()
    }

    /// The fleet's shared handoff ledger (clone it into every replica
    /// via [`VerifierHandle::spawn_with_ledger`]).
    pub fn ledger(&self) -> SessionLedger {
        self.ledger.clone()
    }

    /// The shared address book fleet dialers resolve through.
    pub fn directory(&self) -> FleetDirectory {
        self.directory.clone()
    }

    /// Register an already-spawned replica under `addr`. The verifier
    /// should have been spawned with this fleet's ledger, or handoffs
    /// to/from it will be rejected resumes instead of imports.
    pub fn register(&mut self, addr: &str, verifier: VerifierHandle) -> u32 {
        self.next_id += 1;
        self.directory
            .lock()
            .expect("fleet directory poisoned")
            .insert(addr.to_string(), verifier.clone());
        self.replicas.push(FleetReplica {
            id: self.next_id,
            addr: addr.to_string(),
            verifier,
            healthy: true,
            quarantined: false,
            draining: false,
            last: None,
            refreshed_at_ms: None,
        });
        self.next_id
    }

    /// Spawn an in-process replica (own verifier thread + backend) and
    /// register it: the loopback twin of adding a `serve-cloud` node.
    pub fn spawn_loopback_replica(
        &mut self,
        addr: &str,
        vcfg: VerifierConfig,
        make_backend: impl FnOnce() -> Result<Box<dyn VerifyBackend>> + Send + 'static,
    ) -> Result<u32> {
        let v = VerifierHandle::spawn_with_ledger(vcfg, self.ledger.clone(), make_backend)?;
        Ok(self.register(addr, v))
    }

    pub fn replicas(&self) -> &[FleetReplica] {
        &self.replicas
    }

    pub fn replica(&self, addr: &str) -> Option<&FleetReplica> {
        self.replicas.iter().find(|r| r.addr == addr)
    }

    /// Verifier handle for `addr` (live replicas only).
    pub fn verifier(&self, addr: &str) -> Option<VerifierHandle> {
        self.replica(addr).map(|r| r.verifier.clone())
    }

    /// Pull fresh telemetry from every replica; a replica that fails to
    /// answer is marked unhealthy — and pulled from the dial directory,
    /// so fleet dials skip it — until a later refresh reaches it again,
    /// which restores both the health flag AND the directory entry
    /// (dials and the control plane must agree on who is reachable).
    /// `now_ms` stamps each successful snapshot for the staleness
    /// window; a failed refresh keeps the old stamp, so the snapshot
    /// ages out of placement naturally.
    pub async fn refresh(&mut self, now_ms: f64) {
        for r in &mut self.replicas {
            if r.quarantined {
                continue; // the operator's verdict outlives liveness
            }
            match r.verifier.info().await {
                Ok(t) => {
                    r.draining = t.draining;
                    r.last = Some(t);
                    r.refreshed_at_ms = Some(now_ms);
                    r.healthy = true;
                    self.directory
                        .lock()
                        .expect("fleet directory poisoned")
                        .insert(r.addr.clone(), r.verifier.clone());
                }
                Err(_) => {
                    r.healthy = false;
                    self.directory
                        .lock()
                        .expect("fleet directory poisoned")
                        .remove(&r.addr);
                }
            }
        }
    }

    /// Least-loaded healthy, non-draining replica other than
    /// `not_addr` — the standard redirect target. Ties break by
    /// registration order (deterministic). Replicas whose telemetry is
    /// older than [`FleetRegistry::staleness_ms`] at `now_ms` rank as
    /// unknown load (never preferred over a fresh snapshot).
    pub fn pick_peer(&self, not_addr: &str, now_ms: f64) -> Option<String> {
        self.replicas
            .iter()
            .filter(|r| r.healthy && !r.quarantined && !r.draining && r.addr != not_addr)
            .min_by_key(|r| (r.effective_load(now_ms, self.staleness_ms), r.id))
            .map(|r| r.addr.clone())
    }

    /// Start draining `addr`: every redirect-capable session's next
    /// head round there is handed to `to`. Sessions of pre-v5 peers
    /// keep decoding in place.
    pub fn drain(&mut self, addr: &str, to: &str) -> Result<()> {
        let r = self
            .replicas
            .iter_mut()
            .find(|r| r.addr == addr)
            .ok_or_else(|| anyhow!("unknown replica '{addr}'"))?;
        r.draining = true;
        r.verifier.set_redirect(Some(to.to_string()));
        Ok(())
    }

    /// Stop a drain (rollback of a scale-down, or the rollout finished).
    pub fn undrain(&mut self, addr: &str) -> Result<()> {
        let r = self
            .replicas
            .iter_mut()
            .find(|r| r.addr == addr)
            .ok_or_else(|| anyhow!("unknown replica '{addr}'"))?;
        r.draining = false;
        r.verifier.set_redirect(None);
        Ok(())
    }

    /// Targeted rebalance: move ONE session (by its server-assigned id
    /// on `addr`) to `to` at its next head round.
    pub fn redirect_session(&self, addr: &str, session: u32, to: &str) -> Result<()> {
        self.verifier(addr)
            .ok_or_else(|| anyhow!("unknown replica '{addr}'"))?
            .redirect_session(session, to.to_string());
        Ok(())
    }

    /// Bulk rebalance (the autoscaler's flow actuator): mark up to `n`
    /// redirect-capable sessions on `from` for handoff to `to` at their
    /// next head round. The verifier picks the lowest session ids first
    /// (deterministic) and skips sessions already marked or pinned to
    /// pre-v5 peers. Returns the ids actually marked — possibly fewer
    /// than `n`, possibly none.
    pub async fn rebalance(&self, from: &str, to: &str, n: usize) -> Result<Vec<u32>> {
        self.verifier(from)
            .ok_or_else(|| anyhow!("unknown replica '{from}'"))?
            .redirect_some(n, to.to_string())
            .await
    }

    /// Staged / canary rollout: hot-swap the deployed target version on
    /// a SUBSET of replicas (live sessions there keep their state and
    /// simply see the new verdict function — the existing single-node
    /// hot-swap, fleet-wide). Returns the new version sequence per
    /// replica, in `subset` order. Rolling BACK a canary is the same
    /// call with the previous version name.
    pub async fn advance_version(&mut self, subset: &[&str], version: &str) -> Result<Vec<u64>> {
        let mut seqs = Vec::with_capacity(subset.len());
        for addr in subset {
            let v = self
                .verifier(addr)
                .ok_or_else(|| anyhow!("unknown replica '{addr}'"))?;
            seqs.push(v.deploy(version).await?);
        }
        Ok(seqs)
    }

    /// Declare a replica dead: it leaves the directory (dials fail over
    /// past it) and is never picked as a redirect target. STICKY — a
    /// later refresh that happens to reach the replica will not
    /// resurrect it behind the operator's back (sessions placed on a
    /// replica that is about to be torn down would be lost); only
    /// [`FleetRegistry::revive`] clears the verdict. Its unexported
    /// sessions are lost — edges with `reroot_on_unknown_session`
    /// re-open on a surviving replica from their committed prefix.
    pub fn mark_dead(&mut self, addr: &str) {
        self.directory
            .lock()
            .expect("fleet directory poisoned")
            .remove(addr);
        if let Some(r) = self.replicas.iter_mut().find(|r| r.addr == addr) {
            r.healthy = false;
            r.quarantined = true;
        }
    }

    /// Lift a [`FleetRegistry::mark_dead`] quarantine: the next refresh
    /// may mark the replica healthy and restore its directory entry.
    pub fn revive(&mut self, addr: &str) {
        if let Some(r) = self.replicas.iter_mut().find(|r| r.addr == addr) {
            r.quarantined = false;
        }
    }

    /// Merged metrics snapshot across every reachable replica: headline
    /// counters summed, latency histograms MERGED — the mergeable-
    /// histogram property is what makes fleet-wide p99 a real quantile
    /// over all rounds, not an average of per-replica percentiles.
    /// Quarantined/unhealthy replicas are skipped; a live replica that
    /// fails to answer is counted in `unreachable`.
    pub async fn fleet_stats(&self) -> FleetStats {
        let mut out = FleetStats::default();
        for r in &self.replicas {
            if r.quarantined || !r.healthy {
                continue;
            }
            match r.verifier.stats().await {
                Ok(m) => {
                    out.replicas += 1;
                    out.sessions_completed += m.sessions_completed;
                    out.rounds += m.rounds;
                    out.batches += m.batches;
                    out.tokens_committed += m.tokens_committed;
                    out.latency.merge(&m.latency);
                }
                Err(_) => out.unreachable += 1,
            }
        }
        out
    }

    /// A fleet-aware [`Reconnect`] for in-process replicas: dials
    /// `initial` through the shared directory, follows `Redirect`
    /// retargets (`set_target`), and on connect failure fails over
    /// through the directory in sorted-address order. Optionally wraps
    /// every connection in a [`FaultTransport`] over `fault` (schedules
    /// span reconnects — the fault-matrix wiring).
    pub fn dial(
        &self,
        initial: &str,
        fault: Option<Arc<Mutex<FaultPlan>>>,
    ) -> Box<dyn Reconnect> {
        Box::new(LoopbackFleetDial {
            directory: self.directory.clone(),
            target: initial.to_string(),
            fault,
        })
    }
}

/// Fleet-wide metrics rollup ([`FleetRegistry::fleet_stats`]).
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// Replicas that answered.
    pub replicas: usize,
    /// Healthy replicas that failed to answer the snapshot request.
    pub unreachable: usize,
    pub sessions_completed: usize,
    pub rounds: usize,
    pub batches: usize,
    pub tokens_committed: usize,
    /// Merged latency histograms across the answering replicas.
    pub latency: LatencySummary,
}

// ---------------------------------------------------------------------
// Fleet-aware dialers (edge side)
// ---------------------------------------------------------------------

/// In-process fleet dialer: resolves its current target through the
/// shared [`FleetDirectory`], spawning the REAL connection handler
/// (`cloud::handle_conn`) on the replica's verifier for each fresh
/// loopback pair — the same wiring `serve_loopback` uses, plus
/// retargeting and failover.
struct LoopbackFleetDial {
    directory: FleetDirectory,
    target: String,
    fault: Option<Arc<Mutex<FaultPlan>>>,
}

impl Reconnect for LoopbackFleetDial {
    fn connect(&mut self) -> BoxFuture<'_, Result<Box<dyn Transport>>> {
        Box::pin(async move {
            // current target first, then fail over through the
            // directory in sorted-address order (deterministic)
            let mut candidates = vec![self.target.clone()];
            {
                let d = self.directory.lock().expect("fleet directory poisoned");
                let mut rest: Vec<String> = d
                    .keys()
                    .filter(|k| **k != self.target)
                    .cloned()
                    .collect();
                rest.sort();
                candidates.extend(rest);
            }
            for addr in candidates {
                let Some(v) = self
                    .directory
                    .lock()
                    .expect("fleet directory poisoned")
                    .get(&addr)
                    .cloned()
                else {
                    continue; // dead replica: skip
                };
                if addr != self.target {
                    log(
                        Level::Debug,
                        "fleet",
                        &format!("failing over from '{}' to '{addr}'", self.target),
                    );
                    self.target = addr.clone();
                }
                let (edge_t, cloud_t) = loopback_pair();
                tokio::spawn(async move {
                    // conn errors under drains/faults are expected; the
                    // verifier parks or exports and the edge resumes
                    let _ = handle_conn(cloud_t, v).await;
                });
                let t: Box<dyn Transport> = match &self.fault {
                    Some(p) => Box::new(FaultTransport::new(Box::new(edge_t), p.clone())),
                    None => Box::new(edge_t),
                };
                return Ok(t);
            }
            Err(anyhow!(
                "fleet directory has no live replica (wanted '{}')",
                self.target
            ))
        })
    }

    fn set_target(&mut self, addr: &str) -> bool {
        self.target = addr.to_string();
        true
    }
}

/// TCP fleet dialer: dials its current target address, follows
/// `Redirect` retargets, and on connect failure fails over through the
/// known replica list (round-robin from the failed target). `addrs`
/// seeds the known list; redirect targets outside it are learned on the
/// fly.
pub fn tcp_fleet_dial(addrs: Vec<String>) -> Box<dyn Reconnect> {
    Box::new(TcpFleetDial {
        addrs: addrs.clone(),
        target: addrs.first().cloned().unwrap_or_default(),
    })
}

struct TcpFleetDial {
    addrs: Vec<String>,
    target: String,
}

impl Reconnect for TcpFleetDial {
    fn connect(&mut self) -> BoxFuture<'_, Result<Box<dyn Transport>>> {
        Box::pin(async move {
            let mut candidates = vec![self.target.clone()];
            candidates.extend(self.addrs.iter().filter(|a| **a != self.target).cloned());
            let mut last_err = anyhow!("no fleet addresses configured");
            for addr in candidates {
                match TcpTransport::connect(&addr).await {
                    Ok(t) => {
                        if addr != self.target {
                            log(
                                Level::Debug,
                                "fleet",
                                &format!("failing over from '{}' to '{addr}'", self.target),
                            );
                            self.target = addr;
                        }
                        return Ok(Box::new(t) as Box<dyn Transport>);
                    }
                    Err(e) => last_err = e,
                }
            }
            Err(last_err)
        })
    }

    fn set_target(&mut self, addr: &str) -> bool {
        if !self.addrs.iter().any(|a| a == addr) {
            self.addrs.push(addr.to_string());
        }
        self.target = addr.to_string();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::backend::SyntheticTarget;

    fn rt() -> tokio::runtime::Runtime {
        tokio::runtime::Builder::new_current_thread()
            .enable_all()
            .build()
            .unwrap()
    }

    #[test]
    fn ledger_export_import_roundtrip() {
        let l = SessionLedger::new();
        assert!(l.is_empty());
        let p = PortableSession {
            committed: vec![1, 70, 71, 80, 81],
            prompt_len: 3,
            max_new: 32,
            rounds: 2,
            accepted: 5,
            drafted: 6,
            done: false,
        };
        l.export(9, p.clone(), 0.0);
        assert_eq!(l.len(), 1);
        // import consumes
        assert_eq!(l.import(9), Some(p.clone()));
        assert!(l.import(9).is_none());
        // newest export wins
        l.export(9, p.clone(), 0.0);
        let p2 = PortableSession {
            rounds: 3,
            ..p.clone()
        };
        l.export(9, p2.clone(), 1.0);
        assert_eq!(l.import(9), Some(p2.clone()));

        // reap removes exactly the stamped entry: a stale stamp (the
        // entry was re-exported by a later hop) is a no-op, the
        // matching stamp clears an abandoned handoff
        let s1 = l.export(9, p.clone(), 2.0);
        let s2 = l.export(9, p2.clone(), 3.0);
        assert!(s2 > s1);
        l.reap(9, s1);
        assert_eq!(l.len(), 1, "stale stamp must not reap a newer export");
        l.reap(9, s2);
        assert!(l.is_empty(), "matching stamp reaps the abandoned entry");
        // reaping an imported (gone) entry is a no-op
        let s3 = l.export(9, p, 4.0);
        assert!(l.import(9).is_some());
        l.reap(9, s3);
        assert!(l.is_empty());
    }

    #[test]
    fn ledger_ttl_sweep_expires_only_old_entries() {
        let l = SessionLedger::new();
        let p = PortableSession {
            committed: vec![1, 70],
            prompt_len: 1,
            max_new: 8,
            rounds: 1,
            accepted: 1,
            drafted: 1,
            done: false,
        };
        assert_eq!(l.next_expiry(100.0), f64::INFINITY, "empty ledger");
        l.export(1, p.clone(), 0.0);
        l.export(2, p.clone(), 500.0);
        l.export(3, p.clone(), 900.0);
        assert_eq!(l.next_expiry(100.0), 100.0);
        // nothing is old enough yet: an entry expires strictly after
        // now - at > ttl
        assert_eq!(l.expire_before(100.0, 100.0), 0);
        assert_eq!(l.len(), 3);
        // the first entry ages out; the later two survive
        assert_eq!(l.expire_before(600.0, 100.0), 1);
        assert_eq!(l.len(), 2);
        assert!(l.import(1).is_none(), "expired entry is gone");
        assert!(l.import(2).is_some(), "fresh entry survives the sweep");
        // a re-export refreshes the timestamp (newest export is truth)
        l.export(3, p, 2000.0);
        assert_eq!(l.expire_before(2001.0, 100.0), 0);
        assert_eq!(l.len(), 1);
        assert_eq!(l.next_expiry(50.0), 2050.0);
    }

    #[test]
    fn registry_tracks_health_load_and_picks_peers() {
        rt().block_on(async {
            let mut reg = FleetRegistry::new();
            for addr in ["replica-a", "replica-b", "replica-c"] {
                reg.spawn_loopback_replica(addr, VerifierConfig::default(), || {
                    Ok(Box::new(SyntheticTarget::new(5)) as Box<dyn VerifyBackend>)
                })
                .unwrap();
            }
            reg.refresh(0.0).await;
            assert!(reg.replicas().iter().all(|r| r.healthy && !r.draining));
            assert!(reg.replicas().iter().all(|r| r.load() == 0));

            // load one replica: it stops being the preferred peer
            let vb = reg.verifier("replica-b").unwrap();
            vb.open(vec![1, 70, 71], 32, 0).await.unwrap();
            reg.refresh(0.0).await;
            assert_eq!(reg.replica("replica-b").unwrap().load(), 1);
            // from a's perspective the least-loaded peer is c (b has a
            // session; ties break by registration order)
            assert_eq!(reg.pick_peer("replica-a", 0.0).unwrap(), "replica-c");

            // draining replicas are not placement targets
            reg.drain("replica-c", "replica-b").unwrap();
            reg.refresh(0.0).await;
            assert!(reg.replica("replica-c").unwrap().draining);
            assert_eq!(reg.pick_peer("replica-a", 0.0).unwrap(), "replica-b");
            reg.undrain("replica-c").unwrap();
            reg.refresh(0.0).await;
            assert!(!reg.replica("replica-c").unwrap().draining);

            // a dead replica leaves the directory and the peer pool
            reg.mark_dead("replica-c");
            assert_eq!(reg.pick_peer("replica-a", 0.0).unwrap(), "replica-b");
            assert!(reg
                .directory()
                .lock()
                .unwrap()
                .get("replica-c")
                .is_none());
            // mark_dead is STICKY: a refresh that still reaches the
            // (in-process, alive) verifier must not resurrect the
            // replica behind the operator's back
            reg.refresh(0.0).await;
            assert!(!reg.replica("replica-c").unwrap().healthy);
            assert!(reg
                .directory()
                .lock()
                .unwrap()
                .get("replica-c")
                .is_none());
            assert_eq!(reg.pick_peer("replica-a", 0.0).unwrap(), "replica-b");
            // ...until the operator revives it
            reg.revive("replica-c");
            reg.refresh(0.0).await;
            assert!(reg.replica("replica-c").unwrap().healthy);
            assert!(reg
                .directory()
                .lock()
                .unwrap()
                .get("replica-c")
                .is_some());

            // staged rollout: canary one replica, then the rest — the
            // per-replica version sequences advance independently
            let seqs = reg
                .advance_version(&["replica-a"], "synthetic_base")
                .await
                .unwrap();
            assert_eq!(seqs.len(), 1);
            reg.refresh(0.0).await;
            let seq_a = reg.replica("replica-a").unwrap().last.as_ref().unwrap().version_seq;
            let seq_b = reg.replica("replica-b").unwrap().last.as_ref().unwrap().version_seq;
            assert!(seq_a > seq_b, "canary must advance ahead of the rest");
        });
    }

    /// Staleness satellite: a replica whose refreshes have stopped
    /// keeps its last (idle-looking) snapshot, but past the staleness
    /// window placement must treat it as UNKNOWN — a stale low load
    /// never beats a fresh honest one.
    #[test]
    fn stale_telemetry_is_never_preferred_for_placement() {
        rt().block_on(async {
            let mut reg = FleetRegistry::new();
            for addr in ["replica-a", "replica-b", "replica-c"] {
                reg.spawn_loopback_replica(addr, VerifierConfig::default(), || {
                    Ok(Box::new(SyntheticTarget::new(5)) as Box<dyn VerifyBackend>)
                })
                .unwrap();
            }
            reg.refresh(0.0).await;
            // c carries a session, b is idle: fresh snapshots pick b
            let vc = reg.verifier("replica-c").unwrap();
            vc.open(vec![1, 70, 71], 32, 0).await.unwrap();
            reg.refresh(0.0).await;
            assert_eq!(reg.pick_peer("replica-a", 0.0).unwrap(), "replica-b");

            // b's refreshes stop while the others keep beating: its
            // idle snapshot ages past the staleness window and ranks as
            // unknown, so the loaded-but-fresh c wins placement
            for r in reg.replicas.iter_mut() {
                if r.addr != "replica-b" {
                    r.refreshed_at_ms = Some(3000.0);
                }
            }
            assert_eq!(reg.pick_peer("replica-a", 3000.0).unwrap(), "replica-c");
            let b = reg.replica("replica-b").unwrap();
            assert_eq!(b.age_ms(3000.0), 3000.0);
            assert_eq!(b.effective_load(3000.0, reg.staleness_ms), usize::MAX);
            assert!(b.load() < usize::MAX, "the raw snapshot itself is still there");

            // one successful refresh re-stamps b and it wins back the
            // placement slot
            reg.refresh(6000.0).await;
            assert_eq!(reg.pick_peer("replica-a", 6000.0).unwrap(), "replica-b");
        });
    }

    #[test]
    fn fleet_stats_merges_across_replicas() {
        rt().block_on(async {
            let mut reg = FleetRegistry::new();
            for addr in ["replica-a", "replica-b"] {
                reg.spawn_loopback_replica(addr, VerifierConfig::default(), || {
                    Ok(Box::new(SyntheticTarget::new(5)) as Box<dyn VerifyBackend>)
                })
                .unwrap();
            }
            reg.refresh(0.0).await;
            let s = reg.fleet_stats().await;
            assert_eq!((s.replicas, s.unreachable), (2, 0));
            assert_eq!(s.rounds, 0);
            assert!(s.latency.is_empty(), "idle fleet records no latency");
            // a quarantined replica is skipped entirely
            reg.mark_dead("replica-b");
            let s = reg.fleet_stats().await;
            assert_eq!(s.replicas, 1);
        });
    }

    #[test]
    fn loopback_fleet_dial_fails_over_past_dead_replicas() {
        rt().block_on(async {
            let mut reg = FleetRegistry::new();
            for addr in ["replica-a", "replica-b"] {
                reg.spawn_loopback_replica(addr, VerifierConfig::default(), || {
                    Ok(Box::new(SyntheticTarget::new(5)) as Box<dyn VerifyBackend>)
                })
                .unwrap();
            }
            let mut dial = reg.dial("replica-a", None);
            // normal dial reaches a live handler
            let mut t = dial.connect().await.unwrap();
            let hello = crate::protocol::frame::Hello {
                wire_version: crate::protocol::frame::WIRE_VERSION,
                mode: crate::protocol::VerifyMode::Greedy,
                k_max: 8,
            };
            t.send_frame(crate::protocol::frame::Frame::control(
                crate::protocol::frame::FrameKind::Hello,
                hello.encode(),
            ))
            .await
            .unwrap();
            let ack = t.recv_frame().await.unwrap().unwrap();
            assert_eq!(ack.kind, crate::protocol::frame::FrameKind::HelloAck);

            // kill a: the next dial lands on b
            reg.mark_dead("replica-a");
            let mut t2 = dial.connect().await.unwrap();
            t2.send_frame(crate::protocol::frame::Frame::control(
                crate::protocol::frame::FrameKind::Hello,
                hello.encode(),
            ))
            .await
            .unwrap();
            assert!(t2.recv_frame().await.unwrap().is_some());

            // kill b too: the dial reports an empty fleet
            reg.mark_dead("replica-b");
            assert!(dial.connect().await.is_err());
        });
    }
}
