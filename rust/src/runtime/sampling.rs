//! Sampling + stochastic speculative verification on the L3 side.
//!
//! The greedy path uses the fused Pallas verify kernel; the stochastic
//! path (Regime B, Temperature = 1) implements Leviathan-style acceptance
//! over the logits the runtime already pulled to the host. The residual
//! pick mirrors python's `ref.sample_verify_ref` so both sides can be
//! cross-checked.

use crate::util::rng::SplitMix64;

/// Softmax with temperature; numerically stable, f32 in/out.
pub fn softmax_temp(logits: &[f32], temperature: f32) -> Vec<f32> {
    let t = temperature.max(1e-6);
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut out: Vec<f32> = logits.iter().map(|&z| ((z - m) / t).exp()).collect();
    let s: f32 = out.iter().sum();
    for v in &mut out {
        *v /= s;
    }
    out
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Top-p (nucleus) sampling at a given temperature.
/// p >= 1.0 degrades to full sampling; temperature == 0 to greedy.
pub fn sample_top_p(logits: &[f32], temperature: f32, top_p: f32, rng: &mut SplitMix64) -> usize {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let probs = softmax_temp(logits, temperature);
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    // nucleus
    let mut kept = Vec::with_capacity(64);
    let mut acc = 0f32;
    for &i in &idx {
        kept.push(i);
        acc += probs[i];
        if acc >= top_p {
            break;
        }
    }
    let r = rng.next_f64() as f32 * acc;
    let mut c = 0f32;
    for &i in &kept {
        c += probs[i];
        if r < c {
            return i;
        }
    }
    *kept.last().unwrap()
}

/// Outcome of one verification round.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    /// Number of draft tokens accepted.
    pub tau: usize,
    /// Correction/bonus token committed after the accepted prefix.
    pub correction: i32,
}

/// Stochastic speculative verification (Leviathan et al.): accept draft
/// token j with prob min(1, p_t/p_d); on the first rejection pick the
/// argmax of the residual max(p_t - p_d, 0) (deterministic residual —
/// mirrors ref.sample_verify_ref); if everything is accepted the bonus
/// token is sampled from the next-position target distribution.
///
/// `target_logits` is row-major [block x vocab]; `draft_probs[j]` is the
/// draft distribution that proposed `draft[j]`.
pub fn stochastic_verify(
    target_logits: &[f32],
    vocab: usize,
    draft_probs: &[Vec<f32>],
    draft: &[i32],
    n_draft: usize,
    temperature: f32,
    top_p: f32,
    rng: &mut SplitMix64,
) -> VerifyOutcome {
    assert!(target_logits.len() >= (n_draft + 1) * vocab);
    assert!(draft_probs.len() >= n_draft && draft.len() >= n_draft);
    let row = |j: usize| &target_logits[j * vocab..(j + 1) * vocab];

    let mut tau = 0usize;
    while tau < n_draft {
        let pt = softmax_temp(row(tau), temperature);
        let tok = draft[tau] as usize;
        let p_t = pt[tok];
        let p_d = draft_probs[tau][tok].max(1e-20);
        let ratio = (p_t / p_d).min(1.0);
        if rng.next_f64() < ratio as f64 {
            tau += 1;
        } else {
            // rejected: residual distribution at this position
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for i in 0..vocab {
                let r = (pt[i] - draft_probs[tau][i]).max(0.0);
                if r > best_v {
                    best_v = r;
                    best = i;
                }
            }
            return VerifyOutcome {
                tau,
                correction: best as i32,
            };
        }
    }
    // all accepted: bonus token from the next-position distribution
    let bonus = sample_top_p(row(n_draft), temperature, top_p, rng);
    VerifyOutcome {
        tau,
        correction: bonus as i32,
    }
}

/// Greedy verification in pure rust — reference mirror of the Pallas
/// kernel (used by tests and the trace validator, NOT the hot path).
pub fn greedy_verify_ref(
    target_logits: &[f32],
    vocab: usize,
    draft: &[i32],
    n_draft: usize,
) -> VerifyOutcome {
    let row = |j: usize| &target_logits[j * vocab..(j + 1) * vocab];
    let mut tau = 0usize;
    while tau < n_draft && argmax(row(tau)) as i32 == draft[tau] {
        tau += 1;
    }
    VerifyOutcome {
        tau,
        correction: argmax(row(tau)) as i32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax_temp(&[1.0, 3.0, 2.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[1] > p[2] && p[2] > p[0]);
        // low temperature sharpens
        let sharp = softmax_temp(&[1.0, 3.0, 2.0], 0.1);
        assert!(sharp[1] > p[1]);
    }

    #[test]
    fn greedy_sampling_at_zero_temperature() {
        let mut rng = SplitMix64::new(1);
        assert_eq!(sample_top_p(&[0.1, 5.0, 0.2], 0.0, 0.9, &mut rng), 1);
    }

    #[test]
    fn top_p_restricts_to_nucleus() {
        // one dominant token: top_p=0.5 must always pick it
        let mut rng = SplitMix64::new(2);
        let logits = [10.0, 0.0, 0.0, 0.0];
        for _ in 0..50 {
            assert_eq!(sample_top_p(&logits, 1.0, 0.5, &mut rng), 0);
        }
    }

    #[test]
    fn sampling_is_distribution_shaped() {
        let mut rng = SplitMix64::new(3);
        let logits = [0.0f32, 2.0, 0.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_top_p(&logits, 1.0, 1.0, &mut rng)] += 1;
        }
        assert!(counts[1] > counts[0] * 3);
    }

    #[test]
    fn greedy_verify_accept_prefix() {
        let vocab = 8;
        let mut logits = vec![0f32; 3 * vocab];
        logits[0 * vocab + 4] = 5.0; // greedy row0 = 4
        logits[1 * vocab + 5] = 5.0; // greedy row1 = 5
        logits[2 * vocab + 6] = 5.0;
        let out = greedy_verify_ref(&logits, vocab, &[4, 9], 2);
        assert_eq!(out, VerifyOutcome { tau: 1, correction: 5 });
    }

    #[test]
    fn stochastic_verify_accepts_identical_distributions() {
        // draft probs == target probs with dominant tokens => accept all
        let vocab = 16;
        let n = 4;
        let mut logits = vec![0f32; (n + 1) * vocab];
        let mut draft_probs = Vec::new();
        let mut draft = Vec::new();
        for j in 0..n {
            logits[j * vocab + j] = 20.0; // ~deterministic
            let p = softmax_temp(&logits[j * vocab..(j + 1) * vocab], 1.0);
            draft_probs.push(p);
            draft.push(j as i32);
        }
        logits[n * vocab + 7] = 20.0;
        let mut rng = SplitMix64::new(4);
        let out = stochastic_verify(&logits, vocab, &draft_probs, &draft, n, 1.0, 0.9, &mut rng);
        assert_eq!(out.tau, n);
        assert_eq!(out.correction, 7);
    }

    #[test]
    fn stochastic_verify_rejects_zero_prob_draft() {
        let vocab = 8;
        let mut logits = vec![0f32; 2 * vocab];
        logits[3] = 20.0; // target strongly prefers 3
        // draft proposed 5, which it believed certain; target p(5) ~ 0
        let mut dp = vec![1e-9f32; vocab];
        dp[5] = 1.0;
        let mut rng = SplitMix64::new(5);
        let out = stochastic_verify(&logits, vocab, &[dp], &[5], 1, 1.0, 0.9, &mut rng);
        assert_eq!(out.tau, 0);
        assert_eq!(out.correction, 3); // residual argmax == target argmax
    }

    #[test]
    fn stochastic_tau_bounds_property() {
        prop::check(100, |rng| {
            let vocab = 16;
            let n = 1 + rng.next_range(7) as usize;
            let mut logits = vec![0f32; (n + 1) * vocab];
            for v in logits.iter_mut() {
                *v = rng.next_normal() as f32;
            }
            let mut draft_probs = Vec::new();
            let mut draft = Vec::new();
            for j in 0..n {
                let mut raw = vec![0f32; vocab];
                for v in raw.iter_mut() {
                    *v = rng.next_normal() as f32;
                }
                draft_probs.push(softmax_temp(&raw, 1.0));
                draft.push(rng.next_range(vocab as u64) as i32);
            }
            let out = stochastic_verify(
                &logits, vocab, &draft_probs, &draft, n, 1.0, 0.9, rng,
            );
            prop::assert_prop(out.tau <= n, "tau exceeds n_draft")?;
            prop::assert_prop(
                (out.correction as usize) < vocab,
                "correction out of vocab",
            )
        });
    }

    #[test]
    fn greedy_is_stochastic_limit() {
        // At very low temperature with confident target, stochastic accepts
        // exactly the greedy prefix.
        let vocab = 8;
        let n = 3;
        let mut logits = vec![0f32; (n + 1) * vocab];
        for j in 0..=n {
            logits[j * vocab + (j % vocab)] = 30.0;
        }
        let draft = [0i32, 1, 7];
        let dp: Vec<Vec<f32>> = draft
            .iter()
            .map(|&d| {
                let mut p = vec![1e-6f32; vocab];
                p[d as usize] = 1.0;
                p
            })
            .collect();
        let mut rng = SplitMix64::new(6);
        let s = stochastic_verify(&logits, vocab, &dp, &draft, n, 0.05, 0.9, &mut rng);
        let g = greedy_verify_ref(&logits, vocab, &draft, n);
        assert_eq!(s.tau, g.tau);
        assert_eq!(s.correction, g.correction);
    }
}
