//! Model runtime: drives the AOT-compiled `forward_block` / `prefill`
//! executables with device-resident weights and a per-session KV cache.
//!
//! Argument order contract (python/compile/aot.py): params in sorted name
//! order, then LoRA adapters in sorted name order (targets only), then
//! tokens[B] i32, pos[1] i32, valid[1] i32, kv f32. Output tuple:
//! (logits [B, vocab] f32, kv_out).
//!
//! The stacked entry (`forward_block_batched`) uses the same argument
//! order with a leading batch dimension on every activation operand:
//! tokens [B, block], pos [B], valid [B], kv [B, ...kv_shape] →
//! (logits [B, block, vocab], kv_out [B, ...kv_shape]). Weights carry
//! no batch dimension — one device-resident upload serves every row.

use super::engine::Engine;
use super::manifest::{ArchInfo, Manifest, WeightInfo};
use super::weights::Bundle;
use anyhow::{bail, Context, Result};
use std::cell::{Cell, RefCell};
use std::rc::Rc;

/// A weight bundle: host literals in HLO argument order plus a cached
/// device-resident upload.
///
/// MEMORY SEMANTICS: the forked vendor `xla` layer supports per-argument
/// donation (`execute_b_opts`), so weight buffers are uploaded ONCE per
/// target version via [`WeightSet::device_buffers`] and passed
/// non-donated to every call — rows of a stacked bucket and successive
/// calls all share the same device allocation. (The published 0.1.6
/// crate donated every `execute_b` input and leaked per-argument
/// buffers under `execute()` — see EXPERIMENTS.md §Perf L3-3 for the
/// measurements that motivated the fork.)
pub struct WeightSet {
    pub info: WeightInfo,
    pub literals: Vec<xla::Literal>,
    pub n_params: usize,
    pub byte_size: usize,
    /// Lazily-populated device upload (once per weight set, i.e. once
    /// per target version — not once per call or per row).
    device: RefCell<Option<Rc<Vec<xla::PjRtBuffer>>>>,
}

impl WeightSet {
    fn from_literals(
        info: WeightInfo,
        n_params: usize,
        byte_size: usize,
        literals: Vec<xla::Literal>,
    ) -> WeightSet {
        WeightSet {
            info,
            literals,
            n_params,
            byte_size,
            device: RefCell::new(None),
        }
    }

    pub fn load(m: &Manifest, arch: &ArchInfo, info: &WeightInfo, lora: bool) -> Result<WeightSet> {
        let bundle = Bundle::load(&m.path(&info.file))?;
        let spec = if lora { &arch.lora } else { &arch.params };
        let mut literals = Vec::with_capacity(spec.len());
        for (name, shape) in spec {
            let t = bundle
                .get(name)
                .with_context(|| format!("bundle {} vs arch {}", info.name, arch.name))?;
            if &t.shape != shape {
                bail!(
                    "tensor '{name}' in {}: shape {:?} != manifest {:?}",
                    info.name,
                    t.shape,
                    shape
                );
            }
            literals.push(t.to_literal()?);
        }
        Ok(WeightSet::from_literals(
            info.clone(),
            bundle.n_params(),
            bundle.byte_size(),
            literals,
        ))
    }

    /// All-zero LoRA adapters for an arch (the base version's "adapter").
    pub fn zero_lora(arch: &ArchInfo) -> Result<WeightSet> {
        let mut literals = Vec::with_capacity(arch.lora.len());
        for (_, shape) in &arch.lora {
            let n: usize = shape.iter().product();
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(&vec![0f32; n]).reshape(&dims)?);
        }
        Ok(WeightSet::from_literals(
            WeightInfo {
                name: "zero_lora".into(),
                arch: arch.name.clone(),
                kind: "lora".into(),
                file: String::new(),
                base: None,
                domain: None,
                target: None,
            },
            0,
            0,
            literals,
        ))
    }

    /// The device-resident upload of this weight set, created on first
    /// use and shared (non-donated) by every subsequent call. Returns
    /// `(buffers, freshly_uploaded)` so callers can account uploads.
    pub fn device_buffers(
        &self,
        client: &xla::PjRtClient,
    ) -> Result<(Rc<Vec<xla::PjRtBuffer>>, bool)> {
        if let Some(b) = self.device.borrow().as_ref() {
            return Ok((b.clone(), false));
        }
        let mut bufs = Vec::with_capacity(self.literals.len());
        for lit in &self.literals {
            bufs.push(client.buffer_from_host_literal(None, lit)?);
        }
        let rc = Rc::new(bufs);
        *self.device.borrow_mut() = Some(rc.clone());
        Ok((rc, true))
    }
}

/// Per-session KV cache: an owned literal + the committed position.
/// "Rollback" (paper §IV-C) is a position-pointer rewind — rejected
/// slots are provably overwritten before they can be attended (DESIGN.md).
pub struct KvState {
    pub lit: xla::Literal,
    pub pos: usize,
    pub max_seq: usize,
}

impl KvState {
    pub fn new(arch: &ArchInfo) -> Result<KvState> {
        let n = arch.kv_elements();
        let dims: Vec<i64> = arch.kv_shape.iter().map(|&d| d as i64).collect();
        Ok(KvState {
            lit: xla::Literal::vec1(&vec![0f32; n]).reshape(&dims)?,
            pos: 0,
            max_seq: arch.max_seq,
        })
    }

    pub fn remaining(&self) -> usize {
        self.max_seq.saturating_sub(self.pos)
    }
}

/// Execution statistics (fed into the metrics layer + §Perf).
#[derive(Debug, Default, Clone)]
pub struct ModelStats {
    pub block_calls: Cell<u64>,
    pub prefill_calls: Cell<u64>,
    /// Stacked entries into `forward_block_batched` (each covers one or
    /// more `block_calls` rows in a single engine dispatch).
    pub stacked_calls: Cell<u64>,
    /// Weight-set uploads into device-resident buffers. The batching
    /// contract pins this to once per weight set (target version), NOT
    /// once per call or per bucket row.
    pub weight_uploads: Cell<u64>,
    pub tokens_processed: Cell<u64>,
    pub exec_nanos: Cell<u64>,
}

/// One architecture's compiled entry points + one weight bundle.
pub struct ModelRuntime {
    pub arch: ArchInfo,
    pub weights: Rc<WeightSet>,
    engine: Rc<Engine>,
    block_exe: Rc<xla::PjRtLoadedExecutable>,
    prefill_exe: Rc<xla::PjRtLoadedExecutable>,
    pub block: usize,
    pub prefill_chunk: usize,
    pub stats: ModelStats,
}

/// One row of a stacked block forward (`forward_block_batched`): the
/// block tokens plus the session's own KV cache.
pub struct BatchFwdItem<'a> {
    pub tokens: &'a [i32],
    pub kv: &'a mut KvState,
}

/// Result of one block forward: per-row logits and the updated cache.
pub struct BlockOut {
    /// Row-major [valid rows kept only] x vocab.
    pub logits: Vec<f32>,
    pub rows: usize,
    pub vocab: usize,
}

impl BlockOut {
    pub fn row(&self, r: usize) -> &[f32] {
        &self.logits[r * self.vocab..(r + 1) * self.vocab]
    }

    pub fn argmax_row(&self, r: usize) -> i32 {
        let row = self.row(r);
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best as i32
    }
}

impl ModelRuntime {
    pub fn new(
        engine: Rc<Engine>,
        m: &Manifest,
        weight_name: &str,
    ) -> Result<ModelRuntime> {
        let info = m.weight(weight_name)?.clone();
        let arch = m.arch(&info.arch)?.clone();
        if info.kind == "lora" {
            bail!("'{weight_name}' is a LoRA adapter, not a full weight bundle");
        }
        let weights = Rc::new(WeightSet::load(m, &arch, &info, false)?);
        let block_exe = engine.load_hlo(&m.path(&arch.hlo_block))?;
        let prefill_exe = engine.load_hlo(&m.path(&arch.hlo_prefill))?;
        Ok(ModelRuntime {
            arch,
            weights,
            engine,
            block_exe,
            prefill_exe,
            block: m.block,
            prefill_chunk: m.prefill_chunk,
            stats: ModelStats::default(),
        })
    }

    /// Wire an arch + weight set to caller-supplied (typically hosted,
    /// closure-backed) entry points. This is how the dispatch, donation,
    /// and stacking machinery is exercised without compiled artifacts —
    /// see the stacked-vs-scalar tests below and `benches`.
    pub fn with_executables(
        engine: Rc<Engine>,
        arch: ArchInfo,
        weights: WeightSet,
        block_exe: xla::PjRtLoadedExecutable,
        prefill_exe: xla::PjRtLoadedExecutable,
        block: usize,
        prefill_chunk: usize,
    ) -> ModelRuntime {
        ModelRuntime {
            arch,
            weights: Rc::new(weights),
            engine,
            block_exe: Rc::new(block_exe),
            prefill_exe: Rc::new(prefill_exe),
            block,
            prefill_chunk,
            stats: ModelStats::default(),
        }
    }

    pub fn new_kv(&self) -> Result<KvState> {
        KvState::new(&self.arch)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Resident weight + LoRA buffer refs (uploaded on first use) and
    /// the matching non-donate mask prefix length.
    fn resident_buffers(
        &self,
        lora: Option<&WeightSet>,
    ) -> Result<(Rc<Vec<xla::PjRtBuffer>>, Option<Rc<Vec<xla::PjRtBuffer>>>)> {
        let client = self.engine.client();
        let (wb, fresh) = self.weights.device_buffers(client)?;
        if fresh {
            self.stats.weight_uploads.set(self.stats.weight_uploads.get() + 1);
        }
        let lora_bufs = if self.arch.lora_rank > 0 {
            let l = lora.expect("target arch requires a LoRA set (use zero_lora for base)");
            assert_eq!(l.literals.len(), self.arch.lora.len());
            let (lb, lfresh) = l.device_buffers(client)?;
            if lfresh {
                self.stats.weight_uploads.set(self.stats.weight_uploads.get() + 1);
            }
            Some(lb)
        } else {
            None
        };
        Ok((wb, lora_bufs))
    }

    fn call(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        lora: Option<&WeightSet>,
        tokens: &[i32],
        pos: usize,
        valid: usize,
        kv: &mut KvState,
    ) -> Result<BlockOut> {
        let t0 = std::time::Instant::now();
        let client = self.engine.client();
        let (wb, lora_bufs) = self.resident_buffers(lora)?;
        let tok_lit = xla::Literal::vec1(tokens);
        let pos_lit = xla::Literal::vec1(&[pos as i32]);
        let valid_lit = xla::Literal::vec1(&[valid as i32]);
        let tok_buf = client.buffer_from_host_literal(None, &tok_lit)?;
        let pos_buf = client.buffer_from_host_literal(None, &pos_lit)?;
        let valid_buf = client.buffer_from_host_literal(None, &valid_lit)?;
        let kv_buf = client.buffer_from_host_literal(None, &kv.lit)?;

        // Resident weights ride every call non-donated; the four
        // per-step activation buffers are donated (consumed on device).
        let mut refs: Vec<&xla::PjRtBuffer> = wb.iter().collect();
        if let Some(lb) = &lora_bufs {
            refs.extend(lb.iter());
        }
        let resident = refs.len();
        refs.extend([&tok_buf, &pos_buf, &valid_buf, &kv_buf]);
        let mut donate = vec![false; resident];
        donate.extend([true; 4]);

        let mut out = self.engine.run_b_opts(exe, &refs, &donate)?;
        if out.len() != 2 {
            bail!("expected (logits, kv) tuple, got {} elements", out.len());
        }
        let kv_out = out.pop().unwrap();
        let logits_lit = out.pop().unwrap();
        let logits = logits_lit.to_vec::<f32>()?;
        kv.lit = kv_out;

        self.stats.tokens_processed.set(self.stats.tokens_processed.get() + valid as u64);
        self.stats
            .exec_nanos
            .set(self.stats.exec_nanos.get() + t0.elapsed().as_nanos() as u64);
        Ok(BlockOut {
            rows: tokens.len(),
            vocab: self.arch.vocab,
            logits,
        })
    }

    /// Forward up to `block` new tokens at kv.pos; advances kv.pos by
    /// `commit` (callers commit fewer rows than they fed on rejection —
    /// that position rewind IS the KV rollback).
    pub fn forward_block(
        &self,
        lora: Option<&WeightSet>,
        tokens: &[i32],
        kv: &mut KvState,
        commit: usize,
    ) -> Result<BlockOut> {
        if tokens.is_empty() || tokens.len() > self.block {
            bail!("block must hold 1..={} tokens, got {}", self.block, tokens.len());
        }
        if kv.pos + tokens.len() > self.arch.max_seq {
            bail!(
                "KV overflow: pos {} + {} > max_seq {}",
                kv.pos,
                tokens.len(),
                self.arch.max_seq
            );
        }
        let mut padded = tokens.to_vec();
        padded.resize(self.block, 0);
        let pos = kv.pos;
        let out = self.call(&self.block_exe, lora, &padded, pos, tokens.len(), kv)?;
        self.stats.block_calls.set(self.stats.block_calls.get() + 1);
        assert!(commit <= tokens.len());
        kv.pos = pos + commit;
        Ok(out)
    }

    /// Stacked block forward over several independent KV sessions: the
    /// batched verification executor's runtime entry. Validates every
    /// row, row-stacks tokens/pos/valid/KV into `[B, ...]` literals, and
    /// executes the whole bucket through ONE engine dispatch; per-row
    /// logits and KV caches are split back out of the stacked outputs.
    /// KV positions are NOT advanced — verification decides the commit,
    /// and the caller performs the position-pointer rewind exactly as
    /// with `forward_block(.., commit = 0)`.
    ///
    /// Weight buffers are the shared device-resident upload (once per
    /// target version, never per row); only the four stacked activation
    /// operands are built and donated per call. Engine-call-count and
    /// upload-count assertions live in the tests below.
    pub fn forward_block_batched(
        &self,
        lora: Option<&WeightSet>,
        items: &mut [BatchFwdItem<'_>],
    ) -> Result<Vec<BlockOut>> {
        for it in items.iter() {
            if it.tokens.is_empty() || it.tokens.len() > self.block {
                bail!(
                    "block must hold 1..={} tokens, got {}",
                    self.block,
                    it.tokens.len()
                );
            }
            if it.kv.pos + it.tokens.len() > self.arch.max_seq {
                bail!(
                    "KV overflow: pos {} + {} > max_seq {}",
                    it.kv.pos,
                    it.tokens.len(),
                    self.arch.max_seq
                );
            }
        }
        if items.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = std::time::Instant::now();
        let client = self.engine.client();
        let (wb, lora_bufs) = self.resident_buffers(lora)?;

        // Row-stack the activation operands: tokens [B, block], pos [B],
        // valid [B], kv [B, ...kv_shape].
        let b = items.len();
        let kvn = self.arch.kv_elements();
        let mut tokens = Vec::with_capacity(b * self.block);
        let mut pos = Vec::with_capacity(b);
        let mut valid = Vec::with_capacity(b);
        let mut kv = Vec::with_capacity(b * kvn);
        for it in items.iter() {
            tokens.extend_from_slice(it.tokens);
            tokens.resize(tokens.len() + self.block - it.tokens.len(), 0);
            pos.push(it.kv.pos as i32);
            valid.push(it.tokens.len() as i32);
            kv.extend_from_slice(&it.kv.lit.to_vec::<f32>()?);
        }
        let mut kv_dims: Vec<i64> = vec![b as i64];
        kv_dims.extend(self.arch.kv_shape.iter().map(|&d| d as i64));
        let tok_lit =
            xla::Literal::vec1(&tokens).reshape(&[b as i64, self.block as i64])?;
        let pos_lit = xla::Literal::vec1(&pos);
        let valid_lit = xla::Literal::vec1(&valid);
        let kv_lit = xla::Literal::vec1(&kv).reshape(&kv_dims)?;
        let tok_buf = client.buffer_from_host_literal(None, &tok_lit)?;
        let pos_buf = client.buffer_from_host_literal(None, &pos_lit)?;
        let valid_buf = client.buffer_from_host_literal(None, &valid_lit)?;
        let kv_buf = client.buffer_from_host_literal(None, &kv_lit)?;

        let mut refs: Vec<&xla::PjRtBuffer> = wb.iter().collect();
        if let Some(lb) = &lora_bufs {
            refs.extend(lb.iter());
        }
        let resident = refs.len();
        refs.extend([&tok_buf, &pos_buf, &valid_buf, &kv_buf]);
        let mut donate = vec![false; resident];
        donate.extend([true; 4]);

        // ONE dispatch for the whole bucket.
        let mut out = self.engine.run_b_opts(&self.block_exe, &refs, &donate)?;
        if out.len() != 2 {
            bail!("expected (logits, kv) tuple, got {} elements", out.len());
        }
        let kv_out = out.pop().unwrap().to_vec::<f32>()?;
        let logits = out.pop().unwrap().to_vec::<f32>()?;
        let row_logits = self.block * self.arch.vocab;
        if logits.len() != b * row_logits || kv_out.len() != b * kvn {
            bail!(
                "stacked output shape mismatch: {} logits / {} kv for B={b}",
                logits.len(),
                kv_out.len()
            );
        }

        let kv_row_dims: Vec<i64> = self.arch.kv_shape.iter().map(|&d| d as i64).collect();
        let mut result = Vec::with_capacity(b);
        for (r, it) in items.iter_mut().enumerate() {
            it.kv.lit =
                xla::Literal::vec1(&kv_out[r * kvn..(r + 1) * kvn]).reshape(&kv_row_dims)?;
            self.stats
                .tokens_processed
                .set(self.stats.tokens_processed.get() + it.tokens.len() as u64);
            self.stats.block_calls.set(self.stats.block_calls.get() + 1);
            result.push(BlockOut {
                rows: self.block,
                vocab: self.arch.vocab,
                logits: logits[r * row_logits..(r + 1) * row_logits].to_vec(),
            });
        }
        self.stats
            .stacked_calls
            .set(self.stats.stacked_calls.get() + 1);
        self.stats
            .exec_nanos
            .set(self.stats.exec_nanos.get() + t0.elapsed().as_nanos() as u64);
        Ok(result)
    }

    /// Chunked prompt ingestion. Returns the logits row after the last
    /// prompt token (the next-token distribution) and commits the prompt.
    pub fn prefill(
        &self,
        lora: Option<&WeightSet>,
        prompt: &[i32],
        kv: &mut KvState,
    ) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if kv.pos + prompt.len() > self.arch.max_seq {
            bail!("prompt of {} tokens overflows max_seq", prompt.len());
        }
        let mut last_row = None;
        for chunk in prompt.chunks(self.prefill_chunk) {
            let mut padded = chunk.to_vec();
            padded.resize(self.prefill_chunk, 0);
            let pos = kv.pos;
            let out = self.call(&self.prefill_exe, lora, &padded, pos, chunk.len(), kv)?;
            self.stats.prefill_calls.set(self.stats.prefill_calls.get() + 1);
            kv.pos = pos + chunk.len();
            last_row = Some(out.row(chunk.len() - 1).to_vec());
        }
        Ok(last_row.unwrap())
    }
}

/// The fused Pallas verification kernel (L1), AOT-compiled per vocab.
pub struct VerifyRuntime {
    engine: Rc<Engine>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub block: usize,
    pub vocab: usize,
}

impl VerifyRuntime {
    pub fn new(engine: Rc<Engine>, m: &Manifest, vocab: usize) -> Result<VerifyRuntime> {
        let rel = m
            .verify_hlo
            .get(&vocab)
            .ok_or_else(|| anyhow::anyhow!("no verify kernel for vocab {vocab}"))?;
        let exe = engine.load_hlo(&m.path(rel))?;
        Ok(VerifyRuntime {
            engine,
            exe,
            block: m.block,
            vocab,
        })
    }

    /// Greedy verification: (tau, correction, greedy tokens per row).
    pub fn verify(&self, logits: &[f32], draft: &[i32], n_draft: usize) -> Result<(usize, i32, Vec<i32>)> {
        assert_eq!(logits.len(), self.block * self.vocab);
        assert_eq!(draft.len(), self.block - 1);
        let logits_lit = xla::Literal::vec1(logits)
            .reshape(&[self.block as i64, self.vocab as i64])?;
        let draft_lit = xla::Literal::vec1(draft);
        let n_lit = xla::Literal::vec1(&[n_draft as i32]);
        let out = self
            .engine
            .run(&self.exe, &[&logits_lit, &draft_lit, &n_lit])?;
        let tau = out[0].to_vec::<i32>()?[0] as usize;
        let corr = out[1].to_vec::<i32>()?[0];
        let greedy = out[2].to_vec::<i32>()?;
        Ok((tau, corr, greedy))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> Option<(Rc<Engine>, Manifest)> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&root).unwrap();
        if !m.weights.contains_key("target_llama2t_base") {
            return None;
        }
        Some((Rc::new(Engine::cpu().unwrap()), m))
    }

    // ----- hosted toy model (no artifacts needed) --------------------

    const TOY_VOCAB: usize = 4;
    const TOY_BLOCK: usize = 9;
    const TOY_KV: [usize; 2] = [2, 2];

    fn toy_arch() -> ArchInfo {
        ArchInfo {
            name: "toy".into(),
            vocab: TOY_VOCAB,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            d_ff: 2,
            max_seq: 64,
            n_experts: 0,
            lora_rank: 0,
            draft_head: false,
            kv_shape: TOY_KV.to_vec(),
            params: vec![("w".into(), vec![2])],
            lora: vec![],
            hlo_block: String::new(),
            hlo_prefill: String::new(),
        }
    }

    fn toy_weights() -> WeightSet {
        WeightSet::from_literals(
            WeightInfo {
                name: "toy".into(),
                arch: "toy".into(),
                kind: "base".into(),
                file: String::new(),
                base: None,
                domain: None,
                target: None,
            },
            2,
            8,
            vec![xla::Literal::vec1(&[0.5f32, 1.5])],
        )
    }

    /// Deterministic per-row toy forward implementing BOTH calling
    /// conventions: rank-1 tokens = scalar, rank-2 tokens [B, block] =
    /// stacked. Per-row math is the identical f32 expression in the
    /// identical order, so scalar and stacked outputs must be
    /// bit-identical row for row.
    fn toy_block_exe() -> xla::PjRtLoadedExecutable {
        let kvn: usize = TOY_KV.iter().product();
        xla::PjRtLoadedExecutable::hosted(move |args| {
            let w = args[0].to_vec::<f32>()?[0];
            let tok = args[1].to_vec::<i32>()?;
            let stacked = args[1].shape_dims().len() == 2;
            let b = if stacked {
                args[1].shape_dims()[0] as usize
            } else {
                1
            };
            let block = tok.len() / b;
            let pos = args[2].to_vec::<i32>()?;
            let valid = args[3].to_vec::<i32>()?;
            let kv = args[4].to_vec::<f32>()?;
            let mut logits = vec![0f32; b * block * TOY_VOCAB];
            let mut kv_out = vec![0f32; b * kvn];
            for r in 0..b {
                let p = pos[r] as f32;
                let v = valid[r] as f32;
                let kvs: f32 = kv[r * kvn..(r + 1) * kvn].iter().sum();
                for t in 0..block {
                    for c in 0..TOY_VOCAB {
                        logits[(r * block + t) * TOY_VOCAB + c] = w
                            + tok[r * block + t] as f32 * 0.5
                            + p * 0.25
                            + v * 0.125
                            + kvs
                            + (t * TOY_VOCAB + c) as f32 * 0.01;
                    }
                }
                for i in 0..kvn {
                    kv_out[r * kvn + i] = kv[r * kvn + i] + v;
                }
            }
            let kv_dims = args[4].shape_dims().to_vec();
            let logits_lit = if stacked {
                xla::Literal::vec1(&logits)
                    .reshape(&[b as i64, block as i64, TOY_VOCAB as i64])?
            } else {
                xla::Literal::vec1(&logits).reshape(&[block as i64, TOY_VOCAB as i64])?
            };
            let kv_lit = xla::Literal::vec1(&kv_out).reshape(&kv_dims)?;
            Ok(xla::Literal::tuple(vec![logits_lit, kv_lit]))
        })
    }

    fn toy_runtime() -> ModelRuntime {
        ModelRuntime::with_executables(
            Rc::new(Engine::cpu().unwrap()),
            toy_arch(),
            toy_weights(),
            toy_block_exe(),
            toy_block_exe(),
            TOY_BLOCK,
            TOY_BLOCK,
        )
    }

    #[test]
    fn stacked_matches_scalar_across_ragged_k() {
        // one session per draft length K = 1..=8 (ragged bucket), each
        // with a distinct KV state and position
        let rt_scalar = toy_runtime();
        let rt_stacked = toy_runtime();
        let kvn: usize = TOY_KV.iter().product();
        let mk_kv = |i: usize| {
            let vals: Vec<f32> = (0..kvn).map(|j| (i * kvn + j) as f32 * 0.1).collect();
            let dims: Vec<i64> = TOY_KV.iter().map(|&d| d as i64).collect();
            KvState {
                lit: xla::Literal::vec1(&vals).reshape(&dims).unwrap(),
                pos: 3 * i,
                max_seq: 64,
            }
        };
        let rows: Vec<Vec<i32>> =
            (1..=8).map(|k| (0..k).map(|t| (10 * k + t) as i32).collect()).collect();

        // scalar: one forward_block per row, commit 0 (pure verify shape)
        let mut scalar_out = Vec::new();
        let mut scalar_kv = Vec::new();
        for (i, toks) in rows.iter().enumerate() {
            let mut kv = mk_kv(i);
            let out = rt_scalar.forward_block(None, toks, &mut kv, 0).unwrap();
            scalar_out.push(out);
            scalar_kv.push(kv);
        }

        // stacked: the whole ragged bucket in one call
        let mut kvs: Vec<KvState> = (0..rows.len()).map(mk_kv).collect();
        let mut items: Vec<BatchFwdItem<'_>> = rows
            .iter()
            .zip(kvs.iter_mut())
            .map(|(toks, kv)| BatchFwdItem { tokens: toks, kv })
            .collect();
        let stacked_out = rt_stacked.forward_block_batched(None, &mut items).unwrap();
        drop(items);

        assert_eq!(stacked_out.len(), scalar_out.len());
        for (r, (s, b)) in scalar_out.iter().zip(&stacked_out).enumerate() {
            assert_eq!(s.logits, b.logits, "row {r} logits diverge");
            assert_eq!(s.vocab, b.vocab);
        }
        for (r, (s, b)) in scalar_kv.iter().zip(&kvs).enumerate() {
            assert_eq!(s.lit, b.lit, "row {r} kv diverges");
            assert_eq!(s.pos, b.pos, "row {r} pos must stay unadvanced");
        }
    }

    #[test]
    fn stacked_bucket_costs_one_dispatch_and_one_upload() {
        let rt = toy_runtime();
        let mut kvs: Vec<KvState> = (0..4).map(|_| rt.new_kv().unwrap()).collect();
        let rows: Vec<Vec<i32>> = (0..4).map(|i| vec![i as i32 + 1; 3]).collect();
        let before = rt.engine().dispatches();
        let mut items: Vec<BatchFwdItem<'_>> = rows
            .iter()
            .zip(kvs.iter_mut())
            .map(|(toks, kv)| BatchFwdItem { tokens: toks, kv })
            .collect();
        rt.forward_block_batched(None, &mut items).unwrap();
        drop(items);
        // ONE engine dispatch for the whole 4-row bucket...
        assert_eq!(rt.engine().dispatches() - before, 1);
        assert_eq!(rt.stats.stacked_calls.get(), 1);
        assert_eq!(rt.stats.block_calls.get(), 4);
        // ...and one weight upload TOTAL, not one per row
        assert_eq!(rt.stats.weight_uploads.get(), 1);

        // a second bucket re-uses the resident weights: +1 dispatch, +0 uploads
        let mut items: Vec<BatchFwdItem<'_>> = rows
            .iter()
            .zip(kvs.iter_mut())
            .map(|(toks, kv)| BatchFwdItem { tokens: toks, kv })
            .collect();
        rt.forward_block_batched(None, &mut items).unwrap();
        assert_eq!(rt.engine().dispatches() - before, 2);
        assert_eq!(rt.stats.weight_uploads.get(), 1);
    }

    #[test]
    fn scalar_calls_share_the_resident_weight_upload() {
        let rt = toy_runtime();
        let mut kv = rt.new_kv().unwrap();
        let before = rt.engine().dispatches();
        rt.forward_block(None, &[1, 2, 3], &mut kv, 3).unwrap();
        rt.forward_block(None, &[4, 5], &mut kv, 2).unwrap();
        assert_eq!(rt.engine().dispatches() - before, 2);
        assert_eq!(rt.stats.weight_uploads.get(), 1, "upload once per version");
        assert_eq!(kv.pos, 5);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let rt = toy_runtime();
        let out = rt.forward_block_batched(None, &mut []).unwrap();
        assert!(out.is_empty());
        assert_eq!(rt.stats.stacked_calls.get(), 0);
        assert_eq!(rt.engine().dispatches(), 0);
    }

    // ----- artifact-gated tests (real compiled model) ----------------

    #[test]
    fn verify_kernel_roundtrip() {
        let Some((e, m)) = setup() else { return };
        let v = VerifyRuntime::new(e, &m, 512).unwrap();
        // logits that make greedy row r = 5 + r; row j predicts draft[j]
        let mut logits = vec![0f32; 9 * 512];
        for r in 0..9 {
            logits[r * 512 + 5 + r] = 10.0;
        }
        let draft = [5, 6, 99, 0, 0, 0, 0, 0];
        let (tau, corr, greedy) = v.verify(&logits, &draft, 5).unwrap();
        assert_eq!(greedy[0], 5);
        assert_eq!(tau, 2); // 5, 6 accepted; 99 != greedy[2]=7 rejected
        assert_eq!(corr, 7); // correction = greedy[tau] = greedy[2]
    }

    #[test]
    fn block_forward_and_incremental_consistency() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();
        let toks: Vec<i32> = (0..9).map(|i| 20 + i).collect();

        // one shot
        let mut kv_a = rt.new_kv().unwrap();
        let one = rt.forward_block(Some(&lora), &toks, &mut kv_a, 9).unwrap();

        // two chunks through the cache
        let mut kv_b = rt.new_kv().unwrap();
        let _ = rt.forward_block(Some(&lora), &toks[..5], &mut kv_b, 5).unwrap();
        let two = rt.forward_block(Some(&lora), &toks[5..], &mut kv_b, 4).unwrap();

        for r in 0..4 {
            let a = one.row(5 + r);
            let b = two.row(r);
            let max_err = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-3, "row {r} err {max_err}");
        }
        assert_eq!(kv_a.pos, 9);
        assert_eq!(kv_b.pos, 9);
    }

    #[test]
    fn prefill_matches_block_path() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();
        let prompt: Vec<i32> = (0..7).map(|i| 30 + 2 * i).collect();

        let mut kv_a = rt.new_kv().unwrap();
        let row_a = rt.prefill(Some(&lora), &prompt, &mut kv_a).unwrap();

        let mut kv_b = rt.new_kv().unwrap();
        let out = rt.forward_block(Some(&lora), &prompt, &mut kv_b, 7).unwrap();
        let row_b = out.row(6);

        let max_err = row_a
            .iter()
            .zip(row_b)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-3, "err {max_err}");
    }

    #[test]
    fn kv_rollback_pointer_rewind_is_safe() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();

        // clean trajectory: 4 then 3 committed tokens
        let toks: Vec<i32> = vec![40, 41, 42, 43, 44, 45, 46];
        let mut kv_clean = rt.new_kv().unwrap();
        rt.forward_block(Some(&lora), &toks[..4], &mut kv_clean, 4).unwrap();
        let clean = rt.forward_block(Some(&lora), &toks[4..], &mut kv_clean, 3).unwrap();

        // dirty: speculate 4 + 4 garbage rows, commit only 4 (rollback),
        // then feed the real continuation.
        let mut kv = rt.new_kv().unwrap();
        let spec: Vec<i32> = vec![40, 41, 42, 43, 99, 98, 97, 96];
        rt.forward_block(Some(&lora), &spec, &mut kv, 4).unwrap();
        let dirty = rt.forward_block(Some(&lora), &toks[4..], &mut kv, 3).unwrap();

        for r in 0..3 {
            let max_err = clean
                .row(r)
                .iter()
                .zip(dirty.row(r))
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-3, "row {r} err {max_err}");
        }
    }

    #[test]
    fn kv_overflow_is_rejected() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();
        let mut kv = rt.new_kv().unwrap();
        kv.pos = rt.arch.max_seq - 2;
        let toks = vec![1i32; 9];
        assert!(rt.forward_block(Some(&lora), &toks, &mut kv, 0).is_err());
    }
}
