//! Model runtime: drives the AOT-compiled `forward_block` / `prefill`
//! executables with resident weight literals and a per-session KV cache.
//!
//! Argument order contract (python/compile/aot.py): params in sorted name
//! order, then LoRA adapters in sorted name order (targets only), then
//! tokens[B] i32, pos[1] i32, valid[1] i32, kv f32. Output tuple:
//! (logits [B, vocab] f32, kv_out).

use super::engine::Engine;
use super::manifest::{ArchInfo, Manifest, WeightInfo};
use super::weights::Bundle;
use anyhow::{bail, Context, Result};
use std::cell::Cell;
use std::rc::Rc;

/// A weight bundle uploaded as xla literals in HLO argument order.
///
/// MEMORY SEMANTICS of the published xla 0.1.6 crate (measured, see
/// EXPERIMENTS.md §Perf L3-3): `execute()` over literals LEAKS the
/// device buffer it creates per argument (~the KV size per call → OOM
/// over long experiment runs). The call path therefore creates its own
/// buffers per call, hands them to `execute_b`, and frees them after —
/// same copy volume, zero net growth. (A cached-weight-buffer variant
/// crashed inside the prebuilt shim and was abandoned; fresh buffers
/// measured leak-free and stable.)
pub struct WeightSet {
    pub info: WeightInfo,
    pub literals: Vec<xla::Literal>,
    pub n_params: usize,
    pub byte_size: usize,
}

impl WeightSet {
    pub fn load(m: &Manifest, arch: &ArchInfo, info: &WeightInfo, lora: bool) -> Result<WeightSet> {
        let bundle = Bundle::load(&m.path(&info.file))?;
        let spec = if lora { &arch.lora } else { &arch.params };
        let mut literals = Vec::with_capacity(spec.len());
        for (name, shape) in spec {
            let t = bundle
                .get(name)
                .with_context(|| format!("bundle {} vs arch {}", info.name, arch.name))?;
            if &t.shape != shape {
                bail!(
                    "tensor '{name}' in {}: shape {:?} != manifest {:?}",
                    info.name,
                    t.shape,
                    shape
                );
            }
            literals.push(t.to_literal()?);
        }
        Ok(WeightSet {
            info: info.clone(),
            n_params: bundle.n_params(),
            byte_size: bundle.byte_size(),
            literals,
        })
    }

    /// All-zero LoRA adapters for an arch (the base version's "adapter").
    pub fn zero_lora(arch: &ArchInfo) -> Result<WeightSet> {
        let mut literals = Vec::with_capacity(arch.lora.len());
        for (_, shape) in &arch.lora {
            let n: usize = shape.iter().product();
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(&vec![0f32; n]).reshape(&dims)?);
        }
        Ok(WeightSet {
            info: WeightInfo {
                name: "zero_lora".into(),
                arch: arch.name.clone(),
                kind: "lora".into(),
                file: String::new(),
                base: None,
                domain: None,
                target: None,
            },
            n_params: 0,
            byte_size: 0,
            literals,
        })
    }
}

/// Per-session KV cache: an owned literal + the committed position.
/// "Rollback" (paper §IV-C) is a position-pointer rewind — rejected
/// slots are provably overwritten before they can be attended (DESIGN.md).
pub struct KvState {
    pub lit: xla::Literal,
    pub pos: usize,
    pub max_seq: usize,
}

impl KvState {
    pub fn new(arch: &ArchInfo) -> Result<KvState> {
        let n = arch.kv_elements();
        let dims: Vec<i64> = arch.kv_shape.iter().map(|&d| d as i64).collect();
        Ok(KvState {
            lit: xla::Literal::vec1(&vec![0f32; n]).reshape(&dims)?,
            pos: 0,
            max_seq: arch.max_seq,
        })
    }

    pub fn remaining(&self) -> usize {
        self.max_seq.saturating_sub(self.pos)
    }
}

/// Execution statistics (fed into the metrics layer + §Perf).
#[derive(Debug, Default, Clone)]
pub struct ModelStats {
    pub block_calls: Cell<u64>,
    pub prefill_calls: Cell<u64>,
    /// Stacked entries into `forward_block_batched` (each covers one or
    /// more `block_calls` rows in a single engine dispatch).
    pub stacked_calls: Cell<u64>,
    pub tokens_processed: Cell<u64>,
    pub exec_nanos: Cell<u64>,
}

/// One architecture's compiled entry points + one weight bundle.
pub struct ModelRuntime {
    pub arch: ArchInfo,
    pub weights: Rc<WeightSet>,
    engine: Rc<Engine>,
    block_exe: Rc<xla::PjRtLoadedExecutable>,
    prefill_exe: Rc<xla::PjRtLoadedExecutable>,
    pub block: usize,
    pub prefill_chunk: usize,
    pub stats: ModelStats,
}

/// One row of a stacked block forward (`forward_block_batched`): the
/// block tokens plus the session's own KV cache.
pub struct BatchFwdItem<'a> {
    pub tokens: &'a [i32],
    pub kv: &'a mut KvState,
}

/// Result of one block forward: per-row logits and the updated cache.
pub struct BlockOut {
    /// Row-major [valid rows kept only] x vocab.
    pub logits: Vec<f32>,
    pub rows: usize,
    pub vocab: usize,
}

impl BlockOut {
    pub fn row(&self, r: usize) -> &[f32] {
        &self.logits[r * self.vocab..(r + 1) * self.vocab]
    }

    pub fn argmax_row(&self, r: usize) -> i32 {
        let row = self.row(r);
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best as i32
    }
}

impl ModelRuntime {
    pub fn new(
        engine: Rc<Engine>,
        m: &Manifest,
        weight_name: &str,
    ) -> Result<ModelRuntime> {
        let info = m.weight(weight_name)?.clone();
        let arch = m.arch(&info.arch)?.clone();
        if info.kind == "lora" {
            bail!("'{weight_name}' is a LoRA adapter, not a full weight bundle");
        }
        let weights = Rc::new(WeightSet::load(m, &arch, &info, false)?);
        let block_exe = engine.load_hlo(&m.path(&arch.hlo_block))?;
        let prefill_exe = engine.load_hlo(&m.path(&arch.hlo_prefill))?;
        Ok(ModelRuntime {
            arch,
            weights,
            engine,
            block_exe,
            prefill_exe,
            block: m.block,
            prefill_chunk: m.prefill_chunk,
            stats: ModelStats::default(),
        })
    }

    pub fn new_kv(&self) -> Result<KvState> {
        KvState::new(&self.arch)
    }

    fn call(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        lora: Option<&WeightSet>,
        tokens: &[i32],
        pos: usize,
        valid: usize,
        kv: &mut KvState,
    ) -> Result<BlockOut> {
        // Fresh buffers per call + execute_b (donating) — see the
        // WeightSet doc comment for why NOT execute() (leaks per-arg
        // buffers) and why NOT cached buffers (donation frees them).
        let t0 = std::time::Instant::now();
        let client = self.engine.client();
        let tok_lit = xla::Literal::vec1(tokens);
        let pos_lit = xla::Literal::vec1(&[pos as i32]);
        let valid_lit = xla::Literal::vec1(&[valid as i32]);

        let mut bufs: Vec<xla::PjRtBuffer> =
            Vec::with_capacity(self.weights.literals.len() + self.arch.lora.len() + 4);
        for lit in &self.weights.literals {
            bufs.push(client.buffer_from_host_literal(None, lit)?);
        }
        if self.arch.lora_rank > 0 {
            let l = lora.expect("target arch requires a LoRA set (use zero_lora for base)");
            assert_eq!(l.literals.len(), self.arch.lora.len());
            for lit in &l.literals {
                bufs.push(client.buffer_from_host_literal(None, lit)?);
            }
        }
        bufs.push(client.buffer_from_host_literal(None, &tok_lit)?);
        bufs.push(client.buffer_from_host_literal(None, &pos_lit)?);
        bufs.push(client.buffer_from_host_literal(None, &valid_lit)?);
        bufs.push(client.buffer_from_host_literal(None, &kv.lit)?);
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();

        let mut out = self.engine.run_b(exe, &refs)?;
        drop(refs);
        drop(bufs); // caller-owned buffers freed here — execute() would have leaked its internal copies
        if out.len() != 2 {
            bail!("expected (logits, kv) tuple, got {} elements", out.len());
        }
        let kv_out = out.pop().unwrap();
        let logits_lit = out.pop().unwrap();
        let logits = logits_lit.to_vec::<f32>()?;
        kv.lit = kv_out;

        self.stats.tokens_processed.set(self.stats.tokens_processed.get() + valid as u64);
        self.stats
            .exec_nanos
            .set(self.stats.exec_nanos.get() + t0.elapsed().as_nanos() as u64);
        Ok(BlockOut {
            rows: tokens.len(),
            vocab: self.arch.vocab,
            logits,
        })
    }

    /// Forward up to `block` new tokens at kv.pos; advances kv.pos by
    /// `commit` (callers commit fewer rows than they fed on rejection —
    /// that position rewind IS the KV rollback).
    pub fn forward_block(
        &self,
        lora: Option<&WeightSet>,
        tokens: &[i32],
        kv: &mut KvState,
        commit: usize,
    ) -> Result<BlockOut> {
        if tokens.is_empty() || tokens.len() > self.block {
            bail!("block must hold 1..={} tokens, got {}", self.block, tokens.len());
        }
        if kv.pos + tokens.len() > self.arch.max_seq {
            bail!(
                "KV overflow: pos {} + {} > max_seq {}",
                kv.pos,
                tokens.len(),
                self.arch.max_seq
            );
        }
        let mut padded = tokens.to_vec();
        padded.resize(self.block, 0);
        let pos = kv.pos;
        let out = self.call(&self.block_exe.clone(), lora, &padded, pos, tokens.len(), kv)?;
        self.stats.block_calls.set(self.stats.block_calls.get() + 1);
        assert!(commit <= tokens.len());
        kv.pos = pos + commit;
        Ok(out)
    }

    /// Stacked block forward over several independent KV sessions: the
    /// batched verification executor's runtime entry. Validates every
    /// row, then executes all of them through ONE `Engine::run_batched`
    /// call, in row order. KV positions are NOT advanced — verification
    /// decides the commit, and the caller performs the position-pointer
    /// rewind exactly as with `forward_block(.., commit = 0)`.
    ///
    /// Buffers are still created per row: the published xla crate's
    /// `execute_b` donates its inputs, so rows cannot share uploaded
    /// weight buffers (see the `WeightSet` doc comment on the measured
    /// leak/crash tradeoffs). What this entry amortizes today is the
    /// per-call host dispatch; a true `[B, block]` stacked executable
    /// plugs in behind `Engine::run_batched` without touching callers.
    pub fn forward_block_batched(
        &self,
        lora: Option<&WeightSet>,
        items: &mut [BatchFwdItem<'_>],
    ) -> Result<Vec<BlockOut>> {
        for it in items.iter() {
            if it.tokens.is_empty() || it.tokens.len() > self.block {
                bail!(
                    "block must hold 1..={} tokens, got {}",
                    self.block,
                    it.tokens.len()
                );
            }
            if it.kv.pos + it.tokens.len() > self.arch.max_seq {
                bail!(
                    "KV overflow: pos {} + {} > max_seq {}",
                    it.kv.pos,
                    it.tokens.len(),
                    self.arch.max_seq
                );
            }
        }
        let t0 = std::time::Instant::now();
        let client = self.engine.client();
        let mut row_bufs: Vec<Vec<xla::PjRtBuffer>> = Vec::with_capacity(items.len());
        for it in items.iter() {
            let mut padded = it.tokens.to_vec();
            padded.resize(self.block, 0);
            let tok_lit = xla::Literal::vec1(&padded);
            let pos_lit = xla::Literal::vec1(&[it.kv.pos as i32]);
            let valid_lit = xla::Literal::vec1(&[it.tokens.len() as i32]);
            let mut bufs: Vec<xla::PjRtBuffer> =
                Vec::with_capacity(self.weights.literals.len() + self.arch.lora.len() + 4);
            for lit in &self.weights.literals {
                bufs.push(client.buffer_from_host_literal(None, lit)?);
            }
            if self.arch.lora_rank > 0 {
                let l = lora.expect("target arch requires a LoRA set (use zero_lora for base)");
                assert_eq!(l.literals.len(), self.arch.lora.len());
                for lit in &l.literals {
                    bufs.push(client.buffer_from_host_literal(None, lit)?);
                }
            }
            bufs.push(client.buffer_from_host_literal(None, &tok_lit)?);
            bufs.push(client.buffer_from_host_literal(None, &pos_lit)?);
            bufs.push(client.buffer_from_host_literal(None, &valid_lit)?);
            bufs.push(client.buffer_from_host_literal(None, &it.kv.lit)?);
            row_bufs.push(bufs);
        }
        let argsets: Vec<Vec<&xla::PjRtBuffer>> =
            row_bufs.iter().map(|b| b.iter().collect()).collect();
        let outs = self.engine.run_batched(&self.block_exe, &argsets)?;
        drop(argsets);
        drop(row_bufs); // same ownership discipline as `call`
        let mut result = Vec::with_capacity(items.len());
        for (it, mut out) in items.iter_mut().zip(outs) {
            if out.len() != 2 {
                bail!("expected (logits, kv) tuple, got {} elements", out.len());
            }
            let kv_out = out.pop().unwrap();
            let logits_lit = out.pop().unwrap();
            let logits = logits_lit.to_vec::<f32>()?;
            it.kv.lit = kv_out;
            self.stats
                .tokens_processed
                .set(self.stats.tokens_processed.get() + it.tokens.len() as u64);
            self.stats.block_calls.set(self.stats.block_calls.get() + 1);
            result.push(BlockOut {
                rows: self.block,
                vocab: self.arch.vocab,
                logits,
            });
        }
        self.stats
            .stacked_calls
            .set(self.stats.stacked_calls.get() + 1);
        self.stats
            .exec_nanos
            .set(self.stats.exec_nanos.get() + t0.elapsed().as_nanos() as u64);
        Ok(result)
    }

    /// Chunked prompt ingestion. Returns the logits row after the last
    /// prompt token (the next-token distribution) and commits the prompt.
    pub fn prefill(
        &self,
        lora: Option<&WeightSet>,
        prompt: &[i32],
        kv: &mut KvState,
    ) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if kv.pos + prompt.len() > self.arch.max_seq {
            bail!("prompt of {} tokens overflows max_seq", prompt.len());
        }
        let mut last_row = None;
        for chunk in prompt.chunks(self.prefill_chunk) {
            let mut padded = chunk.to_vec();
            padded.resize(self.prefill_chunk, 0);
            let pos = kv.pos;
            let out = self.call(&self.prefill_exe.clone(), lora, &padded, pos, chunk.len(), kv)?;
            self.stats.prefill_calls.set(self.stats.prefill_calls.get() + 1);
            kv.pos = pos + chunk.len();
            last_row = Some(out.row(chunk.len() - 1).to_vec());
        }
        Ok(last_row.unwrap())
    }
}

/// The fused Pallas verification kernel (L1), AOT-compiled per vocab.
pub struct VerifyRuntime {
    engine: Rc<Engine>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub block: usize,
    pub vocab: usize,
}

impl VerifyRuntime {
    pub fn new(engine: Rc<Engine>, m: &Manifest, vocab: usize) -> Result<VerifyRuntime> {
        let rel = m
            .verify_hlo
            .get(&vocab)
            .ok_or_else(|| anyhow::anyhow!("no verify kernel for vocab {vocab}"))?;
        let exe = engine.load_hlo(&m.path(rel))?;
        Ok(VerifyRuntime {
            engine,
            exe,
            block: m.block,
            vocab,
        })
    }

    /// Greedy verification: (tau, correction, greedy tokens per row).
    pub fn verify(&self, logits: &[f32], draft: &[i32], n_draft: usize) -> Result<(usize, i32, Vec<i32>)> {
        assert_eq!(logits.len(), self.block * self.vocab);
        assert_eq!(draft.len(), self.block - 1);
        let logits_lit = xla::Literal::vec1(logits)
            .reshape(&[self.block as i64, self.vocab as i64])?;
        let draft_lit = xla::Literal::vec1(draft);
        let n_lit = xla::Literal::vec1(&[n_draft as i32]);
        let out = self
            .engine
            .run(&self.exe, &[&logits_lit, &draft_lit, &n_lit])?;
        let tau = out[0].to_vec::<i32>()?[0] as usize;
        let corr = out[1].to_vec::<i32>()?[0];
        let greedy = out[2].to_vec::<i32>()?;
        Ok((tau, corr, greedy))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> Option<(Rc<Engine>, Manifest)> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&root).unwrap();
        if !m.weights.contains_key("target_llama2t_base") {
            return None;
        }
        Some((Rc::new(Engine::cpu().unwrap()), m))
    }

    #[test]
    fn verify_kernel_roundtrip() {
        let Some((e, m)) = setup() else { return };
        let v = VerifyRuntime::new(e, &m, 512).unwrap();
        // logits that make greedy row r = 5 + r; row j predicts draft[j]
        let mut logits = vec![0f32; 9 * 512];
        for r in 0..9 {
            logits[r * 512 + 5 + r] = 10.0;
        }
        let draft = [5, 6, 99, 0, 0, 0, 0, 0];
        let (tau, corr, greedy) = v.verify(&logits, &draft, 5).unwrap();
        assert_eq!(greedy[0], 5);
        assert_eq!(tau, 2); // 5, 6 accepted; 99 != greedy[2]=7 rejected
        assert_eq!(corr, 7); // correction = greedy[tau] = greedy[2]
    }

    #[test]
    fn block_forward_and_incremental_consistency() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();
        let toks: Vec<i32> = (0..9).map(|i| 20 + i).collect();

        // one shot
        let mut kv_a = rt.new_kv().unwrap();
        let one = rt.forward_block(Some(&lora), &toks, &mut kv_a, 9).unwrap();

        // two chunks through the cache
        let mut kv_b = rt.new_kv().unwrap();
        let _ = rt.forward_block(Some(&lora), &toks[..5], &mut kv_b, 5).unwrap();
        let two = rt.forward_block(Some(&lora), &toks[5..], &mut kv_b, 4).unwrap();

        for r in 0..4 {
            let a = one.row(5 + r);
            let b = two.row(r);
            let max_err = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-3, "row {r} err {max_err}");
        }
        assert_eq!(kv_a.pos, 9);
        assert_eq!(kv_b.pos, 9);
    }

    #[test]
    fn prefill_matches_block_path() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();
        let prompt: Vec<i32> = (0..7).map(|i| 30 + 2 * i).collect();

        let mut kv_a = rt.new_kv().unwrap();
        let row_a = rt.prefill(Some(&lora), &prompt, &mut kv_a).unwrap();

        let mut kv_b = rt.new_kv().unwrap();
        let out = rt.forward_block(Some(&lora), &prompt, &mut kv_b, 7).unwrap();
        let row_b = out.row(6);

        let max_err = row_a
            .iter()
            .zip(row_b)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-3, "err {max_err}");
    }

    #[test]
    fn kv_rollback_pointer_rewind_is_safe() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();

        // clean trajectory: 4 then 3 committed tokens
        let toks: Vec<i32> = vec![40, 41, 42, 43, 44, 45, 46];
        let mut kv_clean = rt.new_kv().unwrap();
        rt.forward_block(Some(&lora), &toks[..4], &mut kv_clean, 4).unwrap();
        let clean = rt.forward_block(Some(&lora), &toks[4..], &mut kv_clean, 3).unwrap();

        // dirty: speculate 4 + 4 garbage rows, commit only 4 (rollback),
        // then feed the real continuation.
        let mut kv = rt.new_kv().unwrap();
        let spec: Vec<i32> = vec![40, 41, 42, 43, 99, 98, 97, 96];
        rt.forward_block(Some(&lora), &spec, &mut kv, 4).unwrap();
        let dirty = rt.forward_block(Some(&lora), &toks[4..], &mut kv, 3).unwrap();

        for r in 0..3 {
            let max_err = clean
                .row(r)
                .iter()
                .zip(dirty.row(r))
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-3, "row {r} err {max_err}");
        }
    }

    #[test]
    fn kv_overflow_is_rejected() {
        let Some((e, m)) = setup() else { return };
        let rt = ModelRuntime::new(e, &m, "target_llama2t_base").unwrap();
        let lora = WeightSet::zero_lora(&rt.arch).unwrap();
        let mut kv = rt.new_kv().unwrap();
        kv.pos = rt.arch.max_seq - 2;
        let toks = vec![1i32; 9];
        assert!(rt.forward_block(Some(&lora), &toks, &mut kv, 0).is_err());
    }
}
