//! Model/version registry: the cloud side's view of its evolving model
//! fleet (base weights + hot-swappable LoRA adapters) and the edge side's
//! static draft bundles.
//!
//! The registry is the piece that makes FlexSpec's decoupling concrete in
//! code: one compiled `forward_block` executable per *architecture*
//! serves every *version*, because adapters are runtime arguments. A
//! "model update" on the cloud is a LoRA upload — the edge bundle never
//! changes.

use super::engine::Engine;
use super::manifest::Manifest;
use super::model::{ModelRuntime, VerifyRuntime, WeightSet};
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// A resolved target version: full weights + the adapter to apply.
#[derive(Clone)]
pub struct TargetVersion {
    pub name: String,
    pub runtime: Rc<ModelRuntime>,
    pub lora: Rc<WeightSet>,
    /// Version sequence number s in the paper's {M_t^(s)} notation.
    pub seq: u64,
}

pub struct Registry {
    pub engine: Rc<Engine>,
    pub manifest: Rc<Manifest>,
    runtimes: RefCell<HashMap<String, Rc<ModelRuntime>>>,
    loras: RefCell<HashMap<String, Rc<WeightSet>>>,
    zero_loras: RefCell<HashMap<String, Rc<WeightSet>>>,
    verifies: RefCell<HashMap<usize, Rc<VerifyRuntime>>>,
    version_counter: RefCell<u64>,
}

impl Registry {
    pub fn open(engine: Rc<Engine>, manifest: Rc<Manifest>) -> Registry {
        Registry {
            engine,
            manifest,
            runtimes: RefCell::new(HashMap::new()),
            loras: RefCell::new(HashMap::new()),
            zero_loras: RefCell::new(HashMap::new()),
            verifies: RefCell::new(HashMap::new()),
            version_counter: RefCell::new(0),
        }
    }

    /// Open with defaults: CPU engine + manifest from the default root.
    pub fn open_default() -> Result<Registry> {
        let engine = Rc::new(Engine::cpu()?);
        let manifest = Rc::new(Manifest::load(Manifest::default_root())?);
        Ok(Self::open(engine, manifest))
    }

    /// Full model runtime (base target, full-FT target, or any draft).
    pub fn model(&self, weight_name: &str) -> Result<Rc<ModelRuntime>> {
        if let Some(rt) = self.runtimes.borrow().get(weight_name) {
            return Ok(rt.clone());
        }
        let rt = Rc::new(ModelRuntime::new(
            self.engine.clone(),
            &self.manifest,
            weight_name,
        )?);
        self.runtimes
            .borrow_mut()
            .insert(weight_name.to_string(), rt.clone());
        Ok(rt)
    }

    /// LoRA adapter bundle by name.
    pub fn lora(&self, name: &str) -> Result<Rc<WeightSet>> {
        if let Some(l) = self.loras.borrow().get(name) {
            return Ok(l.clone());
        }
        let info = self.manifest.weight(name)?;
        if info.kind != "lora" {
            bail!("'{name}' is not a LoRA bundle (kind = {})", info.kind);
        }
        let arch = self.manifest.arch(&info.arch)?;
        let ws = Rc::new(WeightSet::load(&self.manifest, arch, info, true)?);
        self.loras.borrow_mut().insert(name.to_string(), ws.clone());
        Ok(ws)
    }

    /// The all-zero adapter for an arch (selects the base behaviour).
    pub fn zero_lora(&self, arch_name: &str) -> Result<Rc<WeightSet>> {
        if let Some(l) = self.zero_loras.borrow().get(arch_name) {
            return Ok(l.clone());
        }
        let arch = self.manifest.arch(arch_name)?;
        let ws = Rc::new(WeightSet::zero_lora(arch)?);
        self.zero_loras
            .borrow_mut()
            .insert(arch_name.to_string(), ws.clone());
        Ok(ws)
    }

    /// Resolve a target *version*:
    ///   "target_<fam>_base"        -> base weights + zero adapter
    ///   "lora_<fam>_<domain>"      -> base weights + that adapter
    ///   "target_<fam>_code_full"   -> full-FT weights + zero adapter
    pub fn target_version(&self, name: &str) -> Result<TargetVersion> {
        let info = self.manifest.weight(name)?.clone();
        let seq = {
            let mut c = self.version_counter.borrow_mut();
            *c += 1;
            *c
        };
        let version = match info.kind.as_str() {
            "base" | "full" => TargetVersion {
                name: name.to_string(),
                lora: self.zero_lora(&info.arch)?,
                runtime: self.model(name)?,
                seq,
            },
            "lora" => {
                let base = info
                    .base
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("lora '{name}' missing base"))?;
                TargetVersion {
                    name: name.to_string(),
                    runtime: self.model(&base)?,
                    lora: self.lora(name)?,
                    seq,
                }
            }
            k => bail!("'{name}' (kind {k}) is not a target version"),
        };
        Ok(version)
    }

    /// The fused verification kernel for a vocabulary size.
    pub fn verify(&self, vocab: usize) -> Result<Rc<VerifyRuntime>> {
        if let Some(v) = self.verifies.borrow().get(&vocab) {
            return Ok(v.clone());
        }
        let v = Rc::new(VerifyRuntime::new(
            self.engine.clone(),
            &self.manifest,
            vocab,
        )?);
        self.verifies.borrow_mut().insert(vocab, v.clone());
        Ok(v)
    }

    /// Weight-bundle names of a given kind (e.g. every "lora" version).
    pub fn names_of_kind(&self, kind: &str) -> Vec<String> {
        self.manifest
            .weights
            .values()
            .filter(|w| w.kind == kind)
            .map(|w| w.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open() -> Option<Registry> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&root).unwrap();
        if !m.weights.contains_key("lora_llama2t_gsm8k") {
            return None;
        }
        Some(Registry::open(
            Rc::new(Engine::cpu().unwrap()),
            Rc::new(m),
        ))
    }

    #[test]
    fn version_resolution_shares_runtime() {
        let Some(reg) = open() else { return };
        let base = reg.target_version("target_llama2t_base").unwrap();
        let math = reg.target_version("lora_llama2t_gsm8k").unwrap();
        // same compiled executable + weights, different adapters
        assert!(Rc::ptr_eq(&base.runtime, &math.runtime));
        assert!(!Rc::ptr_eq(&base.lora, &math.lora));
        assert!(math.seq > base.seq);
    }

    #[test]
    fn lora_changes_model_output() {
        let Some(reg) = open() else { return };
        let base = reg.target_version("target_llama2t_base").unwrap();
        let math = reg.target_version("lora_llama2t_gsm8k").unwrap();
        let toks: Vec<i32> = (0..9).map(|i| 70 + i).collect();

        let mut kv1 = base.runtime.new_kv().unwrap();
        let a = base
            .runtime
            .forward_block(Some(&base.lora), &toks, &mut kv1, 9)
            .unwrap();
        let mut kv2 = base.runtime.new_kv().unwrap();
        let b = math
            .runtime
            .forward_block(Some(&math.lora), &toks, &mut kv2, 9)
            .unwrap();
        let diff = a
            .logits
            .iter()
            .zip(&b.logits)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(diff > 1e-3, "LoRA adapter had no effect (diff {diff})");
    }

    #[test]
    fn kind_filters() {
        let Some(reg) = open() else { return };
        let loras = reg.names_of_kind("lora");
        assert!(loras.iter().any(|n| n.contains("gsm8k")));
        assert!(reg.names_of_kind("base").len() >= 1);
    }

    #[test]
    fn rejects_lora_as_model() {
        let Some(reg) = open() else { return };
        assert!(reg.model("lora_llama2t_gsm8k").is_err());
    }
}
