//! FSWB1 weight-bundle reader — the rust half of the wire format written
//! by `python/compile/export.py` (see that file for the layout).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"FSWB1\x00\x00\x00";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// A host tensor loaded from a bundle.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    /// Raw little-endian data; length == element_count * 4.
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is not f32");
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != Dtype::I32 {
            bail!("tensor is not i32");
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Build an xla literal with this tensor's shape and data.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match self.dtype {
            Dtype::F32 => {
                let v = self.as_f32()?;
                xla::Literal::vec1(&v).reshape(&dims)?
            }
            Dtype::I32 => {
                let v = self.as_i32()?;
                xla::Literal::vec1(&v).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// An ordered (by name) weight bundle.
#[derive(Debug, Clone, Default)]
pub struct Bundle {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Bundle {
    pub fn load(path: &Path) -> Result<Bundle> {
        let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&raw).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(raw: &[u8]) -> Result<Bundle> {
        let mut r = Cursor { raw, pos: 0 };
        if r.take(8)? != MAGIC.as_slice() {
            bail!("bad magic (not an FSWB1 bundle)");
        }
        let n = r.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = r.u32()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())?;
            let dtype = match r.u32()? {
                0 => Dtype::F32,
                1 => Dtype::I32,
                d => bail!("unknown dtype tag {d}"),
            };
            let ndim = r.u32()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            let nbytes = r.u64()? as usize;
            let expect: usize = shape.iter().product::<usize>() * 4;
            if nbytes != expect {
                bail!("tensor '{name}': byte length {nbytes} != shape implies {expect}");
            }
            let data = r.take(nbytes)?.to_vec();
            tensors.insert(name, Tensor { shape, dtype, data });
        }
        if r.pos != raw.len() {
            bail!("trailing bytes after last tensor");
        }
        Ok(Bundle { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("bundle is missing tensor '{name}'"))
    }

    /// Total parameter count (for the paper's memory-footprint table).
    pub fn n_params(&self) -> usize {
        self.tensors.values().map(|t| t.element_count()).sum()
    }

    pub fn byte_size(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }
}

struct Cursor<'a> {
    raw: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.raw.len() {
            bail!("truncated bundle at byte {}", self.pos);
        }
        let s = &self.raw[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a bundle in-memory exactly like python's export.write_bundle.
    fn golden_bytes() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&2u32.to_le_bytes());
        // "a.vec" i32 [3]
        out.extend_from_slice(&5u32.to_le_bytes());
        out.extend_from_slice(b"a.vec");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&3u32.to_le_bytes());
        out.extend_from_slice(&12u64.to_le_bytes());
        for v in [1i32, 2, 3] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        // "b.mat" f32 [2,2]
        out.extend_from_slice(&5u32.to_le_bytes());
        out.extend_from_slice(b"b.mat");
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&16u64.to_le_bytes());
        for v in [1.5f32, -2.0, 0.0, 4.25] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parses_golden() {
        let b = Bundle::parse(&golden_bytes()).unwrap();
        assert_eq!(b.tensors.len(), 2);
        let a = b.get("a.vec").unwrap();
        assert_eq!(a.shape, vec![3]);
        assert_eq!(a.as_i32().unwrap(), vec![1, 2, 3]);
        let m = b.get("b.mat").unwrap();
        assert_eq!(m.shape, vec![2, 2]);
        assert_eq!(m.as_f32().unwrap(), vec![1.5, -2.0, 0.0, 4.25]);
        assert_eq!(b.n_params(), 7);
        assert_eq!(b.byte_size(), 28);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut raw = golden_bytes();
        raw[0] = b'X';
        assert!(Bundle::parse(&raw).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let raw = golden_bytes();
        assert!(Bundle::parse(&raw[..raw.len() - 1]).is_err());
        let mut extra = raw.clone();
        extra.push(0);
        assert!(Bundle::parse(&extra).is_err());
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let b = Bundle::parse(&golden_bytes()).unwrap();
        let e = b.get("nope").unwrap_err().to_string();
        assert!(e.contains("nope"));
    }

    #[test]
    fn loads_real_bundle_if_present() {
        let p = std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/weights/target_llama2t_base.bin"
        ));
        if p.exists() {
            let b = Bundle::load(p).unwrap();
            assert!(b.n_params() > 100_000);
            assert!(b.tensors.contains_key("embed"));
        }
    }
}
