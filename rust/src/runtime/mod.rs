//! Runtime layer: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! FSWB1 weight bundles) and executes them via the PJRT C API (`xla`
//! crate). This is the only module that touches XLA; everything above it
//! (coordinator, baselines, experiments) sees typed rust APIs.

pub mod engine;
pub mod kv;
pub mod manifest;
pub mod model;
pub mod registry;
pub mod sampling;
pub mod weights;

pub use engine::Engine;
pub use kv::{KvBlockPool, KvLease, PoolExhausted};
pub use manifest::{ArchInfo, DomainInfo, Manifest, WeightInfo};
pub use model::{BatchFwdItem, BlockOut, KvState, ModelRuntime, VerifyRuntime, WeightSet};
pub use registry::{Registry, TargetVersion};
