//! PJRT engine: CPU client + HLO-text loading + executable cache.
//!
//! One `Engine` per OS thread (PJRT handles are not `Send`); the
//! coordinator performs logical concurrency via the discrete-event clock
//! on a single thread, which also keeps every experiment deterministic.
//!
//! Every execution funnels through one dispatch point that counts
//! device calls (`dispatches()`), so tests can assert the batching
//! contract structurally: one stacked dispatch per planner bucket, not
//! one per row.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    compile_count: RefCell<usize>,
    dispatch_count: RefCell<u64>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: RefCell::new(HashMap::new()),
            compile_count: RefCell::new(0),
            dispatch_count: RefCell::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn compiles(&self) -> usize {
        *self.compile_count.borrow()
    }

    /// Device executions performed so far (each `run*` call is exactly
    /// one). The batched-verification contract is asserted against this
    /// counter: a bucket of B rows must cost ONE dispatch.
    pub fn dispatches(&self) -> u64 {
        *self.dispatch_count.borrow()
    }

    /// Load an HLO **text** file (see python/compile/aot.py for why text,
    /// not serialized proto), compile it, and cache by path.
    pub fn load_hlo(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {key} (run `make artifacts`?)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?,
        );
        *self.compile_count.borrow_mut() += 1;
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Unwrap one execution's single tuple output into its elements.
    /// jax-lowered modules always return a tuple root (return_tuple=True).
    fn unpack(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        *self.dispatch_count.borrow_mut() += 1;
        let mut lit = out[0][0].to_literal_sync()?;
        let elems = lit.decompose_tuple()?;
        Ok(elems)
    }

    /// Execute over host literals (cold path: uploads per call).
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.unpack(exe.execute::<&xla::Literal>(args)?)
    }

    /// Execute over device-resident buffers, donating EVERY argument
    /// (upstream `execute_b` semantics). Only correct when every input
    /// is per-call scratch; weights go through [`run_b_opts`](Self::run_b_opts).
    pub fn run_b(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.unpack(exe.execute_b::<&xla::PjRtBuffer>(args)?)
    }

    /// Execute over device-resident buffers with a per-argument
    /// donation mask — the hot path. Weight buffers are passed with
    /// `donate = false` so ONE upload (per target version) serves every
    /// row of every call; per-step activations (tokens/pos/valid/KV)
    /// are donated as usual. This is also the whole batched story: the
    /// model layer row-stacks a planner bucket into `[B, ...]` literals
    /// and makes exactly one `run_b_opts` call per bucket.
    pub fn run_b_opts(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
        donate: &[bool],
    ) -> Result<Vec<xla::Literal>> {
        self.unpack(exe.execute_b_opts::<&xla::PjRtBuffer>(args, donate)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn engine_creates_cpu_client() {
        let e = Engine::cpu().unwrap();
        assert_eq!(e.platform(), "cpu");
        assert_eq!(e.dispatches(), 0);
    }

    #[test]
    fn hlo_cache_deduplicates_compiles() {
        let p = artifacts().join("hlo/verify_v512.hlo.txt");
        if !p.exists() {
            return;
        }
        let e = Engine::cpu().unwrap();
        let a = e.load_hlo(&p).unwrap();
        let b = e.load_hlo(&p).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(e.compiles(), 1);
    }

    #[test]
    fn missing_hlo_is_a_clear_error() {
        let e = Engine::cpu().unwrap();
        let err = match e.load_hlo(Path::new("/nonexistent.hlo.txt")) {
            Ok(_) => panic!("expected error"),
            Err(err) => err.to_string(),
        };
        assert!(err.contains("nonexistent"));
    }

    #[test]
    fn dispatch_counter_tracks_each_execution_once() {
        let e = Engine::cpu().unwrap();
        // identity over its single argument, wrapped in the tuple root
        let exe = xla::PjRtLoadedExecutable::hosted(|args| {
            Ok(xla::Literal::tuple(vec![args[0].clone()]))
        });
        let lit = xla::Literal::vec1(&[3i32, 4]);
        let out = e.run(&exe, &[&lit]).unwrap();
        assert_eq!(out[0].to_vec::<i32>().unwrap(), vec![3, 4]);
        assert_eq!(e.dispatches(), 1);

        let buf = e.client().buffer_from_host_literal(None, &lit).unwrap();
        let out = e.run_b_opts(&exe, &[&buf], &[false]).unwrap();
        assert_eq!(out[0].to_vec::<i32>().unwrap(), vec![3, 4]);
        assert_eq!(e.dispatches(), 2);
        // non-donated: the buffer survives for a donate-all call
        let out = e.run_b(&exe, &[&buf]).unwrap();
        assert_eq!(out[0].to_vec::<i32>().unwrap(), vec![3, 4]);
        assert_eq!(e.dispatches(), 3);
        assert!(buf.to_literal_sync().is_err(), "run_b must donate");
    }
}
