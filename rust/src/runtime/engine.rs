//! PJRT engine: CPU client + HLO-text loading + executable cache.
//!
//! One `Engine` per OS thread (PJRT handles are not `Send`); the
//! coordinator performs logical concurrency via the discrete-event clock
//! on a single thread, which also keeps every experiment deterministic.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    compile_count: RefCell<usize>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: RefCell::new(HashMap::new()),
            compile_count: RefCell::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn compiles(&self) -> usize {
        *self.compile_count.borrow()
    }

    /// Load an HLO **text** file (see python/compile/aot.py for why text,
    /// not serialized proto), compile it, and cache by path.
    pub fn load_hlo(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {key} (run `make artifacts`?)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?,
        );
        *self.compile_count.borrow_mut() += 1;
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute and unwrap the single tuple output into its elements.
    /// jax-lowered modules always return a tuple root (return_tuple=True).
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<&xla::Literal>(args)?;
        let mut lit = out[0][0].to_literal_sync()?;
        let elems = lit.decompose_tuple()?;
        Ok(elems)
    }

    /// Same, over device-resident buffers (hot path: weights stay
    /// uploaded across calls — see WeightSet::buffers).
    pub fn run_b(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let mut lit = out[0][0].to_literal_sync()?;
        let elems = lit.decompose_tuple()?;
        Ok(elems)
    }

    /// Execute one compiled entry point over MANY argument sets through
    /// a single engine call, returning outputs in input order — the
    /// seam the batched verification executor drives (one call per
    /// planner bucket). The prebuilt PJRT shim runs the sets
    /// back-to-back on the device, amortizing the per-call host
    /// dispatch here; a true stacked `[B, ...]` executable (one XLA
    /// program over the whole bucket) replaces ONLY this function, so
    /// no caller changes when it lands.
    pub fn run_batched(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        argsets: &[Vec<&xla::PjRtBuffer>],
    ) -> Result<Vec<Vec<xla::Literal>>> {
        argsets.iter().map(|args| self.run_b(exe, args)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn engine_creates_cpu_client() {
        let e = Engine::cpu().unwrap();
        assert_eq!(e.platform(), "cpu");
    }

    #[test]
    fn hlo_cache_deduplicates_compiles() {
        let p = artifacts().join("hlo/verify_v512.hlo.txt");
        if !p.exists() {
            return;
        }
        let e = Engine::cpu().unwrap();
        let a = e.load_hlo(&p).unwrap();
        let b = e.load_hlo(&p).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(e.compiles(), 1);
    }

    #[test]
    fn missing_hlo_is_a_clear_error() {
        let e = Engine::cpu().unwrap();
        let err = match e.load_hlo(Path::new("/nonexistent.hlo.txt")) {
            Ok(_) => panic!("expected error"),
            Err(err) => err.to_string(),
        };
        assert!(err.contains("nonexistent"));
    }
}
