//! Typed view of `artifacts/manifest.json` (produced by
//! `python/compile/build.py`): the model zoo, weight inventory, grammar
//! tables and the acceptance calibration measured at build time.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ArchInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_experts: usize,
    pub lora_rank: usize,
    pub draft_head: bool,
    pub kv_shape: Vec<usize>,
    /// Ordered (sorted) parameter names/shapes — the HLO argument order.
    pub params: Vec<(String, Vec<usize>)>,
    /// Ordered LoRA adapter names/shapes (empty for drafts).
    pub lora: Vec<(String, Vec<usize>)>,
    pub hlo_block: String,
    pub hlo_prefill: String,
}

impl ArchInfo {
    pub fn kv_elements(&self) -> usize {
        self.kv_shape.iter().product()
    }

    pub fn n_params(&self) -> usize {
        self.params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

#[derive(Debug, Clone)]
pub struct WeightInfo {
    pub name: String,
    pub arch: String,
    pub kind: String, // base | lora | full | draft_flex | draft_generic | draft_synced
    pub file: String,
    pub base: Option<String>,
    pub domain: Option<String>,
    pub target: Option<String>,
}

#[derive(Debug, Clone)]
pub struct DomainInfo {
    pub name: String,
    pub offset: u64,
    pub size: u64,
    pub mult: u64,
    pub inc: u64,
    pub p_det: f64,
    pub p_eos: f64,
    pub prompt_len: (u64, u64),
    pub gen_len: (u64, u64),
    pub evolved_mult: u64,
    pub evolved_inc: u64,
    pub evolve_mod: u64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub block: usize,
    pub k_max: usize,
    pub prefill_chunk: usize,
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
    pub archs: BTreeMap<String, ArchInfo>,
    pub weights: BTreeMap<String, WeightInfo>,
    pub verify_hlo: BTreeMap<usize, String>,
    pub domains: BTreeMap<String, DomainInfo>,
    pub calibration: BTreeMap<String, f64>,
}

fn shapes(j: &Json) -> Vec<(String, Vec<usize>)> {
    j.as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|pair| {
            let p = pair.as_arr().unwrap();
            (
                p[0].as_str().unwrap().to_string(),
                p[1].as_arr()
                    .unwrap()
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect(),
            )
        })
        .collect()
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let mut archs = BTreeMap::new();
        for (name, a) in j.req("archs")?.as_obj().context("archs")? {
            archs.insert(
                name.clone(),
                ArchInfo {
                    name: name.clone(),
                    vocab: a.req("vocab")?.as_usize().unwrap(),
                    d_model: a.req("d_model")?.as_usize().unwrap(),
                    n_layers: a.req("n_layers")?.as_usize().unwrap(),
                    n_heads: a.req("n_heads")?.as_usize().unwrap(),
                    d_ff: a.req("d_ff")?.as_usize().unwrap(),
                    max_seq: a.req("max_seq")?.as_usize().unwrap(),
                    n_experts: a.req("n_experts")?.as_usize().unwrap(),
                    lora_rank: a.req("lora_rank")?.as_usize().unwrap(),
                    draft_head: a.req("draft_head")?.as_bool().unwrap_or(false),
                    kv_shape: a
                        .req("kv_shape")?
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect(),
                    params: shapes(a.req("params")?),
                    lora: shapes(a.req("lora")?),
                    hlo_block: a.req("hlo_block")?.as_str().unwrap().to_string(),
                    hlo_prefill: a.req("hlo_prefill")?.as_str().unwrap().to_string(),
                },
            );
        }

        let mut weights = BTreeMap::new();
        for (name, w) in j.req("weights")?.as_obj().context("weights")? {
            weights.insert(
                name.clone(),
                WeightInfo {
                    name: name.clone(),
                    arch: w.req("arch")?.as_str().unwrap().to_string(),
                    kind: w.req("kind")?.as_str().unwrap().to_string(),
                    file: w.req("file")?.as_str().unwrap().to_string(),
                    base: w.get("base").and_then(|v| v.as_str()).map(String::from),
                    domain: w.get("domain").and_then(|v| v.as_str()).map(String::from),
                    target: w.get("target").and_then(|v| v.as_str()).map(String::from),
                },
            );
        }

        let mut verify_hlo = BTreeMap::new();
        for (v, p) in j.req("verify_hlo")?.as_obj().context("verify_hlo")? {
            verify_hlo.insert(v.parse::<usize>()?, p.as_str().unwrap().to_string());
        }

        let mut domains = BTreeMap::new();
        for (name, d) in j.req("domains")?.as_obj().context("domains")? {
            let pair = |key: &str| -> Result<(u64, u64)> {
                let a = d.req(key)?.as_arr().context("pair")?;
                Ok((a[0].as_f64().unwrap() as u64, a[1].as_f64().unwrap() as u64))
            };
            domains.insert(
                name.clone(),
                DomainInfo {
                    name: name.clone(),
                    offset: d.req("offset")?.as_f64().unwrap() as u64,
                    size: d.req("size")?.as_f64().unwrap() as u64,
                    mult: d.req("mult")?.as_f64().unwrap() as u64,
                    inc: d.req("inc")?.as_f64().unwrap() as u64,
                    p_det: d.req("p_det")?.as_f64().unwrap(),
                    p_eos: d.req("p_eos")?.as_f64().unwrap(),
                    prompt_len: pair("prompt_len")?,
                    gen_len: pair("gen_len")?,
                    evolved_mult: d.req("evolved_mult")?.as_f64().unwrap() as u64,
                    evolved_inc: d.req("evolved_inc")?.as_f64().unwrap() as u64,
                    evolve_mod: d.req("evolve_mod")?.as_f64().unwrap() as u64,
                },
            );
        }

        let mut calibration = BTreeMap::new();
        if let Some(obj) = j.get("acceptance_calibration").and_then(|c| c.as_obj()) {
            for (k, v) in obj {
                if let Some(x) = v.as_f64() {
                    calibration.insert(k.clone(), x);
                }
            }
        }

        Ok(Manifest {
            root,
            block: j.req("block")?.as_usize().unwrap(),
            k_max: j.req("k_max")?.as_usize().unwrap(),
            prefill_chunk: j.req("prefill_chunk")?.as_usize().unwrap(),
            bos: j.req("bos")?.as_i64().unwrap() as i32,
            eos: j.req("eos")?.as_i64().unwrap() as i32,
            pad: j.req("pad")?.as_i64().unwrap() as i32,
            archs,
            weights,
            verify_hlo,
            domains,
            calibration,
        })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchInfo> {
        self.archs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown arch '{name}'"))
    }

    pub fn weight(&self, name: &str) -> Result<&WeightInfo> {
        self.weights
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown weight bundle '{name}'"))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Default artifacts directory: $FLEXSPEC_ARTIFACTS or ./artifacts.
    pub fn default_root() -> PathBuf {
        std::env::var("FLEXSPEC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&root).unwrap();
        assert_eq!(m.block, 9);
        assert_eq!(m.k_max, 8);
        let l2t = m.arch("llama2t").unwrap();
        assert_eq!(l2t.vocab, 512);
        assert_eq!(l2t.kv_shape, vec![4, 2, 4, 256, 32]);
        assert!(!l2t.params.is_empty());
        assert!(m.weights.contains_key("target_llama2t_base"));
        assert!(m.domains.contains_key("gsm8k"));
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let e = Manifest::load("/nonexistent/dir").unwrap_err().to_string();
        assert!(e.contains("make artifacts"), "{e}");
    }
}
