//! Paged KV block pool: the allocator behind the continuous-batching
//! verifier's per-slot sequence state.
//!
//! A sequence's KV cache is a list of fixed-size pages (`page_tokens`
//! committed positions per page) leased out of one shared pool, so the
//! verifier admits a draft whenever pages are free — no per-session
//! max_seq reservation, no window-edge quantization. Three operations
//! map onto the serving lifecycle:
//!
//! * **grow** — admission/extension: lease enough tail pages to cover
//!   the committed prefix + the speculated block;
//! * **rollback** — rejection: return the tail pages past the accepted
//!   length (the paged analogue of the position-pointer rewind in
//!   [`KvState`](super::model::KvState) — rejected pages are returned
//!   to the free list before anyone else can attend to them);
//! * **release** — verdict applied / session finished: return every
//!   page.
//!
//! The pool is pure bookkeeping over page indices (the actual tensor
//! storage lives with the backend); what it guarantees — and what the
//! randomized-churn tests pin — is the allocator invariant: pages are
//! never leaked and never aliased. `free + leased == capacity` at every
//! step, and no page is ever owned by two live leases.

use std::fmt;

/// Error returned when the pool cannot cover a `grow`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolExhausted {
    pub wanted_pages: usize,
    pub free_pages: usize,
}

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kv pool exhausted: need {} pages, {} free",
            self.wanted_pages, self.free_pages
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// A lease over pool pages backing one sequence. Dropping a lease
/// without [`KvBlockPool::release`] leaks its pages (caught by
/// [`KvBlockPool::audit`] / the debug assertion in tests); the verifier
/// releases on every teardown path.
#[derive(Debug, Default)]
pub struct KvLease {
    id: u64,
    pages: Vec<u32>,
    /// Token length this lease currently covers (<= pages * page_tokens).
    len_tokens: usize,
}

impl KvLease {
    pub fn pages(&self) -> &[u32] {
        &self.pages
    }

    pub fn len_tokens(&self) -> usize {
        self.len_tokens
    }

    pub fn page_count(&self) -> usize {
        self.pages.len()
    }
}

/// Fixed-capacity page allocator with a LIFO free list (hot pages are
/// reused first — the same locality argument as a slab).
#[derive(Debug)]
pub struct KvBlockPool {
    page_tokens: usize,
    /// owner[page] = lease id currently holding the page (0 = free).
    owner: Vec<u64>,
    free: Vec<u32>,
    next_lease: u64,
    /// Lifetime counters for the metrics layer.
    pub pages_leased: u64,
    pub pages_returned: u64,
    pub peak_in_use: usize,
}

impl KvBlockPool {
    /// A pool of `capacity_pages` pages, each covering `page_tokens`
    /// committed positions.
    pub fn new(capacity_pages: usize, page_tokens: usize) -> KvBlockPool {
        assert!(page_tokens > 0, "page_tokens must be positive");
        KvBlockPool {
            page_tokens,
            owner: vec![0; capacity_pages],
            // LIFO: page 0 pops first
            free: (0..capacity_pages as u32).rev().collect(),
            next_lease: 0,
            pages_leased: 0,
            pages_returned: 0,
            peak_in_use: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.owner.len()
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    /// Pages needed to cover `tokens` positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Whether a `grow` to `tokens` on `lease` would succeed right now.
    pub fn can_grow(&self, lease: &KvLease, tokens: usize) -> bool {
        self.pages_for(tokens).saturating_sub(lease.page_count()) <= self.free.len()
    }

    /// A fresh empty lease (no pages yet).
    pub fn lease(&mut self) -> KvLease {
        self.next_lease += 1;
        KvLease {
            id: self.next_lease,
            pages: Vec::new(),
            len_tokens: 0,
        }
    }

    /// Extend `lease` to cover `tokens` positions, allocating tail
    /// pages as needed. All-or-nothing: on `PoolExhausted` the lease is
    /// unchanged. Shrinking via `grow` is a no-op on pages (use
    /// [`rollback`](Self::rollback)).
    pub fn grow(&mut self, lease: &mut KvLease, tokens: usize) -> Result<(), PoolExhausted> {
        let want = self.pages_for(tokens);
        if want > lease.pages.len() {
            let need = want - lease.pages.len();
            if need > self.free.len() {
                return Err(PoolExhausted {
                    wanted_pages: need,
                    free_pages: self.free.len(),
                });
            }
            for _ in 0..need {
                let p = self.free.pop().expect("checked above");
                debug_assert_eq!(self.owner[p as usize], 0, "free page had an owner");
                self.owner[p as usize] = lease.id;
                lease.pages.push(p);
            }
            self.pages_leased += need as u64;
            self.peak_in_use = self.peak_in_use.max(self.in_use());
        }
        lease.len_tokens = lease.len_tokens.max(tokens);
        Ok(())
    }

    /// Shrink `lease` back to `tokens` positions, returning every tail
    /// page past the new length (rejected speculation → pages go back
    /// to the free list immediately).
    pub fn rollback(&mut self, lease: &mut KvLease, tokens: usize) {
        let keep = self.pages_for(tokens);
        while lease.pages.len() > keep {
            let p = lease.pages.pop().expect("len checked");
            debug_assert_eq!(self.owner[p as usize], lease.id, "rollback of foreign page");
            self.owner[p as usize] = 0;
            self.free.push(p);
            self.pages_returned += 1;
        }
        lease.len_tokens = lease.len_tokens.min(tokens);
    }

    /// Return every page of `lease` to the pool.
    pub fn release(&mut self, mut lease: KvLease) {
        self.rollback(&mut lease, 0);
    }

    /// Allocator invariant check: every page is either free or owned,
    /// exactly once. `Err` carries a human-readable violation.
    pub fn audit(&self) -> Result<(), String> {
        if self.free.len() + self.in_use() != self.capacity() {
            return Err(format!(
                "page conservation broken: {} free + {} in use != {} capacity",
                self.free.len(),
                self.in_use(),
                self.capacity()
            ));
        }
        let mut seen = vec![false; self.capacity()];
        for &p in &self.free {
            let i = p as usize;
            if i >= self.capacity() {
                return Err(format!("free list names page {i} beyond capacity"));
            }
            if seen[i] {
                return Err(format!("page {i} appears twice in the free list"));
            }
            if self.owner[i] != 0 {
                return Err(format!("page {i} is free but owned by lease {}", self.owner[i]));
            }
            seen[i] = true;
        }
        let owned = self.owner.iter().filter(|&&o| o != 0).count();
        if owned != self.in_use() {
            return Err(format!(
                "{owned} owned pages but {} accounted in use",
                self.in_use()
            ));
        }
        if self.pages_leased - self.pages_returned != self.in_use() as u64 {
            return Err(format!(
                "counter drift: {} leased - {} returned != {} in use",
                self.pages_leased,
                self.pages_returned,
                self.in_use()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn grow_rollback_release_roundtrip() {
        let mut pool = KvBlockPool::new(8, 16);
        assert_eq!(pool.pages_for(0), 0);
        assert_eq!(pool.pages_for(1), 1);
        assert_eq!(pool.pages_for(16), 1);
        assert_eq!(pool.pages_for(17), 2);

        let mut a = pool.lease();
        pool.grow(&mut a, 40).unwrap(); // 3 pages
        assert_eq!(a.page_count(), 3);
        assert_eq!(a.len_tokens(), 40);
        assert_eq!(pool.free_pages(), 5);

        // growing within the last page allocates nothing
        pool.grow(&mut a, 48).unwrap();
        assert_eq!(a.page_count(), 3);
        assert_eq!(pool.free_pages(), 5);

        // rollback returns the tail pages immediately
        pool.rollback(&mut a, 17);
        assert_eq!(a.page_count(), 2);
        assert_eq!(a.len_tokens(), 17);
        assert_eq!(pool.free_pages(), 6);

        pool.release(a);
        assert_eq!(pool.free_pages(), 8);
        pool.audit().unwrap();
    }

    #[test]
    fn exhaustion_is_all_or_nothing() {
        let mut pool = KvBlockPool::new(4, 8);
        let mut a = pool.lease();
        pool.grow(&mut a, 24).unwrap(); // 3 of 4 pages
        let mut b = pool.lease();
        let err = pool.grow(&mut b, 17).unwrap_err(); // needs 3, 1 free
        assert_eq!(err.wanted_pages, 3);
        assert_eq!(err.free_pages, 1);
        // the failed grow left b untouched and the pool consistent
        assert_eq!(b.page_count(), 0);
        assert_eq!(pool.free_pages(), 1);
        pool.audit().unwrap();
        assert!(!pool.can_grow(&b, 17));
        assert!(pool.can_grow(&b, 8));
        pool.grow(&mut b, 8).unwrap();
        assert_eq!(pool.free_pages(), 0);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.free_pages(), 4);
    }

    #[test]
    fn leases_never_alias_pages() {
        let mut pool = KvBlockPool::new(16, 4);
        let mut a = pool.lease();
        let mut b = pool.lease();
        pool.grow(&mut a, 20).unwrap();
        pool.grow(&mut b, 20).unwrap();
        for p in a.pages() {
            assert!(!b.pages().contains(p), "page {p} aliased across leases");
        }
        // a's rolled-back pages may be re-leased to b, but never shared
        pool.rollback(&mut a, 4);
        pool.grow(&mut b, 40).unwrap();
        for p in a.pages() {
            assert!(!b.pages().contains(p), "page {p} aliased after rollback");
        }
        pool.audit().unwrap();
        pool.release(a);
        pool.release(b);
    }

    #[test]
    fn randomized_churn_never_leaks_or_aliases() {
        // mirrors the verifier's eviction-sweep churn tests: thousands
        // of grow/rollback/release cycles across interleaved leases,
        // auditing conservation after every step, across the pinned
        // determinism seeds
        for seed in [3u64, 17, 42] {
            let mut rng = SplitMix64::new(seed);
            let mut pool = KvBlockPool::new(64, 8);
            let mut live: Vec<KvLease> = Vec::new();
            for step in 0..2000 {
                match rng.next_range(4) {
                    0 => {
                        let mut l = pool.lease();
                        let want = 1 + rng.next_range(64) as usize;
                        if pool.can_grow(&l, want) {
                            pool.grow(&mut l, want).unwrap();
                        }
                        live.push(l);
                    }
                    1 if !live.is_empty() => {
                        let i = rng.next_range(live.len() as u64) as usize;
                        let want = live[i].len_tokens() + 1 + rng.next_range(24) as usize;
                        if pool.can_grow(&live[i], want) {
                            pool.grow(&mut live[i], want).unwrap();
                        } else {
                            assert!(pool.grow(&mut live[i], want).is_err());
                        }
                    }
                    2 if !live.is_empty() => {
                        let i = rng.next_range(live.len() as u64) as usize;
                        let back = rng.next_range(live[i].len_tokens() as u64 + 1) as usize;
                        pool.rollback(&mut live[i], back);
                    }
                    _ if !live.is_empty() => {
                        let i = rng.next_range(live.len() as u64) as usize;
                        pool.release(live.swap_remove(i));
                    }
                    _ => {}
                }
                // conservation + alias audit after every mutation
                pool.audit().unwrap_or_else(|e| panic!("step {step}: {e}"));
                let leased: usize = live.iter().map(|l| l.page_count()).sum();
                assert_eq!(
                    leased,
                    pool.in_use(),
                    "step {step}: live leases and pool disagree"
                );
            }
            for l in live.drain(..) {
                pool.release(l);
            }
            assert_eq!(pool.free_pages(), pool.capacity(), "seed {seed} leaked pages");
            pool.audit().unwrap();
        }
    }
}
