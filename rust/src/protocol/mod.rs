//! Edge↔cloud wire protocol with byte-accurate accounting (DESIGN.md S6).
//!
//! Every latency number in the evaluation flows through `B_up(K)` of
//! eq. (8), so the protocol layer is explicit about what crosses the air:
//!
//! * `DraftMsg` (uplink): session/round framing + K draft token ids
//!   (varint) + per-token draft probability payloads. The probability
//!   payload is what lossless verification fallback needs on the cloud
//!   side; it dominates the uplink and is why large strides hurt on weak
//!   links (paper §III-D: "five tokens ≈ 200 ms of uplink delay").
//! * `VerifyMsg` (downlink): tau + correction token + flow control.
//! * `SyncMsg` accounting covers the update-storm analysis (Table I).
//!
//! `WIRE_SCALE` maps our 512-token vocabulary payloads to the paper's
//! 32k-vocab 70B deployment so absolute milliseconds stay comparable
//! (calibrated in EXPERIMENTS.md §Calibration).

//! For the *serving* subsystem (real sockets rather than byte-accounted
//! simulation) `frame` adds the length-prefixed stream codec and the
//! wire-format version handshake (`Hello`/`HelloAck`) that gates every
//! connection.

pub mod codec;
pub mod frame;

use codec::{read_u16, read_u32, read_varint, write_u16, write_u32, write_varint};
use anyhow::{bail, Result};

/// Transport + framing overhead per message (IP/UDP/QUIC-ish + app header).
pub const O_HEADER_BYTES: usize = 96;

/// Scale factor from our tiny-vocab payloads to the paper's deployment
/// (vocab 32k vs 512 → top-p payloads ~6x larger after top-k truncation).
pub const WIRE_SCALE: f64 = 6.0;

/// Verification mode — decides what the uplink must carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyMode {
    /// Greedy (Regime A): token ids only.
    Greedy,
    /// Stochastic (Regime B): ids + quantized draft distributions.
    Stochastic,
}

/// What a method's uplink actually ships (the decisive difference on
/// weak links — paper §II-B: FlexSpec "transmits lightweight token
/// indices instead of heavy activations"):
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFormat {
    /// FlexSpec / DSSD / PLD / Lookahead: varint ids + f16 chosen-prob
    /// per token. Residual distributions for Regime B are reconstructed
    /// cloud-side (documented approximation; Algorithm 2 itself is
    /// greedy).
    Compact,
    /// Tightly-coupled datacenter designs shipped unmodified to the
    /// edge: EAGLE-2 candidate trees, Medusa head products, Std-SD's
    /// lossless per-token distribution sketches.
    Sketch,
}

/// Uplink draft block (Algorithm 2 step 1 → step 2 handoff).
#[derive(Debug, Clone, PartialEq)]
pub struct DraftMsg {
    pub session: u32,
    pub round: u32,
    pub tokens: Vec<i32>,
    /// For stochastic verification: per-token draft probability of the
    /// chosen token (f16-quantized on the wire) plus a truncated top-k
    /// remainder sketch; we transmit the chosen-prob and account the
    /// sketch in bytes (contents reconstructed cloud-side from ids).
    pub chosen_probs: Vec<f32>,
    pub mode: VerifyMode,
    pub wire: WireFormat,
    /// Pipelined drafting (wire v3): committed length the edge held when
    /// this round was drafted. Meaningful only when `spec` is non-empty.
    pub basis_len: u64,
    /// Pipelined drafting (wire v3): the OPTIMISTIC tokens the edge
    /// assumed committed beyond `basis_len` when drafting this round
    /// (prior in-flight rounds' draft blocks + their predicted bonus
    /// tokens). Empty for a draft from the true committed prefix — which
    /// also keeps the encoding byte-identical to wire v2, so v2 peers
    /// and v2 captures decode unchanged. The cloud verifies this round
    /// only if its committed sequence equals exactly
    /// `committed[..basis_len] ++ spec`; otherwise the draft is stale
    /// and discarded (cancel-on-reject).
    pub spec: Vec<i32>,
    /// Tree speculation (wire v8): parent pointers giving `tokens` a
    /// tree topology instead of a single chain. `parents[i] == 0`
    /// attaches `tokens[i]` to the committed prefix; `parents[i] == j`
    /// with `j > 0` makes it a child of `tokens[j - 1]` (so
    /// `parents[i] <= i` always — nodes only reference earlier nodes).
    /// Empty means a linear chain, which also keeps the encoding
    /// byte-identical to wire v7 and below. The cloud verifies every
    /// root→leaf path as one ragged row of the same stacked batch and
    /// commits the longest accepted path ([`VerifyMsg::leaf`]).
    pub tree: Vec<u8>,
}

/// Per-token distribution sketch size on the wire (stochastic mode):
/// top-k (id: u16, prob: f16) entries the cloud needs for the residual
/// distribution. k = 256 of our 512-vocab ≈ the truncated top-p cover.
pub const PROB_SKETCH_BYTES: usize = 256 * 4;

impl DraftMsg {
    /// Serialize (the id/prob part; the sketch is accounted, not built —
    /// the cloud reconstructs residuals from its own forward pass in this
    /// reproduction, see cloud.rs).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.tokens.len() * 3);
        write_u32(&mut out, self.session);
        write_u32(&mut out, self.round);
        out.push(match self.mode {
            VerifyMode::Greedy => 0,
            VerifyMode::Stochastic => 1,
        });
        out.push(self.tokens.len() as u8);
        for &t in &self.tokens {
            write_varint(&mut out, t as u64);
        }
        if self.mode == VerifyMode::Stochastic {
            for &p in &self.chosen_probs {
                write_u16(&mut out, f32_to_f16_bits(p));
            }
        }
        // wire v3 speculative-basis tail — present only for pipelined
        // drafts, so non-speculative messages stay byte-identical to v2
        if !self.spec.is_empty() {
            write_varint(&mut out, self.basis_len);
            write_varint(&mut out, self.spec.len() as u64);
            for &t in &self.spec {
                write_varint(&mut out, t as u64);
            }
        } else if !self.tree.is_empty() {
            // wire v8 tree marker: a zero-length spec tail (which every
            // pre-v8 decoder rejects as "bad speculative basis length")
            // announces that one parent byte per token follows
            write_varint(&mut out, 0);
            write_varint(&mut out, 0);
        }
        // wire v8 tree-topology tail — absent for linear drafts, so
        // chain messages stay byte-identical to v7 and below
        if !self.tree.is_empty() {
            out.extend_from_slice(&self.tree);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<DraftMsg> {
        let mut pos = 0usize;
        let session = read_u32(buf, &mut pos)?;
        let round = read_u32(buf, &mut pos)?;
        let mode = match buf.get(pos) {
            Some(0) => VerifyMode::Greedy,
            Some(1) => VerifyMode::Stochastic,
            _ => bail!("bad mode byte"),
        };
        pos += 1;
        let n = *buf.get(pos).ok_or_else(|| anyhow::anyhow!("truncated"))? as usize;
        pos += 1;
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..n {
            tokens.push(read_varint(buf, &mut pos)? as i32);
        }
        let mut chosen_probs = Vec::new();
        if mode == VerifyMode::Stochastic {
            for _ in 0..n {
                chosen_probs.push(f16_bits_to_f32(read_u16(buf, &mut pos)?));
            }
        }
        // v2 messages end here; a v3 pipelined draft appends its
        // speculative basis (see `spec` field docs), a v8 tree draft a
        // parent-pointer tail behind a zero-length spec marker
        let mut basis_len = 0u64;
        let mut spec = Vec::new();
        let mut tree = Vec::new();
        if pos < buf.len() {
            basis_len = read_varint(buf, &mut pos)?;
            let sn = read_varint(buf, &mut pos)? as usize;
            if sn == 0 {
                // wire v8 tree marker (pre-v8 decoders reject exactly
                // here): one parent byte per token, nothing else
                if basis_len != 0 {
                    bail!("draft: tree marker with nonzero basis");
                }
                if n == 0 || buf.len() - pos != n {
                    bail!("draft: tree topology length mismatch");
                }
                tree.extend_from_slice(&buf[pos..]);
                pos = buf.len();
                for (i, &p) in tree.iter().enumerate() {
                    if p as usize > i {
                        bail!("draft: tree parent {p} ahead of node {i}");
                    }
                }
            } else {
                // spec is bounded by depth * (k_max + 1); 255 is generous
                if sn > 255 {
                    bail!("draft: bad speculative basis length {sn}");
                }
                spec.reserve(sn);
                for _ in 0..sn {
                    spec.push(read_varint(buf, &mut pos)? as i32);
                }
                if pos < buf.len() {
                    // tree tail behind a speculative basis: exactly one
                    // parent byte per token (a pre-v8 decoder rejects
                    // the residue as trailing bytes)
                    if buf.len() - pos != n || n == 0 {
                        bail!("draft: tree topology length mismatch");
                    }
                    tree.extend_from_slice(&buf[pos..]);
                    pos = buf.len();
                    for (i, &p) in tree.iter().enumerate() {
                        if p as usize > i {
                            bail!("draft: tree parent {p} ahead of node {i}");
                        }
                    }
                }
            }
        }
        if pos != buf.len() {
            bail!("trailing bytes");
        }
        // wire format is not encoded (it is a per-method deployment
        // property, not per-message state); decode defaults to Compact.
        Ok(DraftMsg {
            session,
            round,
            tokens,
            chosen_probs,
            mode,
            wire: WireFormat::Compact,
            basis_len,
            spec,
            tree,
        })
    }

    /// Whether this draft carries a tree topology (wire v8). Linear
    /// chains — the only shape pre-v8 peers understand — return false.
    pub fn is_tree(&self) -> bool {
        !self.tree.is_empty()
    }

    /// Structural validity of the tree tail: either absent (linear) or
    /// exactly one parent byte per token, each referencing the
    /// committed prefix (0) or an earlier node (`parents[i] <= i`).
    pub fn tree_valid(&self) -> bool {
        self.tree.is_empty()
            || (self.tree.len() == self.tokens.len()
                && self.tree.iter().enumerate().all(|(i, &p)| p as usize <= i))
    }

    /// Leaf node indices in ascending order (a node is a leaf when no
    /// other node names it as parent). For a linear chain this is just
    /// `[k - 1]`; for the edge's comb drafts the main-chain leaf sorts
    /// first because alternates are appended after the chain.
    pub fn tree_leaves(&self) -> Vec<u8> {
        if self.tokens.is_empty() {
            return Vec::new();
        }
        if self.tree.is_empty() {
            return vec![(self.tokens.len() - 1) as u8];
        }
        let mut has_child = vec![false; self.tokens.len()];
        for &p in &self.tree {
            if p > 0 {
                has_child[p as usize - 1] = true;
            }
        }
        (0..self.tokens.len())
            .filter(|&i| !has_child[i])
            .map(|i| i as u8)
            .collect()
    }

    /// Number of root→leaf paths the verifier must check — the ragged
    /// row count of the stacked batch (1 for a linear chain).
    pub fn n_leaves(&self) -> usize {
        if self.tree.is_empty() {
            usize::from(!self.tokens.is_empty())
        } else {
            self.tree_leaves().len()
        }
    }

    /// Root→leaf token path for leaf node index `leaf`, in draft order
    /// (first element attaches to the committed prefix). For a linear
    /// chain this is the whole token vector.
    pub fn tree_path(&self, leaf: u8) -> Vec<i32> {
        if self.tree.is_empty() {
            return self.tokens.clone();
        }
        let mut rev = Vec::new();
        let mut node = leaf as usize + 1;
        while node > 0 {
            rev.push(self.tokens[node - 1]);
            node = self.tree[node - 1] as usize;
        }
        rev.reverse();
        rev
    }

    /// Total air bytes for eq. (8): header + body, plus the per-token
    /// distribution sketch for Sketch-format methods, scaled to
    /// deployment size. The format (not the regime) decides the payload:
    /// the paper's B_up(K) = K*b uses one b for Tables III and IV alike.
    pub fn air_bytes(&self) -> usize {
        let body = self.encode().len();
        let sketch = match self.wire {
            WireFormat::Compact => 0,
            WireFormat::Sketch => self.tokens.len() * PROB_SKETCH_BYTES,
        };
        O_HEADER_BYTES + ((body + sketch) as f64 * WIRE_SCALE) as usize
    }
}

/// Marginal uplink bits added by ONE more draft token (the `b` of
/// eq. (8)/(10)) — what the policy's T_marginal uses.
pub fn bits_per_token(wire: WireFormat) -> f64 {
    let bytes = match wire {
        WireFormat::Compact => 2.0 + 2.0,
        WireFormat::Sketch => 2.0 + 2.0 + PROB_SKETCH_BYTES as f64,
    };
    bytes * WIRE_SCALE * 8.0
}

/// Downlink verification outcome (Algorithm 2 step 2 → step 3 handoff).
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyMsg {
    pub session: u32,
    pub round: u32,
    pub tau: u8,
    pub correction: i32,
    pub eos: bool,
    /// Tree speculation (wire v8): which leaf's root→leaf path `tau`
    /// counts along, as the leaf's node index into the draft's token
    /// vector. `None` for linear rounds — which also keeps the encoding
    /// byte-identical to wire v7 and below. The edge reconstructs the
    /// winning path from its own retained tree; only the index crosses
    /// the air.
    pub leaf: Option<u8>,
}

impl VerifyMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        write_u32(&mut out, self.session);
        write_u32(&mut out, self.round);
        out.push(self.tau);
        out.push(self.eos as u8);
        write_varint(&mut out, self.correction as u64);
        // wire v8 leaf tail — absent for linear rounds, so chain
        // verdicts stay byte-identical to v7 and below
        if let Some(leaf) = self.leaf {
            out.push(leaf);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<VerifyMsg> {
        let mut pos = 0usize;
        let session = read_u32(buf, &mut pos)?;
        let round = read_u32(buf, &mut pos)?;
        let tau = *buf.get(pos).ok_or_else(|| anyhow::anyhow!("truncated"))?;
        pos += 1;
        let eos = *buf.get(pos).ok_or_else(|| anyhow::anyhow!("truncated"))? == 1;
        pos += 1;
        let correction = read_varint(buf, &mut pos)? as i32;
        // v7 verdicts end here; a v8 tree verdict appends the winning
        // leaf's node index
        let leaf = if pos < buf.len() {
            let b = buf[pos];
            pos += 1;
            Some(b)
        } else {
            None
        };
        if pos != buf.len() {
            bail!("trailing bytes");
        }
        Ok(VerifyMsg {
            session,
            round,
            tau,
            correction,
            eos,
            leaf,
        })
    }

    pub fn air_bytes(&self) -> usize {
        O_HEADER_BYTES + self.encode().len()
    }
}

/// Prompt upload (session start): header + varint token ids.
pub fn prompt_air_bytes(prompt_len: usize) -> usize {
    O_HEADER_BYTES + 2 * prompt_len
}

// --- minimal f16 (IEEE 754 half) conversion for wire quantization ---

pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;
    if exp == 0xff {
        return sign | 0x7c00 | if frac != 0 { 1 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        sign | 0x7c00 // overflow -> inf
    } else if e <= 0 {
        if e < -10 {
            sign
        } else {
            let m = (frac | 0x80_0000) >> (1 - e + 13);
            sign | m as u16
        }
    } else {
        sign | ((e as u16) << 10) | (frac >> 13) as u16
    }
}

pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((h >> 10) & 0x1f) as i32;
    let frac = (h & 0x3ff) as f32;
    match exp {
        0 => sign * frac * 2f32.powi(-24), // zero / subnormal
        0x1f => {
            if h & 0x3ff == 0 {
                sign * f32::INFINITY
            } else {
                f32::NAN
            }
        }
        _ => sign * (1.0 + frac / 1024.0) * 2f32.powi(exp - 15),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn draft_msg_roundtrip_greedy() {
        let m = DraftMsg {
            session: 7,
            round: 42,
            tokens: vec![5, 300, 511, 0],
            chosen_probs: vec![],
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        };
        assert_eq!(DraftMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn draft_msg_roundtrip_stochastic_quantizes() {
        let m = DraftMsg {
            session: 1,
            round: 2,
            tokens: vec![10, 20],
            chosen_probs: vec![0.75, 0.124],
            mode: VerifyMode::Stochastic,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        };
        let back = DraftMsg::decode(&m.encode()).unwrap();
        assert_eq!(back.tokens, m.tokens);
        for (a, b) in back.chosen_probs.iter().zip(&m.chosen_probs) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn draft_msg_speculative_basis_roundtrip_and_v2_compat() {
        // a non-speculative v3 message is byte-identical to v2 ...
        let plain = DraftMsg {
            session: 3,
            round: 9,
            tokens: vec![7, 8, 9],
            chosen_probs: vec![],
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        };
        let mut v2_bytes = Vec::new();
        // hand-rolled v2 layout: session, round, mode, count, tokens
        crate::protocol::codec::write_u32(&mut v2_bytes, 3);
        crate::protocol::codec::write_u32(&mut v2_bytes, 9);
        v2_bytes.push(0);
        v2_bytes.push(3);
        for t in [7u64, 8, 9] {
            crate::protocol::codec::write_varint(&mut v2_bytes, t);
        }
        assert_eq!(plain.encode(), v2_bytes, "empty spec must stay v2-identical");
        assert_eq!(DraftMsg::decode(&v2_bytes).unwrap(), plain);

        // ... while a pipelined draft carries basis_len + spec and
        // roundtrips exactly (both verify regimes)
        for mode in [VerifyMode::Greedy, VerifyMode::Stochastic] {
            let spec_msg = DraftMsg {
                session: 3,
                round: 10,
                tokens: vec![20, 21],
                chosen_probs: if mode == VerifyMode::Stochastic {
                    vec![0.5, 0.25]
                } else {
                    vec![]
                },
                mode,
                wire: WireFormat::Compact,
                basis_len: 123,
                spec: vec![7, 8, 9, 300],
                tree: vec![],
            };
            let back = DraftMsg::decode(&spec_msg.encode()).unwrap();
            assert_eq!(back.spec, spec_msg.spec);
            assert_eq!(back.basis_len, 123);
            assert_eq!(back.round, 10);
            assert!(
                spec_msg.air_bytes() > plain.air_bytes(),
                "speculation costs air bytes"
            );
        }

        // truncated spec tail is rejected
        let spec_msg = DraftMsg {
            session: 1,
            round: 2,
            tokens: vec![5],
            chosen_probs: vec![],
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 4,
            spec: vec![6, 7],
            tree: vec![],
        };
        let bytes = spec_msg.encode();
        assert!(DraftMsg::decode(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn verify_msg_roundtrip() {
        let m = VerifyMsg {
            session: 9,
            round: 3,
            tau: 5,
            correction: 123,
            eos: true,
            leaf: None,
        };
        assert_eq!(VerifyMsg::decode(&m.encode()).unwrap(), m);
        // a v8 tree verdict appends one leaf byte and roundtrips; the
        // linear encoding is a strict prefix of it
        let t = VerifyMsg { leaf: Some(6), ..m.clone() };
        assert_eq!(VerifyMsg::decode(&t.encode()).unwrap(), t);
        assert_eq!(t.encode().len(), m.encode().len() + 1);
        assert_eq!(&t.encode()[..m.encode().len()], &m.encode()[..]);
    }

    #[test]
    fn air_bytes_grow_linearly_with_k() {
        let mk = |k: usize, wire| DraftMsg {
            session: 0,
            round: 0,
            tokens: vec![100; k],
            chosen_probs: vec![0.5; k],
            mode: VerifyMode::Stochastic,
            wire,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        };
        let c1 = mk(1, WireFormat::Compact).air_bytes();
        let c5 = mk(5, WireFormat::Compact).air_bytes();
        let s1 = mk(1, WireFormat::Sketch).air_bytes();
        let s5 = mk(5, WireFormat::Sketch).air_bytes();
        assert!(c5 > c1 && s5 > s1);
        // sketch payload dominates; compact stays packet-sized
        assert!(s5 - s1 > 4 * (PROB_SKETCH_BYTES as f64 * WIRE_SCALE * 0.9) as usize);
        assert!(c5 < 600, "compact must stay light: {c5}");
        // paper §III-D anchor: 5 sketch-format tokens over 1.5 Mbps ≈ 200 ms
        let ms = (s5 as f64 * 8.0) / 1.5e6 * 1e3;
        assert!((100.0..300.0).contains(&ms), "wifi uplink for K=5: {ms} ms");
    }

    #[test]
    fn bits_per_token_consistent_with_messages() {
        let b = bits_per_token(WireFormat::Sketch);
        let mk = |k: usize| DraftMsg {
            session: 0,
            round: 0,
            tokens: vec![100; k],
            chosen_probs: vec![0.5; k],
            mode: VerifyMode::Stochastic,
            wire: WireFormat::Sketch,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        };
        let delta_bits = (mk(6).air_bytes() - mk(5).air_bytes()) as f64 * 8.0;
        assert!((delta_bits - b).abs() / b < 0.1, "{delta_bits} vs {b}");
    }

    #[test]
    fn f16_roundtrip_property() {
        prop::check(300, |rng| {
            let x = (rng.next_f64() as f32) * 2.0 - 1.0;
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            prop::assert_close(y as f64, x as f64, 1e-3, "f16 roundtrip")
        });
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(0.0)), 0.0);
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e6)).is_infinite());
        assert!((f16_bits_to_f32(f32_to_f16_bits(1.0)) - 1.0).abs() < 1e-6);
        assert!((f16_bits_to_f32(f32_to_f16_bits(6e-5)) - 6e-5).abs() < 1e-5);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(DraftMsg::decode(&[1, 2, 3]).is_err());
        let m = DraftMsg {
            session: 0,
            round: 0,
            tokens: vec![1],
            chosen_probs: vec![],
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: vec![],
        };
        let mut buf = m.encode();
        buf.push(0xff);
        assert!(DraftMsg::decode(&buf).is_err());
    }

    /// A comb over 4 chain tokens with alternates at depths 2 and 3:
    /// nodes 0..4 are the chain, node 4 branches off after chain node 1,
    /// node 5 after chain node 2.
    fn comb_draft() -> DraftMsg {
        DraftMsg {
            session: 11,
            round: 4,
            tokens: vec![40, 41, 42, 43, 140, 141],
            chosen_probs: vec![],
            mode: VerifyMode::Greedy,
            wire: WireFormat::Compact,
            basis_len: 0,
            spec: vec![],
            tree: vec![0, 1, 2, 3, 2, 3],
        }
    }

    #[test]
    fn draft_msg_tree_roundtrip_and_paths() {
        let m = comb_draft();
        assert!(m.tree_valid() && m.is_tree());
        let back = DraftMsg::decode(&m.encode()).unwrap();
        assert_eq!(back, m);
        assert_eq!(m.tree_leaves(), vec![3, 4, 5]);
        assert_eq!(m.n_leaves(), 3);
        assert_eq!(m.tree_path(3), vec![40, 41, 42, 43]);
        assert_eq!(m.tree_path(4), vec![40, 41, 140]);
        assert_eq!(m.tree_path(5), vec![40, 41, 42, 141]);
        // linear helpers: the whole chain is the single path
        let lin = DraftMsg { tree: vec![], tokens: vec![7, 8], ..m.clone() };
        assert_eq!(lin.tree_leaves(), vec![1]);
        assert_eq!(lin.n_leaves(), 1);
        assert_eq!(lin.tree_path(1), vec![7, 8]);
    }

    #[test]
    fn draft_msg_linear_stays_v7_identical_and_tree_downgrades_cleanly() {
        // branching == 1 (empty tree) must not move a single byte
        let lin = DraftMsg { tree: vec![], ..comb_draft() };
        let mut v7_bytes = Vec::new();
        crate::protocol::codec::write_u32(&mut v7_bytes, 11);
        crate::protocol::codec::write_u32(&mut v7_bytes, 4);
        v7_bytes.push(0);
        v7_bytes.push(6);
        for t in [40u64, 41, 42, 43, 140, 141] {
            crate::protocol::codec::write_varint(&mut v7_bytes, t);
        }
        assert_eq!(lin.encode(), v7_bytes, "empty tree must stay v7-identical");

        // a tree draft decodes under v8 but its marker is exactly the
        // zero-length spec a v7 decoder rejects: simulate the old
        // decoder by checking the marker position carries sn == 0
        let tree_bytes = comb_draft().encode();
        let mut pos = v7_bytes.len();
        assert_eq!(read_varint(&tree_bytes, &mut pos).unwrap(), 0, "basis");
        assert_eq!(read_varint(&tree_bytes, &mut pos).unwrap(), 0, "sn marker");
        assert_eq!(&tree_bytes[..v7_bytes.len()], &v7_bytes[..]);

        // malformed trees are rejected: wrong length, forward parent
        let mut short = tree_bytes.clone();
        short.pop();
        assert!(DraftMsg::decode(&short).is_err());
        let mut forward = comb_draft();
        forward.tree[1] = 5; // parent ahead of node 1
        assert!(DraftMsg::decode(&forward.encode()).is_err());
        assert!(!forward.tree_valid());
    }

    #[test]
    fn draft_msg_tree_behind_spec_roundtrips() {
        // pipelined rounds stay linear in practice, but the codec keeps
        // the combination well-formed: spec tail first, then parents
        let m = DraftMsg {
            basis_len: 9,
            spec: vec![3, 4],
            ..comb_draft()
        };
        assert_eq!(DraftMsg::decode(&m.encode()).unwrap(), m);
        let bytes = m.encode();
        assert!(DraftMsg::decode(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn draft_msg_random_tree_topology_roundtrip_property() {
        prop::check(200, |rng| {
            let n = 1 + rng.next_range(8) as usize;
            let tokens: Vec<i32> = (0..n).map(|_| rng.next_range(512) as i32).collect();
            // random valid topology: each node attaches to the prefix
            // (0) or any earlier node
            let tree: Vec<u8> = (0..n)
                .map(|i| rng.next_range(i as u64 + 1) as u8)
                .collect();
            let m = DraftMsg {
                session: rng.next_range(1 << 20) as u32,
                round: rng.next_range(1 << 10) as u32,
                tokens,
                chosen_probs: vec![],
                mode: VerifyMode::Greedy,
                wire: WireFormat::Compact,
                basis_len: 0,
                spec: vec![],
                tree,
            };
            if !m.tree_valid() {
                return Err("generated topology must be valid".into());
            }
            let back = DraftMsg::decode(&m.encode())
                .map_err(|e| format!("decode failed: {e}"))?;
            if back != m {
                return Err(format!("roundtrip mismatch: {back:?} vs {m:?}"));
            }
            // every leaf path starts at a prefix-attached node and has
            // positive length bounded by the node count
            let leaves = back.tree_leaves();
            if leaves.is_empty() {
                return Err("tree must have at least one leaf".into());
            }
            for leaf in leaves {
                let path = back.tree_path(leaf);
                if path.is_empty() || path.len() > n {
                    return Err(format!("bad path for leaf {leaf}: {path:?}"));
                }
            }
            Ok(())
        });
    }
}
